"""The paper's Figs. 3-9 in one script: every collective on the sim
backend, each against its XLA-analogue reference, with alpha-beta fits
from modeled NoC stage times.

Run:  PYTHONPATH=src python examples/collectives_showcase.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.core import sim_ctx, epiphany3, abmodel
from repro.core import collectives as coll

topo = epiphany3()
n = topo.n_pes
ctx = sim_ctx(n, topo)
link = abmodel.EPIPHANY_NOC

rows = []
for nbytes in [8 << i for i in range(8)]:
    stages = {
        "put": [(float(nbytes), 1.0)],
        "get(IPI)": [(float(nbytes), 1.0), (8.0, 1.0)],
        "broadcast": coll.broadcast_stages(n, nbytes, topo),
        "fcollect": coll.fcollect_stages(n, nbytes, topo),
        "reduce": coll.allreduce_stages(n, nbytes, topo),
        "alltoall": coll.alltoall_stages(n, nbytes * n, topo),
        "barrier": coll.barrier_stages(n, topo),
    }
    rows.append((nbytes, {k: abmodel.modeled_collective_time(v, link)
                          for k, v in stages.items()}))

names = list(rows[0][1])
print(f"{'bytes':>8} " + " ".join(f"{x:>12}" for x in names))
for nbytes, r in rows:
    print(f"{nbytes:8d} " + " ".join(f"{r[k]*1e6:10.2f}us" for k in names))

# alpha-beta fit, like the paper's figure subtitles
for op in ("put", "broadcast", "reduce"):
    fit = abmodel.fit([r[0] for r in rows], [r[1][op] for r in rows])
    print(f"{op}: alpha={fit.alpha*1e6:.3f}us  "
          f"beta^-1={fit.inv_beta/1e9:.3f} GB/s")
