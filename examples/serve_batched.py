"""Serve a small model through the continuous-batching engine: paged
KV cache on the symmetric heap, one-pass prefill, per-step join/evict
with requests arriving every other engine step (DESIGN.md §15).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main([
        "--arch", "qwen2-0.5b", "--smoke", "--continuous",
        "--requests", "8", "--rate", "2", "--slots", "4",
        "--prompt-len", "16", "--tokens", "16", "--cache-len", "64",
        "--page-size", "8"])
