"""Serve a small model with batched requests: prefill + decode over the
shmem substrate, greedy sampling through vocab-sharded logits.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main([
        "--arch", "qwen2-0.5b", "--smoke", "--batch", "4",
        "--prompt-len", "16", "--tokens", "16", "--cache-len", "64"])
