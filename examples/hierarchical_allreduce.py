"""Hierarchical two-level allreduce over mesh row teams (DESIGN.md §11).

Splits a 2D mesh into row teams, runs the hierarchical allreduce
(intra-row reduce-scatter -> cross-row allreduce among the chunk owners
-> intra-row allgather), checks it against the flat algorithms, and shows
the cost model choosing flat vs hierarchical per message size — including
on a two-tier mesh whose cross axis costs 10x (the §8 pod story).

  PYTHONPATH=src python examples/hierarchical_allreduce.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core import team as team_mod
from repro.core.topology import MeshTopology, epiphany3


def main():
    topo = epiphany3()                       # the paper's 4x4 chip
    n = topo.n_pes
    ctx = sim_ctx(n, topo)

    rows = ctx.team_split_2d()               # row teams (axis=-1)
    cols = rows.complement()                 # every row's rank-j members
    print(f"mesh {topo.shape}: {rows.n_teams} row teams x {rows.size} PEs; "
          f"row 1 = {rows.teams[1].members}, peer team 1 = "
          f"{cols.teams[1].members}")

    x = jnp.asarray(np.random.RandomState(0).randn(n, 4096)
                    .astype(np.float32))
    flat = ctx.to_all(x, "sum", algorithm="ring")
    hier = ctx.to_all(x, "sum", algorithm="hier", partition=rows)
    err = float(jnp.max(jnp.abs(flat - hier)))
    assert np.allclose(np.asarray(flat), np.asarray(hier),
                       rtol=2e-4, atol=1e-5), err
    print(f"hier == flat ring within float tolerance (max |diff| {err:.2e})")

    xi = jnp.asarray((np.arange(n * 512) % 251).reshape(n, 512)
                     .astype(np.int32))
    assert np.array_equal(
        np.asarray(ctx.to_all(xi, "sum", algorithm="hier", partition=rows)),
        np.asarray(ctx.to_all(xi, "sum", algorithm="ring")))
    print("hier == flat EXACTLY for int dtypes")

    # a team-scoped reduction through the 1.3 active-set shim
    shim = ctx.to_all(x, "sum", PE_start=0, logPE_stride=2, PE_size=4)
    explicit = ctx.to_all(x, "sum",
                          team=team_mod.from_active_set(0, 2, 4, n))
    assert np.array_equal(np.asarray(shim), np.asarray(explicit))
    print("active-set (PE_start=0, logPE_stride=2, PE_size=4) == explicit "
          "team API")

    # cost-model selection: flat for tiny messages, hier beyond the
    # cross-over; on the podded mesh even against chunked flat execution
    link = abmodel.EPIPHANY_NOC
    for nbytes in (64, 4096, 1 << 20):
        algo = coll.choose_algorithm(n, float(nbytes), topo, link,
                                     partition=rows)
        t_hier = coll.allreduce_hier_schedule(
            rows, float(nbytes), topo=topo, link=link).time(topo, link)
        t_ring = coll.allreduce_schedule(n, float(nbytes), "ring")\
            .time(topo, link)
        print(f"  {nbytes:>8}B: choose_algorithm={algo:<5} "
              f"(hier {t_hier * 1e6:8.2f}us vs flat ring "
              f"{t_ring * 1e6:8.2f}us)")

    podded = MeshTopology(shape=(8, 8), torus=(False, True),
                          link_cost=(10.0, 1.0))
    prows = team_mod.split_2d(team_mod.team_world(podded.n_pes), podded, -1)
    algo, chunks = coll.choose_schedule(podded.n_pes, float(1 << 18),
                                        podded, abmodel.ICI_V5E,
                                        partition=prows)
    print(f"podded 8x8 (cross axis 10x), 256KiB: choose_schedule picks "
          f"({algo}, chunks={chunks})")
    assert algo == "hier"
    print("OK")


if __name__ == "__main__":
    main()
