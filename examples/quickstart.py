"""Quickstart: the paper's library, end to end, on one CPU.

1. SHMEM collectives on a simulated 16-PE Epiphany-style mesh (the
   paper's platform), with alpha-beta timing fits like Figs. 3-9.
2. A tiny LM trained for a few steps over the same collectives.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import sim_ctx, epiphany3, abmodel
from repro.core import collectives as coll

# --- 1. the library on the paper's 4x4 chip ------------------------------
topo = epiphany3()
ctx = sim_ctx(topo.n_pes, topo)
x = jnp.arange(topo.n_pes * 8, dtype=jnp.float32).reshape(topo.n_pes, 8)

print("== ARL OpenSHMEM for Epiphany, in JAX ==")
print("n_pes:", ctx.n_pes)
print("broadcast(root=5) ok:",
      bool((ctx.broadcast(x, 5) == x[5]).all()))
print("fcollect shape:", ctx.fcollect(x).shape)
print("sum_to_all ok:",
      bool(np.allclose(ctx.to_all(x, "sum"), np.asarray(x).sum(0))))
tok = ctx.barrier_all()
print("dissemination barrier rounds:",
      len(coll.barrier_stages(ctx.n_pes, topo)))

# modeled times on the Epiphany NoC (the paper's alpha-beta methodology)
for nbytes in (64, 1024, 8192):
    t = abmodel.modeled_collective_time(
        coll.broadcast_stages(16, nbytes, topo), abmodel.EPIPHANY_NOC)
    print(f"broadcast {nbytes:5d} B -> modeled {t * 1e6:7.2f} us "
          f"({nbytes / t / 1e9:.2f} GB/s effective)")

# --- 2. train a tiny LM over the same collectives -------------------------
from repro.launch import train as train_mod

print("\n== tiny LM trained over shmem collectives ==")
losses = train_mod.main([
    "--arch", "qwen2-0.5b", "--smoke", "--steps", "10",
    "--data", "1", "--model", "1", "--seq-len", "64", "--batch", "8"])
print("final loss:", losses[-1])
