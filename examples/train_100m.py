"""End-to-end driver: train a ~100M-param GQA model for a few hundred
steps with checkpoint/restart, on the shmem substrate.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
(CPU: ~100M params is the largest comfortable single-host size.)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.models.config import ModelConfig
import repro.configs.registry as registry
from repro.launch import train as train_mod

# ~100M params: 12L, d=768, 12H/4kv, ff 2048, 32k vocab
CFG_100M = ModelConfig(
    name="gqa-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
    remat="none", microbatches=1)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/shmemjax_100m")
    args = ap.parse_args()
    # register under a temp name so the launcher can find it
    import repro.configs as C

    mod = type(sys)("repro.configs._tmp100m")
    mod.CONFIG = CFG_100M
    mod.smoke = lambda: CFG_100M
    sys.modules["repro.configs._tmp100m"] = mod
    registry.ARCHS["gqa-100m"] = "_tmp100m"

    train_mod.main([
        "--arch", "gqa-100m", "--steps", str(args.steps),
        "--data", "1", "--model", "1", "--seq-len", "256", "--batch", "8",
        "--ckpt-dir", args.ckpt_dir, "--resume", "auto",
        "--ckpt-every", "100"])
