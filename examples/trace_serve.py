"""End-to-end observability demo: serve a handful of requests through
the continuous-batching engine with the distributed tracer and serving
metrics attached, then write a Chrome trace you can open at
ui.perfetto.dev (DESIGN.md §16).

The engine runs on a (1, 2) mesh — two forced host devices — so the
per-step attention allreduces actually run as collectives and the trace
carries per-PE stage spans and cross-PE flow links, plus an eager SIM
collective on the 4x4 Epiphany mesh for the NoC heatmap.

Run:  PYTHONPATH=src python examples/trace_serve.py
Then: load bench-reports/trace_serve.json at ui.perfetto.dev
"""
import os
import sys

sys.path.insert(0, "src")
# two host devices BEFORE jax imports: tp=2 makes the per-step
# collectives real (axis size 1 would skip them entirely)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import numpy as np  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.core import ShmemContext, SimNetOps, epiphany3  # noqa: E402
from repro.core.trace import LEVEL_FULL, Tracer  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.serve.engine import ServeEngine  # noqa: E402
from repro.serve.metrics import ServeMetrics  # noqa: E402

OUT_DIR = os.environ.get("BENCH_OUT_DIR", "bench-reports")

tracer = Tracer(level=LEVEL_FULL)
metrics = ServeMetrics()
metrics.attach(tracer)

# -- 1. serve a small request trace with tp=2 --------------------------------
eng = ServeEngine(smoke_config("qwen2-0.5b"), make_mesh(1, 2),
                  max_slots=3, page_size=8, max_seq=32, prompt_bucket=16,
                  profile=tracer, metrics=metrics)
rng = np.random.default_rng(0)
with tracer.span("serve.session"):
    for n in (5, 9, 3, 12):
        eng.submit(rng.integers(1, eng.cfg.vocab, size=n, dtype=np.int32),
                   6)
    eng.run()
print(f"[trace_serve] served {len(eng.results)} requests in "
      f"{eng.steps} engine steps")

# -- 2. one eager SIM collective on the 4x4 mesh: stage spans + heatmap ------
import jax.numpy as jnp  # noqa: E402

sim = ShmemContext(SimNetOps(16), topo=epiphany3(), profile=tracer)
with tracer.span("sim.allreduce_demo", n_pes=16):
    sim.to_all(jnp.ones((16, 2048), jnp.float32), algorithm="rd")

# -- 3. export ---------------------------------------------------------------
os.makedirs(OUT_DIR, exist_ok=True)
trace_path = os.path.join(OUT_DIR, "trace_serve.json")
metrics_path = os.path.join(OUT_DIR, "serve_metrics.json")
tracer.dump_chrome(trace_path)
metrics.dump(metrics_path)

flows = sum(1 for e in tracer._events if e.get("ph") == "s")
print(f"[trace_serve] {len(tracer._events)} events "
      f"({flows} cross-PE flow links) -> {trace_path}")
print(f"[trace_serve] ttft p50 = "
      f"{metrics.ttft_s.percentile(50) * 1e3:.1f}ms, per-token p50 = "
      f"{metrics.per_token_s.percentile(50) * 1e3:.2f}ms -> {metrics_path}")
print("[trace_serve] open the trace at https://ui.perfetto.dev")
