"""Synthetic sharded token pipeline with host-side prefetch.

Deterministic per (seed, step, shard): any data shard can be regenerated
after a restart or an elastic re-shard without coordination — the data
pipeline never becomes the fault-tolerance bottleneck.  A background
thread keeps a bounded prefetch queue ahead of the training loop.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Zipf-ish token stream with next-token targets."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frames_dim: int | None = None,
                 frontend_tokens: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frames_dim = frames_dim
        self.frontend_tokens = frontend_tokens

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        # zipf-flavored ids, clipped into vocab
        raw = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        toks = (raw % (self.vocab - 2)) + 1
        out = {}
        if self.frames_dim is not None:
            out["frames"] = rng.standard_normal(
                (self.global_batch, self.seq_len, self.frames_dim),
                dtype=np.float32).astype(np.float32)
            out["targets"] = toks[:, :self.seq_len].astype(np.int32)
            return out
        out["tokens"] = toks[:, :self.seq_len].astype(np.int32)
        out["targets"] = toks[:, 1:].astype(np.int32)
        if self.frontend_tokens:
            out["frontend_embeds"] = rng.standard_normal(
                (self.global_batch, self.frontend_tokens, self.frames_dim
                 or 0) if self.frames_dim else
                (self.global_batch, self.frontend_tokens, 1),
                dtype=np.float32)
        return out

    def iterate(self, start_step: int = 0, prefetch: int = 2
                ) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put(self.batch(s))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_pipeline(cfg, shape: str, seed: int = 0) -> SyntheticLM:
    from ..models.config import SHAPES
    s = SHAPES[shape]
    return SyntheticLM(
        vocab=cfg.vocab, seq_len=s["seq_len"],
        global_batch=s["global_batch"], seed=seed,
        frames_dim=cfg.d_model if cfg.frontend == "audio" else None,
        frontend_tokens=(cfg.n_frontend_tokens
                         if cfg.frontend == "vision" else 0))
