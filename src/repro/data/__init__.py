"""data subsystem."""
