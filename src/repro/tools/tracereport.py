"""Text summary of a Chrome trace produced by `Tracer.dump_chrome`
(DESIGN.md §16) — the "where did the time go" view without opening
ui.perfetto.dev: top spans by total wall time, quiet/fence stall
fractions, the hottest NoC links as an ASCII heatmap, and (with
``--metrics``) the serving latency percentiles.

``--check`` validates both documents against the expected schema
(hand-rolled structural checks, no external jsonschema dependency) and
exits non-zero on violations — the CI artifact gate.  ``--diff OTHER``
prints the per-span/per-stage wall deltas and hottest-link shifts
against a second trace (``repro.tools.perfdiff``); a trace whose
``repro`` section embeds a ``roofline`` summary (benchmarks/roofline.py)
gets an MFU/bottleneck section.

  PYTHONPATH=src python -m repro.tools.tracereport trace.json \\
      --metrics metrics.json --check
  PYTHONPATH=src python -m repro.tools.tracereport new.json --diff old.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


# ---------------------------------------------------------------------------
# schema validation (the CI --check gate)
# ---------------------------------------------------------------------------

_EVENT_PHASES = {"X", "B", "E", "i", "I", "s", "t", "f", "b", "n", "e",
                 "M", "C"}


def validate_trace(doc: dict) -> list[str]:
    """Structural check of a Chrome trace-event JSON-object document.
    Returns a list of violations (empty == valid)."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing/invalid traceEvents array"]
    if not evs:
        errs.append("traceEvents is empty")
    open_async: dict[tuple, int] = {}
    flows: dict[object, list[str]] = {}
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _EVENT_PHASES:
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        if "name" not in ev:
            errs.append(f"event {i}: missing name")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                errs.append(f"event {i}: missing/invalid ts")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"event {i}: X event without dur")
        if ph in ("b", "n", "e"):
            key = (ev.get("cat"), ev.get("id"), ev.get("name"))
            if ph == "b":
                open_async[key] = open_async.get(key, 0) + 1
            elif ph == "e":
                if open_async.get(key, 0) <= 0:
                    errs.append(f"event {i}: async end without begin {key}")
                else:
                    open_async[key] -= 1
        if ph in ("s", "f"):
            flows.setdefault(ev.get("id"), []).append(ph)
    for key, n in open_async.items():
        if n:
            errs.append(f"unclosed async span {key}")
    for fid, phs in flows.items():
        if "s" in phs and "f" not in phs:
            errs.append(f"flow {fid}: start without finish")
        if "f" in phs and "s" not in phs:
            errs.append(f"flow {fid}: finish without start")
    rep = doc.get("repro")
    if rep is not None:
        if not isinstance(rep, dict) or rep.get("schema") != 1:
            errs.append("repro section present but schema != 1")
        elif not isinstance(rep.get("counters", {}), dict):
            errs.append("repro.counters is not an object")
    return errs


def validate_metrics(doc: dict) -> list[str]:
    """Structural check of a MetricsRegistry JSON document."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema") != 1:
        errs.append("schema != 1")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return errs + ["missing/invalid metrics object"]
    for name, m in metrics.items():
        t = m.get("type") if isinstance(m, dict) else None
        if t not in ("counter", "gauge", "histogram"):
            errs.append(f"{name}: bad type {t!r}")
            continue
        if t in ("counter", "gauge") and \
                not isinstance(m.get("value"), (int, float, type(None))):
            errs.append(f"{name}: missing value")
        if t == "histogram":
            if not isinstance(m.get("count"), int):
                errs.append(f"{name}: histogram without count")
            b = m.get("buckets")
            if not (isinstance(b, list)
                    and all(isinstance(x, int) for x in b)):
                errs.append(f"{name}: invalid buckets")
            elif isinstance(m.get("count"), int) and sum(b) != m["count"]:
                errs.append(f"{name}: bucket sum {sum(b)} != count "
                            f"{m['count']}")
    return errs


# ---------------------------------------------------------------------------
# report rendering
# ---------------------------------------------------------------------------

def _top_spans(evs: list[dict], top: int) -> list[tuple[str, float, int]]:
    agg: dict[str, list[float]] = {}
    for ev in evs:
        if ev.get("ph") == "X" and ev.get("pid") == 1:
            agg.setdefault(ev["name"], [0.0, 0])
            agg[ev["name"]][0] += float(ev.get("dur", 0.0))
            agg[ev["name"]][1] += 1
    rows = [(n, tot, int(cnt)) for n, (tot, cnt) in agg.items()]
    rows.sort(key=lambda r: -r[1])
    return rows[:top]


def _stall_report(evs: list[dict]) -> list[str]:
    lines = []
    for ev in evs:
        if ev.get("cat") != "sync" or ev.get("ph") != "X":
            continue
        a = ev.get("args", {})
        issue, stall = a.get("issue_us", 0.0), a.get("stall_us", 0.0)
        tot = issue + stall
        frac = stall / tot if tot > 0 else 0.0
        lines.append(f"  {ev['name']:<10s} issue {issue:10.1f}us  "
                     f"stall {stall:10.1f}us  ({frac:5.1%} stalled)")
    return lines


def _chaos_report(evs: list[dict], rep: dict) -> list[str]:
    """Fault-layer roll-up: the ``fault.*`` counters plus grouped
    ``instant()`` fault events, so a chaos run's injected failures,
    retries, reroutes and recoveries read off one section."""
    lines = []
    counters = rep.get("counters", {}) if isinstance(rep, dict) else {}
    fc = {k: v for k, v in sorted(counters.items())
          if k.startswith("fault.")}
    for k, v in fc.items():
        n = v.get("count", v) if isinstance(v, dict) else v
        lines.append(f"  {k:<28s} {n:>10}")
    insts: dict[str, int] = {}
    for ev in evs:
        if ev.get("ph") in ("i", "I") and \
                str(ev.get("name", "")).startswith("fault."):
            insts[ev["name"]] = insts.get(ev["name"], 0) + 1
    if insts:
        lines.append("  instant events:")
        for name, cnt in sorted(insts.items()):
            lines.append(f"    {name:<26s} x{cnt}")
    return lines


def _ascii_heatmap(hm: dict, width: int = 2) -> list[str]:
    """Per-PE heat (sum of incident link bytes) as a character grid."""
    shape = hm.get("shape", [])
    if len(shape) != 2:
        return []
    rows, cols = shape
    heat = [[0.0] * cols for _ in range(rows)]
    for lk in hm.get("links", []):
        for coord in (lk["coord_a"], lk["coord_b"]):
            r, c = coord
            heat[r][c] += lk["bytes"] / 2.0
    peak = max((h for row in heat for h in row), default=0.0)
    ramp = " .:-=+*#%@"
    out = []
    for row in heat:
        line = ""
        for h in row:
            i = int(h / peak * (len(ramp) - 1)) if peak > 0 else 0
            line += ramp[i] * width
        out.append("  |" + line + "|")
    return out


def report(trace_path: pathlib.Path, metrics_path: pathlib.Path | None,
           top: int) -> None:
    doc = json.loads(trace_path.read_text())
    evs = [e for e in doc.get("traceEvents", []) if isinstance(e, dict)]
    rep = doc.get("repro", {})
    print(f"== tracereport: {trace_path} ==")
    print(f"{len(evs)} events, level {rep.get('level', '?')}, "
          f"{rep.get('events_dropped', 0)} dropped, "
          f"{rep.get('sink_errors', 0)} sink errors")

    rows = _top_spans(evs, top)
    if rows:
        print(f"\ntop {len(rows)} runtime spans by total time:")
        for name, tot, cnt in rows:
            print(f"  {name:<28s} {tot:12.1f}us  x{cnt}")

    stalls = _stall_report(evs)
    if stalls:
        print("\nquiet/fence stall attribution:")
        print("\n".join(stalls))

    chaos = _chaos_report(evs, rep)
    if chaos:
        print("\nchaos summary (fault layer, DESIGN.md §17):")
        print("\n".join(chaos))

    rl = rep.get("roofline")
    if isinstance(rl, dict) and rl.get("cells"):
        pk = rl.get("peaks", {})
        print(f"\nroofline summary (machine {rl.get('machine', '?')}, "
              f"peak {pk.get('flops', 0) / 1e9:.1f} GFLOP/s, "
              f"{pk.get('mem_Bps', 0) / 1e9:.1f} GB/s mem, "
              f"NoC {pk.get('link_GBs', 0):.2f} GB/s):")
        print(f"  {'cell':<26s} {'wall':>10s} {'compute':>10s} "
              f"{'memory':>10s} {'noc':>10s} {'bottleneck':>10s} "
              f"{'MFU':>7s}")
        for c in rl["cells"]:
            print(f"  {c['cell']:<26s} {c['wall_us']:>8.1f}us "
                  f"{c['compute_us']:>8.1f}us {c['memory_us']:>8.1f}us "
                  f"{c['noc_us']:>8.1f}us {c['bottleneck']:>10s} "
                  f"{min(c.get('mfu', 0.0), 9.999):>7.3f}")

    for hm in rep.get("heatmap", []):
        shape = "x".join(map(str, hm["shape"]))
        print(f"\nNoC heatmap ({shape} mesh, {hm['n_links']} links, "
              f"{hm['total_bytes'] / 1e6:.2f}MB total):")
        for lk in hm["links"][:top]:
            print(f"  link {lk['a']:>3d}<->{lk['b']:<3d} "
                  f"{lk['bytes'] / 1e3:10.1f}kB  "
                  f"{lk['coord_a']}-{lk['coord_b']}")
        grid = _ascii_heatmap(hm)
        if grid:
            print("  per-PE heat:")
            print("\n".join(grid))

    if metrics_path is not None:
        mdoc = json.loads(metrics_path.read_text())
        print(f"\n== metrics: {metrics_path} ==")
        print(f"{'metric':<28s} {'count':>8s} {'p50':>12s} {'p90':>12s} "
              f"{'p99':>12s}")
        for name, m in sorted(mdoc.get("metrics", {}).items()):
            if m.get("type") == "histogram" and m.get("count"):
                print(f"{name:<28s} {m['count']:>8d} "
                      + " ".join(f"{(m.get(p) or 0) * 1e3:>10.3f}ms"
                                 for p in ("p50", "p90", "p99")))
            elif m.get("type") == "counter" and m.get("value"):
                print(f"{name:<28s} {m['value']:>8.0f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace JSON from --trace-out")
    ap.add_argument("--metrics", default="",
                    help="metrics registry JSON from --metrics-out")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per section")
    ap.add_argument("--check", action="store_true",
                    help="validate document schemas and exit non-zero on "
                         "violations (the CI artifact gate)")
    ap.add_argument("--diff", default="",
                    help="second trace to diff against: per-span/"
                         "per-stage wall deltas + hottest-link shifts "
                         "(repro.tools.perfdiff)")
    args = ap.parse_args(argv)
    tpath = pathlib.Path(args.trace)
    mpath = pathlib.Path(args.metrics) if args.metrics else None

    if args.diff:
        from . import perfdiff
        rep = perfdiff.diff_traces(
            json.loads(pathlib.Path(args.diff).read_text()),
            json.loads(tpath.read_text()), top=args.top,
            baseline=args.diff, current=str(tpath))
        print(perfdiff.render(rep))
        return

    if args.check:
        errs = validate_trace(json.loads(tpath.read_text()))
        if mpath is not None:
            errs += [f"metrics: {e}" for e in
                     validate_metrics(json.loads(mpath.read_text()))]
        if errs:
            for e in errs:
                print(f"SCHEMA VIOLATION: {e}", file=sys.stderr)
            sys.exit(1)
        print(f"schema check OK: {tpath}"
              + (f" + {mpath}" if mpath else ""))

    report(tpath, mpath, args.top)


if __name__ == "__main__":
    main()
