"""Regression attribution: explain WHY a benchmark row moved, in
cost-model terms (DESIGN.md §18).

``check_regression.py`` can say a pinned grid point regressed >25%;
this tool says which term of the alpha-beta/congestion model moved it.
Given two ``BENCH_*.json`` documents (benchmarks/run.py ``--json``) it
decomposes every over-threshold row delta into:

  * **pick** — the recorded ``picked`` field changed (a different
    algorithm/chunk-count/embedding was selected);
  * **alpha / beta** — refit ``T = alpha + beta*L`` per size-swept row
    family in each document (the same :func:`repro.core.abmodel.fit`
    the calibration sweep uses) and split the delta into the latency
    and bandwidth contributions at the row's payload size;
  * **contention** — the measured congestion factor (the
    ``contention_gamma`` row) shifted between runs;
  * **unexplained** — none of the model terms covers the delta (a new
    code path, machine noise, a changed fingerprint...).

Given two *trace* documents (``Tracer.dump_chrome``) it diffs per-span
and per-stage wall totals and the hottest NoC links instead
(``tracereport --diff`` delegates here).  ``check_regression.py`` runs
the bench-document flavor automatically on a gate failure and ships the
report as a CI artifact.

  PYTHONPATH=src python -m repro.tools.perfdiff BENCH_9.json \\
      bench-reports/BENCH_smoke.json --json perfdiff_report.json
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import re
import sys

_SIZE_RE = re.compile(r"_(\d+)B")
_GAMMA_RE = re.compile(r"gamma=([\d.eE+-]+)")


def load(path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def doc_kind(doc: dict) -> str:
    if "traceEvents" in doc:
        return "trace"
    if "rows" in doc:
        return "bench"
    raise ValueError("document is neither a BENCH_*.json (rows) nor a "
                     "Chrome trace (traceEvents)")


# ---------------------------------------------------------------------------
# bench-document diff
# ---------------------------------------------------------------------------

def _rows_by_key(doc: dict) -> dict[tuple[str, str], dict]:
    return {(r["bench"], r["name"]): r for r in doc.get("rows", [])}


def _family(name: str) -> str | None:
    """Row-family key: the name with its size suffix made a placeholder
    (``allreduce_rd_65536B`` -> ``allreduce_rd_{S}B``) — rows differing
    only in payload size fit one alpha-beta line."""
    if not _SIZE_RE.search(name):
        return None
    return _SIZE_RE.sub("_{S}B", name)


def _family_fits(doc: dict) -> dict[tuple[str, str], object]:
    """(bench, family) -> ABFit over that family's (size, measured)
    points; families without two distinct sizes are skipped (the fit
    would be singular)."""
    from repro.core import abmodel
    groups: dict[tuple[str, str], list[tuple[int, float]]] = {}
    for r in doc.get("rows", []):
        fam = _family(r["name"])
        size = r.get("size_bytes")
        us = r.get("measured_us")
        if fam is None or size is None or us is None:
            continue
        if not math.isfinite(float(us)) or float(us) <= 0.0:
            continue
        groups.setdefault((r["bench"], fam), []).append(
            (int(size), float(us) * 1e-6))
    fits = {}
    for key, pts in groups.items():
        if len({s for s, _ in pts}) < 2:
            continue
        try:
            fits[key] = abmodel.fit([s for s, _ in pts],
                                    [t for _, t in pts])
        except Exception:
            pass
    return fits


def _gamma(doc: dict) -> float | None:
    """The measured congestion factor from the ``contention_gamma``
    row's derived string (measured_us is 0 there by design)."""
    for r in doc.get("rows", []):
        if r["name"] == "contention_gamma":
            m = _GAMMA_RE.search(str(r.get("derived", "")))
            if m:
                return float(m.group(1))
    return None


def diff_bench(base_doc: dict, cur_doc: dict, *, threshold: float = 1.25,
               min_us: float = 20.0,
               baseline: str = "baseline", current: str = "current") -> dict:
    """Attribution report for every shared row whose measured time
    regressed beyond ``threshold`` (base >= ``min_us``)."""
    base = _rows_by_key(base_doc)
    cur = _rows_by_key(cur_doc)
    fits_b = _family_fits(base_doc)
    fits_c = _family_fits(cur_doc)
    g_b, g_c = _gamma(base_doc), _gamma(cur_doc)
    gamma_moved = (g_b is not None and g_c is not None
                   and abs(g_c - g_b) > 0.05)

    m_b = base_doc.get("machine")
    m_c = cur_doc.get("machine")
    regressions = []
    compared = 0
    for key in sorted(set(base) & set(cur)):
        rb, rc = base[key], cur[key]
        b_us, c_us = float(rb["measured_us"]), float(rc["measured_us"])
        if not (math.isfinite(b_us) and math.isfinite(c_us)) \
                or b_us < min_us:
            continue
        compared += 1
        ratio = c_us / b_us
        if ratio <= threshold:
            continue
        entry = {"bench": key[0], "name": key[1], "base_us": b_us,
                 "cur_us": c_us, "ratio": ratio,
                 "delta_us": c_us - b_us, "terms": {}}
        # term 1: a changed algorithm/chunks/embedding pick
        pick_b, pick_c = rb.get("picked"), rc.get("picked")
        if pick_b != pick_c and (pick_b or pick_c):
            entry["terms"]["pick"] = {"base": pick_b, "cur": pick_c}
        # term 2: alpha/beta shift of the row's size family
        fam = _family(key[1])
        fkey = (key[0], fam) if fam else None
        size = rc.get("size_bytes") or rb.get("size_bytes")
        if fkey and fkey in fits_b and fkey in fits_c and size:
            fb, fc = fits_b[fkey], fits_c[fkey]
            entry["family"] = fam
            entry["terms"]["alpha_us"] = (fc.alpha - fb.alpha) * 1e6
            entry["terms"]["beta_us"] = \
                (fc.beta - fb.beta) * float(size) * 1e6
        # term 3: the measured congestion factor moved
        if gamma_moved:
            entry["terms"]["gamma"] = {"base": g_b, "cur": g_c}
        entry["attribution"], entry["detail"] = _classify(entry)
        regressions.append(entry)
    regressions.sort(key=lambda e: -e["ratio"])
    return {
        "kind": "bench",
        "baseline": baseline,
        "current": current,
        "threshold": threshold,
        "machine_base": m_b,
        "machine_cur": m_c,
        "machine_match": (None if m_b is None or m_c is None
                          else m_b == m_c),
        "gamma_base": g_b,
        "gamma_cur": g_c,
        "n_rows_compared": compared,
        "regressions": regressions,
    }


def _classify(entry: dict) -> tuple[str, str]:
    """Dominant-term classification of one regressed row."""
    t = entry["terms"]
    delta = entry["delta_us"]
    if "pick" in t:
        p = t["pick"]
        return "pick", (f"selection changed {p['base']!r} -> "
                        f"{p['cur']!r}: a different algorithm/chunks/"
                        f"embedding executed, not a slower link")
    a = t.get("alpha_us")
    b = t.get("beta_us")
    if a is not None and b is not None:
        dom, dom_us = ("alpha", a) if abs(a) >= abs(b) else ("beta", b)
        if abs(dom_us) >= 0.5 * abs(delta) and dom_us * delta > 0:
            if dom == "alpha":
                return "alpha", (f"family latency intercept moved "
                                 f"{a:+.1f}us (beta term {b:+.1f}us): "
                                 f"per-op overhead, not bandwidth")
            return "beta", (f"family bandwidth term moved {b:+.1f}us at "
                            f"this size (alpha term {a:+.1f}us): "
                            f"per-byte cost, not per-op overhead")
    if "gamma" in t:
        g = t["gamma"]
        return "contention", (f"measured congestion factor moved "
                              f"{g['base']:.2f} -> {g['cur']:.2f}: "
                              f"link-sharing serialization changed")
    return "unexplained", ("no model term covers the delta — suspect "
                           "machine noise, a changed fingerprint, or a "
                           "new code path")


# ---------------------------------------------------------------------------
# trace diff
# ---------------------------------------------------------------------------

def _span_totals(doc: dict, *, cat: str | None = None,
                 pid: int | None = None) -> dict[str, float]:
    agg: dict[str, float] = {}
    for ev in doc.get("traceEvents", []):
        if not isinstance(ev, dict) or ev.get("ph") != "X":
            continue
        if pid is not None and ev.get("pid") != pid:
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        agg[ev["name"]] = agg.get(ev["name"], 0.0) \
            + float(ev.get("dur", 0.0))
    return agg


def _hot_links(doc: dict, top: int) -> list[dict]:
    hms = doc.get("repro", {}).get("heatmap", [])
    if not hms:
        return []
    return [{"a": lk["a"], "b": lk["b"], "bytes": lk["bytes"]}
            for lk in hms[0].get("links", [])[:top]]


def diff_traces(base_doc: dict, cur_doc: dict, *, top: int = 10,
                baseline: str = "baseline",
                current: str = "current") -> dict:
    """Per-span / per-stage wall deltas and hottest-link shifts between
    two tracer timelines."""
    def deltas(b: dict[str, float], c: dict[str, float]) -> list[dict]:
        out = [{"name": n, "base_us": b.get(n, 0.0),
                "cur_us": c.get(n, 0.0),
                "delta_us": c.get(n, 0.0) - b.get(n, 0.0)}
               for n in sorted(set(b) | set(c))]
        out.sort(key=lambda d: -abs(d["delta_us"]))
        return out[:top]

    spans = deltas(_span_totals(base_doc, pid=1),
                   _span_totals(cur_doc, pid=1))
    stages = deltas(_span_totals(base_doc, cat="stage"),
                    _span_totals(cur_doc, cat="stage"))
    hl_b = {(lk["a"], lk["b"]): lk["bytes"]
            for lk in _hot_links(base_doc, top)}
    hl_c = {(lk["a"], lk["b"]): lk["bytes"]
            for lk in _hot_links(cur_doc, top)}
    moves = [{"link": f"{a}<->{b}", "base_bytes": hl_b.get((a, b), 0.0),
              "cur_bytes": hl_c.get((a, b), 0.0)}
             for a, b in sorted(set(hl_b) | set(hl_c))]
    moves.sort(key=lambda m: -abs(m["cur_bytes"] - m["base_bytes"]))
    return {"kind": "trace", "baseline": baseline, "current": current,
            "spans": spans, "stages": stages, "hot_links": moves[:top]}


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def render(rep: dict) -> str:
    lines = [f"== perfdiff: {rep['current']} vs {rep['baseline']} =="]
    if rep["kind"] == "bench":
        if rep.get("machine_match") is False:
            lines.append("NOTE: documents come from DIFFERENT machines "
                         "— wall-time deltas partly reflect hardware")
        if rep.get("gamma_base") is not None \
                and rep.get("gamma_cur") is not None:
            lines.append(f"congestion gamma: {rep['gamma_base']:.2f} -> "
                         f"{rep['gamma_cur']:.2f}")
        regs = rep["regressions"]
        lines.append(f"{rep['n_rows_compared']} rows compared, "
                     f"{len(regs)} regressed beyond "
                     f"x{rep['threshold']:.2f}")
        for e in regs:
            lines.append(f"\n{e['bench']}/{e['name']}: "
                         f"{e['base_us']:.1f}us -> {e['cur_us']:.1f}us "
                         f"(x{e['ratio']:.2f})")
            lines.append(f"  attribution: {e['attribution'].upper()} — "
                         f"{e['detail']}")
            t = e["terms"]
            if "alpha_us" in t:
                lines.append(f"  family fit {e.get('family')}: "
                             f"alpha {t['alpha_us']:+.2f}us  "
                             f"beta*L {t['beta_us']:+.2f}us "
                             f"of {e['delta_us']:+.2f}us")
    else:
        for title, key, unit in (("runtime spans", "spans", "us"),
                                 ("stage spans", "stages", "us")):
            rows = rep.get(key, [])
            if not rows:
                continue
            lines.append(f"\ntop {title} by |delta|:")
            for d in rows:
                lines.append(f"  {d['name']:<28s} "
                             f"{d['base_us']:>10.1f}{unit} -> "
                             f"{d['cur_us']:>10.1f}{unit}  "
                             f"({d['delta_us']:+.1f}{unit})")
        if rep.get("hot_links"):
            lines.append("\nhottest-link shifts:")
            for m in rep["hot_links"]:
                lines.append(f"  {m['link']:<8s} "
                             f"{m['base_bytes']/1e3:>10.1f}kB -> "
                             f"{m['cur_bytes']/1e3:>10.1f}kB")
    return "\n".join(lines)


def diff(base_path, cur_path, *, threshold: float = 1.25,
         min_us: float = 20.0, top: int = 10) -> dict:
    """Auto-detecting entry point: bench-vs-bench or trace-vs-trace."""
    base_doc, cur_doc = load(base_path), load(cur_path)
    kb, kc = doc_kind(base_doc), doc_kind(cur_doc)
    if kb != kc:
        raise ValueError(f"cannot diff a {kb} document against a {kc} "
                         f"document")
    if kb == "bench":
        return diff_bench(base_doc, cur_doc, threshold=threshold,
                          min_us=min_us, baseline=str(base_path),
                          current=str(cur_path))
    return diff_traces(base_doc, cur_doc, top=top,
                       baseline=str(base_path), current=str(cur_path))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("baseline", help="BENCH_*.json or Chrome trace")
    ap.add_argument("current", help="same kind as baseline")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="report rows regressed beyond this ratio")
    ap.add_argument("--min-us", type=float, default=20.0,
                    help="skip rows whose baseline is below this")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per trace-diff section")
    ap.add_argument("--json", default="",
                    help="also write the report as JSON (CI artifact)")
    args = ap.parse_args(argv)
    rep = diff(args.baseline, args.current, threshold=args.threshold,
               min_us=args.min_us, top=args.top)
    print(render(rep))
    if args.json:
        out = pathlib.Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rep, indent=1))
        print(f"[perfdiff] wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
