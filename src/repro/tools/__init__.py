"""Operator-facing command-line tools (DESIGN.md §16).

  python -m repro.tools.tracereport TRACE.json [--metrics M.json]
"""
