"""repro: ShmemJAX — ARL OpenSHMEM for Epiphany, rebuilt for TPU pods in JAX."""
__version__ = "1.0.0"
