"""repro: ShmemJAX — ARL OpenSHMEM for Epiphany, rebuilt for TPU pods in JAX."""
from . import _compat

_compat.install()

__version__ = "1.0.0"
