"""Serving metrics: counters / gauges / histograms plus the engine's
request-lifecycle recorder (DESIGN.md §16).

`MetricsRegistry` is a minimal in-process metrics surface — enough to
answer "where did this request's latency go" without any external
collector:

  * `Counter`   — monotonic event counts (requests, tokens, steps).
  * `Gauge`     — last-observed values (queue depth, KV occupancy).
  * `Histogram` — log-spaced buckets over a fixed range plus a bounded
    raw-sample reservoir, so both bucket counts (cheap, exact export)
    and true percentiles (from the reservoir) are available.  TTFT and
    per-token latency are the headline users.

`ServeMetrics` binds a registry to the `ServeEngine` lifecycle:
enqueue -> admit (+prefill/first token) -> per-step decode -> evict,
with admission backpressure waits and PagePool occupancy/fragmentation
sampled every engine step.  `attach(profile)` lets `to_json()` fold in
the profiler's wire-byte counters and the tracer's NoC heatmap, so one
metrics document carries the full serving + network picture.

Everything here is pure host-side Python; nothing touches JAX, so the
registry costs nothing on the device path and is safe from any thread.
"""
from __future__ import annotations

import json
import math
import pathlib
import threading
import time


class Counter:
    """Monotonic float counter."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_json(self) -> dict:
        return {"type": "counter", "value": self.value, "help": self.help}


class Gauge:
    """Last-observed value (plus running min/max for the summary)."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.n_samples = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.n_samples += 1

    def to_json(self) -> dict:
        return {"type": "gauge", "value": self.value,
                "min": self.min if self.n_samples else None,
                "max": self.max if self.n_samples else None,
                "n_samples": self.n_samples, "help": self.help}


class Histogram:
    """Log-spaced-bucket histogram with a bounded raw reservoir.

    Buckets span [lo, hi) in `n_buckets` equal log steps, with one
    underflow and one overflow bucket at the ends.  The first
    `reservoir` raw observations are kept verbatim so `percentile()` is
    exact for short runs (a serving smoke records hundreds of samples,
    not millions); beyond that, percentiles degrade gracefully to the
    retained prefix while bucket counts stay exact forever.
    """

    def __init__(self, name: str, help: str = "", lo: float = 1e-6,
                 hi: float = 100.0, n_buckets: int = 40,
                 reservoir: int = 8192):
        self.name, self.help = name, help
        self.lo, self.hi = float(lo), float(hi)
        self.n_buckets = int(n_buckets)
        self._log_lo = math.log(self.lo)
        self._log_step = (math.log(self.hi) - self._log_lo) / n_buckets
        self.buckets = [0] * (n_buckets + 2)     # [under, ..., over]
        self.count = 0
        self.sum = 0.0
        self._raw: list[float] = []
        self._reservoir = int(reservoir)

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self.n_buckets + 1
        return 1 + int((math.log(v) - self._log_lo) / self._log_step)

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[self._bucket(v)] += 1
        self.count += 1
        self.sum += v
        if len(self._raw) < self._reservoir:
            self._raw.append(v)

    def bucket_edges(self) -> list[float]:
        return [math.exp(self._log_lo + i * self._log_step)
                for i in range(self.n_buckets + 1)]

    def percentile(self, q: float) -> float:
        """q in [0, 100], from the raw reservoir (nan when empty)."""
        if not self._raw:
            return math.nan
        xs = sorted(self._raw)
        k = min(len(xs) - 1, max(0, round(q / 100.0 * (len(xs) - 1))))
        return xs[k]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def to_json(self) -> dict:
        pct = {f"p{q}": self.percentile(q) for q in (50, 90, 99)}
        return {"type": "histogram", "count": self.count, "sum": self.sum,
                "mean": self.mean if self.count else None,
                **{k: (None if math.isnan(v) else v)
                   for k, v in pct.items()},
                "bucket_lo": self.lo, "bucket_hi": self.hi,
                "buckets": self.buckets, "help": self.help}


class MetricsRegistry:
    """Named metric store with JSON export (schema 1)."""

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._lock = threading.Lock()

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(name, Histogram, help, **kw)

    def _get(self, name, cls, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                                f"not {cls.__name__}")
            return m

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def to_json(self) -> dict:
        return {"schema": 1,
                "metrics": {n: m.to_json()
                            for n, m in sorted(self._metrics.items())}}

    def dump(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_json(), indent=1))


class ServeMetrics:
    """Request-lifecycle metrics for `ServeEngine` (DESIGN.md §16).

    The engine calls the `on_*` hooks at each lifecycle edge; every
    latency is measured host-side around the forced device sync, so the
    per-token histogram records the same wall time `bench_serve.py`
    measures externally (the acceptance-criteria consistency check).
    """

    def __init__(self, registry: MetricsRegistry | None = None):
        r = registry if registry is not None else MetricsRegistry()
        self.registry = r
        self._profile = None
        self._submit_t: dict[int, float] = {}
        self._admit_t: dict[int, float] = {}
        # counters
        self.requests_submitted = r.counter(
            "serve.requests_submitted", "requests entering the queue")
        self.requests_admitted = r.counter(
            "serve.requests_admitted", "requests admitted into slots")
        self.requests_completed = r.counter(
            "serve.requests_completed", "requests evicted with results")
        self.tokens_generated = r.counter(
            "serve.tokens_generated", "total generated tokens")
        self.prefill_runs = r.counter(
            "serve.prefill_runs", "paged prefill forward passes")
        self.decode_steps = r.counter(
            "serve.decode_steps", "batched decode steps executed")
        self.backpressure_waits = r.counter(
            "serve.backpressure_waits",
            "engine steps where the queue head could not get pages")
        self.pe_failures = r.counter(
            "serve.pe_failures", "PE failures detected during step()")
        self.requests_requeued = r.counter(
            "serve.requests_requeued",
            "live requests re-queued after a PE failure")
        self.engine_steps = r.counter(
            "serve.engine_steps", "evict/admit/decode iterations")
        # gauges
        self.queue_depth = r.gauge(
            "serve.queue_depth", "queued (unadmitted) requests")
        self.active_slots = r.gauge(
            "serve.active_slots", "slots holding live sequences")
        self.kv_pages_live = r.gauge(
            "serve.kv_pages_live", "PagePool live pages")
        self.kv_pages_free = r.gauge(
            "serve.kv_pages_free", "PagePool allocatable pages")
        self.kv_occupancy = r.gauge(
            "serve.kv_occupancy", "live / allocatable page fraction")
        self.kv_fragmentation = r.gauge(
            "serve.kv_fragmentation",
            "recycled fraction of the available pages")
        # histograms (seconds)
        self.ttft_s = r.histogram(
            "serve.ttft_s", "submit -> first token latency")
        self.per_token_s = r.histogram(
            "serve.per_token_s", "per-decode-step wall time per token")
        self.admission_wait_s = r.histogram(
            "serve.admission_wait_s", "submit -> admit queue wait")
        self.e2e_s = r.histogram(
            "serve.e2e_s", "submit -> eviction end-to-end latency")
        self.recovery_s = r.histogram(
            "serve.recovery_s", "PE-failure drain + re-queue wall time")

    # -- lifecycle hooks (ServeEngine calls these) ---------------------------
    def on_submit(self, rid: int) -> None:
        self.requests_submitted.inc()
        self._submit_t[rid] = time.perf_counter()

    def on_admit(self, rid: int) -> None:
        now = time.perf_counter()
        self.requests_admitted.inc()
        self._admit_t[rid] = now
        t0 = self._submit_t.get(rid)
        if t0 is not None:
            self.admission_wait_s.observe(now - t0)

    def on_first_token(self, rid: int) -> None:
        self.prefill_runs.inc()
        self.tokens_generated.inc()
        t0 = self._submit_t.get(rid)
        if t0 is not None:
            self.ttft_s.observe(time.perf_counter() - t0)

    def on_decode_step(self, n_active: int, wall_s: float) -> None:
        self.decode_steps.inc()
        self.tokens_generated.inc(n_active)
        self.per_token_s.observe(wall_s)

    def on_evict(self, rid: int) -> None:
        self.requests_completed.inc()
        t0 = self._submit_t.pop(rid, None)
        self._admit_t.pop(rid, None)
        if t0 is not None:
            self.e2e_s.observe(time.perf_counter() - t0)

    def on_backpressure(self) -> None:
        self.backpressure_waits.inc()

    def on_pe_failure(self, n_requeued: int,
                      recovery_s: float | None = None) -> None:
        """A PE failure drained the engine: `n_requeued` live requests
        went back to the queue head (DESIGN.md §17)."""
        self.pe_failures.inc()
        self.requests_requeued.inc(n_requeued)
        if recovery_s is not None:
            self.recovery_s.observe(recovery_s)

    def sample_engine(self, engine) -> None:
        """Per-step gauge sweep: scheduler queue + PagePool state."""
        self.engine_steps.inc()
        sched = engine.scheduler
        pool = engine.kv.pool
        self.queue_depth.set(len(sched.queue))
        self.active_slots.set(len(sched.active_slots()))
        self.kv_pages_live.set(pool.live_pages())
        self.kv_pages_free.set(pool.pages_available())
        self.kv_occupancy.set(pool.occupancy())
        self.kv_fragmentation.set(pool.fragmentation())

    # -- export --------------------------------------------------------------
    def attach(self, profile) -> None:
        """Fold a Profiler/Tracer's wire counters (and heatmap, when the
        profile is a Tracer) into this document's to_json()."""
        self._profile = profile

    def to_json(self) -> dict:
        doc = self.registry.to_json()
        p = self._profile
        if p is not None:
            wire = {k: dict(v) for k, v in p.counters().items()
                    if k.startswith(("rma.", "ppermute", "collective.",
                                     "sync.", "fault."))}
            doc["wire"] = wire
            heatmap = getattr(p, "heatmap", None)
            if callable(heatmap):
                doc["heatmap"] = heatmap()
        return doc

    def dump(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_json(), indent=1))
