"""serve subsystem: continuous-batching engine on the paged
symmetric-heap KV cache (DESIGN.md §15).

Engine imports are lazy (`ServeEngine` pulls in jax/model code); the
pure-host pieces (`PagePool`, `PagedKV`, `Scheduler`) import cheaply for
devices-free scheduler tests."""
from .kv import PagedKV, PagePool, PagePoolError, pages_for  # noqa: F401


def __getattr__(name):
    if name in ("ServeEngine", "Scheduler", "Request", "SlotState"):
        from . import engine
        return getattr(engine, name)
    if name in ("MetricsRegistry", "ServeMetrics", "Counter", "Gauge",
                "Histogram"):
        from . import metrics
        return getattr(metrics, name)
    raise AttributeError(name)
