"""serve subsystem."""
