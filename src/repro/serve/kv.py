"""Paged KV-cache bookkeeping on the symmetric heap (DESIGN.md §15).

The paper's §3.2 symmetric-heap allocator is exactly a paged-KV
allocator waiting to be used: a KV page *is* an offset into one flat
symmetric buffer, identical on every PE.  `PagePool` layers a free list
over the heap's brk discipline — the brk only ever advances page by page
(each new page is one aligned `SymmetricHeap.malloc`), and freed pages
are recycled LIFO from the free list instead of violating the paper's
reverse-order `free` rule.  When every page is free the pool rolls the
brk all the way back (the one legal bulk free), so a drained engine
returns the heap to its initial state.

`PagedKV` adds the per-slot page-table bookkeeping the serving engine
uses: admission reserves a sequence's worst-case pages up front (prompt
+ max_new tokens), so decode can never exhaust the heap mid-flight —
heap pressure surfaces only as admission backpressure, never as a
`HeapError` escaping the engine.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.heap import Allocation, HeapError, SymmetricHeap

NULL_PAGE = 0


class PagePoolError(RuntimeError):
    """Out of KV pages — admission backpressure, not a crash."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold `n_tokens` positions."""
    return -(-max(int(n_tokens), 0) // int(page_size))


class PagePool:
    """Fixed-size-page allocator: free list over the symmetric heap.

    Page ids are heap offsets divided by the page stride (the heap's brk
    starts at 0 and `page_bytes` is alignment-padded, so every page's
    offset is an exact multiple of the stride).  `reserve_null` grabs
    page 0 at construction as the engine's scratch/null page: page-table
    entries of inactive slots point at it, so masked batch rows have a
    writable target that no valid read ever sees.
    """

    def __init__(self, heap: SymmetricHeap | int, page_bytes: int,
                 reserve_null: bool = True):
        if isinstance(heap, int):
            heap = SymmetricHeap(heap)
        if heap.brk != 0:
            raise PagePoolError("PagePool requires a fresh heap (brk=0)")
        self.heap = heap
        align = heap.default_align
        self.page_bytes = -(-int(page_bytes) // align) * align
        if self.page_bytes <= 0:
            raise PagePoolError("page_bytes must be positive")
        self._free: list[int] = []          # LIFO recycled page ids
        self._allocs: list[Allocation] = []  # heap-order, one per page
        self._live: set[int] = set()
        self.null_page: int | None = None
        if reserve_null:
            self.null_page = self._grow()
            self._live.discard(self.null_page)

    # -- capacity ------------------------------------------------------------
    @property
    def num_pages(self) -> int:
        """Total pages the heap can ever hold (including the null page)."""
        return self.heap.capacity // self.page_bytes

    def pages_available(self) -> int:
        unbacked = (self.heap.capacity - self.heap.brk) // self.page_bytes
        return len(self._free) + unbacked

    def can_alloc(self, n: int) -> bool:
        return self.pages_available() >= n

    def live_pages(self) -> int:
        return len(self._live)

    def occupancy(self) -> float:
        """Fraction of allocatable pages currently live (null page
        excluded from the denominator) — the serve-metrics KV gauge."""
        cap = self.num_pages - (1 if self.null_page is not None else 0)
        return len(self._live) / cap if cap > 0 else 0.0

    def fragmentation(self) -> float:
        """Fraction of the available pages that sit on the recycle list
        rather than in unbacked brk headroom.  High values mean the pool
        is serving from churned pages (LIFO reuse working as intended);
        0.0 means a fresh or fully drained pool."""
        avail = self.pages_available()
        return len(self._free) / avail if avail > 0 else 0.0

    # -- alloc/free ----------------------------------------------------------
    def _grow(self) -> int:
        try:
            a = self.heap.malloc(self.page_bytes)
        except HeapError as e:     # contract: HeapError never escapes
            raise PagePoolError(str(e)) from None
        assert a.offset % self.page_bytes == 0, (a.offset, self.page_bytes)
        self._allocs.append(a)
        pid = a.offset // self.page_bytes
        self._live.add(pid)
        return pid

    def alloc(self, n: int = 1) -> list[int]:
        """Allocate `n` pages (free list first, then brk growth) or raise
        `PagePoolError` leaving the pool unchanged (all-or-nothing, so a
        rejected admission holds no partial reservation)."""
        if not self.can_alloc(n):
            raise PagePoolError(
                f"out of KV pages: want {n}, have {self.pages_available()}")
        got: list[int] = []
        for _ in range(n):
            if self._free:
                pid = self._free.pop()
                self._live.add(pid)
                got.append(pid)
            else:
                got.append(self._grow())
        return got

    def free(self, pages) -> None:
        for pid in pages:
            if pid == self.null_page:
                raise PagePoolError("cannot free the reserved null page")
            if pid not in self._live:
                raise PagePoolError(f"free of unallocated page {pid}")
            self._live.remove(pid)
            self._free.append(pid)
        if not self._live:
            self._trim()

    def _trim(self) -> None:
        """All pages free: the one legal bulk release under the paper's
        brk discipline — free the FIRST post-null allocation, which frees
        the whole series, and start the free list over."""
        keep = 1 if self.null_page is not None else 0
        if len(self._allocs) > keep:
            self.heap.free(self._allocs[keep])
            del self._allocs[keep:]
        self._free = []


@dataclasses.dataclass
class SlotPages:
    rid: int
    pages: list[int]
    n_tokens: int


class PagedKV:
    """Per-slot page tables over a `PagePool`.

    `table` is the dense (max_slots, max_pages) int32 page-table array
    the jitted model indexes; unassigned entries point at the null page.
    """

    def __init__(self, pool: PagePool, max_slots: int, max_pages: int):
        self.pool = pool
        self.max_slots = int(max_slots)
        self.max_pages = int(max_pages)
        null = pool.null_page if pool.null_page is not None else NULL_PAGE
        self.table = np.full((max_slots, max_pages), null, np.int32)
        self._slots: list[SlotPages | None] = [None] * max_slots

    # -- admission / eviction -------------------------------------------------
    def can_admit(self, n_pages: int) -> bool:
        return n_pages <= self.max_pages and self.pool.can_alloc(n_pages)

    def admit(self, slot: int, rid: int, n_pages: int,
              n_tokens: int) -> SlotPages:
        if self._slots[slot] is not None:
            raise PagePoolError(f"slot {slot} already occupied")
        if n_pages > self.max_pages:
            raise PagePoolError(
                f"sequence needs {n_pages} pages > max_pages={self.max_pages}")
        pages = self.pool.alloc(n_pages)
        sp = SlotPages(rid=rid, pages=pages, n_tokens=n_tokens)
        self._slots[slot] = sp
        self.table[slot, :n_pages] = pages
        return sp

    def evict(self, slot: int) -> None:
        sp = self._slots[slot]
        if sp is None:
            raise PagePoolError(f"evict of empty slot {slot}")
        # reverse order: pages return LIFO, so the free list hands the
        # next admission the same pages back (fragmentation-free reuse)
        self.pool.free(reversed(sp.pages))
        self._slots[slot] = None
        null = self.pool.null_page if self.pool.null_page is not None \
            else NULL_PAGE
        self.table[slot, :] = null

    def slot(self, i: int) -> SlotPages | None:
        return self._slots[i]

    def occupied(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]
