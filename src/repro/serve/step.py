"""Serve-step builders: batched prefill and single-token decode.

Both builders thread the tuning stack (DESIGN.md §13) through the
`Comm` they construct, so sequence-sharded decode's per-step softmax
reductions run on tuned embedded schedules and land in the profiler's
timeline when one is attached."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.config import ModelConfig
from ..parallel.comm import AxisSpec, Comm


def build_prefill(cfg: ModelConfig, axes: AxisSpec, backend: str, *,
                  allreduce_algo: str = "paper", topo=None, link=None,
                  embedding=None, tuner=None, profile=None):
    def fn(params, batch):
        comm = Comm(axes, backend, allreduce_algo=allreduce_algo,
                    topo=topo, link=link, embedding=embedding,
                    tuner=tuner, profile=profile)
        return transformer.prefill(
            comm, cfg, params, batch.get("tokens"),
            frames=batch.get("frames"),
            frontend_embeds=batch.get("frontend_embeds"))
    return fn


def build_decode_step(cfg: ModelConfig, axes: AxisSpec, backend: str,
                      seq_shards: int = 1, *, allreduce_algo: str = "paper",
                      topo=None, link=None, embedding=None, tuner=None,
                      profile=None):
    def fn(params, cache, batch):
        comm = Comm(axes, backend, allreduce_algo=allreduce_algo,
                    topo=topo, link=link, embedding=embedding,
                    tuner=tuner, profile=profile)
        return transformer.decode_step(
            comm, cfg, params, cache, batch["tokens"], batch["positions"],
            seq_shards=seq_shards)
    return fn


def sample_greedy(comm: Comm, logits):
    """Greedy sampling over vocab-sharded logits: local argmax + global
    combine over the model axis.

    Ties break to the LOWEST global index, matching `jnp.argmax` on the
    unsharded vocab: every shard whose local max equals the global max
    contributes its local winner (already the lowest in-shard index),
    losers contribute an off-the-end sentinel, and a min-reduce picks the
    smallest global index among the tied shards."""
    v_local = logits.shape[-1]
    n = comm.axis_size(comm.axes.model)
    base = comm.axis_index(comm.axes.model) * v_local
    loc_max = jnp.max(logits, -1)
    loc_arg = jnp.argmax(logits, -1) + base
    g_max = comm.allreduce(loc_max, comm.axes.model, "max")
    sentinel = jnp.asarray(n * v_local, loc_arg.dtype)
    winner = jnp.where(loc_max >= g_max, loc_arg,
                       jnp.full_like(loc_arg, sentinel))
    return comm.allreduce(winner, comm.axes.model, "min")
