"""Serve-step builders: batched prefill and single-token decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.config import ModelConfig
from ..parallel.comm import AxisSpec, Comm


def build_prefill(cfg: ModelConfig, axes: AxisSpec, backend: str):
    def fn(params, batch):
        comm = Comm(axes, backend)
        return transformer.prefill(
            comm, cfg, params, batch.get("tokens"),
            frames=batch.get("frames"),
            frontend_embeds=batch.get("frontend_embeds"))
    return fn


def build_decode_step(cfg: ModelConfig, axes: AxisSpec, backend: str,
                      seq_shards: int = 1):
    def fn(params, cache, batch):
        comm = Comm(axes, backend)
        return transformer.decode_step(
            comm, cfg, params, cache, batch["tokens"], batch["positions"],
            seq_shards=seq_shards)
    return fn


def sample_greedy(comm: Comm, logits):
    """Greedy sampling over vocab-sharded logits: local argmax + global
    max-reduce over the model axis."""
    v_local = logits.shape[-1]
    base = comm.axis_index(comm.axes.model) * v_local
    loc_max = jnp.max(logits, -1)
    loc_arg = jnp.argmax(logits, -1) + base
    g_max = comm.allreduce(loc_max, comm.axes.model, "max")
    winner = jnp.where(loc_max >= g_max, loc_arg, jnp.zeros_like(loc_arg))
    return comm.allreduce(winner, comm.axes.model, "max")
