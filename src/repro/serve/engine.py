"""Continuous-batching serving engine on the paged symmetric-heap KV
cache (DESIGN.md §15).

Three pieces, separable on purpose:

  * `Scheduler` — the pure-host continuous-batching policy.  Strict-FIFO
    admission into fixed engine slots with worst-case page reservation
    (prompt + max_new tokens) at admission time, per-step join/evict.
    Deterministic and devices-free, so the policy is unit-testable as a
    plain state machine (tests/test_serve_engine.py drives it with a
    synthetic arrival trace).
  * `PagedKV`/`PagePool` (serve/kv.py) — page bookkeeping on the
    symmetric heap.  Heap pressure is admission backpressure: a request
    that doesn't fit simply waits at the queue head (no skipping, so no
    starvation), and no `HeapError` ever escapes the engine.
  * `ServeEngine` — the device half: a paged prefill fast-path (ONE
    forward pass over the prompt bucket that fills the sequence's KV
    pages) plus a fixed-shape batched decode step over all slots.
    Inactive slots ride along masked (their page-table rows point at the
    reserved null page), so the decode step never recompiles as
    sequences join and leave.  Every per-row op is batch-independent, so
    a request's greedy tokens are bit-identical whether it runs alone or
    joins mid-batch — the engine's core correctness invariant.

Model-axis collectives (attention allreduces, the vocab-sharded greedy
sample) run through `Comm`, so a `TunedSelector`/`Profiler` passed to
the engine prices and records every per-step collective (DESIGN.md §13).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any

import numpy as np

from .kv import PagedKV, PagePool, pages_for
from ..core.fault import PEFailure, fault_event
from ..core.heap import SymmetricHeap


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int


@dataclasses.dataclass
class SlotState:
    rid: int
    prompt: np.ndarray
    max_new: int
    pos: int                     # next position to be written by decode
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Scheduler:
    """Deterministic continuous-batching policy (pure host code).

    Admission is strict FIFO: free slots are filled in slot-index order
    from the queue head, stopping at the first request whose worst-case
    page reservation does not fit — the head is never skipped, so a big
    request cannot starve behind a stream of small ones.  Eviction scans
    slots in index order each step.  Given the same submission sequence
    and per-slot completion times, the (admit, evict) event order is a
    pure function of the trace."""

    def __init__(self, kv: PagedKV, page_size: int):
        self.kv = kv
        self.page_size = int(page_size)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[SlotState | None] = [None] * kv.max_slots
        self._next_rid = 0
        self.n_admitted = 0
        self.n_evicted = 0

    def pages_needed(self, req: Request) -> int:
        return pages_for(len(req.prompt) + req.max_new, self.page_size)

    def submit(self, prompt, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        req = Request(self._next_rid, prompt, int(max_new))
        if self.pages_needed(req) > self.kv.max_pages:
            raise ValueError(
                f"request needs {self.pages_needed(req)} pages "
                f"> max_pages={self.kv.max_pages}")
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def step_evict(self) -> list[tuple[int, SlotState]]:
        """Evict finished sequences (slot-index order), freeing their
        pages back to the pool."""
        out = []
        for i, st in enumerate(self.slots):
            if st is not None and st.done:
                self.kv.evict(i)
                self.slots[i] = None
                self.n_evicted += 1
                out.append((i, st))
        return out

    def step_admit(self) -> list[tuple[int, SlotState]]:
        """Admit queued requests into free slots while pages last."""
        out = []
        for slot, st in enumerate(self.slots):
            if st is not None or not self.queue:
                continue
            req = self.queue[0]
            need = self.pages_needed(req)
            if not self.kv.can_admit(need):
                break           # backpressure: head waits, nobody skips
            self.queue.popleft()
            self.kv.admit(slot, req.rid, need,
                          len(req.prompt) + req.max_new)
            state = SlotState(rid=req.rid, prompt=req.prompt,
                              max_new=req.max_new, pos=len(req.prompt))
            self.slots[slot] = state
            self.n_admitted += 1
            out.append((slot, state))
        return out

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]

    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)


class ServeEngine:
    """Continuous-batching engine: paged prefill + fixed-shape batched
    decode over `max_slots` sequences, greedy sampling through the
    vocab-sharded `sample_greedy`.

    The mesh provides tensor parallelism only (data axis must be 1: the
    batch lives in engine slots, not on a mesh axis).  `kv_heap_bytes`
    caps the per-PE symmetric-heap KV region — by default sized to hold
    every slot's worst-case sequence plus the null page."""

    def __init__(self, cfg, mesh, *, params=None, max_slots: int = 4,
                 page_size: int = 8, max_seq: int = 64,
                 prompt_bucket: int = 32, kv_heap_bytes: int | None = None,
                 backend: str = "shmem", allreduce_algo: str = "paper",
                 topo=None, link=None, embedding=None, tuner=None,
                 profile=None, metrics=None, eos_id: int | None = None,
                 init_key: int = 0, capture_logits: bool = False):
        import dataclasses as dc

        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from ..launch import build
        from ..models import transformer
        from ..parallel.comm import Comm
        from . import step as sstep

        cfg = dc.replace(cfg, fsdp=False)
        if cfg.family not in transformer.paged_families():
            raise ValueError(
                f"paged serving supports {transformer.paged_families()}, "
                f"not {cfg.family!r}")
        dp, tp, pod = build.mesh_dims(mesh)
        if dp != 1 or pod:
            raise ValueError("ServeEngine batches in engine slots; use a "
                             "(1, tp) mesh (data axis must be 1, no pod)")
        if prompt_bucket > max_seq:
            raise ValueError("prompt_bucket must be <= max_seq")
        self.cfg, self.mesh = cfg, mesh
        self.page_size = int(page_size)
        self.max_seq = int(max_seq)
        self.prompt_bucket = int(prompt_bucket)
        self.max_slots = int(max_slots)
        self.eos_id = eos_id
        self.capture_logits = capture_logits
        self._jnp, self._jax = jnp, jax

        max_pages = pages_for(max_seq, page_size)
        pool_shapes = jax.eval_shape(
            lambda: transformer.init_kv_pool(cfg, tp, 1, page_size))
        page_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(pool_shapes))
        if kv_heap_bytes is None:
            kv_heap_bytes = page_bytes * (max_slots * max_pages + 1)
        self.page_bytes = page_bytes
        self.heap = SymmetricHeap(int(kv_heap_bytes))
        pool = PagePool(self.heap, page_bytes)
        if pool.num_pages < 2:
            raise ValueError(
                f"kv_heap_bytes={kv_heap_bytes} holds {pool.num_pages} "
                f"pages of {page_bytes}B; need >= 2 (null + one live)")
        self.kv = PagedKV(pool, max_slots, max_pages)
        self.scheduler = Scheduler(self.kv, page_size)
        self.results: dict[int, np.ndarray] = {}
        self.logits_trace: dict[int, list] = {}
        self.steps = 0
        # observability (DESIGN.md §16): a ServeMetrics records the
        # request lifecycle; when `profile` is a Tracer, each request
        # additionally becomes an async track with enqueue/admit/
        # first-token instants.  Both default to None == zero cost.
        self.profile = profile
        self.metrics = metrics
        from ..core.trace import Tracer
        self._trace = profile if isinstance(profile, Tracer) else None

        axes = build.axis_spec(mesh)
        comm_kw = dict(allreduce_algo=allreduce_algo, topo=topo, link=link,
                       embedding=embedding, tuner=tuner, profile=profile)
        n_dev_pages = pool.num_pages

        with jax.set_mesh(mesh):
            init_fn, pshapes, pspecs = build.make_init_fn(cfg, mesh, backend)
            if params is None:
                params = jax.jit(init_fn)(jax.random.key(init_key))
            self.params = params
            self._pspecs = pspecs

            pool_struct = jax.eval_shape(lambda: transformer.init_kv_pool(
                cfg, tp, n_dev_pages, page_size))
            poolspecs = jax.tree.map(
                lambda _: P(None, None, None, "model", None), pool_struct)
            self._poolspecs = poolspecs
            self.pool = jax.jit(build.shard_mapped(
                lambda: transformer.init_kv_pool(cfg, tp, n_dev_pages,
                                                 page_size),
                mesh, (), poolspecs))()

            def prefill_fn(params, pool, table, tokens, positions, last_idx):
                comm = Comm(axes, backend, **comm_kw)
                logits, pool = transformer.prefill_paged(
                    comm, cfg, params, pool, table, tokens, positions,
                    page_size=page_size)
                lg = jnp.take_along_axis(
                    logits, last_idx[:, None, None], axis=1)[:, 0]
                tok = sstep.sample_greedy(comm, lg)
                return tok, lg, pool

            def decode_fn(params, pool, table, tokens, positions):
                comm = Comm(axes, backend, **comm_kw)
                logits, pool = transformer.decode_step_paged(
                    comm, cfg, params, pool, table, tokens, positions,
                    page_size=page_size)
                lg = logits[:, 0]
                tok = sstep.sample_greedy(comm, lg)
                return tok, lg, pool

            lg_spec = P(None, "model")
            self._pjit = jax.jit(build.shard_mapped(
                prefill_fn, mesh,
                (pspecs, poolspecs, P(), P(), P(), P()),
                (P(), lg_spec, poolspecs)))
            self._djit = jax.jit(build.shard_mapped(
                decode_fn, mesh,
                (pspecs, poolspecs, P(), P(), P()),
                (P(), lg_spec, poolspecs)))

    # -- observability helpers ------------------------------------------------
    def _span(self, name: str, **meta):
        """Nested tracer span, bare profiler op, or nothing — the whole
        disabled cost is this attribute test."""
        if self._trace is not None and self._trace.enabled:
            return self._trace.span(name, **meta)
        if self.profile is not None and self.profile.enabled:
            return self.profile.op(name, kind="span")
        return contextlib.nullcontext()

    def _req_event(self, kind: str, rid: int, **args) -> None:
        """Request-lifecycle edge on the tracer's async request track."""
        t = self._trace
        if t is None or not t.enabled:
            return
        if kind == "enqueue":
            t.begin_async("request", rid, f"req {rid}", **args)
        elif kind == "evict":
            t.end_async("request", rid, f"req {rid}", **args)
        else:
            t.instant_async("request", rid, kind, **args)

    # -- client API -----------------------------------------------------------
    def submit(self, prompt, max_new: int) -> int:
        if len(np.asarray(prompt).reshape(-1)) > self.prompt_bucket:
            raise ValueError(
                f"prompt longer than prompt_bucket={self.prompt_bucket}")
        rid = self.scheduler.submit(prompt, max_new)
        if self.metrics is not None:
            self.metrics.on_submit(rid)
        self._req_event("enqueue", rid, prompt_len=len(
            np.asarray(prompt).reshape(-1)), max_new=int(max_new))
        return rid

    def _emit(self, st: SlotState, tok: int, lg=None) -> None:
        st.out.append(int(tok))
        if self.capture_logits:
            self.logits_trace.setdefault(st.rid, []).append(
                np.asarray(lg, np.float32))
        if (len(st.out) >= st.max_new
                or (self.eos_id is not None and int(tok) == self.eos_id)):
            st.done = True

    def step(self) -> dict:
        """One engine iteration: evict -> admit(+prefill) -> batched
        decode.  Returns {"evicted": [...], "admitted": [...],
        "decoded": n_active}.

        A :class:`~repro.core.fault.PEFailure` surfacing from prefill or
        decode (DESIGN.md §17) triggers a graceful drain instead of
        propagating: every live slot's pages are freed and its request
        re-queued at the queue head in slot order, so FIFO order is
        preserved and — because greedy decode is bit-identical batched
        or alone — regenerated results match what the lost step would
        have produced.  The step then returns ``{"faulted": True,
        "requeued": [...], ...}``."""
        try:
            return self._step_inner()
        except PEFailure as exc:
            return self._fault_drain(exc)

    def _fault_drain(self, exc: PEFailure) -> dict:
        """Graceful drain + re-queue on PE loss (DESIGN.md §17)."""
        t0 = time.perf_counter()
        sched = self.scheduler
        requeued = []
        # reversed slot order + appendleft => queue head ends up in slot
        # order, the admission order the lost batch had (FIFO preserved)
        for i in range(len(sched.slots) - 1, -1, -1):
            st = sched.slots[i]
            if st is None:
                continue
            self.kv.evict(i)
            sched.slots[i] = None
            self.logits_trace.pop(st.rid, None)
            sched.queue.appendleft(Request(st.rid, st.prompt, st.max_new))
            requeued.append(st.rid)
        requeued.reverse()
        wall = time.perf_counter() - t0
        if self.metrics is not None:
            self.metrics.on_pe_failure(len(requeued), wall)
        prof = self.profile if (self.profile is not None
                                and self.profile.enabled) else None
        fault_event(prof, "fault.serve_drain", pe=exc.pe,
                    n_requeued=len(requeued),
                    recovery_us=int(wall * 1e6))
        self.steps += 1
        if self.metrics is not None:
            self.metrics.sample_engine(self)
        return {"evicted": [], "admitted": [], "decoded": 0,
                "faulted": True, "pe": exc.pe, "requeued": requeued}

    def _step_inner(self) -> dict:
        jnp = self._jnp
        sched = self.scheduler
        metrics = self.metrics
        with self._jax.set_mesh(self.mesh), \
                self._span("serve.step", n_pes=0):
            evicted = []
            for slot, st in sched.step_evict():
                self.results[st.rid] = np.asarray(st.out, np.int32)
                evicted.append(st.rid)
                if metrics is not None:
                    metrics.on_evict(st.rid)
                self._req_event("evict", st.rid, n_tokens=len(st.out))

            admitted = []
            admits = sched.step_admit()
            if metrics is not None and sched.queue \
                    and any(s is None for s in sched.slots):
                # free slot + waiting head = page backpressure, the only
                # reason FIFO admission stalls (DESIGN.md §15)
                metrics.on_backpressure()
            for slot, st in admits:
                if metrics is not None:
                    metrics.on_admit(st.rid)
                self._req_event("admit", st.rid, slot=slot)
                Lb = self.prompt_bucket
                toks = np.zeros((1, Lb), np.int32)
                toks[0, :len(st.prompt)] = st.prompt
                positions = jnp.broadcast_to(
                    jnp.arange(Lb, dtype=jnp.int32)[None], (1, Lb))
                trow = jnp.asarray(self.kv.table[slot:slot + 1])
                last = jnp.asarray([len(st.prompt) - 1], jnp.int32)
                with self._span("serve.prefill", nbytes=float(Lb * 4)):
                    tok, lg, self.pool = self._pjit(
                        self.params, self.pool, trow, jnp.asarray(toks),
                        positions, last)
                    tok = np.asarray(tok)      # force sync: first token
                self._emit(st, tok[0],
                           np.asarray(lg)[0] if self.capture_logits
                           else None)
                if metrics is not None:
                    metrics.on_first_token(st.rid)
                self._req_event("first_token", st.rid)
                admitted.append(st.rid)

            active = sched.active_slots()
            if active:
                toks = np.zeros((self.max_slots, 1), np.int32)
                poss = np.zeros((self.max_slots,), np.int32)
                for i in active:
                    st = sched.slots[i]
                    toks[i, 0] = st.out[-1]
                    poss[i] = st.pos
                t0 = time.perf_counter()
                with self._span("serve.decode", n_pes=len(active)):
                    tok, lg, self.pool = self._djit(
                        self.params, self.pool, jnp.asarray(self.kv.table),
                        jnp.asarray(toks), jnp.asarray(poss))
                    tok = np.asarray(tok)      # force sync: step complete
                if metrics is not None:
                    metrics.on_decode_step(len(active),
                                           time.perf_counter() - t0)
                lg = np.asarray(lg) if self.capture_logits else None
                for i in active:
                    st = sched.slots[i]
                    st.pos += 1
                    self._emit(st, tok[i],
                               lg[i] if self.capture_logits else None)
        self.steps += 1
        if metrics is not None:
            metrics.sample_engine(self)
        return {"evicted": evicted, "admitted": admitted,
                "decoded": len(active)}

    def run(self, max_steps: int = 100_000) -> dict[int, np.ndarray]:
        """Drain queue and slots; returns {rid: generated tokens}."""
        for _ in range(max_steps):
            if self.scheduler.idle():
                break
            self.step()
        # final evict pass so the last finishers land in results
        for slot, st in self.scheduler.step_evict():
            self.results[st.rid] = np.asarray(st.out, np.int32)
            if self.metrics is not None:
                self.metrics.on_evict(st.rid)
            self._req_event("evict", st.rid, n_tokens=len(st.out))
        return self.results
