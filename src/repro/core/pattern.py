"""Compiled communication patterns — the precomputed-schedule layer.

The paper's PEs precompute their neighbor lists and remote addresses in
``shmem_init`` so the hot path is a bare memory-mapped store; the JAX
analogue is compiling a static ``(src, dst)`` pattern ONCE into a
:class:`CommPattern` carrying everything every consumer used to rebuild
per call (DESIGN.md §9):

  * the forward pair list (what ``lax.ppermute`` wants),
  * the inverse pattern (gets and atomic fetches run the reverse edges),
  * destination/source masks as device-ready arrays (what ``select`` and
    the SIM backend's gather want),
  * per-pair weighted hop counts against an attached
    :class:`~repro.core.topology.MeshTopology` (what the alpha-beta cost
    model wants).

Patterns are interned per ``(pairs, n_pes)``: compiling the same pattern
twice returns the *same object*, so repeated collective stages and the
put/get/atomic call sites share one compilation, and inverse round-trips
are identity-stable (``p.inverse.inverse is p``).

:class:`Schedule` stacks compiled patterns into the multi-stage plans the
collectives execute; each :class:`Stage` carries its payload bytes so the
``(bytes, hops, max_link_load)`` cost descriptor is derived from the very
object that runs — there is no hand-maintained parallel cost function to
drift.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Sequence, Union

import numpy as np

from .topology import MeshTopology

Pairs = Sequence[tuple[int, int]]
PatternLike = Union["CommPattern", Pairs]

_INTERN_LOCK = threading.Lock()
_INTERN: dict[tuple[tuple[tuple[int, int], ...], int], "CommPattern"] = {}
# Interning is a cache, not a registry: a job issuing data-dependent
# ad-hoc patterns (one per step) must not pin them all for the process
# lifetime.  Beyond the cap the oldest entries are dropped — they keep
# working, they just stop being shared/identity-stable.  The canonical
# collective families (ring/xor/binomial per n_pes) number far below this.
_INTERN_MAX = 4096


class CommPattern:
    """A static point-to-point pattern compiled for a fixed PE count.

    Never construct directly — go through :func:`compile_pattern` (or
    :func:`as_pattern`) so instances are interned and compile-once caching
    holds.  Instances are immutable and hash/compare by identity.
    """

    __slots__ = (
        "pairs", "n_pes", "dst_mask", "src_mask", "src_for_dst",
        "_inverse", "_hops_cache", "_device_cache", "_rounds_cache",
        "_jnp_cache", "_link_cache", "_wave_cache",
    )

    def __init__(self, pairs: tuple[tuple[int, int], ...], n_pes: int,
                 _token=None):
        if _token is not _COMPILE_TOKEN:
            raise TypeError("use compile_pattern()/as_pattern(), not "
                            "CommPattern(...) — patterns are interned")
        self.pairs = pairs
        self.n_pes = n_pes
        src_for_dst = np.full((n_pes,), -1, dtype=np.int64)
        src_mask = np.zeros((n_pes,), dtype=bool)
        dst_mask = np.zeros((n_pes,), dtype=bool)
        for s, d in pairs:
            src_for_dst[d] = s
            src_mask[s] = True
            dst_mask[d] = True
        src_for_dst.setflags(write=False)
        src_mask.setflags(write=False)
        dst_mask.setflags(write=False)
        self.src_for_dst = src_for_dst
        self.src_mask = src_mask
        self.dst_mask = dst_mask
        self._inverse: CommPattern | None = None
        self._hops_cache: dict[MeshTopology, np.ndarray] = {}
        self._device_cache: tuple | None = None
        self._jnp_cache: tuple | None = None
        self._link_cache: dict = {}
        self._wave_cache: dict = {}
        self._rounds_cache: tuple[tuple[tuple[int, int], ...], ...] | None = None

    # -- structure ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.pairs)

    def __iter__(self):
        return iter(self.pairs)

    def __repr__(self) -> str:
        shown = list(self.pairs[:4])
        more = f", +{len(self.pairs) - 4} more" if len(self.pairs) > 4 else ""
        return f"CommPattern(n_pes={self.n_pes}, pairs={shown}{more})"

    @property
    def inverse(self) -> "CommPattern":
        """The reversed-edge pattern (dst, src) — what a get or an atomic
        fetch runs.  Interned, so ``p.inverse.inverse is p``."""
        if self._inverse is None:
            inv = compile_pattern([(d, s) for s, d in self.pairs], self.n_pes)
            self._inverse = inv
            if inv._inverse is None:
                inv._inverse = self
        return self._inverse

    # -- device-ready arrays -------------------------------------------------
    def gather_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """(has_src, gather_idx) for the SIM backend's gather:
        ``recv[d] = x[gather_idx[d]] if has_src[d]``.  Built lazily once.

        Deliberately numpy, not jnp: a cached jnp array created while some
        caller was tracing would leak that trace's tracers into every later
        caller.  Numpy constants are trace-safe and XLA constant-folds the
        per-trace jnp.asarray."""
        if self._device_cache is None:
            has = self.src_for_dst >= 0
            idx = np.where(has, self.src_for_dst, 0)
            has.setflags(write=False)
            idx.setflags(write=False)
            self._device_cache = (has, idx)
        return self._device_cache

    def gather_arrays_device(self) -> tuple:
        """The :meth:`gather_arrays` pair as device-resident ``jnp``
        arrays, built once per pattern, so the SIM backend's hot path
        stops re-uploading the host indices on every ``ppermute`` call.

        A plain ``jnp.asarray`` mid-trace would stage a device_put and
        cache that trace's TRACER (the hazard the gather_arrays docstring
        names); ``jax.ensure_compile_time_eval()`` forces a concrete
        constant regardless of the caller's trace context, which is safe
        to cache and share across traces."""
        if self._jnp_cache is None:
            import jax
            import jax.numpy as jnp
            has, idx = self.gather_arrays()
            with jax.ensure_compile_time_eval():
                self._jnp_cache = (jnp.asarray(has), jnp.asarray(idx))
        return self._jnp_cache

    def unique_src_rounds(self) -> tuple[tuple[tuple[int, int], ...], ...]:
        """The pairs split into rounds with unique sources.

        Destinations are unique by construction, but sources may repeat
        (fan-out: one owner pushing to many requesters, e.g. an IPI-get
        with several readers).  ``lax.ppermute`` requires both sides
        unique, so the SPMD backend runs one ppermute per round — the
        analogue of the owner serializing its pushes on the NoC.  Single
        round (the common case) means one ppermute, zero overhead."""
        if self._rounds_cache is None:
            rounds: list[list[tuple[int, int]]] = []
            used: list[set[int]] = []
            for s, d in self.pairs:
                for r, u in zip(rounds, used):
                    if s not in u:
                        r.append((s, d))
                        u.add(s)
                        break
                else:
                    rounds.append([(s, d)])
                    used.append({s})
            self._rounds_cache = tuple(tuple(r) for r in rounds)
        return self._rounds_cache

    def relabel(self, ranks: Sequence[int], n_pes: int) -> "CommPattern":
        """Map this pattern's PE ids through `ranks` (index -> new PE id)
        and compile for `n_pes` — the team-coordinate -> world-coordinate
        lift (DESIGN.md §11).  Interned like every compiled pattern, so a
        team-relative schedule lifts to the same world objects every call."""
        return compile_pattern(
            [(ranks[s], ranks[d]) for s, d in self.pairs], n_pes)

    # -- topology-derived cost metadata --------------------------------------
    def pair_hops(self, topo: MeshTopology | None) -> np.ndarray:
        """Weighted hop distance of every (src, dst) edge under `topo`
        (1.0 per edge when no topology is attached)."""
        if topo is None:
            return np.ones((len(self.pairs),), dtype=np.float64)
        cached = self._hops_cache.get(topo)
        if cached is None:
            cached = np.array([topo.hops(s, d) for s, d in self.pairs],
                              dtype=np.float64)
            cached.setflags(write=False)
            self._hops_cache[topo] = cached
        return cached

    def max_hops(self, topo: MeshTopology | None) -> float:
        """Worst-path hop count — the stage latency term under
        dimension-ordered routing with no congestion (all edges of a stage
        fly concurrently; the stage completes when the longest one lands)."""
        h = self.pair_hops(topo)
        return float(h.max()) if len(h) else 0.0

    def total_hops(self, topo: MeshTopology | None) -> float:
        """Sum of edge hop counts — the stage's aggregate link occupancy
        (the congestion/energy term, not the latency term)."""
        return float(self.pair_hops(topo).sum())

    def link_loads(self, topo) -> dict[tuple[int, int], float]:
        """Per-physical-link FLOW MULTIPLICITY of this pattern under the
        topology's dimension-ordered routing (``topo.route``) — how many
        flows cross each link, unweighted (per-dimension link costs stay
        in the hop/latency term; weighting loads too would double-price
        slow links, and multiplicity is what ``link_waves`` serializes).

        Keys are canonical undirected links ``(min_pe, max_pe)``: the two
        directions of a mesh link share router switching/arbitration, so
        counter-flows contend — the conservative model, and the one under
        which the paper's farthest-first ordering and the snake embedding
        are visible on small meshes (a purely directed count calls the
        4x4 logical ring congestion-free).  Cached per (pattern, topo)
        like the hop caches; the returned dict is shared — don't mutate."""
        cached = self._link_cache.get(topo)
        if cached is None:
            loads: dict[tuple[int, int], float] = {}
            for s, d in self.pairs:
                if s == d:
                    continue
                for u, v in topo.route(s, d):
                    key = (u, v) if u < v else (v, u)
                    loads[key] = loads.get(key, 0.0) + 1.0
            cached = loads
            self._link_cache[topo] = cached
        return cached

    def max_link_load(self, topo) -> float:
        """The congestion metric: flow multiplicity through the hottest
        physical link — the factor by which the stage's payload serializes
        there.  1.0 with no topology (flat network: every pair its own
        link) or when every routed link carries a single flow."""
        if topo is None:
            return 1.0 if self.pairs else 0.0
        loads = self.link_loads(topo)
        return max(loads.values()) if loads else (1.0 if self.pairs else 0.0)

    def link_waves(self, topo) -> tuple["CommPattern", ...]:
        """The pairs split greedily into sub-patterns whose routes are
        link-disjoint.  A congestion-faithful executor (netops.NocSimNetOps)
        runs one wave at a time — the flows a real NoC could fly
        concurrently — so measured wall time scales with contention the
        way ``max_link_load`` prices it.  Destinations are disjoint across
        waves (unique per pattern), so wave results combine losslessly.
        Cached per (pattern, topo); single wave == no contention."""
        cached = self._wave_cache.get(topo)
        if cached is None:
            waves: list[list[tuple[int, int]]] = []
            used: list[set[tuple[int, int]]] = []
            # farthest-first (paper §3.6): packing the longest routes
            # first keeps the greedy coloring at (or near) the hot-link
            # load bound instead of fragmenting long flows across waves
            order = self.pairs if topo is None else sorted(
                self.pairs, key=lambda p: -topo.hops(p[0], p[1]))
            for s, d in order:
                links = {(u, v) if u < v else (v, u)
                         for u, v in (topo.route(s, d) if topo is not None
                                      else ())}
                for w, u in zip(waves, used):
                    if not (links & u):
                        w.append((s, d))
                        u |= links
                        break
                else:
                    waves.append([(s, d)])
                    used.append(set(links))
            cached = tuple(compile_pattern(w, self.n_pes) for w in waves)
            self._wave_cache[topo] = cached
        return cached


_COMPILE_TOKEN = object()


def _normalize(pattern: Pairs, n_pes: int) -> tuple[tuple[int, int], ...]:
    pairs = tuple(sorted((int(s) % n_pes, int(d) % n_pes)
                         for s, d in pattern))
    dsts = [d for _, d in pairs]
    if len(set(dsts)) != len(dsts):
        raise ValueError(f"pattern names a destination twice: {pairs}")
    return pairs


def intern_get(table: dict, lock: threading.Lock, cap: int, key, build):
    """Shared intern-with-cap: double-checked lookup, FIFO eviction past
    `cap`.  One copy of the concurrency-sensitive machinery for every
    interned family (patterns here, teams in core/team.py)."""
    got = table.get(key)
    if got is None:
        with lock:
            got = table.get(key)
            if got is None:
                got = build()
                while len(table) >= cap:
                    table.pop(next(iter(table)))
                table[key] = got
    return got


def compile_pattern(pattern: Pairs, n_pes: int) -> CommPattern:
    """Compile (and intern) a static (src, dst) pattern for `n_pes` PEs.

    Pairs are taken mod n_pes and canonically sorted, so two call sites
    listing the same edges in different orders share one compiled object.
    """
    if isinstance(pattern, CommPattern):
        if pattern.n_pes != n_pes:
            raise ValueError(
                f"pattern compiled for {pattern.n_pes} PEs used with {n_pes}")
        return pattern
    key = (_normalize(pattern, n_pes), n_pes)
    return intern_get(
        _INTERN, _INTERN_LOCK, _INTERN_MAX, key,
        lambda: CommPattern(key[0], n_pes, _token=_COMPILE_TOKEN))


def as_pattern(pattern: PatternLike, n_pes: int) -> CommPattern:
    """Coerce a raw pair list or an already-compiled pattern."""
    return compile_pattern(pattern, n_pes)


def cache_size() -> int:
    return len(_INTERN)


# -- canonical pattern families (the collectives' vocabulary) ----------------

def ring_pattern(n: int, offset: int = 1) -> CommPattern:
    """Every PE sends to (pe + offset) mod n — one ring/pairwise stage."""
    return compile_pattern([(i, (i + offset) % n) for i in range(n)], n)


def xor_pattern(n: int, stride: int) -> CommPattern:
    """Recursive-doubling exchange: i <-> i ^ stride (n a power of two)."""
    return compile_pattern([(i, i ^ stride) for i in range(n)], n)


def binomial_stage_pattern(n: int, stride: int, root: int = 0) -> CommPattern:
    """One farthest-first binomial broadcast stage: subtree roots at
    relative rank multiples of 2*stride push to rank+stride (paper §3.6)."""
    pairs = []
    for rel in range(0, n, 2 * stride):
        rel_dst = rel + stride
        if rel_dst < n:
            pairs.append(((rel + root) % n, (rel_dst + root) % n))
    return compile_pattern(pairs, n)


# -- schedules ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One serialized step of a collective: a compiled pattern plus the
    per-edge payload it moves."""

    pattern: CommPattern
    nbytes: float

    def cost(self, topo: MeshTopology | None = None
             ) -> tuple[float, float, float]:
        """(bytes, hops, max_link_load) — the alpha-beta model's stage
        descriptor: worst-path latency AND hottest-link serialization
        (``abmodel.LinkModel.time`` prices all three terms)."""
        return (float(self.nbytes), self.pattern.max_hops(topo),
                self.pattern.max_link_load(topo))


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An ordered list of stages; what a collective algorithm *is*.

    The same object both drives execution (consumers iterate `stages` and
    ppermute each `stage.pattern`) and prices itself for the cost model —
    so predicted and executed schedules cannot diverge.
    """

    name: str
    stages: tuple[Stage, ...]

    def __len__(self) -> int:
        return len(self.stages)

    def __iter__(self) -> Iterable[Stage]:
        return iter(self.stages)

    def cost(self, topo: MeshTopology | None = None
             ) -> list[tuple[float, float, float]]:
        """[(bytes, hops, max_link_load)] per stage — feed to
        `abmodel.modeled_collective_time`."""
        return [st.cost(topo) for st in self.stages]

    def time(self, topo: MeshTopology | None = None, link=None) -> float:
        """Alpha-beta modeled wall time of the whole schedule."""
        from . import abmodel
        link = link if link is not None else abmodel.ICI_V5E
        return abmodel.modeled_collective_time(self.cost(topo), link)

    def pipelined_time(self, n_chunks: int,
                       topo: MeshTopology | None = None, link=None) -> float:
        """Modeled wall time when executed chunked/double-buffered in
        `n_chunks` pieces (stage k of chunk i overlapping stage k+1 of
        chunk i-1); n_chunks=1 is the monolithic time."""
        from . import abmodel
        link = link if link is not None else abmodel.ICI_V5E
        return abmodel.modeled_pipelined_time(self.cost(topo), n_chunks, link)

    def total_bytes(self) -> float:
        return sum(st.nbytes for st in self.stages)
