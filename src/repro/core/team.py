"""Teams and team partitions — structured PE grouping (OpenSHMEM 1.4+).

The paper targets OpenSHMEM 1.3, where every collective re-derives its
group from a raw ``(PE_start, logPE_stride, PE_size)`` active set.  The
follow-on Epiphany work (arXiv:1604.04205, arXiv:1704.08343) points at
structured PE grouping as the path to scaling beyond one 2D array; this
module is that layer (DESIGN.md §11):

  * :class:`Team` — an interned, immutable subset of the world PE space
    with rank translation both ways.  A team *is* a coordinate system:
    collective schedules are built in team coordinates (``team.size``
    ranks) once, then *lifted* to world coordinates through the team's
    member list (``Team.lift`` / ``CommPattern.relabel``) — compiled and
    cached per ``(team, pairs)``, interned like every pattern.
  * :class:`TeamPartition` — a disjoint cover of the world by equal-size
    teams (e.g. all rows of a mesh).  Its lift is the *union* of every
    member team's lift, so one world-level ``CommPattern`` runs all the
    teams' stage-k exchanges concurrently — what the hierarchical
    collectives execute.
  * :class:`TeamTopology` — a team-coordinate view of a world
    :class:`~repro.core.topology.MeshTopology`: ``hops(a, b)`` prices
    team rank pairs at the world distance of the members they name, so
    the alpha-beta model can price un-lifted team-relative schedules.

Constructors mirror OpenSHMEM: :func:`team_world`,
:func:`split_strided` (``shmem_team_split_strided``), :func:`split_2d`
(row/column teams from a :class:`MeshTopology`), plus
:func:`from_active_set` — the 1.3 compatibility shim that makes a
``(PE_start, logPE_stride, PE_size)`` triple resolve to the same
interned team (and therefore the same compiled schedules) as the
explicit-team API.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Sequence

import numpy as np

from .pattern import (CommPattern, PatternLike, Schedule, Stage, as_pattern,
                      intern_get)
from .topology import MeshTopology

_INTERN_LOCK = threading.Lock()
_INTERN: dict[tuple[tuple[int, ...], int], "Team"] = {}
# Like pattern interning, a cache with a cap — the canonical families
# (world, rows, columns, active sets) number far below it.
_INTERN_MAX = 1024

_TOKEN = object()


class Team:
    """An immutable ordered subset of the world PE space.

    Never construct directly — go through :func:`make_team` (or the
    named constructors) so instances are interned: the same member list
    yields the *same object*, which keeps per-team schedule caches and
    hash-by-identity cheap.  ``members[r]`` is the world PE of team rank
    ``r``; ranks are dense ``0..size-1``.
    """

    __slots__ = ("members", "world_n", "rank_np", "member_np",
                 "_lift_cache", "_topo_cache")

    def __init__(self, members: tuple[int, ...], world_n: int, _token=None):
        if _token is not _TOKEN:
            raise TypeError("use make_team()/team_world()/split_*(), not "
                            "Team(...) — teams are interned")
        self.members = members
        self.world_n = world_n
        rank = np.full((world_n,), -1, dtype=np.int64)
        member = np.zeros((world_n,), dtype=bool)
        for r, pe in enumerate(members):
            rank[pe] = r
            member[pe] = True
        rank.setflags(write=False)
        member.setflags(write=False)
        self.rank_np = rank          # world pe -> team rank (-1 outside)
        self.member_np = member      # world pe -> in-team?
        self._lift_cache: dict[CommPattern, CommPattern] = {}
        self._topo_cache: dict[MeshTopology, TeamTopology] = {}

    # -- structure ----------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self.members)

    @property
    def covers_world(self) -> bool:
        return len(self.members) == self.world_n

    def __len__(self) -> int:
        return len(self.members)

    def __repr__(self) -> str:
        shown = list(self.members[:6])
        more = f", +{len(self.members) - 6} more" if len(self.members) > 6 else ""
        return f"Team(world_n={self.world_n}, members={shown}{more})"

    # -- translation (shmem_team_translate_pe) -------------------------------
    def translate(self, world_pe: int) -> int:
        """World PE -> team rank, or -1 when `world_pe` is not a member
        (including ids outside the world — no silent modulo wrap)."""
        pe = int(world_pe)
        if not 0 <= pe < self.world_n:
            return -1
        return int(self.rank_np[pe])

    def world_pe(self, team_rank: int) -> int:
        """Team rank -> world PE (the inverse of :meth:`translate`)."""
        return self.members[team_rank]

    # -- team-coordinate -> world-coordinate lifting -------------------------
    def lift(self, pattern: PatternLike) -> CommPattern:
        """Compile a team-coordinate ``(src, dst)`` pattern (ranks in
        ``0..size-1``) into the world-coordinate pattern that executes.
        Cached per (team, pairs): the same team schedule lifts to the
        same interned world objects on every call."""
        p = as_pattern(pattern, self.size)
        got = self._lift_cache.get(p)
        if got is None:
            got = p.relabel(self.members, self.world_n)
            self._lift_cache[p] = got
        return got

    def lift_schedule(self, sched: Schedule) -> Schedule:
        """Lift every stage of a team-coordinate Schedule; stage payloads
        are unchanged (bytes are per-member, not per-team)."""
        return Schedule(f"{sched.name}@team{self.size}", tuple(
            Stage(self.lift(st.pattern), st.nbytes) for st in sched.stages))

    # -- cost-model view ------------------------------------------------------
    def topo_view(self, world_topo: MeshTopology | None):
        """The team's slice of a world topology: a hop metric over team
        ranks, priced at the world distance of the members they name.
        Feed to ``Schedule.cost``/``.time`` to price an *un-lifted*
        team-relative schedule (lifted schedules price against the world
        topology directly and agree by construction)."""
        if world_topo is None:
            return None
        got = self._topo_cache.get(world_topo)
        if got is None:
            got = TeamTopology(self, world_topo)
            self._topo_cache[world_topo] = got
        return got


@dataclasses.dataclass(frozen=True, eq=False)
class TeamTopology:
    """Hop metric over team ranks — a Team's view of the world topology.
    Duck-types the ``hops(a, b)`` surface `CommPattern.pair_hops` and the
    alpha-beta model consume.  Hash/compare by identity (cached per
    (team, world) pair in ``Team.topo_view``)."""

    team: Team
    world: MeshTopology

    @property
    def n_pes(self) -> int:
        return self.team.size

    def hops(self, a: int, b: int) -> float:
        return self.world.hops(self.team.members[a], self.team.members[b])

    def route(self, a: int, b: int) -> tuple[tuple[int, int], ...]:
        """The WORLD route between the members team ranks a/b name — link
        endpoints are world PEs, so an un-lifted team schedule's link
        loads equal the lifted schedule's by construction."""
        return self.world.route(self.team.members[a], self.team.members[b])

    def link_weight(self, u: int, v: int) -> float:
        return self.world.link_weight(u, v)


def make_team(members: Sequence[int], world_n: int) -> Team:
    """Intern (and validate) a team from an explicit world-PE list."""
    mem = tuple(int(m) for m in members)
    if not mem:
        raise ValueError("a team needs at least one member")
    if any(m < 0 or m >= world_n for m in mem):
        raise ValueError(f"member out of range for world_n={world_n}: {mem}")
    if len(set(mem)) != len(mem):
        raise ValueError(f"duplicate members: {mem}")
    key = (mem, world_n)
    return intern_get(_INTERN, _INTERN_LOCK, _INTERN_MAX, key,
                      lambda: Team(mem, world_n, _token=_TOKEN))


def team_world(world_n: int) -> Team:
    """The predefined world team (SHMEM_TEAM_WORLD)."""
    return make_team(range(world_n), world_n)


def split_strided(parent: Team, start: int, stride: int, size: int) -> Team:
    """``shmem_team_split_strided``: ranks start, start+stride, ... of
    `parent` (parent-rank space, so splits compose)."""
    if size <= 0:
        raise ValueError("size must be positive")
    idx = [start + i * stride for i in range(size)]
    if any(i < 0 or i >= parent.size for i in idx):
        raise ValueError(
            f"strided split ({start},{stride},{size}) leaves parent "
            f"(size {parent.size})")
    return make_team([parent.members[i] for i in idx], parent.world_n)


def from_active_set(pe_start: int, log_pe_stride: int, pe_size: int,
                    world_n: int) -> Team:
    """The OpenSHMEM 1.3 active-set shim: ``(PE_start, logPE_stride,
    PE_size)`` resolves to the interned team the explicit API would
    build, so 1.3-style ``to_all`` calls emit the same compiled
    schedules (DESIGN.md §11)."""
    return split_strided(team_world(world_n), pe_start, 1 << log_pe_stride,
                         pe_size)


class TeamPartition:
    """A disjoint, equal-size team cover of a parent PE set.

    Execution view: every PE has a team and a rank within it, and
    :meth:`lift` unions the member teams' lifts of a team-coordinate
    pattern into ONE world pattern — all teams run their stage-k
    exchange concurrently.  This is what the hierarchical collectives
    and the team-relative ring algorithms execute
    (`collectives.allreduce_hier`).
    """

    __slots__ = ("teams", "world_n", "rank_np", "member_np", "team_id_np",
                 "_lift_cache", "_complement")

    def __init__(self, teams: Sequence[Team]):
        teams = tuple(teams)
        if not teams:
            raise ValueError("a partition needs at least one team")
        world_n = teams[0].world_n
        size = teams[0].size
        for t in teams:
            if t.world_n != world_n:
                raise ValueError("teams disagree on world_n")
            if t.size != size:
                raise ValueError(
                    f"partition teams must be equal size: {size} vs {t.size}")
        rank = np.full((world_n,), -1, dtype=np.int64)
        team_id = np.full((world_n,), -1, dtype=np.int64)
        member = np.zeros((world_n,), dtype=bool)
        for ti, t in enumerate(teams):
            for r, pe in enumerate(t.members):
                if member[pe]:
                    raise ValueError(f"PE {pe} appears in two teams")
                rank[pe], team_id[pe], member[pe] = r, ti, True
        for a in (rank, team_id, member):
            a.setflags(write=False)
        self.teams = teams
        self.world_n = world_n
        self.rank_np = rank
        self.member_np = member
        self.team_id_np = team_id
        self._lift_cache: dict[CommPattern, CommPattern] = {}
        self._complement: TeamPartition | None = None

    # -- structure ----------------------------------------------------------
    @property
    def size(self) -> int:
        """Per-team size (uniform)."""
        return self.teams[0].size

    @property
    def n_teams(self) -> int:
        return len(self.teams)

    @property
    def covers_world(self) -> bool:
        return self.n_teams * self.size == self.world_n

    def __repr__(self) -> str:
        return (f"TeamPartition({self.n_teams} teams x {self.size} PEs, "
                f"world_n={self.world_n})")

    def team_of(self, world_pe: int) -> Team:
        pe = int(world_pe)
        ti = self.team_id_np[pe] if 0 <= pe < self.world_n else -1
        if ti < 0:
            raise ValueError(f"PE {world_pe} is not in this partition")
        return self.teams[int(ti)]

    # -- lifting --------------------------------------------------------------
    def lift(self, pattern: PatternLike) -> CommPattern:
        """Union of every team's lift: one world pattern running all the
        teams' copies of a team-coordinate exchange concurrently."""
        p = as_pattern(pattern, self.size)
        got = self._lift_cache.get(p)
        if got is None:
            pairs = [(t.members[s], t.members[d])
                     for t in self.teams for s, d in p.pairs]
            got = as_pattern(pairs, self.world_n)
            self._lift_cache[p] = got
        return got

    def lift_schedule(self, sched: Schedule) -> Schedule:
        return Schedule(
            f"{sched.name}@part{self.n_teams}x{self.size}", tuple(
                Stage(self.lift(st.pattern), st.nbytes)
                for st in sched.stages))

    # -- the peer partition ---------------------------------------------------
    def complement(self) -> "TeamPartition":
        """The peer partition: team j = the rank-j member of every team
        (rows' complement is columns).  After an intra-team
        reduce-scatter each peer team's members own the SAME chunk index,
        which is exactly the group the hierarchical cross-step reduces
        over (DESIGN.md §11)."""
        if self._complement is None:
            peers = [make_team([t.members[j] for t in self.teams],
                               self.world_n) for j in range(self.size)]
            self._complement = TeamPartition(peers)
            self._complement._complement = self
        return self._complement


def split_2d(parent: Team, topo: MeshTopology, axis: int = -1
             ) -> TeamPartition:
    """Partition `parent` into the teams that vary only along `axis` of
    `topo` — rows (axis=-1) or columns (axis=0) of a 2D mesh, and the
    generalization for higher-rank meshes (e.g. axis=0 of a
    (pods, 16, 16) topology groups cross-pod replicas).

    `parent` must be closed under the split: every line of the mesh it
    touches must lie entirely inside it (true for the world team).
    Teams are ordered by the row-major rank of their first member, so
    ``split_2d(world, topo, -1).complement()`` is the column partition.
    """
    ndim = len(topo.shape)
    ax = axis % ndim
    if topo.n_pes != parent.world_n:
        raise ValueError(
            f"topology covers {topo.n_pes} PEs, world is {parent.world_n}")
    lines: dict[tuple[int, ...], list[int]] = {}
    order: list[tuple[int, ...]] = []
    for pe in parent.members:
        c = topo.coords(pe)
        key = c[:ax] + c[ax + 1:]
        if key not in lines:
            lines[key] = []
            order.append(key)
        lines[key].append(pe)
    extent = topo.shape[ax]
    for key, mem in lines.items():
        if len(mem) != extent:
            raise ValueError(
                f"parent team is not closed under axis {ax}: line {key} "
                f"has {len(mem)}/{extent} members")
    teams = [make_team(sorted(lines[k], key=lambda p: topo.coords(p)[ax]),
                       parent.world_n) for k in order]
    return TeamPartition(teams)


def cache_size() -> int:
    return len(_INTERN)
