"""Span-based distributed tracer — Chrome trace-event / Perfetto export
(DESIGN.md §16).

PR 5's :class:`~repro.core.profile.Profiler` records per-op samples *for
the autotuner*; this module turns the same stream into something a human
can open: :class:`Tracer` subclasses ``Profiler`` (so every existing
``profile=`` thread-through — ``ShmemContext``, ``Ctx``, ``Comm``,
``build_train_step``, ``ServeEngine`` — accepts one unchanged, and the
disabled hot path stays the one flag test ``pcontrol`` already pays) and
additionally renders:

  * **per-PE tracks** (pid 0, one tid per PE): every collective whose
    executor noted its :class:`~repro.core.pattern.Schedule` gets one
    sub-span per stage on every participating PE's track, placed inside
    the op's measured interval and apportioned by the stage's share of
    the schedule's payload.  Collectives recorded while JAX was staging
    (``traced=True`` — the ``Comm``-inside-``jit`` path) have no
    execution interval of their own, so their stage spans stretch over
    the modeled time (``predicted_s``) instead, anchored at the staging
    timestamp — the trace shows the schedule *structure* XLA compiled,
    flagged ``traced`` in the event args.
  * **cross-PE flow links** (Chrome ``s``/``f`` events): each stage's
    ``(src, dst)`` pairs become flow arrows from the source PE's stage
    span to the destination PE's, with ids interned from the schedule's
    issue sequence — capped at ``flows_per_op`` per op so a 64-PE ring
    does not drown the trace.
  * a **host runtime track** (pid 1): op/span/sync samples as complete
    events (``train_step`` > ``allreduce`` nest by time), ``quiet``
    stall time as a dedicated child span separate from issue time, RMA
    issues and selection decisions as instants.
  * **async request tracks**: ``begin_async``/``instant_async``/
    ``end_async`` emit Chrome async events (the serving engine's
    enqueue -> admit -> prefill -> first token -> decode -> evict
    lifecycle, keyed by request id).
  * a **NoC link heatmap**: every noted schedule with a topology
    accumulates ``stage.nbytes x link multiplicity`` per physical link
    (:meth:`~repro.core.pattern.CommPattern.link_loads`), exported by
    :meth:`Tracer.heatmap` and embedded in the trace document.

Levels extend ``shmem_pcontrol``: 0 off, 1 counters, 2 counters +
timeline + host-track events, >= 3 additionally per-PE stage spans and
flow links (the "full trace").  ``dump_chrome(path)`` writes a JSON
document loadable at ``ui.perfetto.dev`` / ``chrome://tracing``;
``python -m repro.tools.tracereport`` summarizes one in text.
"""
from __future__ import annotations

import contextlib
import json
import time

from .profile import OpSample, Profiler

PID_PE = 0          # the PE-grid process: tid k = PE k
PID_HOST = 1        # the host runtime process: tid 0 = ops track

LEVEL_FULL = 3      # pcontrol level that adds stage spans + flow links


class Tracer(Profiler):
    """A :class:`Profiler` that additionally renders Chrome trace events.

    Drop-in wherever a profiler is accepted (``profile=``): the base
    class records counters/timeline exactly as before and the overridden
    ``_commit`` turns each committed sample into trace events.  All
    direct-event APIs (``span``/``instant``/``begin_async``/...) cost one
    level test when collection is off."""

    def __init__(self, level: int = LEVEL_FULL, max_events: int = 500_000,
                 flows_per_op: int = 64, **kw):
        super().__init__(level=level, **kw)
        self.max_events = int(max_events)
        self.flows_per_op = int(flows_per_op)
        self._events: list[dict] = []
        self.events_dropped = 0
        self._flow_seq = 0
        self._n_pes_seen = 1
        # per-topology accumulated link bytes: {topo: {(u, v): bytes}}
        self._link_bytes: dict = {}
        # extra JSON sections merged into the document's ``repro``
        # metadata (e.g. the roofline summary benchmarks/roofline.py
        # embeds for ``tracereport``); reserved keys are ignored
        self.sections: dict = {}

    # -- low-level event plumbing --------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def _event(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) < self.max_events:
                self._events.append(ev)
            else:
                self.events_dropped += 1

    def reset(self) -> None:
        super().reset()
        with self._lock:
            self._events = []
            self.events_dropped = 0
            self._flow_seq = 0
            self._link_bytes = {}

    # -- direct span / instant / async APIs ----------------------------------
    @contextlib.contextmanager
    def span(self, name: str, nbytes: float = 0.0, n_pes: int = 0, **meta):
        """An arbitrary nested host-track span, timed like any op (it IS
        an op sample of kind "span", so it lands in the timeline and the
        chrome track both).  `meta` becomes the event's args."""
        with self.op(name, nbytes=nbytes, n_pes=n_pes, kind="span") as s:
            if s is not None and meta:
                s.meta = dict(meta)
            yield s

    def instant(self, name: str, pe: int | None = None, **args) -> None:
        """A host-track (or PE-track, with `pe`) instant event."""
        if self.level < 2:
            return
        ev = {"name": name, "ph": "i", "ts": self._now_us(), "s": "t",
              "pid": PID_HOST if pe is None else PID_PE,
              "tid": 0 if pe is None else int(pe)}
        if args:
            ev["args"] = args
        self._event(ev)

    def _async(self, ph: str, cat: str, aid, name: str, args: dict) -> None:
        if self.level < 2:
            return
        ev = {"name": name, "ph": ph, "cat": cat, "id": str(aid),
              "ts": self._now_us(), "pid": PID_HOST, "tid": 0}
        if args:
            ev["args"] = args
        self._event(ev)

    def begin_async(self, cat: str, aid, name: str, **args) -> None:
        """Open an async track span (e.g. a request lifecycle).  The
        matching :meth:`end_async` must use the same (cat, aid, name)."""
        self._async("b", cat, aid, name, args)

    def instant_async(self, cat: str, aid, name: str, **args) -> None:
        """A point event inside an open async span (admit, first token)."""
        self._async("n", cat, aid, name, args)

    def end_async(self, cat: str, aid, name: str, **args) -> None:
        self._async("e", cat, aid, name, args)

    # -- sample -> events -----------------------------------------------------
    def _commit(self, s: OpSample) -> None:
        super()._commit(s)
        if self.level >= 2 and self.enabled:
            self._render(s)

    def record_rma(self, op: str, nbytes: float, pattern=None,
                   n_pes: int = 0) -> None:
        super().record_rma(op, nbytes, pattern, n_pes=n_pes)
        if self.level >= 2:
            ev = {"name": op, "ph": "i", "ts": self._now_us(), "s": "t",
                  "pid": PID_HOST, "tid": 0, "cat": "rma",
                  "args": {"nbytes": float(nbytes)}}
            self._event(ev)

    def _args_of(self, s: OpSample) -> dict:
        args: dict = {"kind": s.kind}
        for k in ("algorithm", "team", "schedule", "embedding"):
            v = getattr(s, k)
            if v:
                args[k] = v
        if s.nbytes:
            args["nbytes"] = s.nbytes
        if s.chunks > 1:
            args["chunks"] = s.chunks
        if s.n_stages:
            args["n_stages"] = s.n_stages
            args["bytes_moved"] = s.bytes_moved
            args["max_link_load"] = s.max_link_load
        if s.predicted_s == s.predicted_s and s.predicted_s != 0.0:
            args["predicted_us"] = s.predicted_s * 1e6
        if s.traced:
            args["traced"] = True
        if s.kind == "sync":
            args["issue_us"] = s.issue_s * 1e6
            args["stall_us"] = s.stall_s * 1e6
        if s.meta:
            args.update(s.meta)
        return args

    def _render(self, s: OpSample) -> None:
        ts = s.t_start * 1e6
        dur = max(s.wall_s, 0.0) * 1e6
        name = s.collective or s.kind
        if s.algorithm and s.kind == "collective":
            name = f"{name}[{s.algorithm}]"
        if s.kind == "selection":
            self._event({"name": name, "ph": "i", "ts": ts, "s": "t",
                         "pid": PID_HOST, "tid": 0, "cat": "selection",
                         "args": self._args_of(s)})
        else:
            self._event({"name": name, "ph": "X", "ts": ts, "dur": dur,
                         "pid": PID_HOST, "tid": 0, "cat": s.kind,
                         "args": self._args_of(s)})
            if s.kind == "sync" and s.stall_s > 0.0:
                # the stall child span: time quiet spent WAITING on the
                # pending-op queue, visibly separate from issue time
                self._event({"name": f"{s.collective}.stall", "ph": "X",
                             "ts": ts + s.issue_s * 1e6,
                             "dur": s.stall_s * 1e6, "pid": PID_HOST,
                             "tid": 0, "cat": "stall"})
        sched = getattr(s, "_sched", None)
        if sched is None:
            return
        topo = getattr(s, "_topo", None)
        if topo is not None:
            self._account_links(sched, topo)
        if self.level >= LEVEL_FULL:
            if dur <= 0.0:
                # a staged (traced) collective has no execution interval;
                # stretch its stage spans over the modeled time instead
                pred = s.predicted_s
                dur = pred * 1e6 if pred == pred and pred > 0.0 \
                    else 1.0 * max(len(sched.stages), 1)
            self._render_stages(s, sched, ts, dur)

    def _account_links(self, sched, topo) -> None:
        with self._lock:
            lb = self._link_bytes.setdefault(topo, {})
            for st in sched.stages:
                for link, mult in st.pattern.link_loads(topo).items():
                    lb[link] = lb.get(link, 0.0) + st.nbytes * mult

    def _render_stages(self, s: OpSample, sched, ts: float,
                       dur: float) -> None:
        stages = sched.stages
        if not stages:
            return
        weights = [st.nbytes + 1.0 for st in stages]
        total = sum(weights)
        cap = self.flows_per_op
        t = ts
        seen_pe = self._n_pes_seen
        costs = s.stage_costs or []
        for k, st in enumerate(stages):
            d = dur * weights[k] / total
            pes = sorted({p for pair in st.pattern.pairs for p in pair})
            if pes:
                seen_pe = max(seen_pe, pes[-1] + 1)
            args = {"nbytes": st.nbytes, "stage": k}
            if k < len(costs) and isinstance(costs[k], dict):
                # stamp the per-stage cost-model attribution onto the
                # span so a viewer (or tracereport --diff) can compare
                # wall vs modeled stage time directly
                args["hops"] = costs[k].get("hops", 0.0)
                args["link_load"] = costs[k].get("load", 0.0)
                pred = costs[k].get("predicted_s")
                if pred is not None:
                    args["predicted_us"] = pred * 1e6
            if s.traced:
                args["traced"] = True
            for pe in pes:
                self._event({"name": f"{sched.name}.s{k}", "ph": "X",
                             "ts": t, "dur": d, "pid": PID_PE, "tid": pe,
                             "cat": "stage", "args": args})
            for src, dst in st.pattern.pairs:
                if cap <= 0 or src == dst:
                    continue
                cap -= 1
                with self._lock:
                    fid = self._flow_seq
                    self._flow_seq += 1
                self._event({"name": "noc", "ph": "s", "id": fid,
                             "ts": t + 0.6 * d, "pid": PID_PE, "tid": src,
                             "cat": "flow"})
                self._event({"name": "noc", "ph": "f", "bp": "e",
                             "id": fid, "ts": t + 0.9 * d, "pid": PID_PE,
                             "tid": dst, "cat": "flow"})
            t += d
        self._n_pes_seen = seen_pe

    # -- NoC heatmap export ---------------------------------------------------
    def heatmap(self) -> list[dict]:
        """Accumulated per-physical-link wire bytes, one entry per
        topology seen, links sorted hottest-first — the NoC heatmap
        (built on ``CommPattern.link_loads``; rendered as an ASCII grid
        by ``repro.tools.tracereport``)."""
        with self._lock:
            items = [(topo, dict(lb)) for topo, lb in
                     self._link_bytes.items()]
        out = []
        for topo, lb in items:
            links = [{"a": int(u), "b": int(v), "bytes": float(b),
                      "coord_a": list(topo.coords(u)),
                      "coord_b": list(topo.coords(v))}
                     for (u, v), b in sorted(lb.items(),
                                             key=lambda kv: -kv[1])]
            out.append({"shape": list(topo.shape),
                        "n_links": len(links),
                        "max_bytes": links[0]["bytes"] if links else 0.0,
                        "total_bytes": float(sum(lk["bytes"]
                                                 for lk in links)),
                        "links": links})
        return out

    # -- chrome export --------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON-object document: ``traceEvents``
        plus a ``repro`` metadata section (counters, heatmap, schema) the
        viewers ignore and ``tracereport`` reads."""
        meta = [
            {"name": "process_name", "ph": "M", "pid": PID_PE,
             "args": {"name": "PE mesh"}},
            {"name": "process_name", "ph": "M", "pid": PID_HOST,
             "args": {"name": "runtime"}},
            {"name": "thread_name", "ph": "M", "pid": PID_HOST, "tid": 0,
             "args": {"name": "ops"}},
        ]
        for pe in range(self._n_pes_seen):
            meta.append({"name": "thread_name", "ph": "M", "pid": PID_PE,
                         "tid": pe, "args": {"name": f"PE {pe}"}})
        with self._lock:
            events = list(self._events)
        rep = {
            "schema": 1,
            "level": self.level,
            "events_dropped": self.events_dropped,
            "sink_errors": self.sink_errors,
            "counters": self.counters(),
            "heatmap": self.heatmap(),
        }
        for k, v in self.sections.items():
            rep.setdefault(k, v)        # user sections never shadow core
        return {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "repro": rep,
        }

    def dump_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
