"""2D/3D processing-element topology, after the Epiphany eMesh.

The paper's collectives are hop-count aware: the farthest-first broadcast
tree explicitly moves data the greatest mesh distance first so later stages
do not add congestion (paper §3.6).  On TPU the ICI torus plays the NoC
role; this module provides the PE <-> coordinate maps and hop metrics the
algorithms and the alpha-beta cost model use.

Unlike eLib's 2D row/column indexing (which the paper criticizes for not
addressing "arbitrary numbers of working cores or disabled cores"), PEs
here are a dense 0..N-1 rank space with an explicit active-set mapping, so
subsets and non-power-of-two groups are first-class.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A d-dimensional mesh/torus of PEs.

    shape  : extent per dimension, e.g. (4, 4) for Epiphany-III,
             (16, 16) for one v5e pod, (2, 16, 16) for two pods.
    torus  : whether each dimension wraps (ICI axes do; the Epiphany
             eMesh does not).
    link_cost : relative per-hop cost multiplier per dimension (the "pod"
             axis rides DCN, ~10x an ICI hop).
    """

    shape: tuple[int, ...]
    torus: tuple[bool, ...] | None = None
    link_cost: tuple[float, ...] | None = None

    def __post_init__(self):
        if not self.shape or any(int(e) < 1 for e in self.shape):
            raise ValueError(
                f"mesh shape needs >=1 dimension with every extent >= 1, "
                f"got shape={self.shape!r}")
        if self.torus is not None and len(self.torus) != len(self.shape):
            raise ValueError(
                f"torus must name every dimension of shape: "
                f"len(torus)={len(self.torus)} vs len(shape)="
                f"{len(self.shape)} (a short tuple would silently "
                f"mis-price hops via zip truncation)")
        if (self.link_cost is not None
                and len(self.link_cost) != len(self.shape)):
            raise ValueError(
                f"link_cost must name every dimension of shape: "
                f"len(link_cost)={len(self.link_cost)} vs len(shape)="
                f"{len(self.shape)} (a short tuple would silently "
                f"mis-price hops via zip truncation)")

    @property
    def n_pes(self) -> int:
        return math.prod(self.shape)

    def _torus(self) -> tuple[bool, ...]:
        return self.torus if self.torus is not None else tuple(True for _ in self.shape)

    def _cost(self) -> tuple[float, ...]:
        return self.link_cost if self.link_cost is not None else tuple(1.0 for _ in self.shape)

    def coords(self, pe: int) -> tuple[int, ...]:
        """Row-major rank -> coordinate (last dim fastest)."""
        out = []
        for extent in reversed(self.shape):
            out.append(pe % extent)
            pe //= extent
        return tuple(reversed(out))

    def rank(self, coords: Sequence[int]) -> int:
        pe = 0
        for c, extent in zip(coords, self.shape):
            pe = pe * extent + (c % extent)
        return pe

    def hops(self, a: int, b: int) -> float:
        """Weighted hop distance between two PEs (X-then-Y dimension-ordered
        routing, like the eMesh)."""
        ca, cb = self.coords(a), self.coords(b)
        total = 0.0
        for x, y, extent, wrap, cost in zip(ca, cb, self.shape, self._torus(), self._cost()):
            d = abs(x - y)
            if wrap:
                d = min(d, extent - d)
            total += d * cost
        return total

    def max_hops(self) -> float:
        total = 0.0
        for extent, wrap, cost in zip(self.shape, self._torus(), self._cost()):
            d = extent - 1
            if wrap:
                d = extent // 2
            total += d * cost
        return total

    def farthest_first(self, root: int, pes: Sequence[int]) -> list[int]:
        """Order `pes` by decreasing hop distance from `root` (paper §3.6:
        'moving the data the farthest distance first')."""
        return sorted(pes, key=lambda p: (-self.hops(root, p), p))

    # -- XY routing (the eMesh's dimension-ordered wormhole path) ------------
    def route(self, a: int, b: int) -> tuple[tuple[int, int], ...]:
        """The directed link sequence a packet from `a` to `b` traverses
        under dimension-ordered routing: the LAST dimension is corrected
        first (the eMesh routes east/west along the row to the target
        column, then north/south — 'X then Y'), each dimension taking the
        shorter way around when it wraps (ties break toward +).  Every
        element is a (pe, neighbor_pe) hop; ``sum(link_weight(u, v))``
        over the route equals ``hops(a, b)``.  Cached per (topo, a, b)."""
        return _route(self, int(a) % self.n_pes, int(b) % self.n_pes)

    def route_alt(self, a: int, b: int) -> tuple[tuple[int, int], ...]:
        """The ALTERNATE dimension-ordered route: the FIRST dimension is
        corrected first ('Y then X' on a 2D mesh) — the other member of
        the minimal XY/YX route pair.  Same hop count as :meth:`route`
        but (off the source row/column) link-disjoint from it, which is
        what the fault layer retries over when a link on the primary
        route is down (DESIGN.md §17).  Cached per (topo, a, b)."""
        return _route_alt(self, int(a) % self.n_pes, int(b) % self.n_pes)

    def link_weight(self, u: int, v: int) -> float:
        """Per-hop cost of the (u, v) mesh link — the ``link_cost`` of the
        one dimension in which neighbors u and v differ."""
        cu, cv = self.coords(u), self.coords(v)
        for dim, (x, y) in enumerate(zip(cu, cv)):
            if x != y:
                return self._cost()[dim]
        return self._cost()[-1]      # self-link (degenerate 1-PE dims)

    # -- Hamiltonian embeddings (mesh-embedded rings) ------------------------
    def snake_order(self) -> tuple[int, ...]:
        """A Hamiltonian ordering of the PEs in which consecutive PEs are
        mesh NEIGHBORS — the embedding that turns every logical-ring hop
        into one physical hop (the boustrophedon 'snake').

        Where a Hamiltonian *cycle* exists (2D with an even extent, or a
        wrapping dimension that closes the path) the order is a cycle:
        the wrap edge ``order[-1] -> order[0]`` is also a single hop, so
        an offset-1 ring over the order touches every physical link at
        most once (``max_link_load == 1``).  On odd-by-odd non-torus
        meshes no cycle exists (bipartite, odd vertex count) and the
        boustrophedon path is returned — all interior edges one hop, only
        the wrap edge longer.  Candidates are scored by the ring's actual
        link loads under :meth:`route`, so the least-congested embedding
        wins."""
        return _snake(self)


@functools.lru_cache(maxsize=1 << 16)
def _route(topo: MeshTopology, a: int, b: int) -> tuple[tuple[int, int], ...]:
    return _dim_ordered(topo, a, b, reversed(range(len(topo.shape))))


@functools.lru_cache(maxsize=1 << 16)
def _route_alt(topo: MeshTopology, a: int, b: int
               ) -> tuple[tuple[int, int], ...]:
    return _dim_ordered(topo, a, b, range(len(topo.shape)))


def _dim_ordered(topo: MeshTopology, a: int, b: int, dims
                 ) -> tuple[tuple[int, int], ...]:
    ca = list(topo.coords(a))
    cb = topo.coords(b)
    links: list[tuple[int, int]] = []
    for dim in dims:
        extent = topo.shape[dim]
        delta = cb[dim] - ca[dim]
        if topo._torus()[dim]:
            fwd = delta % extent
            back = (-delta) % extent
            step, count = (1, fwd) if fwd <= back else (-1, back)
        else:
            step, count = (1 if delta > 0 else -1), abs(delta)
        for _ in range(count):
            nxt = list(ca)
            nxt[dim] = (ca[dim] + step) % extent
            links.append((topo.rank(ca), topo.rank(nxt)))
            ca = nxt
    return tuple(links)


def _boustrophedon(shape: tuple[int, ...]) -> list[tuple[int, ...]]:
    """Recursive snake: dimension 0 indexes copies of the inner snake,
    alternating direction so consecutive coordinates stay adjacent."""
    if len(shape) == 1:
        return [(i,) for i in range(shape[0])]
    inner = _boustrophedon(shape[1:])
    out: list[tuple[int, ...]] = []
    for i in range(shape[0]):
        seq = inner if i % 2 == 0 else inner[::-1]
        out.extend((i,) + c for c in seq)
    return out


def _spine_cycle(R: int, C: int) -> list[tuple[int, int]] | None:
    """The classic grid Hamiltonian cycle (R even, R,C >= 2): east along
    row 0, boustrophedon over rows 1..R-1 restricted to cols 1..C-1, then
    north up the col-0 'spine' back to the start."""
    if R < 2 or C < 2 or R % 2:
        return None
    order = [(0, c) for c in range(C)]
    for i, r in enumerate(range(1, R)):
        cols = range(C - 1, 0, -1) if i % 2 == 0 else range(1, C)
        order.extend((r, c) for c in cols)
    order.extend((r, 0) for r in range(R - 1, 0, -1))
    return order


@functools.lru_cache(maxsize=256)
def _snake(topo: MeshTopology) -> tuple[int, ...]:
    candidates: list[list[tuple[int, ...]]] = [_boustrophedon(topo.shape)]
    if len(topo.shape) == 2:
        R, C = topo.shape
        cyc = _spine_cycle(R, C)
        if cyc is not None:
            candidates.append(cyc)
        cyc_t = _spine_cycle(C, R)
        if cyc_t is not None:
            candidates.append([(r, c) for c, r in cyc_t])

    def score(order: list[tuple[int, ...]]):
        pes = [topo.rank(c) for c in order]
        loads: dict[tuple[int, int], float] = {}
        worst_edge = 0.0
        for i, pe in enumerate(pes):
            dst = pes[(i + 1) % len(pes)]
            if dst == pe:
                continue
            worst_edge = max(worst_edge, topo.hops(pe, dst))
            for u, v in topo.route(pe, dst):
                key = (u, v) if u < v else (v, u)
                loads[key] = loads.get(key, 0.0) + 1.0   # flow multiplicity
        return (max(loads.values()) if loads else 0.0, worst_edge)

    best = min(candidates, key=score)
    return tuple(topo.rank(c) for c in best)


def epiphany3() -> MeshTopology:
    """The paper's chip: 4x4 mesh, no wraparound."""
    return MeshTopology(shape=(4, 4), torus=(False, False))


def v5e_pod() -> MeshTopology:
    """One 256-chip pod: 16x16 ICI torus."""
    return MeshTopology(shape=(16, 16))


def v5e_multipod(pods: int = 2) -> MeshTopology:
    """`pods` pods linked over DCN: DCN hop ~10x an ICI hop."""
    return MeshTopology(
        shape=(pods, 16, 16),
        torus=(False, True, True),
        link_cost=(10.0, 1.0, 1.0),
    )
