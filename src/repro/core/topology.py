"""2D/3D processing-element topology, after the Epiphany eMesh.

The paper's collectives are hop-count aware: the farthest-first broadcast
tree explicitly moves data the greatest mesh distance first so later stages
do not add congestion (paper §3.6).  On TPU the ICI torus plays the NoC
role; this module provides the PE <-> coordinate maps and hop metrics the
algorithms and the alpha-beta cost model use.

Unlike eLib's 2D row/column indexing (which the paper criticizes for not
addressing "arbitrary numbers of working cores or disabled cores"), PEs
here are a dense 0..N-1 rank space with an explicit active-set mapping, so
subsets and non-power-of-two groups are first-class.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A d-dimensional mesh/torus of PEs.

    shape  : extent per dimension, e.g. (4, 4) for Epiphany-III,
             (16, 16) for one v5e pod, (2, 16, 16) for two pods.
    torus  : whether each dimension wraps (ICI axes do; the Epiphany
             eMesh does not).
    link_cost : relative per-hop cost multiplier per dimension (the "pod"
             axis rides DCN, ~10x an ICI hop).
    """

    shape: tuple[int, ...]
    torus: tuple[bool, ...] | None = None
    link_cost: tuple[float, ...] | None = None

    @property
    def n_pes(self) -> int:
        return math.prod(self.shape)

    def _torus(self) -> tuple[bool, ...]:
        return self.torus if self.torus is not None else tuple(True for _ in self.shape)

    def _cost(self) -> tuple[float, ...]:
        return self.link_cost if self.link_cost is not None else tuple(1.0 for _ in self.shape)

    def coords(self, pe: int) -> tuple[int, ...]:
        """Row-major rank -> coordinate (last dim fastest)."""
        out = []
        for extent in reversed(self.shape):
            out.append(pe % extent)
            pe //= extent
        return tuple(reversed(out))

    def rank(self, coords: Sequence[int]) -> int:
        pe = 0
        for c, extent in zip(coords, self.shape):
            pe = pe * extent + (c % extent)
        return pe

    def hops(self, a: int, b: int) -> float:
        """Weighted hop distance between two PEs (X-then-Y dimension-ordered
        routing, like the eMesh)."""
        ca, cb = self.coords(a), self.coords(b)
        total = 0.0
        for x, y, extent, wrap, cost in zip(ca, cb, self.shape, self._torus(), self._cost()):
            d = abs(x - y)
            if wrap:
                d = min(d, extent - d)
            total += d * cost
        return total

    def max_hops(self) -> float:
        total = 0.0
        for extent, wrap, cost in zip(self.shape, self._torus(), self._cost()):
            d = extent - 1
            if wrap:
                d = extent // 2
            total += d * cost
        return total

    def farthest_first(self, root: int, pes: Sequence[int]) -> list[int]:
        """Order `pes` by decreasing hop distance from `root` (paper §3.6:
        'moving the data the farthest distance first')."""
        return sorted(pes, key=lambda p: (-self.hops(root, p), p))


def epiphany3() -> MeshTopology:
    """The paper's chip: 4x4 mesh, no wraparound."""
    return MeshTopology(shape=(4, 4), torus=(False, False))


def v5e_pod() -> MeshTopology:
    """One 256-chip pod: 16x16 ICI torus."""
    return MeshTopology(shape=(16, 16))


def v5e_multipod(pods: int = 2) -> MeshTopology:
    """`pods` pods linked over DCN: DCN hop ~10x an ICI hop."""
    return MeshTopology(
        shape=(pods, 16, 16),
        torus=(False, True, True),
        link_cost=(10.0, 1.0, 1.0),
    )
