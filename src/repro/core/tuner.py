"""Measured-performance autotuner — closes the selection loop (DESIGN §13).

PRs 1-4 select algorithms, chunk counts and embeddings purely from the
analytic :class:`~repro.core.abmodel.LinkModel`; the companion Epiphany
studies (arXiv:1604.04205, 1410.8772) show measured bandwidth/latency
diverging from such models once contention and runtime overheads enter.
This module keeps a persistent database of MEASURED collective times and
lets measurements override the model:

  * :class:`TuningDB` — JSON-on-disk store keyed by topology fingerprint
    x collective x team shape x payload-size bucket (power of two); each
    point holds per-variant ``(algorithm, chunks, embedding)`` running
    means.  ``best()`` is the measured argmin.
  * :class:`Tuner` — fills the DB: ``tune(ctx, grid)`` runs an offline
    calibration sweep (every candidate variant measured with
    ``profile.measure``, ALWAYS including the analytic selector's own
    pick, so the tuned choice can never be measured-worse than the
    analytic one on covered points); ``observe(sample)`` refines online
    from profiler samples (attach via ``Profiler.add_sink``); and
    ``refit_link`` recovers the LinkModel's alpha/beta (``abmodel.fit``)
    and contention (``fit_contention``) from single-stage measurements —
    the fitted model becomes the analytic PRIOR for unmeasured points.
  * :class:`TunedSelector` — what ``choose_algorithm`` /
    ``choose_schedule`` / ``choose_chunks`` / ``choose_embedding``
    consult FIRST (the ``tuner=`` parameter threaded from
    ``ShmemContext`` / ``Comm`` / ``build_train_step``); a miss falls
    back to the analytic model.  Lookups are restricted to the caller's
    candidate set, so a knob change (say, embeddings disabled) degrades
    to the best measured candidate that is still legal.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Sequence

from . import abmodel
from .profile import OpSample, Profiler, _emb_str, measure

# Payload-size buckets are powers of two: measurements at 6000 B and
# 8000 B land in the same 8192 B bucket — message-size sensitivity below
# a factor of sqrt(2) is noise on real substrates.
def nbytes_bucket(nbytes: float) -> int:
    if nbytes <= 1:
        return 1
    return 1 << int(round(math.log2(float(nbytes))))


def fingerprint(topo, n_pes: int, dead_pes=()) -> str:
    """Topology identity the DB keys on.  Deliberately EXCLUDES the
    backend class: a DB calibrated on the SIM oracle for a given mesh is
    the prior the SPMD run on the same mesh inherits (the warm-then-
    train flow); the DB file itself is per-machine.

    `dead_pes` marks a DEGRADED mesh (DESIGN.md §17): a 4x4 mesh with
    PE 5 dead is a different machine than the full mesh — its snake
    embedding detours, its link loads shift — so measurements under the
    two keys never blend, and the elastic restart path re-tunes under
    the degraded key instead of replaying full-mesh winners."""
    dead = ",".join(str(int(p)) for p in sorted(set(dead_pes)))
    suffix = f":dead{dead}" if dead else ""
    if topo is None or getattr(topo, "n_pes", None) != n_pes:
        return f"flat:n{n_pes}{suffix}"
    t = "".join("1" if w else "0" for w in topo._torus())
    c = ",".join(f"{x:g}" for x in topo._cost())
    return f"mesh{'x'.join(map(str, topo.shape))}:t{t}:c{c}{suffix}"


def variant_key(algorithm: str, chunks: int, embedding=None) -> str:
    return f"{algorithm}|c{int(chunks)}|{_emb_str(embedding)}"


def split_variant(vkey: str) -> tuple[str, int, str]:
    algo, c, emb = vkey.split("|", 2)
    return algo, int(c[1:]), emb


# Online refinement keeps a running mean with the effective sample count
# capped, so a drifting substrate (thermal throttling, a busy host) can
# move the mean instead of being averaged away.
MEAN_CAP = 32


class TuningDB:
    """Persistent measured-performance store (JSON round-trip).

    ``entries[key]["variants"][vkey] = {"mean_s", "n", "predicted_s",
    "live_mean_s", "live_n"}`` with ``key = fp|collective|team|bucket``;
    ``links[fp]`` holds a refitted LinkModel's constants.

    Two measurement methodologies land here and must not blend:
    CALIBRATED times (``source="cal"``, the sweep's jitted steady-state
    timer) and LIVE times (``source="live"``, online refinement from
    eager execution samples, which include per-call dispatch overhead
    and run ~orders of magnitude slower).  Each variant keeps both
    running means; at any grid point ``best()`` compares calibrated
    means when any variant has calibrated data, and falls back to live
    means only on points the sweep never covered — so online samples
    refine uncovered points without corrupting calibrated picks."""

    def __init__(self):
        self.entries: dict[str, dict] = {}
        self.links: dict[str, dict] = {}

    @staticmethod
    def key(fp: str, collective: str, team: str, nbytes: float) -> str:
        return f"{fp}|{collective}|{team}|{nbytes_bucket(nbytes)}"

    def record(self, fp: str, collective: str, team: str, nbytes: float,
               algorithm: str, chunks: int, embedding=None,
               measured_s: float = 0.0, predicted_s=None,
               source: str = "cal") -> None:
        if not algorithm or measured_s <= 0.0:
            return
        k = self.key(fp, collective, team, nbytes)
        e = self.entries.setdefault(k, {"variants": {}})
        vk = variant_key(algorithm, chunks, embedding)
        v = e["variants"].setdefault(
            vk, {"mean_s": 0.0, "n": 0, "predicted_s": None,
                 "live_mean_s": 0.0, "live_n": 0})
        v.setdefault("live_mean_s", 0.0)     # older DB files on disk
        v.setdefault("live_n", 0)
        mean_k, n_k = ("mean_s", "n") if source == "cal" \
            else ("live_mean_s", "live_n")
        n = min(v[n_k] + 1, MEAN_CAP)
        v[mean_k] += (measured_s - v[mean_k]) / n
        v[n_k] = v[n_k] + 1
        # NaN-free on disk: json.dump would emit an invalid literal
        if predicted_s is not None and predicted_s == predicted_s:
            v["predicted_s"] = float(predicted_s)

    def variants(self, fp: str, collective: str, team: str,
                 nbytes: float) -> dict[str, dict] | None:
        e = self.entries.get(self.key(fp, collective, team, nbytes))
        return None if e is None else e["variants"]

    def best(self, fp: str, collective: str, team: str, nbytes: float,
             algos: Sequence[str] | None = None,
             max_chunks: int | None = None,
             widen: int = 0) -> tuple[str, int, str, float] | None:
        """Measured argmin ``(algorithm, chunks, embedding, mean_s)``
        among the variants matching the caller's constraints, or None
        (unmeasured point -> the caller falls back to the analytic
        model).  Calibrated means take precedence per grid point (see
        the class docstring); ``widen`` > 0 also searches +-widen
        neighboring size buckets (nearest first) when the exact bucket
        is empty."""
        b = nbytes_bucket(nbytes)
        buckets = [b]
        for i in range(1, widen + 1):
            buckets += [b << i, max(b >> i, 1)]
        for bk in buckets:
            e = self.entries.get(f"{fp}|{collective}|{team}|{bk}")
            if e is None:
                continue
            cal, live = [], []
            for vk, v in e["variants"].items():
                algo, chunks, emb = split_variant(vk)
                if algos is not None and algo not in algos:
                    continue
                if max_chunks is not None and chunks > max_chunks:
                    continue
                if v["n"] > 0:
                    cal.append((v["mean_s"], algo, chunks, emb))
                elif v.get("live_n", 0) > 0:
                    live.append((v["live_mean_s"], algo, chunks, emb))
            cands = cal or live
            if cands:
                t, algo, chunks, emb = min(cands)
                return algo, chunks, emb, t
        return None

    # -- refitted link models -------------------------------------------------
    def set_link(self, fp: str, link: abmodel.LinkModel) -> None:
        self.links[fp] = {"alpha_s": link.alpha_s, "hop_s": link.hop_s,
                          "bw_Bps": link.bw_Bps,
                          "contention": link.contention}

    def link_model(self, fp: str) -> abmodel.LinkModel | None:
        got = self.links.get(fp)
        return None if got is None else abmodel.LinkModel(**got)

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> dict:
        return {"schema": 1, "entries": self.entries, "links": self.links}

    @classmethod
    def from_json(cls, doc: dict) -> "TuningDB":
        db = cls()
        db.entries = dict(doc.get("entries", {}))
        db.links = dict(doc.get("links", {}))
        return db

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path) -> "TuningDB":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def __len__(self) -> int:
        return len(self.entries)


class TunedSelector:
    """The measured-first selection surface ``choose_*`` consult before
    pricing anything with the analytic model (DESIGN.md §13 precedence:
    measured best -> refitted model -> prior constants)."""

    def __init__(self, db: TuningDB, team: str | None = None,
                 fingerprint: str | None = None):
        self.db = db
        self._team = team
        # Explicit fingerprint override (DESIGN.md §17): the elastic path
        # pins the degraded-mesh key so lookups stop resolving against
        # full-mesh measurements.  None = derive from (topo, n) per call.
        self._fp = fingerprint

    def with_fingerprint(self, fp: str) -> "TunedSelector":
        """A copy of this selector keyed to `fp` — what
        ``ShmemContext.refingerprint`` swaps in after mesh degradation."""
        return TunedSelector(self.db, team=self._team, fingerprint=fp)

    def _t(self, n: int, team: str | None = None) -> str:
        return team or self._team or f"n{n}"

    def _fp_of(self, topo, n: int) -> str:
        return self._fp if self._fp is not None else fingerprint(topo, n)

    def algorithm(self, collective: str, n: int, nbytes: float, topo=None,
                  candidates: Sequence[str] | None = None,
                  team: str | None = None) -> str | None:
        got = self.db.best(self._fp_of(topo, n), collective,
                           self._t(n, team), nbytes, algos=candidates)
        return None if got is None else got[0]

    def schedule(self, collective: str, n: int, nbytes: float, topo=None,
                 algos: Sequence[str] | None = None,
                 max_chunks: int | None = None,
                 team: str | None = None) -> tuple[str, int] | None:
        got = self.db.best(self._fp_of(topo, n), collective,
                           self._t(n, team), nbytes, algos=algos,
                           max_chunks=max_chunks)
        return None if got is None else (got[0], got[1])

    def chunks(self, collective: str, algorithm: str, n: int, nbytes: float,
               topo=None, max_chunks: int | None = None,
               team: str | None = None) -> int | None:
        """Measured-best chunk count FOR the already-chosen algorithm —
        a best variant under a different algorithm says nothing about
        this one's pipelining, so it is a miss."""
        got = self.db.best(self._fp_of(topo, n), collective,
                           self._t(n, team), nbytes, algos=[algorithm],
                           max_chunks=max_chunks)
        return None if got is None else got[1]

    def embedding(self, n: int, nbytes: float, topo=None,
                  collective: str = "allreduce",
                  team: str | None = None):
        """"identity" when the measured best runs un-embedded, the
        winning order/"snake" when it runs embedded, None on a miss.
        Searches +-2 neighboring size buckets: embedding selection keys
        on a representative payload (``EMBED_REF_BYTES``) that a sweep
        grid need not contain exactly."""
        got = self.db.best(self._fp_of(topo, n), collective,
                           self._t(n, team), nbytes, widen=2)
        if got is None:
            return None
        algo, _, emb, _ = got
        if algo != "ring_emb":
            return "identity"
        if emb in ("", "snake"):
            return "snake"
        if emb.startswith("perm:"):
            return tuple(int(p) for p in emb[5:].split(","))
        return "identity"


# Default offline-calibration grid: small enough for CI smoke, wide
# enough to cover the rd/ring/ring_emb cross-overs on a 16-PE mesh.
DEFAULT_GRID: dict[str, Any] = {
    "collectives": ("allreduce", "fcollect"),
    "sizes": (256, 4096, 65536),
    "chunks": (1, 4),
    "iters": 5,
    "warmup": 2,
}


class Tuner:
    """Owns a :class:`TuningDB` plus the loops that fill it.

    ``link`` is the prior :class:`~repro.core.abmodel.LinkModel`
    (defaults to ``abmodel.ICI_V5E``); after ``refit_link`` the DB holds
    the substrate's own fitted constants and :meth:`link_model` returns
    them."""

    def __init__(self, db: TuningDB | None = None, path=None,
                 link: abmodel.LinkModel | None = None):
        self.path = path
        if db is None and path is not None and os.path.exists(path):
            db = TuningDB.load(path)
        self.db = db if db is not None else TuningDB()
        self.link = link if link is not None else abmodel.ICI_V5E

    def selector(self) -> TunedSelector:
        return TunedSelector(self.db)

    def save(self, path=None) -> None:
        target = path or self.path
        if target is None:
            raise ValueError("no path: pass save(path=...) or construct "
                             "Tuner(path=...)")
        self.db.save(target)

    def link_model(self, topo, n_pes: int) -> abmodel.LinkModel:
        """The refitted LinkModel for this topology when one has been
        calibrated, else the prior."""
        got = self.db.link_model(fingerprint(topo, n_pes))
        return got if got is not None else self.link

    # -- online refinement (profiler sink) -----------------------------------
    def observe(self, sample: OpSample) -> None:
        """Refine the DB from one profiler sample — recorded as a LIVE
        measurement (eager dispatch-inclusive timing; the DB keeps it
        separate from calibrated sweep means, see :class:`TuningDB`).
        Skipped: traced samples (their wall time is staging time),
        "measure"-kind samples (``tune`` records those itself as
        calibrated — observing them too would double-count), and samples
        with no resolved algorithm or no fingerprint (attach the
        profiler through ``ShmemContext(profile=..., tuner=...)`` so ops
        carry one)."""
        if (sample.traced or sample.wall_s <= 0.0 or not sample.algorithm
                or sample.kind != "collective"
                or not getattr(sample, "fingerprint", "")
                or not sample.n_pes):
            return
        emb = sample.embedding or None
        self.db.record(sample.fingerprint, sample.collective, sample.team,
                       sample.nbytes, sample.algorithm, sample.chunks,
                       emb, sample.wall_s, sample.predicted_s,
                       source="live")

    # -- offline calibration --------------------------------------------------
    def _variants(self, collective: str, n: int, nbytes: float, topo, link,
                  chunk_grid: Sequence[int]):
        """The candidate (algorithm, chunks, embedding) variants for one
        grid point — every legal algorithm x the chunk grid, PLUS the
        analytic selector's own (algorithm, chunks) pick, so the sweep
        always covers what the model would have run."""
        from . import collectives as coll
        algos = ["ring"] + (["rd"] if n & (n - 1) == 0 else [])
        emb_order = None
        if topo is not None and getattr(topo, "n_pes", None) == n:
            snake = topo.snake_order()
            if snake != tuple(range(n)):
                emb_order = snake
                algos.append("ring_emb")
        out = []
        for algo in algos:
            for c in chunk_grid:
                out.append((algo, int(c),
                            emb_order if algo == "ring_emb" else None))
        if collective in coll._SELECTABLE:
            a, c = coll.choose_schedule(n, nbytes, topo, link,
                                        collective=collective)
            pick = (a, c, emb_order if a == "ring_emb" else None)
            if pick not in out:
                out.append(pick)
        return out

    def tune(self, ctx, grid: dict | None = None) -> dict:
        """Offline calibration sweep on a :class:`ShmemContext` (the SIM
        backend is the intended substrate — eager, single-process,
        deterministic).  Measures every variant of every
        (collective, size) grid point with the shared jit+warmup timer,
        records the results, refits the link model, and returns a
        summary ``{points, variants, best}``."""
        import jax.numpy as jnp
        import numpy as np
        from . import collectives as coll
        from .netops import SimNetOps

        if not isinstance(ctx.net, SimNetOps):
            raise ValueError("tune() calibrates on the SIM backend "
                             "(sim_ctx); SPMD runs inherit the DB by "
                             "topology fingerprint")
        g = dict(DEFAULT_GRID)
        g.update(grid or {})
        n = ctx.n_pes
        topo = ctx.topo
        link = self.link_model(topo, n)
        fp = fingerprint(topo, n)
        team = f"n{n}"
        prof: Profiler | None = getattr(ctx, "profile", None)

        def payload(nbytes: float):
            w = max(1, int(nbytes) // 4)
            return jnp.asarray(np.random.RandomState(0)
                               .randn(n, w).astype(np.float32))

        runners = {
            "allreduce": lambda v, algo, c, emb: coll.allreduce(
                ctx.net, v, "sum", algorithm=algo, pipeline_chunks=c,
                topo=topo, link=link, embedding=emb),
            "fcollect": lambda v, algo, c, emb: coll.fcollect(
                ctx.net, v, algorithm=algo, pipeline_chunks=c,
                topo=topo, link=link, embedding=emb),
        }
        points = variants = 0
        best: dict[str, str] = {}
        for collective in g["collectives"]:
            run = runners[collective]
            build = coll._SELECTABLE[collective]
            for nbytes in g["sizes"]:
                x = payload(nbytes)
                for algo, c, emb in self._variants(collective, n, nbytes,
                                                   topo, link, g["chunks"]):
                    sched = build(n, nbytes, algorithm=algo,
                                  embedding=emb if algo == "ring_emb"
                                  else None)
                    pred = sched.pipelined_time(c, topo, link)
                    t = measure(
                        lambda v, _a=algo, _c=c, _e=emb: run(v, _a, _c, _e),
                        x, warmup=g["warmup"], iters=g["iters"],
                        profile=prof, collective=collective,
                        nbytes=float(nbytes), n_pes=n, team=team,
                        algorithm=algo, chunks=c, embedding=emb,
                        schedule=sched.name, predicted_s=pred,
                        fingerprint=fp)
                    self.db.record(fp, collective, team, nbytes, algo, c,
                                   emb, t, pred)
                    variants += 1
                points += 1
                got = self.db.best(fp, collective, team, nbytes)
                best[f"{collective}@{nbytes_bucket(nbytes)}B"] = \
                    variant_key(got[0], got[1], got[2] or None)
        self.refit_link(ctx, sizes=tuple(g["sizes"]))
        if self.path is not None:
            self.save()
        return {"fingerprint": fp, "points": points, "variants": variants,
                "best": best}

    def refit_link(self, ctx, sizes: Sequence[float] = (256, 4096, 65536)
                   ) -> abmodel.LinkModel:
        """Recover the substrate's own LinkModel from single-stage
        measurements — the generalization of the paper's Fig. 3
        methodology (``abmodel.fit``) plus the congestion calibration
        (``fit_contention``), stored per topology fingerprint so tuned
        AND analytic pricing both use measured constants."""
        import jax.numpy as jnp
        import numpy as np
        from .pattern import ring_pattern

        n = ctx.n_pes
        topo = ctx.topo
        ring = ring_pattern(n)
        sizes = sorted({max(4, int(s)) for s in sizes})
        if len(sizes) < 2:
            sizes = sorted({sizes[0], sizes[0] * 16})
        times = []
        for s in sizes:
            x = jnp.asarray(np.random.RandomState(1)
                            .randn(n, max(1, s // 4)).astype(np.float32))
            times.append(measure(lambda v: ctx.net.ppermute(v, ring), x))
        ab = abmodel.fit(sizes, times)
        prior = self.link_model(topo, n)
        contention = prior.contention
        if topo is not None and getattr(topo, "n_pes", None) == n:
            # the SAME payload through patterns of different hot-link
            # multiplicity: the snake-embedded ring is the load<=1
            # baseline where one exists, the logical ring and a
            # column-funnel offset supply loaded points
            s = sizes[-1]
            x = jnp.asarray(np.random.RandomState(2)
                            .randn(n, max(1, s // 4)).astype(np.float32))
            pats = [ring, ring_pattern(n, n // 2 or 1)]
            snake = topo.snake_order()
            if snake != tuple(range(n)):
                pats.append(ring.relabel(snake, n))
            loads, tms = [], []
            for p in pats:
                loads.append(p.max_link_load(topo))
                tms.append(measure(lambda v, _p=p: ctx.net.ppermute(v, _p),
                                   x))
            try:
                contention = abmodel.fit_contention(loads, tms)
            except ValueError:
                pass                      # no load<=1 baseline on this mesh
        fitted = abmodel.LinkModel(
            alpha_s=max(ab.alpha, 1e-9), hop_s=prior.hop_s,
            bw_Bps=max(ab.inv_beta, 1.0), contention=contention)
        self.db.set_link(fingerprint(topo, n), fitted)
        return fitted
