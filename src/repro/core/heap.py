"""Symmetric heap: the paper's §3.2 brk/sbrk bump allocator, plus pytree
packing (the framework's gradient-bucket fusion is built on it).

Rules enforced exactly as in the paper:
  1. free() must be called in reverse order of allocation when followed by
     further allocations (we check and raise);
  2. realloc() only on the most recent (re)allocation;
  3. alignment must be a power of two >= 8 (default 8).

There is no virtual-address abstraction: an allocation *is* an offset into
one flat symmetric buffer, identical on every PE.  On TPU the flat buffer
is what lets many small gradient reductions fuse into one large one,
amortizing the alpha term — the paper's small-message lesson applied at
pod scale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class HeapError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class Allocation:
    offset: int
    size: int          # requested bytes
    seq: int           # allocation sequence number


class SymmetricHeap:
    """Host-side symmetric-heap bookkeeping (offsets are compile-time)."""

    def __init__(self, capacity: int, default_align: int = 8):
        if default_align < 8 or default_align & (default_align - 1):
            raise HeapError("default alignment must be a power of 2 >= 8")
        self.capacity = capacity
        self.default_align = default_align
        self._brk = 0           # local base memory tracking pointer
        self._live: list[Allocation] = []
        self._seq = 0

    @property
    def brk(self) -> int:
        return self._brk

    def sbrk(self, nbytes: int) -> int:
        """Move the break; returns previous break (like Unix sbrk)."""
        if self._brk + nbytes > self.capacity:
            raise HeapError(
                f"heap exhausted: brk={self._brk} + {nbytes} > {self.capacity}")
        prev = self._brk
        self._brk += nbytes
        return prev

    def malloc(self, nbytes: int, align: int | None = None) -> Allocation:
        align = align or self.default_align
        if align < 8 or align & (align - 1):
            raise HeapError("alignment must be a power of 2 >= 8")
        base = -(-self._brk // align) * align
        self.sbrk((base - self._brk) + nbytes)
        a = Allocation(offset=base, size=nbytes, seq=self._seq)
        self._seq += 1
        self._live.append(a)
        return a

    def align_alloc(self, align: int, nbytes: int) -> Allocation:
        return self.malloc(nbytes, align=align)

    def free(self, alloc: Allocation) -> None:
        """Paper rule 1: moves brk back to alloc.offset, implicitly freeing
        everything allocated after it (so freeing the *first* of a series
        frees the series)."""
        if alloc not in self._live:
            raise HeapError("free of unknown or already-freed allocation")
        self._live = [a for a in self._live if a.seq < alloc.seq]
        self._brk = alloc.offset

    def realloc(self, alloc: Allocation, nbytes: int) -> Allocation:
        """Paper rule 2: only the last (re)allocation may be realloc'd.
        Contents are NOT copied (the paper declines to waste the space)."""
        if not self._live or self._live[-1].seq != alloc.seq:
            raise HeapError("realloc only valid on the last allocation")
        self._live.pop()
        self._brk = alloc.offset
        return self.malloc(nbytes)

    def live_bytes(self) -> int:
        return self._brk


# ---------------------------------------------------------------------------
# pytree packing onto a symmetric flat buffer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackSpec:
    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    offsets: tuple[int, ...]     # element offsets in the flat buffer
    total: int                   # total elements (padded)
    dtype: Any                   # buffer dtype


def plan_pack(tree, dtype=None, align_elems: int = 128) -> PackSpec:
    """Lay a pytree out on a flat symmetric buffer; offsets aligned to the
    TPU lane count so unpacked views keep friendly layouts."""
    leaves, treedef = jax.tree.flatten(tree)
    if dtype is None:
        dtype = jnp.result_type(*[l.dtype for l in leaves])
    shapes, dtypes, offsets = [], [], []
    off = 0
    for l in leaves:
        shapes.append(tuple(l.shape))
        dtypes.append(l.dtype)
        off = -(-off // align_elems) * align_elems
        offsets.append(off)
        off += int(np.prod(l.shape)) if l.shape else 1
    total = -(-off // align_elems) * align_elems
    return PackSpec(treedef, tuple(shapes), tuple(dtypes), tuple(offsets),
                    total, dtype)


def pack(tree, spec: PackSpec):
    leaves = jax.tree.leaves(tree)
    buf = jnp.zeros((spec.total,), spec.dtype)
    for l, off in zip(leaves, spec.offsets):
        buf = jax.lax.dynamic_update_slice(
            buf, l.astype(spec.dtype).reshape(-1), (off,))
    return buf


def unpack(buf, spec: PackSpec):
    leaves = []
    for shape, dt, off in zip(spec.shapes, spec.dtypes, spec.offsets):
        n = int(np.prod(shape)) if shape else 1
        leaves.append(jax.lax.dynamic_slice(buf, (off,), (n,))
                      .reshape(shape).astype(dt))
    return jax.tree.unflatten(spec.treedef, leaves)
