"""The fusion layer: Schedule stages interleaved with Pallas kernel
execution (DESIGN.md §14).

The paper's core speed trick is that communication is not a separate
phase — remote stores issue from inside the compute loop (§4, and the
hybrid-model companion paper's "device kernels issue SHMEM ops").  Up to
PR 5 this repo alternated jitted compute with Schedule-layer collectives;
this module interleaves them, with two flagship fused paths:

ring_attention
    Sequence-sharded attention.  Each ring step's KV-block rotation is a
    CommPattern issued via `put_nbi` on a DEDICATED context (its own
    pending-op queue, so the rotation cannot be drained by unrelated
    traffic) while the flash online-softmax machinery consumes the block
    that arrived in the previous step.  `fence()` orders the puts per ring
    neighbor; `quiet(fk, fv, fp)` completes exactly this step's rotation
    before the next step consumes it — the double-buffer slot protocol.

fused_rs_adam
    Ring reduce-scatter whose FINAL combine lands inside the k-ary
    combine+AdamW kernel (kernels/fused_update.py): the fully-reduced
    gradient chunk is consumed by the optimizer in the same kernel pass
    and the full gradient is never materialized.  Only the updated PARAM
    chunk is allgathered — at param dtype, so vs the unfused
    reduce-scatter + f32 allgather the wire bytes drop from 2B to
    B * (1 + itemsize/4).

choose_attention / choose_grad_rs price the fused variants against the
monolithic ones (abmodel.modeled_overlapped_time) and consult the
measured-performance tuner first, the same contract as choose_algorithm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import abmodel
from . import collectives as coll
from . import netops
from .collectives import allgather_schedule, reduce_scatter_schedule
from .netops import NetOps, SimNetOps
from .pattern import ring_pattern
from ..kernels import fused_update as _fu
from ..kernels import ring_attention as _ra


# ---------------------------------------------------------------------------
# ring attention
# ---------------------------------------------------------------------------

def ring_attention(ctx, q, k, v, q_pos, k_pos, *, causal: bool = True,
                   window: int | None = None, softcap: float | None = None,
                   sm_scale: float | None = None, use_pallas: bool = False,
                   bq: int = _ra.DEFAULT_BQ, bk: int = _ra.DEFAULT_BK,
                   interpret: bool | None = None, out_dtype=None):
    """Sequence-sharded attention over `ctx`'s PE space.

    Each PE holds its query shard q (B, Hq, Lq_shard, D) with global
    positions q_pos (Lq_shard,), and its KV shard k/v (B, Hkv, Lk_shard,
    D) with global positions k_pos (Lk_shard,; -1 marks padded slots).
    The KV shard walks the ring: at step s the NEXT block is issued with
    put_nbi on a private context while the flash partials of the CURRENT
    block are computed, then quiet() completes the rotation — comm hidden
    behind compute whenever the NoC keeps up.  Output matches monolithic
    flash attention over the gathered sequence to f32 allclose (identical
    per-block arithmetic; merge order differs per PE, which online
    softmax absorbs up to rounding)."""
    net = ctx.net
    n = net.n_pes
    kw = dict(causal=causal, window=window, softcap=softcap,
              sm_scale=sm_scale, use_pallas=use_pallas, bq=bq, bk=bk)
    if interpret is not None:
        kw["interpret"] = interpret

    def partials(q_, k_, v_, qp_, kp_):
        return _ra.attn_block_partials(q_, k_, v_, qp_, kp_, **kw)

    out_dtype = q.dtype if out_dtype is None else out_dtype
    if n == 1:
        return _ra.finalize(
            coll._lmap(net, partials, q, k, v, q_pos, k_pos), out_dtype)

    c = ctx.ctx_create()                 # private queue: DESIGN.md §14
    ring = ring_pattern(n)               # PE i -> (i+1) % n, every step
    cur_k, cur_v, cur_kp = k, v, k_pos
    state = None
    for s in range(n):
        last = s == n - 1
        if not last:
            # issue the rotation BEFORE computing on the current block:
            # the 'DMA engine' flies while the kernel runs
            fk = c.put_nbi(cur_k, ring)
            fv = c.put_nbi(cur_v, ring)
            fp = c.put_nbi(cur_kp, ring)
            c.fence()                    # per-neighbor ordering of k/v/pos
        part = coll._lmap(net, partials, q, cur_k, cur_v, q_pos, cur_kp)
        state = part if state is None else _ra.merge_partials(state, part)
        if not last:
            # double-buffer swap: completion of THIS step's rotation is
            # the next step's front buffer
            cur_k, cur_v, cur_kp = c.quiet(fk, fv, fp)
    return _ra.finalize(state, out_dtype)


# ---------------------------------------------------------------------------
# fused reduce-scatter -> AdamW update
# ---------------------------------------------------------------------------

def fused_rs_adam(net: NetOps, g_buf, p_buf, m, v, wd_mask, c1, c2, *,
                  lr: float, b1: float, b2: float, eps: float,
                  wd_coef: float, scale: float = 1.0, out_dtype=None,
                  team=None, use_pallas: bool = False,
                  interpret: bool | None = None, profile=None):
    """Ring reduce-scatter of the flat f32 gradient bucket `g_buf` with
    the final combine fused into the AdamW update of this PE's owned
    param chunk.  `p_buf` is the matching flat f32 param bucket
    (replicated); `m`/`v` are this PE's OWNED moment chunks, shape
    (ceil(size/n),) — they never ride the ring.  wd_mask (full bucket
    length) is nonzero where weight decay applies; c1/c2 the traced
    1-beta**t scalars; `scale` the grad-mean divisor.

    Returns ``(new_p_chunk, new_m, new_v, info)``: the updated owned
    param chunk (cast to `out_dtype`) plus the reduce-scatter `info`
    handle — allgather it with ``coll.allgather_unpad(net, new_p_chunk,
    info, team=team)`` to rebuild the full updated bucket.  Arithmetic is
    bitwise equal to grad_sync(mean)-then-apply_updates on f32 moments
    (kernels/fused_update.py documents why)."""
    out_dtype = p_buf.dtype if out_dtype is None else out_dtype
    fn = coll.OPS["sum"]
    local, incoming, info, mask = coll._reduce_scatter_parts(
        net, g_buf, fn, team=team)
    orig_shape, size, chunk, own_idx = info
    if profile is not None:
        nbytes = coll._payload_bytes(net, g_buf)
        profile.note(algorithm="fused_rs_adam",
                     schedule=reduce_scatter_schedule(net.n_pes, nbytes),
                     collective="grad_sync", nbytes=nbytes,
                     n_pes=net.n_pes)
    n = net.n_pes
    padded = chunk * n

    def flatpad(x):
        f = x.reshape(-1)
        return jnp.pad(f, (0, padded - f.size))

    p_pad = coll._lmap(net, flatpad, p_buf)
    wd_pad = jnp.pad(wd_mask.reshape(-1).astype(jnp.int8),
                     (0, padded - size))
    if isinstance(net, SimNetOps):
        wd_pad = jnp.broadcast_to(wd_pad, (n, padded))
    p_chunk = netops.dyn_slice_block(net, p_pad, own_idx, chunk, axis=-1)
    wd_chunk = netops.dyn_slice_block(net, wd_pad, own_idx, chunk, axis=-1)
    g_parts = [local] if incoming is None else [local, incoming]

    def update(gs, p_, m_, v_, w_):
        return _fu.fused_adam_update(
            gs, p_, m_, v_, w_, c1, c2, lr=lr, b1=b1, b2=b2, eps=eps,
            wd_coef=wd_coef, scale=scale, out_dtype=out_dtype,
            use_pallas=use_pallas, interpret=interpret)

    if isinstance(net, SimNetOps):
        new_p, new_m, new_v = jax.vmap(
            lambda *a: update(list(a[:len(g_parts)]), *a[len(g_parts):]))(
            *g_parts, p_chunk, m, v, wd_chunk)
    else:
        new_p, new_m, new_v = update(g_parts, p_chunk, m, v, wd_chunk)
    new_p = coll._mask_out(net, mask, new_p, keep=p_chunk.astype(out_dtype))
    return new_p, new_m, new_v, info


# ---------------------------------------------------------------------------
# pricing: the fused variants as selectable algorithms
# ---------------------------------------------------------------------------

def choose_attention(n: int, kv_block_bytes: float, block_compute_s: float,
                     *, topo=None, link=None, tuner=None
                     ) -> tuple[str, dict]:
    """"ring" vs "mono" for sequence-sharded attention over n PEs.

    kv_block_bytes: bytes of ONE PE's K+V(+pos) shard — what each ring
    step moves; block_compute_s: flash time of q against one block.  Mono
    allgathers the KV sequence first and computes monolithically; ring
    overlaps each rotation with one block's compute
    (abmodel.modeled_overlapped_time).  A measured-best tuner verdict for
    collective "attention" wins over the analytic model."""
    if n <= 1:
        return "mono", {"ring": 0.0, "mono": 0.0}
    total = kv_block_bytes * n
    ring_stages = allgather_schedule(n, total).cost(topo)
    t_ring = abmodel.modeled_overlapped_time(
        ring_stages, block_compute_s,
        link if link is not None else abmodel.ICI_V5E)
    t_mono = (allgather_schedule(n, total).time(topo, link)
              + n * block_compute_s)
    times = {"ring": t_ring, "mono": t_mono}
    if tuner is not None:
        got = tuner.algorithm("attention", n, total, topo=topo,
                              candidates=("ring", "mono"))
        if got in times:
            return got, times
    return ("ring" if t_ring <= t_mono else "mono"), times


def choose_grad_rs(n: int, bucket_bytes: float, param_itemsize: int = 4,
                   *, topo=None, link=None, tuner=None) -> tuple[str, dict]:
    """"fused" vs "bucketed" for the gradient sync of one f32 bucket.

    Both price the same ring reduce-scatter; the fused path allgathers
    the updated PARAM chunk at `param_itemsize` instead of the f32
    gradient — strictly fewer wire bytes for sub-f32 params, equal for
    f32 (where fusing still saves the separate optimizer kernel pass, so
    ties go to "fused").  Tuner verdicts for collective "grad_sync" win."""
    if n <= 1:
        return "bucketed", {"fused": 0.0, "bucketed": 0.0}
    t_rs = reduce_scatter_schedule(n, bucket_bytes).time(topo, link)
    t_ag_f32 = allgather_schedule(n, bucket_bytes).time(topo, link)
    t_ag_out = allgather_schedule(
        n, bucket_bytes * param_itemsize / 4.0).time(topo, link)
    times = {"fused": t_rs + t_ag_out, "bucketed": t_rs + t_ag_f32}
    if tuner is not None:
        got = tuner.algorithm("grad_sync", n, bucket_bytes, topo=topo,
                              candidates=("fused", "bucketed"))
        if got in times:
            return got, times
    return ("fused" if times["fused"] <= times["bucketed"]
            else "bucketed"), times
