"""ShmemJAX core: the paper's OpenSHMEM library re-targeted to TPU meshes."""
from . import (abmodel, collectives, heap, netops, pattern, shmem, team,
               topology)
from .netops import NetOps, NocSimNetOps, SimNetOps, SpmdNetOps
from .pattern import CommPattern, Schedule, Stage, as_pattern, compile_pattern
from .shmem import Ctx, ShmemContext, sim_ctx, spmd_ctx
from .team import (Team, TeamPartition, from_active_set, make_team, split_2d,
                   split_strided, team_world)
from .topology import MeshTopology, epiphany3, v5e_multipod, v5e_pod

__all__ = [
    "abmodel", "collectives", "heap", "netops", "pattern", "shmem", "team",
    "topology", "NetOps", "NocSimNetOps", "SimNetOps", "SpmdNetOps",
    "CommPattern",
    "Schedule", "Stage", "as_pattern", "compile_pattern", "Ctx",
    "ShmemContext", "sim_ctx", "spmd_ctx", "Team", "TeamPartition",
    "from_active_set", "make_team", "split_2d", "split_strided",
    "team_world", "MeshTopology", "epiphany3", "v5e_multipod", "v5e_pod",
]
