"""ShmemJAX core: the paper's OpenSHMEM library re-targeted to TPU meshes."""
from . import abmodel, collectives, heap, netops, shmem, topology
from .netops import NetOps, SimNetOps, SpmdNetOps
from .shmem import ShmemContext, sim_ctx, spmd_ctx
from .topology import MeshTopology, epiphany3, v5e_multipod, v5e_pod

__all__ = [
    "abmodel", "collectives", "heap", "netops", "shmem", "topology",
    "NetOps", "SimNetOps", "SpmdNetOps", "ShmemContext", "sim_ctx",
    "spmd_ctx", "MeshTopology", "epiphany3", "v5e_multipod", "v5e_pod",
]
