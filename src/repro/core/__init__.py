"""ShmemJAX core: the paper's OpenSHMEM library re-targeted to TPU meshes."""
from . import (abmodel, collectives, elastic, fault, heap, netops, pattern,
               profile, shmem, team, topology, trace, tuner)
from .elastic import DegradedMesh, degrade, recover
from .fault import (DeadlineExceeded, FaultInjector, FaultPlan, LinkFailure,
                    PEFailure)
from .netops import NetOps, NocSimNetOps, SimNetOps, SpmdNetOps
from .pattern import CommPattern, Schedule, Stage, as_pattern, compile_pattern
from .profile import OpSample, Profiler
from .shmem import Ctx, RetryPolicy, ShmemContext, sim_ctx, spmd_ctx
from .team import (Team, TeamPartition, from_active_set, make_team, split_2d,
                   split_strided, team_world)
from .topology import MeshTopology, epiphany3, v5e_multipod, v5e_pod
from .trace import Tracer
from .tuner import TunedSelector, Tuner, TuningDB

__all__ = [
    "abmodel", "collectives", "elastic", "fault", "heap", "netops",
    "pattern", "profile", "shmem", "team", "topology", "trace", "tuner",
    "DegradedMesh", "degrade", "recover", "DeadlineExceeded",
    "FaultInjector", "FaultPlan", "LinkFailure", "PEFailure",
    "RetryPolicy",
    "NetOps", "NocSimNetOps", "SimNetOps", "SpmdNetOps", "CommPattern",
    "Schedule", "Stage", "as_pattern", "compile_pattern", "Ctx",
    "ShmemContext", "sim_ctx", "spmd_ctx", "Team", "TeamPartition",
    "from_active_set", "make_team", "split_2d", "split_strided",
    "team_world", "MeshTopology", "epiphany3", "v5e_multipod", "v5e_pod",
    "OpSample", "Profiler", "Tracer", "TunedSelector", "Tuner", "TuningDB",
]
