"""ShmemJAX core: the paper's OpenSHMEM library re-targeted to TPU meshes."""
from . import abmodel, collectives, heap, netops, pattern, shmem, topology
from .netops import NetOps, SimNetOps, SpmdNetOps
from .pattern import CommPattern, Schedule, Stage, as_pattern, compile_pattern
from .shmem import ShmemContext, sim_ctx, spmd_ctx
from .topology import MeshTopology, epiphany3, v5e_multipod, v5e_pod

__all__ = [
    "abmodel", "collectives", "heap", "netops", "pattern", "shmem",
    "topology", "NetOps", "SimNetOps", "SpmdNetOps", "CommPattern",
    "Schedule", "Stage", "as_pattern", "compile_pattern", "ShmemContext",
    "sim_ctx", "spmd_ctx", "MeshTopology", "epiphany3", "v5e_multipod",
    "v5e_pod",
]
