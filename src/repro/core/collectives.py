"""The paper's collective algorithms (§3.6), written once over NetOps.

Algorithm choices mirror the paper exactly:

  * barrier        — dissemination (ceil(log2 N) rounds, 8*log2(N) bytes of
                     sync state); the 'WAND hardware barrier' analogue is a
                     zero-byte psum left to XLA (shmem.py).
  * broadcast      — binomial tree, *farthest-first*: largest stride first
                     so later stages do not add network congestion.
  * fcollect       — recursive doubling for powers of two, ring otherwise.
  * collect        — ring (the paper's linear-scaling variant).
  * reductions     — dissemination/recursive-doubling for powers of two,
                     ring (reduce-scatter + allgather) otherwise.
  * alltoall       — pairwise exchange, one ring offset per stage.

Every algorithm is a ``*_schedule`` builder returning a
:class:`~repro.core.pattern.Schedule` of compiled
:class:`~repro.core.pattern.CommPattern` stages (DESIGN.md §9).  The
executor iterates the schedule's stages; the alpha-beta cost descriptor
(``*_stages``, the benchmarks' `derived` column, the roofline cross-check)
is ``schedule.cost(topo)`` on the *same object* — predicted and executed
schedules cannot drift apart.  `choose_algorithm` prices candidate
schedules with the cost model to pick the cheapest (`algorithm="auto"`).

All functions take the PE-local array (under SPMD) or the PE-stacked array
(under SIM) — `_lmap` hides the difference for shape-changing local ops.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .netops import NetOps, SimNetOps
from .pattern import (CommPattern, Schedule, Stage, as_pattern,
                      binomial_stage_pattern, intern_get, ring_pattern,
                      xor_pattern)
from . import team as team_mod


def _lmap(net: NetOps, f: Callable, *xs):
    """Apply a PE-local function under either backend."""
    if isinstance(net, SimNetOps):
        return jax.vmap(f)(*xs)
    return f(*xs)


def _ceil_log2(n: int) -> int:
    return max(1, n - 1).bit_length() if n > 1 else 0


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _bcast_pe(net: NetOps, shape) -> jnp.ndarray:
    """my_pe broadcast to pair with local arrays in _lmap."""
    return net.my_pe()


def _payload_bytes(net: NetOps, x) -> float:
    """Per-PE payload bytes of tree `x` (the SIM backend's leading PE axis
    is not payload)."""
    leaves = jax.tree.leaves(x)
    total = float(sum(l.size * l.dtype.itemsize for l in leaves))
    if isinstance(net, SimNetOps):
        total /= net.n_pes
    return total


# ---------------------------------------------------------------------------
# team-relative execution view (DESIGN.md §11)
# ---------------------------------------------------------------------------
# Every executor below is written against a *group view*: my rank within
# the group, the group size, a lift of group-coordinate patterns to the
# world patterns that execute, and (for proper-subset teams) the member
# mask that bounds where results are defined.  team=None is the world —
# rank is the PE id and lift is the interning pass-through, so the flat
# paths are byte-for-byte what they were.

def _team_view(net: NetOps, team):
    """(rank, size, lift, member_mask) for `team`: a Team, a
    TeamPartition (all member teams run concurrently — each PE uses its
    own team's coordinates), or None for the world.

    rank is the per-PE group rank (clamped to 0 off-team; off-team
    results are masked out by the callers).  member_mask is a host bool
    array over world PEs, or None when the group covers the world."""
    if team is None:
        return net.my_pe(), net.n_pes, \
            (lambda p: as_pattern(p, net.n_pes)), None
    if team.world_n != net.n_pes:
        raise ValueError(f"team compiled for world_n={team.world_n} "
                         f"used on a {net.n_pes}-PE net")
    rank = jnp.asarray(np.maximum(team.rank_np, 0))[net.my_pe()]
    mask = None if team.covers_world else team.member_np
    return rank, team.size, team.lift, mask


def _mask_out(net: NetOps, mask, out, keep=None):
    """Restore non-members: `keep` (same shape) where given, zeros for
    shape-changing collectives — OpenSHMEM leaves non-participants
    undefined; we pin them for determinism and testability."""
    if mask is None:
        return out
    keep = jnp.zeros_like(out) if keep is None else keep
    return net.select(mask, out, keep)


# ---------------------------------------------------------------------------
# mesh embeddings — ring collectives in snake coordinates (DESIGN.md §12)
# ---------------------------------------------------------------------------
# An embedding is a world-covering rank order: ring position i is served by
# PE order[i].  With `topo.snake_order()` every logical ring hop becomes ONE
# physical hop and (on meshes with a Hamiltonian cycle) no two ring flows
# share a physical link — max_link_load 1 vs the logical ring's contended
# row-wrap columns.  Execution reuses the team machinery: the order IS a
# covering Team, so lifted patterns are interned and shared with the
# schedules that price them.

def _embedding_team(order: Sequence[int], world_n: int):
    return team_mod.make_team(order, world_n)


def embedding_team(embedding, topo, n: int, link=None):
    """Resolve the embedding knob straight to its world-covering Team (the
    coordinate system embedded rings execute in), or None when the
    identity/logical ring is the embedding.  The Comm/grad-sync layers use
    this to run reduce-scatter + allgather pairs in embedded coordinates."""
    order = _resolve_embedding(embedding, topo, n, link)
    return None if order is None else _embedding_team(order, n)


def _resolve_embedding(embedding, topo, n: int, link=None, tuner=None):
    """The embedding knob: None -> off; "snake" -> the topology's snake
    order; "auto" -> cost-model pick (snake vs a greedy remap vs identity,
    `choose_embedding` — measured-first when a `tuner` is threaded); an
    explicit order passes through validated.  Returns a world rank order,
    or None when the identity (logical ring) is the embedding."""
    if embedding is None:
        return None
    if isinstance(embedding, str):
        if embedding not in ("auto", "snake"):
            # validate BEFORE the topo gate: a typo'd knob must raise even
            # when no usable topology is attached (it would otherwise be
            # silently read as "off" exactly when the user can't notice)
            raise ValueError(f"unknown embedding {embedding!r} "
                             "(None | 'auto' | 'snake' | explicit order)")
        if topo is None or getattr(topo, "n_pes", None) != n:
            return None
        if embedding == "auto":
            return choose_embedding(n, topo, link, tuner=tuner)
        order = topo.snake_order()
        return None if order == tuple(range(n)) else order
    order = tuple(int(p) for p in embedding)
    if sorted(order) != list(range(n)):
        raise ValueError(f"embedding must be a permutation of 0..{n - 1}")
    return None if order == tuple(range(n)) else order


# Representative payload for embedding selection: large enough that the
# bandwidth (congestion) term dominates, where embeddings matter.
EMBED_REF_BYTES = float(1 << 20)
# Greedy remap is O(n^2) schedule evaluations per pass — worth it on
# chip-scale meshes, not on pod-scale ones (where the snake already wins).
EMBED_GREEDY_MAX_PES = 64

_EMBED_LOCK = threading.Lock()
_EMBED_CACHE: dict = {}
_EMBED_CACHE_MAX = 256


def choose_embedding(n: int, topo, link=None, tuner=None):
    """Cost-model embedding selection: price the ring allreduce schedule
    under the identity, the snake order, and (small meshes) a greedy
    `optimize_embedding` remap seeded from the snake; return the winning
    order, or None when the logical ring already prices best.  Cached per
    (topo, n, link).

    A `tuner` (``repro.core.tuner.TunedSelector``) is consulted FIRST:
    when the tuning DB holds measurements near the reference payload for
    this topology, the measured-best embedding (identity / snake / an
    explicit order) overrides the analytic pricing (DESIGN.md §13)."""
    if topo is None or getattr(topo, "n_pes", None) != n or n <= 2:
        return None
    if tuner is not None:
        pick = tuner.embedding(n, EMBED_REF_BYTES, topo)
        if pick is not None:
            if pick == "identity":
                return None
            order = topo.snake_order() if pick == "snake" else tuple(pick)
            return None if order == tuple(range(n)) else order

    def _build():
        def _sched(order):
            if order is None:
                return allreduce_schedule(n, EMBED_REF_BYTES, "ring")
            return allreduce_schedule(n, EMBED_REF_BYTES, "ring_emb",
                                      embedding=order)

        snake = topo.snake_order()
        candidates: list[tuple[int, ...] | None] = [None]
        if snake != tuple(range(n)):
            candidates.append(snake)
            if n <= EMBED_GREEDY_MAX_PES:
                _, perm = optimize_embedding(_sched(snake), topo, link)
                greedy = tuple(perm[p] for p in snake)
                if greedy not in candidates:
                    candidates.append(greedy)
        # boxed so an identity result (None) still caches — intern_get
        # treats a bare None as a miss
        return (min(candidates, key=lambda o: _sched(o).time(topo, link)),)

    return intern_get(_EMBED_CACHE, _EMBED_LOCK, _EMBED_CACHE_MAX,
                      (topo, n, link), _build)[0]


def optimize_embedding(schedule: Schedule, topo, link=None,
                       max_passes: int = 2
                       ) -> tuple[Schedule, tuple[int, ...]]:
    """Greedy rank remap: hill-climb pairwise PE swaps that lower the
    congestion-priced time (dominated by ``max_link_load``) of the
    schedule's stages on `topo`.  Returns ``(remapped_schedule, perm)``
    with ``perm[old_pe] = new_pe`` — stage patterns are relabeled through
    `perm` (`CommPattern.relabel`, interned as usual).

    The remapped schedule is a *different coordinate system*, not a
    drop-in replacement: run it by treating `perm` as an embedding (the
    covering Team whose rank r is PE ``perm[order[r]]``), exactly how the
    `embedding=` knob executes — data placement follows the relabel."""
    if not schedule.stages:
        return schedule, ()
    from . import abmodel
    n = schedule.stages[0].pattern.n_pes
    perm = list(range(n))
    lk = link if link is not None else abmodel.ICI_V5E
    # ring schedules repeat ONE (pattern, bytes) stage 2(n-1) times —
    # price each unique stage once and weight by its count, instead of
    # rebuilding the full Schedule per candidate swap
    uniq: dict[tuple[CommPattern, float], int] = {}
    for st in schedule.stages:
        key = (st.pattern, st.nbytes)
        uniq[key] = uniq.get(key, 0) + 1

    def _priced(p: Sequence[int]) -> float:
        # score the remapped pairs directly — interning a throwaway
        # CommPattern per candidate swap would churn the global pattern
        # cache (and its device/hop caches) with never-reused entries
        total = 0.0
        for (pat, nb), cnt in uniq.items():
            pairs = [(p[s], p[d]) for s, d in pat.pairs]
            if topo is None:
                hops = load = 1.0 if pairs else 0.0
            else:
                hops = max((topo.hops(s, d) for s, d in pairs), default=0.0)
                loads: dict[tuple[int, int], float] = {}
                for s, d in pairs:
                    if s == d:
                        continue
                    for u, v in topo.route(s, d):
                        key = (u, v) if u < v else (v, u)
                        loads[key] = loads.get(key, 0.0) + 1.0
                load = max(loads.values()) if loads \
                    else (1.0 if pairs else 0.0)
            total += cnt * lk.time(nb, hops, load)
        return total

    def _relabel(p: Sequence[int]) -> Schedule:
        return Schedule(f"{schedule.name}.remap", tuple(
            Stage(st.pattern.relabel(p, n), st.nbytes)
            for st in schedule.stages))

    best_t = _priced(perm)
    for _ in range(max_passes):
        improved = False
        for i in range(n):
            for j in range(i + 1, n):
                perm[i], perm[j] = perm[j], perm[i]
                t = _priced(perm)
                if t < best_t - 1e-15:
                    best_t, improved = t, True
                else:
                    perm[i], perm[j] = perm[j], perm[i]
        if not improved:
            break
    return _relabel(perm), tuple(perm)


def embed_team(team, topo, order=None):
    """The embedding computed in TEAM coordinates: reorder a team's
    members along the world embedding order (the topology's snake by
    default; pass `order` to honor an explicit/greedy world order), so
    the team-relative ring lifts to near-neighbor world flows
    (DESIGN.md §12).  Interned (teams are); returns the same team when
    the order already matches or no usable topology is given."""
    if order is None:
        if topo is None or getattr(topo, "n_pes", None) != team.world_n:
            return team
        order = topo.snake_order()
    pos = {pe: i for i, pe in enumerate(order)}
    members = tuple(sorted(team.members, key=lambda p: pos[p]))
    if members == team.members:
        return team
    return team_mod.make_team(members, team.world_n)


def _team_embed_view(team, topo, embedding, link=None):
    """Resolve the embedding knob to the embedded TEAM view, honoring the
    same world-order semantics as the flat path: strings are validated
    ("auto"/"snake"; typos raise), explicit world orders are honored, a
    knob resolving to the identity leaves the team untouched, and
    embedding=None (an explicit algorithm="ring_emb" request) takes the
    snake default."""
    if embedding is None:
        return embed_team(team, topo)
    order = _resolve_embedding(embedding, topo, team.world_n, link)
    if order is None:
        return team                  # knob resolves to the identity
    return embed_team(team, topo, order)


_EMBED_PART_LOCK = threading.Lock()
_EMBED_PART_CACHE: dict = {}


def _embed_partition(partition, topo, embedding=None, link=None):
    """embed_team over every member team of a partition (the hierarchical
    allreduce's intra phases then ride embedded rings), against the SAME
    world order the flat path would resolve from the knob — an explicit
    or "auto"/greedy order is honored, not silently replaced by the
    snake.  Cached per (partition, topo, order) so lift caches survive
    across calls."""
    if topo is None:
        return partition
    order = _resolve_embedding(embedding, topo, partition.world_n, link) \
        if embedding is not None else None
    if embedding is not None and order is None:
        return partition            # knob resolves to the identity

    def _build():
        teams = [embed_team(t, topo, order) for t in partition.teams]
        if all(a is b for a, b in zip(teams, partition.teams)):
            return partition
        return team_mod.TeamPartition(teams)

    return intern_get(_EMBED_PART_CACHE, _EMBED_PART_LOCK, 256,
                      (partition, topo, order), _build)


# ---------------------------------------------------------------------------
# schedule builders — one per paper algorithm
# ---------------------------------------------------------------------------

def _ring_stage_pattern(n: int, embedding=None) -> CommPattern:
    """The offset-1 ring stage, optionally in embedding coordinates:
    ring position i (PE embedding[i]) sends to position i+1.  The lifted
    object is the SAME interned pattern the embedded executor runs."""
    p = ring_pattern(n)
    return p if embedding is None else p.relabel(embedding, n)


def barrier_schedule(n: int, algorithm: str = "dissem") -> Schedule:
    """"dissem": round k exchanges 8 bytes of sync state with PE (i + 2^k)
    — the paper's 8*log2(N) sync array.  "tree": binomial gather to PE 0
    then binomial broadcast — 2x the rounds but each round is a sparse
    tree stage, the low-congestion candidate `choose_barrier` prices
    against dissemination's dense all-PE exchanges."""
    if algorithm == "tree":
        gather = [Stage(binomial_stage_pattern(n, 1 << k).inverse, 8.0)
                  for k in range(_ceil_log2(n))]
        bcast = [Stage(binomial_stage_pattern(n, 1 << k), 8.0)
                 for k in reversed(range(_ceil_log2(n)))]
        return Schedule("barrier.tree", tuple(gather + bcast))
    return Schedule("barrier.dissemination", tuple(
        Stage(ring_pattern(n, 1 << k), 8.0) for k in range(_ceil_log2(n))))


def choose_barrier(n: int, topo=None, link=None, team=None) -> str:
    """Price the dissemination barrier against the tree barrier with the
    congestion-aware model and return the cheaper ("dissem" | "tree").
    With `team`, candidates are lifted to the world flows that execute
    before pricing (team ranks are not world PEs)."""
    if n <= 1:
        return "dissem"

    def _priced(a: str) -> float:
        s = barrier_schedule(n, a)
        if team is not None:
            s = team.lift_schedule(s)
        return s.time(topo, link)

    return min(("dissem", "tree"), key=_priced)


def broadcast_schedule(n: int, nbytes: float = 0.0, root: int = 0) -> Schedule:
    """Farthest-first binomial tree: stride p2/2 down to 1 (paper §3.6:
    'moving the data the farthest distance first')."""
    stages = []
    stride = (1 << _ceil_log2(n)) >> 1
    while stride >= 1:
        stages.append(Stage(binomial_stage_pattern(n, stride, root),
                            float(nbytes)))
        stride >>= 1
    return Schedule("broadcast.binomial_ff", tuple(stages))


def fcollect_schedule(n: int, nbytes: float = 0.0,
                      algorithm: str | None = None,
                      embedding=None) -> Schedule:
    """Allgather of `nbytes` blocks: recursive doubling (payload doubles
    per stage), ring (n-1 single-block stages), or the mesh-embedded ring
    ("ring_emb": every hop one physical hop over `embedding`)."""
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    if algo == "rd":
        return Schedule("fcollect.rd", tuple(
            Stage(xor_pattern(n, 1 << k), nbytes * (1 << k))
            for k in range(_ceil_log2(n))))
    emb = embedding if algo == "ring_emb" else None
    return Schedule("fcollect.ring_emb" if emb is not None
                    else "fcollect.ring", tuple(
                        Stage(_ring_stage_pattern(n, emb), float(nbytes))
                        for _ in range(max(n - 1, 0))))


def reduce_scatter_schedule(n: int, nbytes: float = 0.0,
                            embedding=None) -> Schedule:
    """Ring reduce-scatter: n-1 stages, each moving one 1/n chunk (over
    the embedding order when one is given)."""
    return Schedule("reduce_scatter.ring", tuple(
        Stage(_ring_stage_pattern(n, embedding), nbytes / max(n, 1))
        for _ in range(max(n - 1, 0))))


def allgather_schedule(n: int, nbytes: float = 0.0,
                       embedding=None) -> Schedule:
    """Ring allgather of the scattered 1/n chunks (reduce-scatter's dual)."""
    return Schedule("allgather.ring", tuple(
        Stage(_ring_stage_pattern(n, embedding), nbytes / max(n, 1))
        for _ in range(max(n - 1, 0))))


def allreduce_schedule(n: int, nbytes: float = 0.0,
                       algorithm: str | None = None,
                       embedding=None) -> Schedule:
    """to_all: recursive doubling (log2 N full-buffer stages,
    alpha-optimal), ring reduce-scatter + allgather (~2x buffer total,
    bandwidth-optimal), or the mesh-embedded ring ("ring_emb": the same
    ring in snake coordinates — one physical hop per stage, hot-link
    load 1 where the topology admits a Hamiltonian cycle)."""
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    if algo == "rd":
        return Schedule("allreduce.rd", tuple(
            Stage(xor_pattern(n, 1 << k), float(nbytes))
            for k in range(_ceil_log2(n))))
    emb = embedding if algo == "ring_emb" else None
    return Schedule("allreduce.ring_emb" if emb is not None
                    else "allreduce.ring",
                    reduce_scatter_schedule(n, nbytes, emb).stages
                    + allgather_schedule(n, nbytes, emb).stages)


def alltoall_schedule(n: int, nbytes_total: float = 0.0) -> Schedule:
    """Pairwise exchange (paper Fig. 9): stage j sends one 1/n block to the
    PE j ring offsets away."""
    per = nbytes_total / max(n, 1)
    return Schedule("alltoall.pairwise", tuple(
        Stage(ring_pattern(n, j), per) for j in range(1, n)))


# Collectives with more than one algorithm to choose between.
_SELECTABLE: dict[str, Callable[..., Schedule]] = {
    "allreduce": allreduce_schedule,
    "fcollect": fcollect_schedule,
}


def allreduce_hier_schedule(partition, nbytes: float = 0.0,
                            cross_algorithm: str | None = None,
                            topo=None, link=None, embedding=None) -> Schedule:
    """The hierarchical two-level allreduce as ONE world Schedule
    (DESIGN.md §11): intra-team ring reduce-scatter, cross-team allreduce
    of the owned 1/K chunk over the peer teams (the partition's
    complement — every team's rank-j members), intra-team ring allgather.
    Each phase's team-coordinate stages lift to union patterns, so all
    teams fly their stage-k exchange concurrently; stage payloads and hop
    costs come from the lifted objects that execute.  cross_algorithm
    None cost-model-selects the cross step (rd's log2(M) chunk sends vs
    the ring's ~2x chunk bytes), same as the executor.  `embedding`
    non-None reorders each member team along the topology's snake
    (`embed_team`) before lifting — the intra phases then ride embedded
    rings, mirroring the executor's `_embed_partition`."""
    if embedding is not None:
        partition = _embed_partition(partition, topo,
                                     embedding=embedding,
                                     link=link)
    K = partition.size
    peers = partition.complement()
    if cross_algorithm is None:
        cross_algorithm = choose_algorithm(peers.size, nbytes / max(K, 1),
                                           topo, link, team=peers)
    stages = tuple(
        partition.lift_schedule(reduce_scatter_schedule(K, nbytes)).stages
        + peers.lift_schedule(
            allreduce_schedule(peers.size, nbytes / max(K, 1),
                               cross_algorithm)).stages
        + partition.lift_schedule(allgather_schedule(K, nbytes)).stages)
    return Schedule(
        f"allreduce.hier[{partition.n_teams}x{K}]", stages)


def allreduce_hier(net: NetOps, x, op: str = "sum",
                   combine: Callable | None = None, partition=None,
                   cross_algorithm: str | None = None, topo=None, link=None,
                   embedding=None):
    """Hierarchical two-level allreduce over a covering TeamPartition:

      1. intra-team ring reduce-scatter — team rank r ends up owning the
         team-reduced chunk (r+1) mod K;
      2. cross-team allreduce among the chunk owners: the peer teams
         (partition.complement(), every team's rank-j members) each hold
         the SAME chunk index, so reducing within a peer team completes
         that chunk globally;
      3. intra-team ring allgather of the completed chunks.

    Numerically this reorders the summation relative to the flat
    algorithms — exact for int dtypes, allclose within float tolerance
    (documented in DESIGN.md §11).  On a 2D mesh with row teams this
    keeps phases 1/3 on row links and moves only 1/K of the payload
    across rows — the fewest-largest-messages policy of §8."""
    if partition is None:
        raise ValueError("allreduce_hier needs a TeamPartition")
    if not partition.covers_world:
        raise ValueError("allreduce_hier needs a partition covering the "
                         "world (every PE contributes)")
    fn = combine or OPS[op]
    if embedding is not None:
        partition = _embed_partition(partition, topo,
                                     embedding=embedding,
                                     link=link)
    peers = partition.complement()
    if cross_algorithm is None:
        # cost-model-select the cross step from the UNPADDED chunk bytes,
        # exactly as allreduce_hier_schedule prices it — the executed and
        # priced algorithms cannot diverge (even when padding rounds the
        # actual chunk up)
        nbytes = _payload_bytes(net, x)
        cross_algorithm = choose_algorithm(
            peers.size, nbytes / max(partition.size, 1), topo, link,
            team=peers)
    own, info = _reduce_scatter_ring(net, x, fn, team=partition)
    if peers.size > 1:
        own = allreduce(net, own, op, combine=combine,
                        algorithm=cross_algorithm, team=peers,
                        topo=topo, link=link)
    return allgather_unpad(net, own, info, team=partition)


def choose_algorithm(n: int, nbytes: float, topo=None, link=None,
                     collective: str = "allreduce", team=None,
                     partition=None, embedding=None, tuner=None) -> str:
    """Cost-model algorithm selection: price each candidate schedule with
    the congestion-aware alpha-beta model on `topo`/`link` and take the
    cheapest.

    This replaces the hand-tuned byte-threshold switch: recursive doubling
    pays log2(N) full-payload sends (alpha-optimal), the ring pays ~2x the
    payload in 2(N-1) chunk sends (bandwidth-optimal); where the cross-over
    falls depends on alpha, beta AND the mesh hop/contention costs, which
    is exactly what the model prices.

    With `team`, candidates are priced in team coordinates (lifted to the
    world patterns that execute, so team hop costs are the members' world
    distances).  With `partition` (allreduce only), the hierarchical
    two-level schedule joins the candidate set — "hier" wins whenever
    keeping the bulk bytes on intra-team links beats the flat ring.  With
    `embedding` enabled ("auto"/"snake"/an order), the mesh-embedded ring
    "ring_emb" joins too (DESIGN.md §12) — one physical hop per stage,
    hot-link load 1 where the mesh admits a Hamiltonian cycle.

    A `tuner` (``repro.core.tuner.TunedSelector``) is consulted FIRST:
    the measured-best algorithm among the legal candidates overrides the
    analytic pricing; unmeasured points fall through to the model
    (DESIGN.md §13 precedence)."""
    if team is not None:
        n = team.size
    if n <= 1:
        return "ring"
    build = _SELECTABLE[collective]
    emb_view = None          # the embedded TEAM view (team path only)
    emb = None               # the world embedding order (flat path only)
    if team is not None:
        if embedding is not None:
            reordered = _team_embed_view(team, topo, embedding, link)
            emb_view = None if reordered is team else reordered
    else:
        emb = _resolve_embedding(embedding, topo, n, link, tuner=tuner)

    def _priced(a: str) -> float:
        if a == "hier":
            return allreduce_hier_schedule(
                partition, nbytes, topo=topo, link=link,
                embedding=embedding).time(topo, link)
        if team is not None:
            view = emb_view if a == "ring_emb" else team
            algo = "ring" if a == "ring_emb" else a
            return view.lift_schedule(
                build(n, nbytes, algorithm=algo)).time(topo, link)
        return build(n, nbytes, algorithm=a,
                     embedding=emb if a == "ring_emb" else None
                     ).time(topo, link)

    candidates = ["ring"] + (["rd"] if _is_pow2(n) else [])
    if emb is not None or emb_view is not None:
        candidates.append("ring_emb")
    if (partition is not None and team is None and collective == "allreduce"
            and partition.covers_world and partition.n_teams > 1
            and partition.size > 1):
        candidates.append("hier")
    if tuner is not None and team is None:
        # measured-first, restricted to the legal candidate set so knob
        # changes degrade to the best measured candidate that still runs
        pick = tuner.algorithm(collective, n, nbytes, topo,
                               candidates=candidates)
        if pick is not None:
            return pick
    return min(candidates, key=_priced)


# Upper bound on pipeline depth "auto" will consider; deeper pipelines pay
# one more per-stage alpha per chunk for ever-shrinking drain savings.
PIPELINE_MAX_CHUNKS = 16


def choose_schedule(n: int, nbytes: float, topo=None, link=None,
                    collective: str = "allreduce",
                    max_chunks: int = PIPELINE_MAX_CHUNKS,
                    partition=None, embedding=None,
                    tuner=None) -> tuple[str, int]:
    """choose_algorithm extended over the pipelining axis: price every
    candidate (algorithm, chunk-count) pair with the alpha-beta model —
    `abmodel.modeled_pipelined_time` for chunked, eq. 1 for monolithic —
    and return the cheapest ``(algorithm, n_chunks)``.

    n_chunks == 1 means monolithic execution; above the modeled pipelining
    cross-over (where the drained bandwidth saving outweighs the per-chunk
    alpha) the chunk count grows toward `max_chunks`.  With `partition`
    (allreduce only) the hierarchical schedule competes too — priced
    monolithic, since team-relative execution does not pipeline
    (DESIGN.md §11).  With `embedding` enabled, the mesh-embedded ring
    competes at every chunk count (it pipelines like the logical ring,
    DESIGN.md §12)."""
    from . import abmodel
    if n <= 1:
        return "ring", 1
    link = link if link is not None else abmodel.ICI_V5E
    build = _SELECTABLE[collective]
    emb = _resolve_embedding(embedding, topo, n, link, tuner=tuner)
    best, best_t = ("ring", 1), math.inf
    algos = ["ring"] + (["rd"] if _is_pow2(n) else []) \
        + (["ring_emb"] if emb is not None else [])
    hier_ok = (partition is not None and collective == "allreduce"
               and partition.covers_world and partition.n_teams > 1
               and partition.size > 1)
    if tuner is not None:
        # measured-best (algorithm, chunk-count) pair for this point
        # (DESIGN.md §13); the analytic pricing below is the fallback
        pick = tuner.schedule(collective, n, nbytes, topo,
                              algos=algos + (["hier"] if hier_ok else []),
                              max_chunks=max_chunks)
        if pick is not None:
            return ("hier", 1) if pick[0] == "hier" else pick
    for algo in algos:
        cost = build(n, nbytes, algorithm=algo,
                     embedding=emb if algo == "ring_emb" else None
                     ).cost(topo)
        c = abmodel.choose_chunks(cost, link, max_chunks=max_chunks)
        t = abmodel.modeled_pipelined_time(cost, c, link)
        if t < best_t:
            best, best_t = (algo, c), t
    if hier_ok:
        t = allreduce_hier_schedule(
            partition, nbytes, topo=topo, link=link,
            embedding=embedding).time(topo, link)
        if t < best_t:
            best, best_t = ("hier", 1), t
    return best


# ---------------------------------------------------------------------------
# cost descriptors — thin views over the same schedules that execute
# ---------------------------------------------------------------------------

def barrier_stages(n: int, topo=None) -> list[tuple[float, float, float]]:
    """[(bytes, hops, max_link_load)] per stage for the cost model."""
    return barrier_schedule(n).cost(topo)


def broadcast_stages(n: int, nbytes: float, topo=None):
    return broadcast_schedule(n, nbytes).cost(topo)


def fcollect_stages(n: int, nbytes: float, topo=None, algorithm=None):
    return fcollect_schedule(n, nbytes, algorithm).cost(topo)


def allreduce_stages(n: int, nbytes: float, topo=None, algorithm=None):
    return allreduce_schedule(n, nbytes, algorithm).cost(topo)


def alltoall_stages(n: int, nbytes_total: float, topo=None):
    return alltoall_schedule(n, nbytes_total).cost(topo)


# ---------------------------------------------------------------------------
# pipelined (chunked, double-buffered) schedule execution — DESIGN.md §10
# ---------------------------------------------------------------------------
# Large payloads split into static contiguous pieces; the executor issues
# stage k of piece c at pipeline step k + c, so stage k of chunk i overlaps
# stage k+1 of chunk i-1 (the paper's e-DMA double-buffering discipline).
# Pieces are dataflow-independent and every stage op (ppermute, select,
# elementwise combine, static block slicing) commutes with contiguous
# slicing of the payload, so pipelined execution is BIT-IDENTICAL to the
# eager/monolithic path — same ops, same per-element reduction order.

def _chunk_bounds(width: int, n_chunks) -> list[tuple[int, int]]:
    """Static contiguous piece boundaries (roughly equal; always at least
    one piece, so zero-width payloads still run a single empty piece)."""
    c = max(1, min(int(n_chunks), int(width)))
    if width <= 0:
        return [(0, 0)]
    edges = np.linspace(0, width, c + 1).astype(int)
    return [(int(lo), int(hi)) for lo, hi in zip(edges[:-1], edges[1:])
            if hi > lo]


def _software_pipeline(pieces, n_stages: int, stage_fn):
    """Run `stage_fn(piece_idx, stage_idx, state) -> state` over all pieces
    in pipelined issue order: step t advances piece c through stage t - c.
    Fill takes S steps, drain C-1 — the (S + C - 1)-slot pipeline that
    `abmodel.modeled_pipelined_time` prices."""
    states = list(pieces)
    n_pieces = len(states)
    for t in range(n_stages + n_pieces - 1):
        for c in range(n_pieces):
            k = t - c
            if 0 <= k < n_stages:
                states[c] = stage_fn(c, k, states[c])
    return states


def _resolve_chunks(pipeline_chunks, schedule: Schedule, topo=None,
                    link=None, tuner=None, key: tuple | None = None) -> int:
    """None/1 -> monolithic; "auto" -> abmodel.choose_chunks on the
    executing schedule's own cost descriptor (measured-first when a
    `tuner` and a ``(collective, algorithm, n, nbytes, topo)`` key are
    threaded); an int passes through."""
    if pipeline_chunks in (None, 0, 1):
        return 1
    if pipeline_chunks == "auto":
        from . import abmodel
        link = link if link is not None else abmodel.ICI_V5E
        return abmodel.choose_chunks(schedule.cost(topo), link,
                                     max_chunks=PIPELINE_MAX_CHUNKS,
                                     tuner=tuner, key=key)
    return int(pipeline_chunks)


def _slice_axis(v, lo: int, hi: int, ax: int):
    sl = [slice(None)] * v.ndim
    sl[ax] = slice(lo, hi)
    return v[tuple(sl)]


def _flat_pieces(net: NetOps, x, n_chunks):
    """Flatten the per-PE payload and cut it into static contiguous pieces;
    returns (pieces, bounds, restore)."""
    sim = isinstance(net, SimNetOps)
    shape = x.shape
    flat = x.reshape((shape[0], -1) if sim else (-1,))
    bounds = _chunk_bounds(flat.shape[-1], n_chunks)
    pieces = [flat[..., lo:hi] for lo, hi in bounds]

    def restore(parts):
        return jnp.concatenate(parts, axis=-1).reshape(shape)

    return pieces, bounds, restore


def _interleave_blocks(outs, bounds, n: int, ax: int):
    """Inverse of within-block chunking: each per-piece output carries `n`
    blocks of its piece's width along `ax`; reassemble the n full blocks
    (block i = concat over pieces of each piece's block i)."""
    cols = []
    for i in range(n):
        for out, (lo, hi) in zip(outs, bounds):
            w = hi - lo
            cols.append(_slice_axis(out, i * w, (i + 1) * w, ax))
    return jnp.concatenate(cols, axis=ax)


# ---------------------------------------------------------------------------
# barrier
# ---------------------------------------------------------------------------

def barrier(net: NetOps, token=None, team=None, algorithm: str | None = None,
            topo=None, link=None, profile=None):
    """Software barrier: dissemination (default — round k exchanges a
    token with rank (i + 2^k) of the group) or "tree" (binomial gather to
    rank 0, then binomial broadcast — sparser rounds, the low-congestion
    alternative); "auto" prices the two with the congestion model
    (`choose_barrier`).  `team`-relative ranks when a team is given.

    Returns a scalar token; thread it into downstream computation to order
    operations (the SPMD analogue of 'all cores reached this line')."""
    _, n, lift, _ = _team_view(net, team)
    algo = algorithm or "dissem"
    if algo == "auto":
        algo = choose_barrier(n, topo, link, team=team)
    if profile is not None:
        profile.note(algorithm=algo, schedule=barrier_schedule(n, algo),
                     topo=topo, link=link, collective="barrier", n_pes=n)
    tok = jnp.zeros((), jnp.int32) if token is None else token
    if isinstance(net, SimNetOps):
        tok = jnp.broadcast_to(tok, (net.n_pes,) + tok.shape[1:]) \
            if tok.ndim == 0 else tok
    stages = barrier_schedule(n, algo).stages
    if algo == "tree":
        n_gather = _ceil_log2(n)
        for st in stages[:n_gather]:          # reduce partial sums to rank 0
            tok = tok + net.ppermute(tok, lift(st.pattern))
        for st in stages[n_gather:]:          # broadcast the root's token
            p = lift(st.pattern)
            tok = net.select(p, net.ppermute(tok, p), tok)
        return tok
    for st in stages:
        tok = tok + net.ppermute(tok, lift(st.pattern))
    return tok


# ---------------------------------------------------------------------------
# broadcast (farthest-first binomial tree)
# ---------------------------------------------------------------------------

def broadcast(net: NetOps, x, root: int = 0, pipeline_chunks=None,
              topo=None, link=None, team=None, profile=None, tuner=None):
    """Farthest-first binomial broadcast; with `team`, `root` is a TEAM
    rank and only members take the root's value (non-members keep x)."""
    _, n, lift, _ = _team_view(net, team)
    if n == 1:
        return x
    nbytes = _payload_bytes(net, x)
    sched = broadcast_schedule(n, nbytes, root)
    chunks = _resolve_chunks(pipeline_chunks, sched, topo, link, tuner,
                             ("broadcast", "binomial_ff", n, nbytes, topo)) \
        if team is None else 1
    if profile is not None:
        profile.note(algorithm="binomial_ff", chunks=chunks, schedule=sched,
                     topo=topo, link=link, collective="broadcast",
                     nbytes=nbytes, n_pes=n)
    if chunks > 1:
        pieces, _, restore = _flat_pieces(net, x, chunks)

        def stage(c, k, buf):
            st = sched.stages[k]
            recv = net.ppermute(buf, st.pattern)
            return net.select(st.pattern, recv, buf)

        return restore(_software_pipeline(pieces, len(sched.stages), stage))
    buf = x
    for st in sched.stages:
        p = lift(st.pattern)
        recv = net.ppermute(buf, p)
        buf = net.select(p, recv, buf)
    return buf


# ---------------------------------------------------------------------------
# fcollect / collect (allgather)
# ---------------------------------------------------------------------------

def fcollect(net: NetOps, x, axis: int = 0, algorithm: str | None = None,
             pipeline_chunks=None, topo=None, link=None, team=None,
             embedding=None, profile=None, tuner=None):
    """Concatenate equal-size blocks from all group members along `axis`.

    Recursive doubling (log2 N stages, doubling message size) when the
    group size is a power of two, ring otherwise — the paper's
    fcollect/collect split.  "auto" cost-model-selects; "ring_emb" (or an
    enabled `embedding` with the ring) runs the MESH-EMBEDDED ring: the
    ring in snake coordinates, with one static block permutation restoring
    PE order afterwards — the output is bit-identical to the logical ring
    (pure data movement), only the flows change (DESIGN.md §12).
    `pipeline_chunks` > 1 executes the schedule chunked/double-buffered
    (bit-identical; §10).  With `team`, blocks concatenate in TEAM-rank
    order; non-members return zeros (team collectives run monolithic,
    §11)."""
    _, n, _, _ = _team_view(net, team)
    if n == 1:
        return x
    emb = _resolve_embedding(embedding, topo, n, link, tuner=tuner) \
        if team is None else None
    nbytes = _payload_bytes(net, x)
    if algorithm == "auto":
        # teams take the raw knob (choose_algorithm prices the embedded
        # team view); the flat path passes the resolved order
        algo = choose_algorithm(n, nbytes, topo, link, collective="fcollect",
                                team=team,
                                embedding=emb if team is None else embedding,
                                tuner=tuner)
    else:
        algo = algorithm or ("rd" if _is_pow2(n) else "ring")
        if algorithm is None and algo == "ring" and (
                emb is not None
                or (team is not None and embedding is not None)):
            algo = "ring_emb"       # default policy + enabled embedding
    if algo == "ring_emb":
        if team is not None:        # embedding in team coordinates (§12)
            if profile is not None:
                profile.note(algorithm="ring_emb", collective="fcollect",
                             nbytes=nbytes, n_pes=n)
            return _collect_ring_team_embedded(net, x, axis, team, topo,
                                               embedding, link)
        if emb is None:
            # explicit algorithm= without the knob: snake default (as
            # allreduce); stays "ring" when no usable topology exists
            emb = _resolve_embedding("snake", topo, n, link)
        if emb is None:
            algo = "ring"                     # no usable embedding: logical
    sched = fcollect_schedule(n, nbytes, algo,
                              embedding=emb if algo == "ring_emb" else None)
    chunks = 1 if team is not None else _resolve_chunks(
        pipeline_chunks, sched, topo, link, tuner,
        ("fcollect", algo, n, nbytes, topo))
    if profile is not None:
        profile.note(algorithm=algo, chunks=chunks, schedule=sched,
                     topo=topo, link=link, collective="fcollect",
                     nbytes=nbytes, n_pes=n,
                     embedding=emb if algo == "ring_emb" else None)
    if algo == "ring_emb":
        return _collect_ring_embedded(net, x, axis, emb, n_chunks=chunks)
    if algo == "rd":
        return _fcollect_rd(net, x, axis, n_chunks=chunks, team=team)
    return _collect_ring(net, x, axis, n_chunks=chunks, team=team)


def collect(net: NetOps, x, axis: int = 0, pipeline_chunks=None,
            topo=None, link=None, team=None, embedding=None, profile=None,
            tuner=None):
    """The paper's linear-scaling ring collect (mesh-embedded when
    `embedding` is enabled — bit-identical output, near-neighbor flows)."""
    _, n, _, _ = _team_view(net, team)
    if n == 1:
        return x
    nbytes = _payload_bytes(net, x)
    if team is not None and embedding is not None:
        if profile is not None:
            profile.note(algorithm="ring_emb", collective="collect",
                         nbytes=nbytes, n_pes=n)
        return _collect_ring_team_embedded(net, x, axis, team, topo,
                                           embedding, link)
    emb = _resolve_embedding(embedding, topo, n, link, tuner=tuner) \
        if team is None else None
    algo = "ring_emb" if emb is not None else "ring"
    sched = fcollect_schedule(n, nbytes, algo, embedding=emb)
    chunks = 1 if team is not None else _resolve_chunks(
        pipeline_chunks, sched, topo, link, tuner,
        ("collect", algo, n, nbytes, topo))
    if profile is not None:
        profile.note(algorithm=algo, chunks=chunks, schedule=sched,
                     topo=topo, link=link, collective="collect",
                     nbytes=nbytes, n_pes=n, embedding=emb)
    if emb is not None:
        return _collect_ring_embedded(net, x, axis, emb, n_chunks=chunks)
    return _collect_ring(net, x, axis, n_chunks=chunks, team=team)


def _permute_blocks_static(net: NetOps, x, idx_np, n: int, axis: int):
    """out block t = x block idx_np[t] — a HOST-constant block gather
    (same for every PE), the post-pass that restores world block order
    after an embedded ring ran in snake coordinates."""
    sim = isinstance(net, SimNetOps)
    ax = axis + (1 if sim else 0)
    shp = x.shape
    vb = x.reshape(shp[:ax] + (n, shp[ax] // n) + shp[ax + 1:])
    out = jnp.take(vb, jnp.asarray(np.asarray(idx_np)), axis=ax)
    return out.reshape(shp)


def _collect_ring_team_embedded(net: NetOps, x, axis: int, team, topo,
                                embedding=None, link=None):
    """Team-scoped embedded ring collect: run the ring over the team
    REORDERED along the world embedding order (`_team_embed_view` — the
    embedding in team coordinates), then statically restore blocks to the
    ORIGINAL team's rank order, so the output layout is identical to the
    plain team path (bitwise — pure data movement).  Falls back to the
    plain team ring when no usable topology is attached."""
    view = _team_embed_view(team, topo, embedding, link)
    out = _collect_ring(net, x, axis, team=view)
    if view is team:
        return out
    # view path leaves block t = member with VIEW rank t; original team
    # rank j's member sits at view position view.rank_np[members[j]]
    idx = np.array([view.rank_np[m] for m in team.members])
    return _permute_blocks_static(net, out, idx, team.size, axis)


def _collect_ring_embedded(net: NetOps, x, axis: int, order,
                           n_chunks: int = 1):
    """Ring collect over the embedding order: run the team-relative ring
    in snake coordinates (every hop one physical hop), then restore PE
    block order with one static block permutation.  Pure data movement —
    bitwise identical to the logical ring's output; chunks pipeline like
    the logical ring (the embedding team covers the world)."""
    n = len(order)
    emb_team = _embedding_team(order, n)
    out = _collect_ring(net, x, axis, n_chunks=n_chunks, team=emb_team)
    # team path leaves block t = PE order[t]'s data; PE j's block sits at
    # position rank_np[j]
    return _permute_blocks_static(net, out, emb_team.rank_np, n, axis)


def _out_zeros_like(x, axis, n, pe_leading):
    shp = list(x.shape)
    ax = axis + (1 if pe_leading else 0)
    shp[ax] = shp[ax] * n
    return jnp.zeros(shp, x.dtype)


def _fcollect_rd(net: NetOps, x, axis: int, n_chunks: int = 1, team=None):
    rank, n, lift, mask = _team_view(net, team)
    blk = x.shape[axis + (1 if isinstance(net, SimNetOps) else 0)]
    buf = _out_zeros_like(x, axis, n, isinstance(net, SimNetOps))

    def place(b, v, i):
        starts = [0] * b.ndim
        starts[axis] = i * blk
        return lax.dynamic_update_slice(b, v, tuple(starts))

    buf = _lmap(net, place, buf, x, rank)
    stages = fcollect_schedule(n, _payload_bytes(net, x), "rd").stages
    if team is not None:
        for st in stages:
            buf = buf + net.ppermute(buf, lift(st.pattern))
        return _mask_out(net, mask, buf)
    if n_chunks > 1:
        # every stage is elementwise (ppermute + add of disjoint regions),
        # so pipelining slices the filled output buffer directly
        pieces, _, restore = _flat_pieces(net, buf, n_chunks)

        def stage(c, k, b):
            return b + net.ppermute(b, stages[k].pattern)

        return restore(_software_pipeline(pieces, len(stages), stage))
    for st in stages:
        recv = net.ppermute(buf, st.pattern)
        buf = buf + recv  # disjoint filled regions, zeros elsewhere
    return buf


# Ring collectives use a STATIC schedule: every PE-dependent block index
# is hoisted into one pre- or post-rotation (a single gather), so loop
# bodies contain no dynamic_update_slice at all.  This mirrors how the
# paper's PEs precompute their schedule in shmem_init, and it is what
# keeps per-stage HBM traffic at one block instead of one full buffer
# (EXPERIMENTS.md §Perf P1).  Set "dus" to get the naive baseline back.
RING_SCHEDULE = "static"


def _take_blocks(net: NetOps, x, idx, nblk: int, axis: int):
    """out block t = x block idx[t] (idx traced per PE), one gather."""
    def one(v, ix):
        shp = v.shape
        vb = v.reshape(shp[:axis] + (nblk, shp[axis] // nblk)
                       + shp[axis + 1:])
        taken = jnp.take(vb, ix, axis=axis)
        return taken.reshape(shp)
    return _lmap(net, one, x, idx)


def _collect_ring(net: NetOps, x, axis: int, n_chunks: int = 1, team=None):
    rank, n, lift, mask = _team_view(net, team)
    if RING_SCHEDULE == "dus" and team is None:
        return _collect_ring_dus(net, x, axis)
    sim = isinstance(net, SimNetOps)
    ax = axis + (1 if sim else 0)
    stages = fcollect_schedule(n, _payload_bytes(net, x), "ring").stages
    # out block i = stacked part (rank - i) mod n
    idx = (rank[..., None] - jnp.arange(n)) % n if sim \
        else (rank - jnp.arange(n)) % n
    if team is not None and (n_chunks <= 1 or mask is not None):
        # proper-subset teams run monolithic (§11); covering teams — the
        # embedded ring's coordinate system — fall through and may chunk
        parts = [x]
        cur = x
        for st in stages:
            cur = net.ppermute(cur, lift(st.pattern))
            parts.append(cur)               # part t holds block (rank - t)
        stacked = jnp.concatenate(parts, axis=ax)
        return _mask_out(net, mask, _take_blocks(net, stacked, idx, n, axis))
    if n_chunks > 1:
        # chunk WITHIN the per-PE block along `axis` so each piece runs the
        # identical ring; block order is restored piece-wise and the full
        # blocks reassembled by interleaving
        bounds = _chunk_bounds(x.shape[ax], n_chunks)
        pieces = [[_slice_axis(x, lo, hi, ax)] for lo, hi in bounds]

        def stage(c, k, parts):
            return parts + [net.ppermute(parts[-1], lift(stages[k].pattern))]

        outs = []
        for parts in _software_pipeline(pieces, len(stages), stage):
            stacked_c = jnp.concatenate(parts, axis=ax)
            outs.append(_take_blocks(net, stacked_c, idx, n, axis))
        return _interleave_blocks(outs, bounds, n, ax)
    parts = [x]
    cur = x
    for st in stages:
        cur = net.ppermute(cur, st.pattern)
        parts.append(cur)                   # part t holds block (pe - t)
    stacked = jnp.concatenate(parts, axis=ax)
    return _take_blocks(net, stacked, idx, n, axis)


def _collect_ring_dus(net: NetOps, x, axis: int):
    n = net.n_pes
    sim = isinstance(net, SimNetOps)
    blk = x.shape[axis + (1 if sim else 0)]
    buf = _out_zeros_like(x, axis, n, sim)
    pe = net.my_pe()
    ring = ring_pattern(n)

    cur = x
    for j in range(n):
        idx_arr = (pe - j) % n

        def place(b, v, i):
            starts = [0] * b.ndim
            starts[axis] = i * blk
            return lax.dynamic_update_slice(b, v, tuple(starts))

        buf = _lmap(net, place, buf, cur, idx_arr)
        if j < n - 1:
            cur = net.ppermute(cur, ring)
    return buf


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

OPS: dict[str, Callable] = {
    "sum": jnp.add,
    "prod": jnp.multiply,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
}


RING_BYTES_THRESHOLD = 1 << 20   # 1 MiB: the old hand-tuned switch point,
                                 # kept as a reference for tests/benches;
                                 # "auto" now prices schedules instead.


def allreduce(net: NetOps, x, op: str = "sum", combine: Callable | None = None,
              algorithm: str | None = None, topo=None, link=None,
              pipeline_chunks=None, team=None, partition=None,
              embedding=None, profile=None, tuner=None):
    """shmem_TYPE_OP_to_all.

    Algorithm selection generalizes the paper's PE-count switch (§3.6:
    dissemination for powers of two, ring otherwise).  "auto" prices the
    candidate schedules with the alpha-beta model on `topo`
    (`choose_algorithm`): recursive doubling moves the FULL buffer log2(N)
    times (alpha-optimal), the ring moves ~2x the buffer total
    (bandwidth-optimal), so large payloads take the ring even at
    power-of-two PE counts.  Explicit "rd"/"ring" override; "hier" runs
    the hierarchical two-level schedule over `partition` (DESIGN.md §11),
    and "auto" prices it as a candidate whenever a partition is given.

    `team` scopes the reduction to a Team (members reduce among
    themselves; non-members pass x through unchanged) or runs every team
    of a TeamPartition concurrently; team execution is monolithic.

    `pipeline_chunks` > 1 executes the chosen schedule chunked and
    double-buffered (bit-identical to monolithic; DESIGN.md §10);
    "auto" for BOTH knobs prices every (algorithm, chunk-count) pair
    (`choose_schedule`) and runs the cheapest.

    `embedding` ("auto" / "snake" / an explicit rank order) enables the
    MESH-EMBEDDED ring (DESIGN.md §12): the same ring algorithm run in
    snake coordinates, so every stage is one physical hop and (meshes
    with a Hamiltonian cycle) no two flows share a link.  It joins the
    "auto" candidate set as "ring_emb" and re-coordinates default-policy
    rings; results are exact for int dtypes and allclose for floats (the
    ring summation order follows the embedding)."""
    fn = combine or OPS[op]
    nbytes = _payload_bytes(net, x)
    if team is not None:
        if algorithm == "hier" or partition is not None:
            raise ValueError(
                "team= and partition= are mutually exclusive: hier runs "
                "over a world-covering partition=; team-scoped reductions "
                "are flat rd/ring")
        _, n, _, _ = _team_view(net, team)
        if n == 1:
            return x
        if algorithm == "auto":
            algo = choose_algorithm(n, nbytes, topo, link, team=team,
                                    embedding=embedding, tuner=tuner)
        elif algorithm in (None, "paper"):
            algo = "rd" if _is_pow2(n) else "ring"
            if algorithm is None and algo == "ring" and embedding is not None:
                algo = "ring_emb"
        else:
            algo = algorithm
        if profile is not None:
            profile.note(algorithm=algo, collective="allreduce",
                         nbytes=nbytes, n_pes=n)
        if algo == "ring_emb":
            # the embedding in team coordinates: the reordered team IS the
            # embedded ring (same members, snake-adjacent rank order) —
            # also for an explicit algorithm= without the knob, mirroring
            # the flat path's snake default
            return _allreduce_team(
                net, x, fn, "ring",
                _team_embed_view(team, topo, embedding, link))
        return _allreduce_team(net, x, fn, algo, team)
    n = net.n_pes
    if n == 1:
        return x
    if algorithm == "hier":
        if profile is not None:
            profile.note(algorithm="hier", collective="allreduce",
                         nbytes=nbytes, n_pes=n)
        return allreduce_hier(net, x, op, combine=combine,
                              partition=partition, topo=topo, link=link,
                              embedding=embedding)
    emb = _resolve_embedding(embedding, topo, n, link, tuner=tuner)
    if algorithm == "ring_emb" and emb is None:
        # explicit algorithm= without the knob: default to the snake, and
        # resolve BEFORE chunk selection so choose_chunks prices the
        # embedded stages that actually execute
        emb = _resolve_embedding("snake", topo, n, link)
    if algorithm == "auto" and pipeline_chunks == "auto":
        algo, chunks = choose_schedule(n, nbytes, topo, link,
                                       partition=partition, embedding=emb,
                                       tuner=tuner)
    else:
        if algorithm == "auto":
            algo = choose_algorithm(n, nbytes, topo, link,
                                    partition=partition, embedding=emb,
                                    tuner=tuner)
        elif algorithm is None:
            algo = "rd" if _is_pow2(n) else "ring"
            if algo == "ring" and emb is not None:
                algo = "ring_emb"   # default policy + enabled embedding
        else:
            algo = algorithm
        chunks = 1 if algo == "hier" else _resolve_chunks(
            pipeline_chunks,
            allreduce_schedule(n, nbytes, algo, embedding=emb), topo, link,
            tuner, ("allreduce", algo, n, nbytes, topo))
    if profile is not None:
        sched = None if algo == "hier" else allreduce_schedule(
            n, nbytes, algo,
            embedding=emb if algo == "ring_emb" else None)
        profile.note(algorithm=algo, chunks=chunks, schedule=sched,
                     topo=topo, link=link, collective="allreduce",
                     nbytes=nbytes, n_pes=n,
                     embedding=emb if algo == "ring_emb" else None)
    if algo == "hier":
        return allreduce_hier(net, x, op, combine=combine,
                              partition=partition, topo=topo, link=link,
                              embedding=embedding)
    if algo == "ring_emb":
        if emb is None:
            algo = "ring"           # no usable embedding: logical ring
        else:
            emb_team = _embedding_team(emb, n)
            if chunks > 1:
                return _allreduce_ring_pipelined(net, x, fn, chunks,
                                                 team=emb_team)
            rs, info = _reduce_scatter_ring(net, x, fn, team=emb_team)
            return allgather_unpad(net, rs, info, team=emb_team)
    if algo == "rd":
        stages = allreduce_schedule(n, nbytes, "rd").stages
        if chunks > 1:
            return jax.tree.map(
                lambda v: _allreduce_rd_pipelined(net, v, fn, stages, chunks),
                x)
        for st in stages:
            recv = net.ppermute(x, st.pattern)
            x = jax.tree.map(fn, x, recv)
        return x
    if chunks > 1:
        return _allreduce_ring_pipelined(net, x, fn, chunks)
    rs, shape_info = _reduce_scatter_ring(net, x, fn)
    return allgather_unpad(net, rs, shape_info)


def _allreduce_team(net: NetOps, x, fn, algo: str, team):
    """Team-scoped allreduce (monolithic): rd runs lifted xor stages with
    the combine applied everywhere (non-members receive zeros and are
    restored by the final mask); ring runs the team-relative
    reduce-scatter + allgather."""
    _, n, lift, mask = _team_view(net, team)
    if algo == "rd":
        out = x
        for st in allreduce_schedule(n, _payload_bytes(net, x), "rd").stages:
            recv = net.ppermute(out, lift(st.pattern))
            out = jax.tree.map(fn, out, recv)
    else:
        rs, info = _reduce_scatter_ring(net, x, fn, team=team)
        out = allgather_unpad(net, rs, info, team=team)
    return _mask_out(net, mask, out, keep=x)


def _allreduce_rd_pipelined(net: NetOps, x, fn, stages, n_chunks: int):
    """Recursive doubling is elementwise per stage (ppermute + combine), so
    pipelining slices the flat payload directly."""
    pieces, _, restore = _flat_pieces(net, x, n_chunks)

    def stage(c, k, buf):
        return fn(buf, net.ppermute(buf, stages[k].pattern))

    return restore(_software_pipeline(pieces, len(stages), stage))


def _allreduce_ring_pipelined(net: NetOps, x, fn, n_chunks: int, team=None):
    """Ring reduce-scatter + allgather, chunked WITHIN the owned 1/n block
    so every element keeps its monolithic block index — and therefore its
    exact reduction order (bit-identical to the eager path).  The fused
    pipeline lets chunk i's allgather stages overlap chunk i+1's
    reduce-scatter stages.

    `team` must be a WORLD-COVERING team (an embedding): the ring then
    runs in its rank coordinates — the mesh-embedded pipelined allreduce
    — with patterns lifted to the world flows that execute."""
    rank, n, lift, mask = _team_view(net, team)
    assert mask is None, "pipelined ring needs a world-covering group"
    sim = isinstance(net, SimNetOps)
    orig_shape = x.shape[1:] if sim else x.shape
    size = int(np.prod(orig_shape))
    chunk = -(-size // n)
    padded = chunk * n

    def flatpad(v):
        f = v.reshape(-1)
        return jnp.pad(f, (0, padded - size))

    buf = _lmap(net, flatpad, x)
    idx = (rank[..., None] + jnp.arange(n)) % n if sim \
        else (rank + jnp.arange(n)) % n
    r = _take_blocks(net, buf, idx, n, 0)

    nbytes = _payload_bytes(net, x)
    rs = reduce_scatter_schedule(n, nbytes).stages
    ag = allgather_schedule(n, float(padded * buf.dtype.itemsize)).stages
    bounds = _chunk_bounds(chunk, n_chunks)

    def piece_of(t: int, lo: int, hi: int):
        base = t * chunk
        return r[..., base + lo:base + hi]

    def stage(c, k, state):
        lo, hi = bounds[c]
        cur, parts = state
        if k < len(rs):
            j = k + 1
            cur = net.ppermute(cur, lift(rs[k].pattern))
            cur = fn(piece_of(n - j, lo, hi), cur)
            return (cur, (cur,) if k == len(rs) - 1 else parts)
        cur = net.ppermute(cur, lift(ag[k - len(rs)].pattern))
        return (cur, parts + (cur,))

    init = [(piece_of(0, lo, hi), ()) for lo, hi in bounds]
    finals = _software_pipeline(init, len(rs) + len(ag), stage)
    idx2 = (rank[..., None] + 1 - jnp.arange(n)) % n if sim \
        else (rank + 1 - jnp.arange(n)) % n
    outs = []
    for _, parts in finals:
        stacked_c = jnp.concatenate(parts, axis=-1)
        outs.append(_take_blocks(net, stacked_c, idx2, n, 0))
    out = _interleave_blocks(outs, bounds, n, -1)

    def unpad(b):
        return b[:size].reshape(orig_shape)

    return _lmap(net, unpad, out)


def reduce_scatter(net: NetOps, x, op: str = "sum",
                   combine: Callable | None = None, team=None, profile=None):
    """Ring reduce-scatter; returns this PE's owned chunk of the flattened,
    padded array plus the info needed to allgather/unpad it.  With `team`
    the ring runs in team coordinates (a TeamPartition runs every team's
    ring concurrently); chunk ownership is by team rank."""
    fn = combine or OPS[op]
    if profile is not None:
        nbytes = _payload_bytes(net, x)
        profile.note(algorithm="ring",
                     schedule=reduce_scatter_schedule(net.n_pes, nbytes),
                     collective="reduce_scatter", nbytes=nbytes,
                     n_pes=net.n_pes)
    return _reduce_scatter_ring(net, x, fn, team=team)


def _reduce_scatter_parts(net: NetOps, x, fn, team=None):
    """The ring reduce-scatter of `_reduce_scatter_ring` with the FINAL
    combine left undone: runs all n-1 ring stages but returns the last
    stage's two operands separately instead of `fn`-combining them, so a
    fused consumer (core/fusion.fused_rs_adam) can land that combine
    inside its own kernel (DESIGN.md §14).

    Returns ``(local_last, incoming, info, mask)``: the owned chunk is
    ``fn(local_last, incoming)`` (``incoming`` is None when n == 1 and
    ``local_last`` is already final).  `info`/`mask` as in
    `_reduce_scatter_ring`; callers must apply `_mask_out(net, mask, ...)`
    to whatever they derive from the chunk."""
    rank, n, lift, mask = _team_view(net, team)
    sim = isinstance(net, SimNetOps)
    orig_shape = x.shape[1:] if sim else x.shape
    size = int(np.prod(orig_shape))
    chunk = -(-size // n)
    padded = chunk * n

    def flatpad(v):
        f = v.reshape(-1)
        return jnp.pad(f, (0, padded - size))

    buf = _lmap(net, flatpad, x)
    idx = (rank[..., None] + jnp.arange(n)) % n if sim \
        else (rank + jnp.arange(n)) % n
    r = _take_blocks(net, buf, idx, n, 0)

    def static_chunk(b, t):
        return b[..., t * chunk:(t + 1) * chunk] if sim \
            else b[t * chunk:(t + 1) * chunk]

    # rank p ends up owning the fully-reduced chunk (p + 1) % n
    own_idx = (rank + 1) % n
    info = (orig_shape, size, chunk, own_idx)
    cur = static_chunk(r, 0)                     # chunk[rank]
    if n == 1:
        return cur, None, info, mask
    sched = reduce_scatter_schedule(n, _payload_bytes(net, x))
    for j, st in enumerate(sched.stages[:-1], start=1):
        cur = net.ppermute(cur, lift(st.pattern))
        cur = fn(static_chunk(r, n - j), cur)    # chunk[(rank - j) mod n]
    incoming = net.ppermute(cur, lift(sched.stages[-1].pattern))
    return static_chunk(r, 1), incoming, info, mask


def _reduce_scatter_ring(net: NetOps, x, fn, team=None):
    """Ring reduce-scatter with the static schedule (§Perf P1): one
    pre-rotation puts every stage's chunk at a STATIC offset, so the loop
    body is free of dynamic slicing (r block t = chunk (rank + t) mod n).
    `rank` is the group rank of the `team` view (the PE id for the
    world); non-members of a proper-subset team get a zero chunk."""
    local, incoming, info, mask = _reduce_scatter_parts(net, x, fn,
                                                        team=team)
    cur = local if incoming is None else fn(local, incoming)
    return _mask_out(net, mask, cur), info


def allgather_unpad(net: NetOps, chunk_val, info, team=None):
    """Ring allgather of a `reduce_scatter` result, undoing its flatten/pad.

    `info` is the handle `reduce_scatter` returned alongside the owned
    chunk: ``(orig_shape, size, chunk, own_idx)``.  Static schedule: parts
    arrive in ring order; one post-gather restores block order, then the
    padding is stripped and the original shape restored.  Composing
    ``allgather_unpad(net, *reduce_scatter(net, x))`` is the
    bandwidth-optimal ring allreduce (~2x payload on the wire vs log2(N)x
    for recursive doubling) — the ZeRO-style gradient-sync building block
    (DESIGN.md §8).  Pass the same `team` the reduce-scatter ran with;
    non-members of a proper-subset team read zeros."""
    orig_shape, size, chunk, own_idx = info
    rank, n, lift, mask = _team_view(net, team)
    sim = isinstance(net, SimNetOps)
    nbytes = float(chunk * n * chunk_val.dtype.itemsize)
    parts = [chunk_val]                 # part t = chunk (rank + 1 - t) mod n
    cur = chunk_val
    for st in allgather_schedule(n, nbytes).stages:
        cur = net.ppermute(cur, lift(st.pattern))
        parts.append(cur)
    stacked = jnp.concatenate(parts, axis=-1)
    # out block i = part (rank + 1 - i) mod n
    idx = (rank[..., None] + 1 - jnp.arange(n)) % n if sim \
        else (rank + 1 - jnp.arange(n)) % n
    out = _take_blocks(net, stacked, idx, n, 0)

    def unpad(b):
        return b[:size].reshape(orig_shape)

    return _mask_out(net, mask, _lmap(net, unpad, out))


# Backwards-compatible private alias (promoted to the public API above).
_allgather_unpad = allgather_unpad


# ---------------------------------------------------------------------------
# alltoall (pairwise exchange — paper Fig. 9)
# ---------------------------------------------------------------------------

def alltoall(net: NetOps, x, axis: int = 0, pipeline_chunks=None,
             topo=None, link=None, team=None, profile=None, tuner=None):
    """out[src-block] = x_src[my-block]; x's `axis` dim = group size *
    block (group = the world, or `team`'s members in team-rank order).

    Static schedule (§Perf P1): one pre-rotation makes every stage's send
    block a static slice; received parts concatenate in ring order and one
    post-gather restores block order — no per-stage dynamic updates.
    `pipeline_chunks` > 1 chunks each block's payload and pipelines the
    pairwise sends (bit-identical; DESIGN.md §10; team execution is
    monolithic, non-members return zeros)."""
    rank, n, lift, mask = _team_view(net, team)
    if n == 1:
        return x
    sim = isinstance(net, SimNetOps)
    ax = axis + (1 if sim else 0)
    dim = x.shape[ax]
    assert dim % n == 0, f"alltoall axis dim {dim} not divisible by n={n}"

    # pre-rotate: r block t = x block (rank + t) mod n
    idx = (rank[..., None] + jnp.arange(n)) % n if sim \
        else (rank + jnp.arange(n)) % n
    r = _take_blocks(net, x, idx, n, axis)
    blk = dim // n
    nbytes = _payload_bytes(net, x)
    sched = alltoall_schedule(n, nbytes)
    out_idx = (rank[..., None] - jnp.arange(n)) % n if sim \
        else (rank - jnp.arange(n)) % n

    def static_blk(v, t, lo=0, hi=blk):
        sl = [slice(None)] * v.ndim
        sl[ax] = slice(t * blk + lo, t * blk + hi)
        return v[tuple(sl)]

    if team is not None:
        if profile is not None:
            profile.note(algorithm="pairwise", schedule=sched, topo=topo,
                         link=link, collective="alltoall",
                         nbytes=nbytes, n_pes=n)
        parts = [static_blk(r, 0)]
        for j, st in enumerate(sched.stages, start=1):
            parts.append(net.ppermute(static_blk(r, j), lift(st.pattern)))
        stacked = jnp.concatenate(parts, axis=ax)
        return _mask_out(net, mask,
                         _take_blocks(net, stacked, out_idx, n, axis))

    chunks = _resolve_chunks(pipeline_chunks, sched, topo, link, tuner,
                             ("alltoall", "pairwise", n, nbytes, topo))
    if profile is not None:
        profile.note(algorithm="pairwise", chunks=chunks, schedule=sched,
                     topo=topo, link=link, collective="alltoall",
                     nbytes=nbytes, n_pes=n)
    if chunks > 1:
        bounds = _chunk_bounds(blk, chunks)

        def stage(c, k, parts):
            lo, hi = bounds[c]
            st = sched.stages[k]
            return parts + (net.ppermute(static_blk(r, k + 1, lo, hi),
                                         st.pattern),)

        init = [(static_blk(r, 0, lo, hi),) for lo, hi in bounds]
        outs = []
        for parts in _software_pipeline(init, len(sched.stages), stage):
            stacked_c = jnp.concatenate(parts, axis=ax)
            outs.append(_take_blocks(net, stacked_c, out_idx, n, axis))
        return _interleave_blocks(outs, bounds, n, ax)

    parts = [static_blk(r, 0)]          # own block: out[pe] = x_pe[pe]
    for j, st in enumerate(sched.stages, start=1):
        recv = net.ppermute(static_blk(r, j), st.pattern)
        parts.append(recv)              # part t = out-block (pe - t) mod n
    stacked = jnp.concatenate(parts, axis=ax)
    return _take_blocks(net, stacked, out_idx, n, axis)


# ---------------------------------------------------------------------------
# point-to-point RMA
# ---------------------------------------------------------------------------

def put(net: NetOps, x, pattern: Sequence[tuple[int, int]]):
    """One-sided put along a static (src, dst) pattern; PEs not receiving
    keep zeros (use shmem.put for merge-with-local semantics)."""
    return net.ppermute(x, pattern)


def get(net: NetOps, x, pattern: Sequence[tuple[int, int]]):
    """get along (requester, owner) pairs: owner pushes — the IPI-get.
    The inverse pairs are compiled directly so fan-out reads (many
    requesters naming one owner) validate against the executed pattern."""
    if isinstance(pattern, CommPattern):
        return net.ppermute(x, pattern.inverse)
    return net.ppermute(x, [(o, r) for r, o in pattern])


# ---------------------------------------------------------------------------
# scans (substrate for atomics)
# ---------------------------------------------------------------------------

def exclusive_scan(net: NetOps, x, op: str = "sum"):
    """Exclusive scan over the PE axis of a per-PE scalar/array.

    This realizes the observable semantics of concurrent shmem atomics in
    PE order (DESIGN.md §6): fetch_add's return on PE i = init + sum of
    contributions of PEs < i."""
    n = net.n_pes
    fn = OPS[op]
    identity = {"sum": 0, "prod": 1, "max": None, "min": None,
                "and": -1, "or": 0, "xor": 0}[op]
    sim = isinstance(net, SimNetOps)
    xb = x[:, None] if (sim and x.ndim == 1) else jnp.expand_dims(x, 0 if not sim else 1)
    all_vals = fcollect(net, xb, axis=0)
    pe = net.my_pe()

    def scan_one(vals, i):
        idx = jnp.arange(n)
        if identity is None:  # max/min: mask with +-inf
            fill = jnp.array(jnp.finfo(vals.dtype).min if op == "max"
                             else jnp.finfo(vals.dtype).max, vals.dtype)
            masked = jnp.where((idx < i)[(...,) + (None,) * (vals.ndim - 1)], vals, fill)
            return jnp.max(masked, 0) if op == "max" else jnp.min(masked, 0)
        masked = jnp.where((idx < i)[(...,) + (None,) * (vals.ndim - 1)], vals,
                           jnp.array(identity, vals.dtype))
        if op == "sum":
            return jnp.sum(masked, 0)
        if op == "prod":
            return jnp.prod(masked, 0)
        red = masked[0]
        for k in range(1, n):
            red = fn(red, masked[k])
        return red

    return _lmap(net, scan_one, all_vals, pe)
