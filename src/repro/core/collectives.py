"""The paper's collective algorithms (§3.6), written once over NetOps.

Algorithm choices mirror the paper exactly:

  * barrier        — dissemination (ceil(log2 N) rounds, 8*log2(N) bytes of
                     sync state); the 'WAND hardware barrier' analogue is a
                     zero-byte psum left to XLA (shmem.py).
  * broadcast      — binomial tree, *farthest-first*: largest stride first
                     so later stages do not add network congestion.
  * fcollect       — recursive doubling for powers of two, ring otherwise.
  * collect        — ring (the paper's linear-scaling variant).
  * reductions     — dissemination/recursive-doubling for powers of two,
                     ring (reduce-scatter + allgather) otherwise.
  * alltoall       — pairwise exchange, one ring offset per stage.

Every algorithm is a ``*_schedule`` builder returning a
:class:`~repro.core.pattern.Schedule` of compiled
:class:`~repro.core.pattern.CommPattern` stages (DESIGN.md §9).  The
executor iterates the schedule's stages; the alpha-beta cost descriptor
(``*_stages``, the benchmarks' `derived` column, the roofline cross-check)
is ``schedule.cost(topo)`` on the *same object* — predicted and executed
schedules cannot drift apart.  `choose_algorithm` prices candidate
schedules with the cost model to pick the cheapest (`algorithm="auto"`).

All functions take the PE-local array (under SPMD) or the PE-stacked array
(under SIM) — `_lmap` hides the difference for shape-changing local ops.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .netops import NetOps, SimNetOps
from .pattern import (CommPattern, Schedule, Stage, as_pattern,
                      binomial_stage_pattern, ring_pattern, xor_pattern)


def _lmap(net: NetOps, f: Callable, *xs):
    """Apply a PE-local function under either backend."""
    if isinstance(net, SimNetOps):
        return jax.vmap(f)(*xs)
    return f(*xs)


def _ceil_log2(n: int) -> int:
    return max(1, n - 1).bit_length() if n > 1 else 0


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _bcast_pe(net: NetOps, shape) -> jnp.ndarray:
    """my_pe broadcast to pair with local arrays in _lmap."""
    return net.my_pe()


def _payload_bytes(net: NetOps, x) -> float:
    """Per-PE payload bytes of tree `x` (the SIM backend's leading PE axis
    is not payload)."""
    leaves = jax.tree.leaves(x)
    total = float(sum(l.size * l.dtype.itemsize for l in leaves))
    if isinstance(net, SimNetOps):
        total /= net.n_pes
    return total


# ---------------------------------------------------------------------------
# team-relative execution view (DESIGN.md §11)
# ---------------------------------------------------------------------------
# Every executor below is written against a *group view*: my rank within
# the group, the group size, a lift of group-coordinate patterns to the
# world patterns that execute, and (for proper-subset teams) the member
# mask that bounds where results are defined.  team=None is the world —
# rank is the PE id and lift is the interning pass-through, so the flat
# paths are byte-for-byte what they were.

def _team_view(net: NetOps, team):
    """(rank, size, lift, member_mask) for `team`: a Team, a
    TeamPartition (all member teams run concurrently — each PE uses its
    own team's coordinates), or None for the world.

    rank is the per-PE group rank (clamped to 0 off-team; off-team
    results are masked out by the callers).  member_mask is a host bool
    array over world PEs, or None when the group covers the world."""
    if team is None:
        return net.my_pe(), net.n_pes, \
            (lambda p: as_pattern(p, net.n_pes)), None
    if team.world_n != net.n_pes:
        raise ValueError(f"team compiled for world_n={team.world_n} "
                         f"used on a {net.n_pes}-PE net")
    rank = jnp.asarray(np.maximum(team.rank_np, 0))[net.my_pe()]
    mask = None if team.covers_world else team.member_np
    return rank, team.size, team.lift, mask


def _mask_out(net: NetOps, mask, out, keep=None):
    """Restore non-members: `keep` (same shape) where given, zeros for
    shape-changing collectives — OpenSHMEM leaves non-participants
    undefined; we pin them for determinism and testability."""
    if mask is None:
        return out
    keep = jnp.zeros_like(out) if keep is None else keep
    return net.select(mask, out, keep)


# ---------------------------------------------------------------------------
# schedule builders — one per paper algorithm
# ---------------------------------------------------------------------------

def barrier_schedule(n: int) -> Schedule:
    """Dissemination: round k exchanges 8 bytes of sync state with PE
    (i + 2^k) — the paper's 8*log2(N) sync array."""
    return Schedule("barrier.dissemination", tuple(
        Stage(ring_pattern(n, 1 << k), 8.0) for k in range(_ceil_log2(n))))


def broadcast_schedule(n: int, nbytes: float = 0.0, root: int = 0) -> Schedule:
    """Farthest-first binomial tree: stride p2/2 down to 1 (paper §3.6:
    'moving the data the farthest distance first')."""
    stages = []
    stride = (1 << _ceil_log2(n)) >> 1
    while stride >= 1:
        stages.append(Stage(binomial_stage_pattern(n, stride, root),
                            float(nbytes)))
        stride >>= 1
    return Schedule("broadcast.binomial_ff", tuple(stages))


def fcollect_schedule(n: int, nbytes: float = 0.0,
                      algorithm: str | None = None) -> Schedule:
    """Allgather of `nbytes` blocks: recursive doubling (payload doubles
    per stage) or ring (n-1 single-block stages)."""
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    if algo == "rd":
        return Schedule("fcollect.rd", tuple(
            Stage(xor_pattern(n, 1 << k), nbytes * (1 << k))
            for k in range(_ceil_log2(n))))
    return Schedule("fcollect.ring", tuple(
        Stage(ring_pattern(n), float(nbytes)) for _ in range(max(n - 1, 0))))


def reduce_scatter_schedule(n: int, nbytes: float = 0.0) -> Schedule:
    """Ring reduce-scatter: n-1 stages, each moving one 1/n chunk."""
    return Schedule("reduce_scatter.ring", tuple(
        Stage(ring_pattern(n), nbytes / max(n, 1))
        for _ in range(max(n - 1, 0))))


def allgather_schedule(n: int, nbytes: float = 0.0) -> Schedule:
    """Ring allgather of the scattered 1/n chunks (reduce-scatter's dual)."""
    return Schedule("allgather.ring", tuple(
        Stage(ring_pattern(n), nbytes / max(n, 1))
        for _ in range(max(n - 1, 0))))


def allreduce_schedule(n: int, nbytes: float = 0.0,
                       algorithm: str | None = None) -> Schedule:
    """to_all: recursive doubling (log2 N full-buffer stages,
    alpha-optimal) or ring reduce-scatter + allgather (~2x buffer total,
    bandwidth-optimal)."""
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    if algo == "rd":
        return Schedule("allreduce.rd", tuple(
            Stage(xor_pattern(n, 1 << k), float(nbytes))
            for k in range(_ceil_log2(n))))
    return Schedule("allreduce.ring",
                    reduce_scatter_schedule(n, nbytes).stages
                    + allgather_schedule(n, nbytes).stages)


def alltoall_schedule(n: int, nbytes_total: float = 0.0) -> Schedule:
    """Pairwise exchange (paper Fig. 9): stage j sends one 1/n block to the
    PE j ring offsets away."""
    per = nbytes_total / max(n, 1)
    return Schedule("alltoall.pairwise", tuple(
        Stage(ring_pattern(n, j), per) for j in range(1, n)))


# Collectives with more than one algorithm to choose between.
_SELECTABLE: dict[str, Callable[..., Schedule]] = {
    "allreduce": allreduce_schedule,
    "fcollect": fcollect_schedule,
}


def allreduce_hier_schedule(partition, nbytes: float = 0.0,
                            cross_algorithm: str | None = None,
                            topo=None, link=None) -> Schedule:
    """The hierarchical two-level allreduce as ONE world Schedule
    (DESIGN.md §11): intra-team ring reduce-scatter, cross-team allreduce
    of the owned 1/K chunk over the peer teams (the partition's
    complement — every team's rank-j members), intra-team ring allgather.
    Each phase's team-coordinate stages lift to union patterns, so all
    teams fly their stage-k exchange concurrently; stage payloads and hop
    costs come from the lifted objects that execute.  cross_algorithm
    None cost-model-selects the cross step (rd's log2(M) chunk sends vs
    the ring's ~2x chunk bytes), same as the executor."""
    K = partition.size
    peers = partition.complement()
    if cross_algorithm is None:
        cross_algorithm = choose_algorithm(peers.size, nbytes / max(K, 1),
                                           topo, link, team=peers)
    stages = tuple(
        partition.lift_schedule(reduce_scatter_schedule(K, nbytes)).stages
        + peers.lift_schedule(
            allreduce_schedule(peers.size, nbytes / max(K, 1),
                               cross_algorithm)).stages
        + partition.lift_schedule(allgather_schedule(K, nbytes)).stages)
    return Schedule(
        f"allreduce.hier[{partition.n_teams}x{K}]", stages)


def allreduce_hier(net: NetOps, x, op: str = "sum",
                   combine: Callable | None = None, partition=None,
                   cross_algorithm: str | None = None, topo=None, link=None):
    """Hierarchical two-level allreduce over a covering TeamPartition:

      1. intra-team ring reduce-scatter — team rank r ends up owning the
         team-reduced chunk (r+1) mod K;
      2. cross-team allreduce among the chunk owners: the peer teams
         (partition.complement(), every team's rank-j members) each hold
         the SAME chunk index, so reducing within a peer team completes
         that chunk globally;
      3. intra-team ring allgather of the completed chunks.

    Numerically this reorders the summation relative to the flat
    algorithms — exact for int dtypes, allclose within float tolerance
    (documented in DESIGN.md §11).  On a 2D mesh with row teams this
    keeps phases 1/3 on row links and moves only 1/K of the payload
    across rows — the fewest-largest-messages policy of §8."""
    if partition is None:
        raise ValueError("allreduce_hier needs a TeamPartition")
    if not partition.covers_world:
        raise ValueError("allreduce_hier needs a partition covering the "
                         "world (every PE contributes)")
    fn = combine or OPS[op]
    peers = partition.complement()
    if cross_algorithm is None:
        # cost-model-select the cross step from the UNPADDED chunk bytes,
        # exactly as allreduce_hier_schedule prices it — the executed and
        # priced algorithms cannot diverge (even when padding rounds the
        # actual chunk up)
        nbytes = _payload_bytes(net, x)
        cross_algorithm = choose_algorithm(
            peers.size, nbytes / max(partition.size, 1), topo, link,
            team=peers)
    own, info = _reduce_scatter_ring(net, x, fn, team=partition)
    if peers.size > 1:
        own = allreduce(net, own, op, combine=combine,
                        algorithm=cross_algorithm, team=peers,
                        topo=topo, link=link)
    return allgather_unpad(net, own, info, team=partition)


def choose_algorithm(n: int, nbytes: float, topo=None, link=None,
                     collective: str = "allreduce", team=None,
                     partition=None) -> str:
    """Cost-model algorithm selection: price each candidate schedule with
    the alpha-beta model (eq. 1) on `topo`/`link` and take the cheapest.

    This replaces the hand-tuned byte-threshold switch: recursive doubling
    pays log2(N) full-payload sends (alpha-optimal), the ring pays ~2x the
    payload in 2(N-1) chunk sends (bandwidth-optimal); where the cross-over
    falls depends on alpha, beta AND the mesh hop costs, which is exactly
    what the model prices.

    With `team`, candidates are priced in team coordinates (lifted to the
    world patterns that execute, so team hop costs are the members' world
    distances).  With `partition` (allreduce only), the hierarchical
    two-level schedule joins the candidate set — "hier" wins whenever
    keeping the bulk bytes on intra-team links beats the flat ring."""
    if team is not None:
        n = team.size
    if n <= 1:
        return "ring"
    build = _SELECTABLE[collective]

    def _priced(a: str) -> float:
        if a == "hier":
            return allreduce_hier_schedule(
                partition, nbytes, topo=topo, link=link).time(topo, link)
        s = build(n, nbytes, algorithm=a)
        if team is not None:
            s = team.lift_schedule(s)
        return s.time(topo, link)

    candidates = ["ring"] + (["rd"] if _is_pow2(n) else [])
    if (partition is not None and team is None and collective == "allreduce"
            and partition.covers_world and partition.n_teams > 1
            and partition.size > 1):
        candidates.append("hier")
    return min(candidates, key=_priced)


# Upper bound on pipeline depth "auto" will consider; deeper pipelines pay
# one more per-stage alpha per chunk for ever-shrinking drain savings.
PIPELINE_MAX_CHUNKS = 16


def choose_schedule(n: int, nbytes: float, topo=None, link=None,
                    collective: str = "allreduce",
                    max_chunks: int = PIPELINE_MAX_CHUNKS,
                    partition=None) -> tuple[str, int]:
    """choose_algorithm extended over the pipelining axis: price every
    candidate (algorithm, chunk-count) pair with the alpha-beta model —
    `abmodel.modeled_pipelined_time` for chunked, eq. 1 for monolithic —
    and return the cheapest ``(algorithm, n_chunks)``.

    n_chunks == 1 means monolithic execution; above the modeled pipelining
    cross-over (where the drained bandwidth saving outweighs the per-chunk
    alpha) the chunk count grows toward `max_chunks`.  With `partition`
    (allreduce only) the hierarchical schedule competes too — priced
    monolithic, since team-relative execution does not pipeline
    (DESIGN.md §11)."""
    from . import abmodel
    if n <= 1:
        return "ring", 1
    link = link if link is not None else abmodel.ICI_V5E
    build = _SELECTABLE[collective]
    best, best_t = ("ring", 1), math.inf
    for algo in ["ring"] + (["rd"] if _is_pow2(n) else []):
        cost = build(n, nbytes, algorithm=algo).cost(topo)
        c = abmodel.choose_chunks(cost, link, max_chunks=max_chunks)
        t = abmodel.modeled_pipelined_time(cost, c, link)
        if t < best_t:
            best, best_t = (algo, c), t
    if (partition is not None and collective == "allreduce"
            and partition.covers_world and partition.n_teams > 1
            and partition.size > 1):
        t = allreduce_hier_schedule(
            partition, nbytes, topo=topo, link=link).time(topo, link)
        if t < best_t:
            best, best_t = ("hier", 1), t
    return best


# ---------------------------------------------------------------------------
# cost descriptors — thin views over the same schedules that execute
# ---------------------------------------------------------------------------

def barrier_stages(n: int, topo=None) -> list[tuple[float, float]]:
    """[(bytes, hops)] per stage for the cost model."""
    return barrier_schedule(n).cost(topo)


def broadcast_stages(n: int, nbytes: float, topo=None):
    return broadcast_schedule(n, nbytes).cost(topo)


def fcollect_stages(n: int, nbytes: float, topo=None, algorithm=None):
    return fcollect_schedule(n, nbytes, algorithm).cost(topo)


def allreduce_stages(n: int, nbytes: float, topo=None, algorithm=None):
    return allreduce_schedule(n, nbytes, algorithm).cost(topo)


def alltoall_stages(n: int, nbytes_total: float, topo=None):
    return alltoall_schedule(n, nbytes_total).cost(topo)


# ---------------------------------------------------------------------------
# pipelined (chunked, double-buffered) schedule execution — DESIGN.md §10
# ---------------------------------------------------------------------------
# Large payloads split into static contiguous pieces; the executor issues
# stage k of piece c at pipeline step k + c, so stage k of chunk i overlaps
# stage k+1 of chunk i-1 (the paper's e-DMA double-buffering discipline).
# Pieces are dataflow-independent and every stage op (ppermute, select,
# elementwise combine, static block slicing) commutes with contiguous
# slicing of the payload, so pipelined execution is BIT-IDENTICAL to the
# eager/monolithic path — same ops, same per-element reduction order.

def _chunk_bounds(width: int, n_chunks) -> list[tuple[int, int]]:
    """Static contiguous piece boundaries (roughly equal; always at least
    one piece, so zero-width payloads still run a single empty piece)."""
    c = max(1, min(int(n_chunks), int(width)))
    if width <= 0:
        return [(0, 0)]
    edges = np.linspace(0, width, c + 1).astype(int)
    return [(int(lo), int(hi)) for lo, hi in zip(edges[:-1], edges[1:])
            if hi > lo]


def _software_pipeline(pieces, n_stages: int, stage_fn):
    """Run `stage_fn(piece_idx, stage_idx, state) -> state` over all pieces
    in pipelined issue order: step t advances piece c through stage t - c.
    Fill takes S steps, drain C-1 — the (S + C - 1)-slot pipeline that
    `abmodel.modeled_pipelined_time` prices."""
    states = list(pieces)
    n_pieces = len(states)
    for t in range(n_stages + n_pieces - 1):
        for c in range(n_pieces):
            k = t - c
            if 0 <= k < n_stages:
                states[c] = stage_fn(c, k, states[c])
    return states


def _resolve_chunks(pipeline_chunks, schedule: Schedule, topo=None,
                    link=None) -> int:
    """None/1 -> monolithic; "auto" -> abmodel.choose_chunks on the
    executing schedule's own cost descriptor; an int passes through."""
    if pipeline_chunks in (None, 0, 1):
        return 1
    if pipeline_chunks == "auto":
        from . import abmodel
        link = link if link is not None else abmodel.ICI_V5E
        return abmodel.choose_chunks(schedule.cost(topo), link,
                                     max_chunks=PIPELINE_MAX_CHUNKS)
    return int(pipeline_chunks)


def _slice_axis(v, lo: int, hi: int, ax: int):
    sl = [slice(None)] * v.ndim
    sl[ax] = slice(lo, hi)
    return v[tuple(sl)]


def _flat_pieces(net: NetOps, x, n_chunks):
    """Flatten the per-PE payload and cut it into static contiguous pieces;
    returns (pieces, bounds, restore)."""
    sim = isinstance(net, SimNetOps)
    shape = x.shape
    flat = x.reshape((shape[0], -1) if sim else (-1,))
    bounds = _chunk_bounds(flat.shape[-1], n_chunks)
    pieces = [flat[..., lo:hi] for lo, hi in bounds]

    def restore(parts):
        return jnp.concatenate(parts, axis=-1).reshape(shape)

    return pieces, bounds, restore


def _interleave_blocks(outs, bounds, n: int, ax: int):
    """Inverse of within-block chunking: each per-piece output carries `n`
    blocks of its piece's width along `ax`; reassemble the n full blocks
    (block i = concat over pieces of each piece's block i)."""
    cols = []
    for i in range(n):
        for out, (lo, hi) in zip(outs, bounds):
            w = hi - lo
            cols.append(_slice_axis(out, i * w, (i + 1) * w, ax))
    return jnp.concatenate(cols, axis=ax)


# ---------------------------------------------------------------------------
# barrier
# ---------------------------------------------------------------------------

def barrier(net: NetOps, token=None, team=None):
    """Dissemination barrier: round k exchanges a token with rank
    (i + 2^k) of the group (`team`-relative ranks when a team is given).

    Returns a scalar token; thread it into downstream computation to order
    operations (the SPMD analogue of 'all cores reached this line')."""
    _, n, lift, _ = _team_view(net, team)
    tok = jnp.zeros((), jnp.int32) if token is None else token
    if isinstance(net, SimNetOps):
        tok = jnp.broadcast_to(tok, (net.n_pes,) + tok.shape[1:]) \
            if tok.ndim == 0 else tok
    for st in barrier_schedule(n).stages:
        tok = tok + net.ppermute(tok, lift(st.pattern))
    return tok


# ---------------------------------------------------------------------------
# broadcast (farthest-first binomial tree)
# ---------------------------------------------------------------------------

def broadcast(net: NetOps, x, root: int = 0, pipeline_chunks=None,
              topo=None, link=None, team=None):
    """Farthest-first binomial broadcast; with `team`, `root` is a TEAM
    rank and only members take the root's value (non-members keep x)."""
    _, n, lift, _ = _team_view(net, team)
    if n == 1:
        return x
    sched = broadcast_schedule(n, _payload_bytes(net, x), root)
    chunks = _resolve_chunks(pipeline_chunks, sched, topo, link) \
        if team is None else 1
    if chunks > 1:
        pieces, _, restore = _flat_pieces(net, x, chunks)

        def stage(c, k, buf):
            st = sched.stages[k]
            recv = net.ppermute(buf, st.pattern)
            return net.select(st.pattern, recv, buf)

        return restore(_software_pipeline(pieces, len(sched.stages), stage))
    buf = x
    for st in sched.stages:
        p = lift(st.pattern)
        recv = net.ppermute(buf, p)
        buf = net.select(p, recv, buf)
    return buf


# ---------------------------------------------------------------------------
# fcollect / collect (allgather)
# ---------------------------------------------------------------------------

def fcollect(net: NetOps, x, axis: int = 0, algorithm: str | None = None,
             pipeline_chunks=None, topo=None, link=None, team=None):
    """Concatenate equal-size blocks from all group members along `axis`.

    Recursive doubling (log2 N stages, doubling message size) when the
    group size is a power of two, ring otherwise — the paper's
    fcollect/collect split.  `pipeline_chunks` > 1 executes the schedule
    chunked/double-buffered (bit-identical; DESIGN.md §10).  With `team`,
    blocks concatenate in TEAM-rank order; non-members return zeros
    (team collectives run monolithic, §11)."""
    _, n, _, _ = _team_view(net, team)
    if n == 1:
        return x
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    nbytes = _payload_bytes(net, x)
    chunks = 1 if team is not None else _resolve_chunks(
        pipeline_chunks, fcollect_schedule(n, nbytes, algo), topo, link)
    if algo == "rd":
        return _fcollect_rd(net, x, axis, n_chunks=chunks, team=team)
    return _collect_ring(net, x, axis, n_chunks=chunks, team=team)


def collect(net: NetOps, x, axis: int = 0, pipeline_chunks=None,
            topo=None, link=None, team=None):
    """The paper's linear-scaling ring collect."""
    _, n, _, _ = _team_view(net, team)
    if n == 1:
        return x
    chunks = 1 if team is not None else _resolve_chunks(
        pipeline_chunks,
        fcollect_schedule(n, _payload_bytes(net, x), "ring"), topo, link)
    return _collect_ring(net, x, axis, n_chunks=chunks, team=team)


def _out_zeros_like(x, axis, n, pe_leading):
    shp = list(x.shape)
    ax = axis + (1 if pe_leading else 0)
    shp[ax] = shp[ax] * n
    return jnp.zeros(shp, x.dtype)


def _fcollect_rd(net: NetOps, x, axis: int, n_chunks: int = 1, team=None):
    rank, n, lift, mask = _team_view(net, team)
    blk = x.shape[axis + (1 if isinstance(net, SimNetOps) else 0)]
    buf = _out_zeros_like(x, axis, n, isinstance(net, SimNetOps))

    def place(b, v, i):
        starts = [0] * b.ndim
        starts[axis] = i * blk
        return lax.dynamic_update_slice(b, v, tuple(starts))

    buf = _lmap(net, place, buf, x, rank)
    stages = fcollect_schedule(n, _payload_bytes(net, x), "rd").stages
    if team is not None:
        for st in stages:
            buf = buf + net.ppermute(buf, lift(st.pattern))
        return _mask_out(net, mask, buf)
    if n_chunks > 1:
        # every stage is elementwise (ppermute + add of disjoint regions),
        # so pipelining slices the filled output buffer directly
        pieces, _, restore = _flat_pieces(net, buf, n_chunks)

        def stage(c, k, b):
            return b + net.ppermute(b, stages[k].pattern)

        return restore(_software_pipeline(pieces, len(stages), stage))
    for st in stages:
        recv = net.ppermute(buf, st.pattern)
        buf = buf + recv  # disjoint filled regions, zeros elsewhere
    return buf


# Ring collectives use a STATIC schedule: every PE-dependent block index
# is hoisted into one pre- or post-rotation (a single gather), so loop
# bodies contain no dynamic_update_slice at all.  This mirrors how the
# paper's PEs precompute their schedule in shmem_init, and it is what
# keeps per-stage HBM traffic at one block instead of one full buffer
# (EXPERIMENTS.md §Perf P1).  Set "dus" to get the naive baseline back.
RING_SCHEDULE = "static"


def _take_blocks(net: NetOps, x, idx, nblk: int, axis: int):
    """out block t = x block idx[t] (idx traced per PE), one gather."""
    def one(v, ix):
        shp = v.shape
        vb = v.reshape(shp[:axis] + (nblk, shp[axis] // nblk)
                       + shp[axis + 1:])
        taken = jnp.take(vb, ix, axis=axis)
        return taken.reshape(shp)
    return _lmap(net, one, x, idx)


def _collect_ring(net: NetOps, x, axis: int, n_chunks: int = 1, team=None):
    rank, n, lift, mask = _team_view(net, team)
    if RING_SCHEDULE == "dus" and team is None:
        return _collect_ring_dus(net, x, axis)
    sim = isinstance(net, SimNetOps)
    ax = axis + (1 if sim else 0)
    stages = fcollect_schedule(n, _payload_bytes(net, x), "ring").stages
    # out block i = stacked part (rank - i) mod n
    idx = (rank[..., None] - jnp.arange(n)) % n if sim \
        else (rank - jnp.arange(n)) % n
    if team is not None:
        parts = [x]
        cur = x
        for st in stages:
            cur = net.ppermute(cur, lift(st.pattern))
            parts.append(cur)               # part t holds block (rank - t)
        stacked = jnp.concatenate(parts, axis=ax)
        return _mask_out(net, mask, _take_blocks(net, stacked, idx, n, axis))
    if n_chunks > 1:
        # chunk WITHIN the per-PE block along `axis` so each piece runs the
        # identical ring; block order is restored piece-wise and the full
        # blocks reassembled by interleaving
        bounds = _chunk_bounds(x.shape[ax], n_chunks)
        pieces = [[_slice_axis(x, lo, hi, ax)] for lo, hi in bounds]

        def stage(c, k, parts):
            return parts + [net.ppermute(parts[-1], stages[k].pattern)]

        outs = []
        for parts in _software_pipeline(pieces, len(stages), stage):
            stacked_c = jnp.concatenate(parts, axis=ax)
            outs.append(_take_blocks(net, stacked_c, idx, n, axis))
        return _interleave_blocks(outs, bounds, n, ax)
    parts = [x]
    cur = x
    for st in stages:
        cur = net.ppermute(cur, st.pattern)
        parts.append(cur)                   # part t holds block (pe - t)
    stacked = jnp.concatenate(parts, axis=ax)
    return _take_blocks(net, stacked, idx, n, axis)


def _collect_ring_dus(net: NetOps, x, axis: int):
    n = net.n_pes
    sim = isinstance(net, SimNetOps)
    blk = x.shape[axis + (1 if sim else 0)]
    buf = _out_zeros_like(x, axis, n, sim)
    pe = net.my_pe()
    ring = ring_pattern(n)

    cur = x
    for j in range(n):
        idx_arr = (pe - j) % n

        def place(b, v, i):
            starts = [0] * b.ndim
            starts[axis] = i * blk
            return lax.dynamic_update_slice(b, v, tuple(starts))

        buf = _lmap(net, place, buf, cur, idx_arr)
        if j < n - 1:
            cur = net.ppermute(cur, ring)
    return buf


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

OPS: dict[str, Callable] = {
    "sum": jnp.add,
    "prod": jnp.multiply,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
}


RING_BYTES_THRESHOLD = 1 << 20   # 1 MiB: the old hand-tuned switch point,
                                 # kept as a reference for tests/benches;
                                 # "auto" now prices schedules instead.


def allreduce(net: NetOps, x, op: str = "sum", combine: Callable | None = None,
              algorithm: str | None = None, topo=None, link=None,
              pipeline_chunks=None, team=None, partition=None):
    """shmem_TYPE_OP_to_all.

    Algorithm selection generalizes the paper's PE-count switch (§3.6:
    dissemination for powers of two, ring otherwise).  "auto" prices the
    candidate schedules with the alpha-beta model on `topo`
    (`choose_algorithm`): recursive doubling moves the FULL buffer log2(N)
    times (alpha-optimal), the ring moves ~2x the buffer total
    (bandwidth-optimal), so large payloads take the ring even at
    power-of-two PE counts.  Explicit "rd"/"ring" override; "hier" runs
    the hierarchical two-level schedule over `partition` (DESIGN.md §11),
    and "auto" prices it as a candidate whenever a partition is given.

    `team` scopes the reduction to a Team (members reduce among
    themselves; non-members pass x through unchanged) or runs every team
    of a TeamPartition concurrently; team execution is monolithic.

    `pipeline_chunks` > 1 executes the chosen schedule chunked and
    double-buffered (bit-identical to monolithic; DESIGN.md §10);
    "auto" for BOTH knobs prices every (algorithm, chunk-count) pair
    (`choose_schedule`) and runs the cheapest."""
    fn = combine or OPS[op]
    nbytes = _payload_bytes(net, x)
    if team is not None:
        if algorithm == "hier" or partition is not None:
            raise ValueError(
                "team= and partition= are mutually exclusive: hier runs "
                "over a world-covering partition=; team-scoped reductions "
                "are flat rd/ring")
        _, n, _, _ = _team_view(net, team)
        if n == 1:
            return x
        if algorithm == "auto":
            algo = choose_algorithm(n, nbytes, topo, link, team=team)
        elif algorithm in (None, "paper"):
            algo = "rd" if _is_pow2(n) else "ring"
        else:
            algo = algorithm
        return _allreduce_team(net, x, fn, algo, team)
    n = net.n_pes
    if n == 1:
        return x
    if algorithm == "hier":
        return allreduce_hier(net, x, op, combine=combine,
                              partition=partition, topo=topo, link=link)
    if algorithm == "auto" and pipeline_chunks == "auto":
        algo, chunks = choose_schedule(n, nbytes, topo, link,
                                       partition=partition)
    else:
        if algorithm == "auto":
            algo = choose_algorithm(n, nbytes, topo, link,
                                    partition=partition)
        elif algorithm is None:
            algo = "rd" if _is_pow2(n) else "ring"
        else:
            algo = algorithm
        chunks = 1 if algo == "hier" else _resolve_chunks(
            pipeline_chunks, allreduce_schedule(n, nbytes, algo), topo, link)
    if algo == "hier":
        return allreduce_hier(net, x, op, combine=combine,
                              partition=partition, topo=topo, link=link)
    if algo == "rd":
        stages = allreduce_schedule(n, nbytes, "rd").stages
        if chunks > 1:
            return jax.tree.map(
                lambda v: _allreduce_rd_pipelined(net, v, fn, stages, chunks),
                x)
        for st in stages:
            recv = net.ppermute(x, st.pattern)
            x = jax.tree.map(fn, x, recv)
        return x
    if chunks > 1:
        return _allreduce_ring_pipelined(net, x, fn, chunks)
    rs, shape_info = _reduce_scatter_ring(net, x, fn)
    return allgather_unpad(net, rs, shape_info)


def _allreduce_team(net: NetOps, x, fn, algo: str, team):
    """Team-scoped allreduce (monolithic): rd runs lifted xor stages with
    the combine applied everywhere (non-members receive zeros and are
    restored by the final mask); ring runs the team-relative
    reduce-scatter + allgather."""
    _, n, lift, mask = _team_view(net, team)
    if algo == "rd":
        out = x
        for st in allreduce_schedule(n, _payload_bytes(net, x), "rd").stages:
            recv = net.ppermute(out, lift(st.pattern))
            out = jax.tree.map(fn, out, recv)
    else:
        rs, info = _reduce_scatter_ring(net, x, fn, team=team)
        out = allgather_unpad(net, rs, info, team=team)
    return _mask_out(net, mask, out, keep=x)


def _allreduce_rd_pipelined(net: NetOps, x, fn, stages, n_chunks: int):
    """Recursive doubling is elementwise per stage (ppermute + combine), so
    pipelining slices the flat payload directly."""
    pieces, _, restore = _flat_pieces(net, x, n_chunks)

    def stage(c, k, buf):
        return fn(buf, net.ppermute(buf, stages[k].pattern))

    return restore(_software_pipeline(pieces, len(stages), stage))


def _allreduce_ring_pipelined(net: NetOps, x, fn, n_chunks: int):
    """Ring reduce-scatter + allgather, chunked WITHIN the owned 1/n block
    so every element keeps its monolithic block index — and therefore its
    exact reduction order (bit-identical to the eager path).  The fused
    pipeline lets chunk i's allgather stages overlap chunk i+1's
    reduce-scatter stages."""
    n = net.n_pes
    sim = isinstance(net, SimNetOps)
    orig_shape = x.shape[1:] if sim else x.shape
    size = int(np.prod(orig_shape))
    chunk = -(-size // n)
    padded = chunk * n
    pe = net.my_pe()

    def flatpad(v):
        f = v.reshape(-1)
        return jnp.pad(f, (0, padded - size))

    buf = _lmap(net, flatpad, x)
    idx = (pe[..., None] + jnp.arange(n)) % n if sim \
        else (pe + jnp.arange(n)) % n
    r = _take_blocks(net, buf, idx, n, 0)

    nbytes = _payload_bytes(net, x)
    rs = reduce_scatter_schedule(n, nbytes).stages
    ag = allgather_schedule(n, float(padded * buf.dtype.itemsize)).stages
    bounds = _chunk_bounds(chunk, n_chunks)

    def piece_of(t: int, lo: int, hi: int):
        base = t * chunk
        return r[..., base + lo:base + hi]

    def stage(c, k, state):
        lo, hi = bounds[c]
        cur, parts = state
        if k < len(rs):
            j = k + 1
            cur = net.ppermute(cur, rs[k].pattern)
            cur = fn(piece_of(n - j, lo, hi), cur)
            return (cur, (cur,) if k == len(rs) - 1 else parts)
        cur = net.ppermute(cur, ag[k - len(rs)].pattern)
        return (cur, parts + (cur,))

    init = [(piece_of(0, lo, hi), ()) for lo, hi in bounds]
    finals = _software_pipeline(init, len(rs) + len(ag), stage)
    idx2 = (pe[..., None] + 1 - jnp.arange(n)) % n if sim \
        else (pe + 1 - jnp.arange(n)) % n
    outs = []
    for _, parts in finals:
        stacked_c = jnp.concatenate(parts, axis=-1)
        outs.append(_take_blocks(net, stacked_c, idx2, n, 0))
    out = _interleave_blocks(outs, bounds, n, -1)

    def unpad(b):
        return b[:size].reshape(orig_shape)

    return _lmap(net, unpad, out)


def reduce_scatter(net: NetOps, x, op: str = "sum",
                   combine: Callable | None = None, team=None):
    """Ring reduce-scatter; returns this PE's owned chunk of the flattened,
    padded array plus the info needed to allgather/unpad it.  With `team`
    the ring runs in team coordinates (a TeamPartition runs every team's
    ring concurrently); chunk ownership is by team rank."""
    fn = combine or OPS[op]
    return _reduce_scatter_ring(net, x, fn, team=team)


def _reduce_scatter_ring(net: NetOps, x, fn, team=None):
    """Ring reduce-scatter with the static schedule (§Perf P1): one
    pre-rotation puts every stage's chunk at a STATIC offset, so the loop
    body is free of dynamic slicing (r block t = chunk (rank + t) mod n).
    `rank` is the group rank of the `team` view (the PE id for the
    world); non-members of a proper-subset team get a zero chunk."""
    rank, n, lift, mask = _team_view(net, team)
    sim = isinstance(net, SimNetOps)
    orig_shape = x.shape[1:] if sim else x.shape
    size = int(np.prod(orig_shape))
    chunk = -(-size // n)
    padded = chunk * n

    def flatpad(v):
        f = v.reshape(-1)
        return jnp.pad(f, (0, padded - size))

    buf = _lmap(net, flatpad, x)
    idx = (rank[..., None] + jnp.arange(n)) % n if sim \
        else (rank + jnp.arange(n)) % n
    r = _take_blocks(net, buf, idx, n, 0)

    def static_chunk(b, t):
        return b[..., t * chunk:(t + 1) * chunk] if sim \
            else b[t * chunk:(t + 1) * chunk]

    cur = static_chunk(r, 0)                     # chunk[rank]
    sched = reduce_scatter_schedule(n, _payload_bytes(net, x))
    for j, st in enumerate(sched.stages, start=1):
        cur = net.ppermute(cur, lift(st.pattern))
        cur = fn(static_chunk(r, n - j), cur)    # chunk[(rank - j) mod n]
    # rank p now owns the fully-reduced chunk (p + 1) % n
    own_idx = (rank + 1) % n
    info = (orig_shape, size, chunk, own_idx)
    return _mask_out(net, mask, cur), info


def allgather_unpad(net: NetOps, chunk_val, info, team=None):
    """Ring allgather of a `reduce_scatter` result, undoing its flatten/pad.

    `info` is the handle `reduce_scatter` returned alongside the owned
    chunk: ``(orig_shape, size, chunk, own_idx)``.  Static schedule: parts
    arrive in ring order; one post-gather restores block order, then the
    padding is stripped and the original shape restored.  Composing
    ``allgather_unpad(net, *reduce_scatter(net, x))`` is the
    bandwidth-optimal ring allreduce (~2x payload on the wire vs log2(N)x
    for recursive doubling) — the ZeRO-style gradient-sync building block
    (DESIGN.md §8).  Pass the same `team` the reduce-scatter ran with;
    non-members of a proper-subset team read zeros."""
    orig_shape, size, chunk, own_idx = info
    rank, n, lift, mask = _team_view(net, team)
    sim = isinstance(net, SimNetOps)
    nbytes = float(chunk * n * chunk_val.dtype.itemsize)
    parts = [chunk_val]                 # part t = chunk (rank + 1 - t) mod n
    cur = chunk_val
    for st in allgather_schedule(n, nbytes).stages:
        cur = net.ppermute(cur, lift(st.pattern))
        parts.append(cur)
    stacked = jnp.concatenate(parts, axis=-1)
    # out block i = part (rank + 1 - i) mod n
    idx = (rank[..., None] + 1 - jnp.arange(n)) % n if sim \
        else (rank + 1 - jnp.arange(n)) % n
    out = _take_blocks(net, stacked, idx, n, 0)

    def unpad(b):
        return b[:size].reshape(orig_shape)

    return _mask_out(net, mask, _lmap(net, unpad, out))


# Backwards-compatible private alias (promoted to the public API above).
_allgather_unpad = allgather_unpad


# ---------------------------------------------------------------------------
# alltoall (pairwise exchange — paper Fig. 9)
# ---------------------------------------------------------------------------

def alltoall(net: NetOps, x, axis: int = 0, pipeline_chunks=None,
             topo=None, link=None, team=None):
    """out[src-block] = x_src[my-block]; x's `axis` dim = group size *
    block (group = the world, or `team`'s members in team-rank order).

    Static schedule (§Perf P1): one pre-rotation makes every stage's send
    block a static slice; received parts concatenate in ring order and one
    post-gather restores block order — no per-stage dynamic updates.
    `pipeline_chunks` > 1 chunks each block's payload and pipelines the
    pairwise sends (bit-identical; DESIGN.md §10; team execution is
    monolithic, non-members return zeros)."""
    rank, n, lift, mask = _team_view(net, team)
    if n == 1:
        return x
    sim = isinstance(net, SimNetOps)
    ax = axis + (1 if sim else 0)
    dim = x.shape[ax]
    assert dim % n == 0, f"alltoall axis dim {dim} not divisible by n={n}"

    # pre-rotate: r block t = x block (rank + t) mod n
    idx = (rank[..., None] + jnp.arange(n)) % n if sim \
        else (rank + jnp.arange(n)) % n
    r = _take_blocks(net, x, idx, n, axis)
    blk = dim // n
    sched = alltoall_schedule(n, _payload_bytes(net, x))
    out_idx = (rank[..., None] - jnp.arange(n)) % n if sim \
        else (rank - jnp.arange(n)) % n

    def static_blk(v, t, lo=0, hi=blk):
        sl = [slice(None)] * v.ndim
        sl[ax] = slice(t * blk + lo, t * blk + hi)
        return v[tuple(sl)]

    if team is not None:
        parts = [static_blk(r, 0)]
        for j, st in enumerate(sched.stages, start=1):
            parts.append(net.ppermute(static_blk(r, j), lift(st.pattern)))
        stacked = jnp.concatenate(parts, axis=ax)
        return _mask_out(net, mask,
                         _take_blocks(net, stacked, out_idx, n, axis))

    chunks = _resolve_chunks(pipeline_chunks, sched, topo, link)
    if chunks > 1:
        bounds = _chunk_bounds(blk, chunks)

        def stage(c, k, parts):
            lo, hi = bounds[c]
            st = sched.stages[k]
            return parts + (net.ppermute(static_blk(r, k + 1, lo, hi),
                                         st.pattern),)

        init = [(static_blk(r, 0, lo, hi),) for lo, hi in bounds]
        outs = []
        for parts in _software_pipeline(init, len(sched.stages), stage):
            stacked_c = jnp.concatenate(parts, axis=ax)
            outs.append(_take_blocks(net, stacked_c, out_idx, n, axis))
        return _interleave_blocks(outs, bounds, n, ax)

    parts = [static_blk(r, 0)]          # own block: out[pe] = x_pe[pe]
    for j, st in enumerate(sched.stages, start=1):
        recv = net.ppermute(static_blk(r, j), st.pattern)
        parts.append(recv)              # part t = out-block (pe - t) mod n
    stacked = jnp.concatenate(parts, axis=ax)
    return _take_blocks(net, stacked, out_idx, n, axis)


# ---------------------------------------------------------------------------
# point-to-point RMA
# ---------------------------------------------------------------------------

def put(net: NetOps, x, pattern: Sequence[tuple[int, int]]):
    """One-sided put along a static (src, dst) pattern; PEs not receiving
    keep zeros (use shmem.put for merge-with-local semantics)."""
    return net.ppermute(x, pattern)


def get(net: NetOps, x, pattern: Sequence[tuple[int, int]]):
    """get along (requester, owner) pairs: owner pushes — the IPI-get.
    The inverse pairs are compiled directly so fan-out reads (many
    requesters naming one owner) validate against the executed pattern."""
    if isinstance(pattern, CommPattern):
        return net.ppermute(x, pattern.inverse)
    return net.ppermute(x, [(o, r) for r, o in pattern])


# ---------------------------------------------------------------------------
# scans (substrate for atomics)
# ---------------------------------------------------------------------------

def exclusive_scan(net: NetOps, x, op: str = "sum"):
    """Exclusive scan over the PE axis of a per-PE scalar/array.

    This realizes the observable semantics of concurrent shmem atomics in
    PE order (DESIGN.md §6): fetch_add's return on PE i = init + sum of
    contributions of PEs < i."""
    n = net.n_pes
    fn = OPS[op]
    identity = {"sum": 0, "prod": 1, "max": None, "min": None,
                "and": -1, "or": 0, "xor": 0}[op]
    sim = isinstance(net, SimNetOps)
    xb = x[:, None] if (sim and x.ndim == 1) else jnp.expand_dims(x, 0 if not sim else 1)
    all_vals = fcollect(net, xb, axis=0)
    pe = net.my_pe()

    def scan_one(vals, i):
        idx = jnp.arange(n)
        if identity is None:  # max/min: mask with +-inf
            fill = jnp.array(jnp.finfo(vals.dtype).min if op == "max"
                             else jnp.finfo(vals.dtype).max, vals.dtype)
            masked = jnp.where((idx < i)[(...,) + (None,) * (vals.ndim - 1)], vals, fill)
            return jnp.max(masked, 0) if op == "max" else jnp.min(masked, 0)
        masked = jnp.where((idx < i)[(...,) + (None,) * (vals.ndim - 1)], vals,
                           jnp.array(identity, vals.dtype))
        if op == "sum":
            return jnp.sum(masked, 0)
        if op == "prod":
            return jnp.prod(masked, 0)
        red = masked[0]
        for k in range(1, n):
            red = fn(red, masked[k])
        return red

    return _lmap(net, scan_one, all_vals, pe)
