"""The paper's collective algorithms (§3.6), written once over NetOps.

Algorithm choices mirror the paper exactly:

  * barrier        — dissemination (ceil(log2 N) rounds, 8*log2(N) bytes of
                     sync state); the 'WAND hardware barrier' analogue is a
                     zero-byte psum left to XLA (shmem.py).
  * broadcast      — binomial tree, *farthest-first*: largest stride first
                     so later stages do not add network congestion.
  * fcollect       — recursive doubling for powers of two, ring otherwise.
  * collect        — ring (the paper's linear-scaling variant).
  * reductions     — dissemination/recursive-doubling for powers of two,
                     ring (reduce-scatter + allgather) otherwise.
  * alltoall       — pairwise exchange, one ring offset per stage.

Every routine also has a ``*_stages`` descriptor used by the alpha-beta
cost model (benchmarks' `derived` column and the roofline cross-check).

All functions take the PE-local array (under SPMD) or the PE-stacked array
(under SIM) — `_lmap` hides the difference for shape-changing local ops.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .netops import NetOps, SimNetOps


def _lmap(net: NetOps, f: Callable, *xs):
    """Apply a PE-local function under either backend."""
    if isinstance(net, SimNetOps):
        return jax.vmap(f)(*xs)
    return f(*xs)


def _ceil_log2(n: int) -> int:
    return max(1, n - 1).bit_length() if n > 1 else 0


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _bcast_pe(net: NetOps, shape) -> jnp.ndarray:
    """my_pe broadcast to pair with local arrays in _lmap."""
    return net.my_pe()


# ---------------------------------------------------------------------------
# barrier
# ---------------------------------------------------------------------------

def barrier(net: NetOps, token=None):
    """Dissemination barrier: round k exchanges a token with PE (i + 2^k).

    Returns a scalar token; thread it into downstream computation to order
    operations (the SPMD analogue of 'all cores reached this line')."""
    n = net.n_pes
    tok = jnp.zeros((), jnp.int32) if token is None else token
    if isinstance(net, SimNetOps):
        tok = jnp.broadcast_to(tok, (n,) + tok.shape[1:]) if tok.ndim == 0 else tok
    for k in range(_ceil_log2(n)):
        stride = 1 << k
        perm = [(i, (i + stride) % n) for i in range(n)]
        tok = tok + net.ppermute(tok, perm)
    return tok


def barrier_stages(n: int, topo=None) -> list[tuple[float, float]]:
    """[(bytes, hops)] per stage for the cost model (8 bytes of sync state
    per round, as in the paper's 8*log2(N) sync array)."""
    out = []
    for k in range(_ceil_log2(n)):
        stride = 1 << k
        hops = _stride_hops(stride, n, topo)
        out.append((8.0, hops))
    return out


def _stride_hops(stride: int, n: int, topo) -> float:
    if topo is None:
        return 1.0
    return topo.hops(0, stride % n)


# ---------------------------------------------------------------------------
# broadcast (farthest-first binomial tree)
# ---------------------------------------------------------------------------

def broadcast(net: NetOps, x, root: int = 0):
    n = net.n_pes
    if n == 1:
        return x
    p2 = 1 << _ceil_log2(n)
    buf = x
    # farthest-first: stride p2/2 down to 1 (paper: move the data the
    # farthest distance first).
    stride = p2 >> 1
    while stride >= 1:
        perm = []
        dst_mask = np.zeros((n,), dtype=bool)
        for rel in range(0, n, 2 * stride):
            src = (rel + root) % n
            rel_dst = rel + stride
            if rel_dst < n:
                dst = (rel_dst + root) % n
                perm.append((src, dst))
                dst_mask[dst] = True
        recv = net.ppermute(buf, perm)
        buf = net.select(dst_mask, recv, buf)
        stride >>= 1
    return buf


def broadcast_stages(n: int, nbytes: float, topo=None):
    out = []
    p2 = 1 << _ceil_log2(n)
    stride = p2 >> 1
    while stride >= 1:
        out.append((float(nbytes), _stride_hops(stride, n, topo)))
        stride >>= 1
    return out


# ---------------------------------------------------------------------------
# fcollect / collect (allgather)
# ---------------------------------------------------------------------------

def fcollect(net: NetOps, x, axis: int = 0, algorithm: str | None = None):
    """Concatenate equal-size blocks from all PEs along `axis`.

    Recursive doubling (log2 N stages, doubling message size) when N is a
    power of two, ring otherwise — the paper's fcollect/collect split."""
    n = net.n_pes
    if n == 1:
        return x
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    if algo == "rd":
        return _fcollect_rd(net, x, axis)
    return _collect_ring(net, x, axis)


def collect(net: NetOps, x, axis: int = 0):
    """The paper's linear-scaling ring collect."""
    return _collect_ring(net, x, axis)


def _out_zeros_like(x, axis, n, pe_leading):
    shp = list(x.shape)
    ax = axis + (1 if pe_leading else 0)
    shp[ax] = shp[ax] * n
    return jnp.zeros(shp, x.dtype)


def _fcollect_rd(net: NetOps, x, axis: int):
    n = net.n_pes
    blk = x.shape[axis + (1 if isinstance(net, SimNetOps) else 0)]
    buf = _out_zeros_like(x, axis, n, isinstance(net, SimNetOps))
    pe = net.my_pe()

    def place(b, v, i):
        starts = [0] * b.ndim
        starts[axis] = i * blk
        return lax.dynamic_update_slice(b, v, tuple(starts))

    buf = _lmap(net, place, buf, x, pe)
    for k in range(_ceil_log2(n)):
        stride = 1 << k
        perm = [(i, i ^ stride) for i in range(n)]
        recv = net.ppermute(buf, perm)
        buf = buf + recv  # disjoint filled regions, zeros elsewhere
    return buf


# Ring collectives use a STATIC schedule: every PE-dependent block index
# is hoisted into one pre- or post-rotation (a single gather), so loop
# bodies contain no dynamic_update_slice at all.  This mirrors how the
# paper's PEs precompute their schedule in shmem_init, and it is what
# keeps per-stage HBM traffic at one block instead of one full buffer
# (EXPERIMENTS.md §Perf P1).  Set "dus" to get the naive baseline back.
RING_SCHEDULE = "static"


def _take_blocks(net: NetOps, x, idx, nblk: int, axis: int):
    """out block t = x block idx[t] (idx traced per PE), one gather."""
    def one(v, ix):
        shp = v.shape
        vb = v.reshape(shp[:axis] + (nblk, shp[axis] // nblk)
                       + shp[axis + 1:])
        taken = jnp.take(vb, ix, axis=axis)
        return taken.reshape(shp)
    return _lmap(net, one, x, idx)


def _collect_ring(net: NetOps, x, axis: int):
    n = net.n_pes
    if RING_SCHEDULE == "dus":
        return _collect_ring_dus(net, x, axis)
    pe = net.my_pe()
    ring = [(i, (i + 1) % n) for i in range(n)]
    parts = [x]
    cur = x
    for j in range(1, n):
        cur = net.ppermute(cur, ring)
        parts.append(cur)                   # part t holds block (pe - t)
    sim = isinstance(net, SimNetOps)
    stacked = jnp.concatenate(parts, axis=axis + (1 if sim else 0))
    # out block i = stacked part (pe - i) mod n
    idx = (pe[..., None] - jnp.arange(n)) % n if sim \
        else (pe - jnp.arange(n)) % n
    return _take_blocks(net, stacked, idx, n, axis)


def _collect_ring_dus(net: NetOps, x, axis: int):
    n = net.n_pes
    sim = isinstance(net, SimNetOps)
    blk = x.shape[axis + (1 if sim else 0)]
    buf = _out_zeros_like(x, axis, n, sim)
    pe = net.my_pe()
    ring = [(i, (i + 1) % n) for i in range(n)]

    cur = x
    for j in range(n):
        idx_arr = (pe - j) % n

        def place(b, v, i):
            starts = [0] * b.ndim
            starts[axis] = i * blk
            return lax.dynamic_update_slice(b, v, tuple(starts))

        buf = _lmap(net, place, buf, cur, idx_arr)
        if j < n - 1:
            cur = net.ppermute(cur, ring)
    return buf


def fcollect_stages(n: int, nbytes: float, topo=None, algorithm=None):
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    out = []
    if algo == "rd":
        for k in range(_ceil_log2(n)):
            stride = 1 << k
            out.append((nbytes * stride, _stride_hops(stride, n, topo)))
    else:
        for _ in range(n - 1):
            out.append((float(nbytes), _stride_hops(1, n, topo)))
    return out


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

OPS: dict[str, Callable] = {
    "sum": jnp.add,
    "prod": jnp.multiply,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "and": jnp.bitwise_and,
    "or": jnp.bitwise_or,
    "xor": jnp.bitwise_xor,
}


RING_BYTES_THRESHOLD = 1 << 20   # 1 MiB: beyond this, bandwidth wins


def allreduce(net: NetOps, x, op: str = "sum", combine: Callable | None = None,
              algorithm: str | None = None):
    """shmem_TYPE_OP_to_all.

    Algorithm selection generalizes the paper's PE-count switch (§3.6:
    dissemination for powers of two, ring otherwise) with its own
    small-vs-large-message lesson: recursive doubling moves the FULL
    buffer log2(N) times (alpha-optimal), the ring moves ~2x the buffer
    total (bandwidth-optimal), so large payloads take the ring even at
    power-of-two PE counts ("auto").  Explicit "rd"/"ring" override."""
    n = net.n_pes
    if n == 1:
        return x
    fn = combine or OPS[op]
    if algorithm in (None, "auto"):
        leaves = jax.tree.leaves(x)
        nbytes = sum(l.size * l.dtype.itemsize for l in leaves)
        if algorithm == "auto" and nbytes >= RING_BYTES_THRESHOLD:
            algo = "ring"
        else:
            algo = "rd" if _is_pow2(n) else "ring"
    else:
        algo = algorithm
    if algo == "rd":
        for k in range(_ceil_log2(n)):
            stride = 1 << k
            perm = [(i, i ^ stride) for i in range(n)]
            recv = net.ppermute(x, perm)
            x = jax.tree.map(fn, x, recv)
        return x
    rs, shape_info = _reduce_scatter_ring(net, x, fn)
    return _allgather_unpad(net, rs, shape_info)


def reduce_scatter(net: NetOps, x, op: str = "sum",
                   combine: Callable | None = None):
    """Ring reduce-scatter; returns this PE's owned chunk of the flattened,
    padded array plus the info needed to allgather/unpad it."""
    fn = combine or OPS[op]
    return _reduce_scatter_ring(net, x, fn)


def _reduce_scatter_ring(net: NetOps, x, fn):
    """Ring reduce-scatter with the static schedule (§Perf P1): one
    pre-rotation puts every stage's chunk at a STATIC offset, so the loop
    body is free of dynamic slicing (r block t = chunk (pe + t) mod n)."""
    n = net.n_pes
    sim = isinstance(net, SimNetOps)
    orig_shape = x.shape[1:] if sim else x.shape
    size = int(np.prod(orig_shape))
    chunk = -(-size // n)
    padded = chunk * n
    pe = net.my_pe()

    def flatpad(v):
        f = v.reshape(-1)
        return jnp.pad(f, (0, padded - size))

    buf = _lmap(net, flatpad, x)
    idx = (pe[..., None] + jnp.arange(n)) % n if sim \
        else (pe + jnp.arange(n)) % n
    r = _take_blocks(net, buf, idx, n, 0)
    ring = [(i, (i + 1) % n) for i in range(n)]

    def static_chunk(b, t):
        return b[..., t * chunk:(t + 1) * chunk] if sim \
            else b[t * chunk:(t + 1) * chunk]

    cur = static_chunk(r, 0)                     # chunk[pe]
    for j in range(1, n):
        cur = net.ppermute(cur, ring)
        cur = fn(static_chunk(r, n - j), cur)    # chunk[(pe - j) mod n]
    # PE p now owns the fully-reduced chunk (p + 1) % n
    own_idx = (pe + 1) % n
    info = (orig_shape, size, chunk, own_idx)
    return cur, info


def _allgather_unpad(net: NetOps, chunk_val, info):
    """Ring allgather of the reduce-scatter result, static schedule: parts
    arrive in ring order; one post-gather restores block order."""
    orig_shape, size, chunk, own_idx = info
    n = net.n_pes
    sim = isinstance(net, SimNetOps)
    pe = net.my_pe()
    ring = [(i, (i + 1) % n) for i in range(n)]
    parts = [chunk_val]                 # part t = chunk (pe + 1 - t) mod n
    cur = chunk_val
    for j in range(1, n):
        cur = net.ppermute(cur, ring)
        parts.append(cur)
    stacked = jnp.concatenate(parts, axis=-1)
    # out block i = part (pe + 1 - i) mod n
    idx = (pe[..., None] + 1 - jnp.arange(n)) % n if sim \
        else (pe + 1 - jnp.arange(n)) % n
    out = _take_blocks(net, stacked, idx, n, 0)

    def unpad(b):
        return b[:size].reshape(orig_shape)

    return _lmap(net, unpad, out)


def allreduce_stages(n: int, nbytes: float, topo=None, algorithm=None):
    algo = algorithm or ("rd" if _is_pow2(n) else "ring")
    out = []
    if algo == "rd":
        for k in range(_ceil_log2(n)):
            stride = 1 << k
            out.append((float(nbytes), _stride_hops(stride, n, topo)))
    else:
        per = nbytes / n
        for _ in range(2 * (n - 1)):
            out.append((per, _stride_hops(1, n, topo)))
    return out


# ---------------------------------------------------------------------------
# alltoall (pairwise exchange — paper Fig. 9)
# ---------------------------------------------------------------------------

def alltoall(net: NetOps, x, axis: int = 0):
    """out[src-block] = x_src[my-block]; x's `axis` dim = n_pes * block.

    Static schedule (§Perf P1): one pre-rotation makes every stage's send
    block a static slice; received parts concatenate in ring order and one
    post-gather restores block order — no per-stage dynamic updates."""
    n = net.n_pes
    if n == 1:
        return x
    sim = isinstance(net, SimNetOps)
    ax = axis + (1 if sim else 0)
    dim = x.shape[ax]
    assert dim % n == 0, f"alltoall axis dim {dim} not divisible by n_pes {n}"
    pe = net.my_pe()

    # pre-rotate: r block t = x block (pe + t) mod n
    idx = (pe[..., None] + jnp.arange(n)) % n if sim \
        else (pe + jnp.arange(n)) % n
    r = _take_blocks(net, x, idx, n, axis)
    blk = dim // n

    def static_blk(v, t):
        sl = [slice(None)] * v.ndim
        sl[ax] = slice(t * blk, (t + 1) * blk)
        return v[tuple(sl)]

    parts = [static_blk(r, 0)]          # own block: out[pe] = x_pe[pe]
    for j in range(1, n):
        perm = [(i, (i + j) % n) for i in range(n)]
        recv = net.ppermute(static_blk(r, j), perm)
        parts.append(recv)              # part t = out-block (pe - t) mod n
    stacked = jnp.concatenate(parts, axis=ax)
    out_idx = (pe[..., None] - jnp.arange(n)) % n if sim \
        else (pe - jnp.arange(n)) % n
    return _take_blocks(net, stacked, out_idx, n, axis)


def alltoall_stages(n: int, nbytes_total: float, topo=None):
    per = nbytes_total / n
    return [(per, _stride_hops(j, n, topo)) for j in range(1, n)]


# ---------------------------------------------------------------------------
# point-to-point RMA
# ---------------------------------------------------------------------------

def put(net: NetOps, x, pattern: Sequence[tuple[int, int]]):
    """One-sided put along a static (src, dst) pattern; PEs not receiving
    keep zeros (use shmem.put for merge-with-local semantics)."""
    return net.ppermute(x, pattern)


def get(net: NetOps, x, pattern: Sequence[tuple[int, int]]):
    """get along (requester, owner) pairs: owner pushes — the IPI-get."""
    inv = [(d, s) for s, d in pattern]
    return net.ppermute(x, inv)


# ---------------------------------------------------------------------------
# scans (substrate for atomics)
# ---------------------------------------------------------------------------

def exclusive_scan(net: NetOps, x, op: str = "sum"):
    """Exclusive scan over the PE axis of a per-PE scalar/array.

    This realizes the observable semantics of concurrent shmem atomics in
    PE order (DESIGN.md §6): fetch_add's return on PE i = init + sum of
    contributions of PEs < i."""
    n = net.n_pes
    fn = OPS[op]
    identity = {"sum": 0, "prod": 1, "max": None, "min": None,
                "and": -1, "or": 0, "xor": 0}[op]
    sim = isinstance(net, SimNetOps)
    xb = x[:, None] if (sim and x.ndim == 1) else jnp.expand_dims(x, 0 if not sim else 1)
    all_vals = fcollect(net, xb, axis=0)
    pe = net.my_pe()

    def scan_one(vals, i):
        idx = jnp.arange(n)
        if identity is None:  # max/min: mask with +-inf
            fill = jnp.array(jnp.finfo(vals.dtype).min if op == "max"
                             else jnp.finfo(vals.dtype).max, vals.dtype)
            masked = jnp.where((idx < i)[(...,) + (None,) * (vals.ndim - 1)], vals, fill)
            return jnp.max(masked, 0) if op == "max" else jnp.min(masked, 0)
        masked = jnp.where((idx < i)[(...,) + (None,) * (vals.ndim - 1)], vals,
                           jnp.array(identity, vals.dtype))
        if op == "sum":
            return jnp.sum(masked, 0)
        if op == "prod":
            return jnp.prod(masked, 0)
        red = masked[0]
        for k in range(1, n):
            red = fn(red, masked[k])
        return red

    return _lmap(net, scan_one, all_vals, pe)
