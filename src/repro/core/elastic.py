"""Elastic restart for a degraded mesh (DESIGN.md §17).

When a PE dies mid-run the OpenSHMEM 1.3 answer is a hang at the next
barrier; this module is the beyond-spec recovery path the fault layer
(:mod:`repro.core.fault`) makes possible:

  1. :func:`degrade` rebuilds the communication structure for the LIVE
     PEs — a :class:`~repro.core.team.Team` whose member order is a
     congestion-optimized ring embedding of the survivors (the analogue
     of re-running the snake embedding on a 4x4 mesh with a hole), and a
     degraded-mesh :func:`~repro.core.tuner.fingerprint` so the
     :class:`~repro.core.tuner.TunedSelector` re-tunes instead of
     replaying full-mesh winners.
  2. :func:`recover` drives the whole protocol on a live context:
     re-fingerprint, restore the last complete checkpoint (global
     arrays, so resharding onto fewer PEs falls out of
     ``manager.restore``), and report recovery wall time to the
     attached profiler.

The ring optimization deliberately does NOT reuse
``collectives.optimize_embedding``: that returns a WORLD-wide
permutation and could relabel a live PE onto a dead one.  Here the
search space is orderings of the live set only — a pairwise-swap hill
climb over (max link load, total weighted hops) of the live ring under
the topology's XY routes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from . import fault as fault_mod
from . import team as team_mod
from . import tuner as tuner_mod
from .topology import MeshTopology


@dataclasses.dataclass(frozen=True)
class DegradedMesh:
    """The rebuilt communication structure for the surviving PEs.

    topo        : the PHYSICAL mesh (unchanged — dead PEs still occupy
                  coordinates; routes must simply avoid addressing them)
    dead        : the dead world PEs, sorted
    live        : the surviving world PEs, in ring-embedded order — the
                  embedding collectives over `team` should use
    team        : interned Team over `live` (members in ring order, so
                  team-rank ring algorithms take mesh-local hops)
    fingerprint : the degraded-mesh tuning key
                  (:func:`repro.core.tuner.fingerprint` with dead_pes)
    """

    topo: MeshTopology | None
    dead: tuple[int, ...]
    live: tuple[int, ...]
    team: team_mod.Team
    fingerprint: str

    @property
    def n_live(self) -> int:
        return len(self.live)


def _ring_cost(topo: MeshTopology, order: Sequence[int]
               ) -> tuple[float, float]:
    """(max link load, total weighted hops) of the ring over `order`
    under XY routing — the same objective the snake-embedding scorer
    uses, restricted to the live ring."""
    loads: dict[tuple[int, int], float] = {}
    hops = 0.0
    for i, pe in enumerate(order):
        dst = order[(i + 1) % len(order)]
        if dst == pe:
            continue
        for u, v in topo.route(pe, dst):
            key = (u, v) if u < v else (v, u)
            loads[key] = loads.get(key, 0.0) + 1.0
            hops += topo.link_weight(u, v)
    return (max(loads.values()) if loads else 0.0, hops)


def _optimize_live_ring(topo: MeshTopology, live: Sequence[int]
                        ) -> tuple[int, ...]:
    """Ring order over the LIVE PEs: seed with the snake order filtered
    to survivors (already near-optimal — a dead PE just shortens the
    snake), then pairwise-swap hill climb until no swap improves
    (max link load, total hops).  Deterministic: first-improvement scan
    in index order."""
    order = [p for p in topo.snake_order() if p in set(live)]
    if len(order) <= 3:
        return tuple(order)
    cost = _ring_cost(topo, order)
    improved = True
    while improved:
        improved = False
        for i in range(len(order) - 1):
            for j in range(i + 1, len(order)):
                order[i], order[j] = order[j], order[i]
                c = _ring_cost(topo, order)
                if c < cost:
                    cost = c
                    improved = True
                else:
                    order[i], order[j] = order[j], order[i]
    return tuple(order)


def degrade(topo: MeshTopology | None, dead_pes: Sequence[int],
            world_n: int | None = None) -> DegradedMesh:
    """Rebuild teams/embedding/fingerprint for the mesh minus
    `dead_pes`.  With no topology (flat PE space) the live ring is just
    the surviving ranks in order."""
    n = world_n if world_n is not None \
        else (topo.n_pes if topo is not None else None)
    if n is None:
        raise ValueError("degrade() needs topo or world_n")
    dead = tuple(sorted({int(p) % n for p in dead_pes}))
    live_set = [p for p in range(n) if p not in dead]
    if not live_set:
        raise ValueError("every PE is dead — nothing to degrade to")
    if topo is not None and getattr(topo, "n_pes", None) == n:
        live = _optimize_live_ring(topo, live_set)
    else:
        live = tuple(live_set)
    return DegradedMesh(
        topo=topo, dead=dead, live=live,
        team=team_mod.make_team(live, n),
        fingerprint=tuner_mod.fingerprint(topo, n, dead_pes=dead))


def recover(ctx, dead_pes: Sequence[int], ckpt_dir, template,
            shardings=None) -> tuple[int, object, DegradedMesh]:
    """The elastic restart protocol on a live
    :class:`~repro.core.shmem.ShmemContext`:

      1. rebuild the degraded-mesh structure (:func:`degrade`),
      2. re-key the context's tuning identity
         (``ctx.refingerprint``) so the TunedSelector re-tunes,
      3. restore the last COMPLETE checkpoint
         (:func:`repro.ckpt.manager.restore` — global arrays reshard
         onto whatever the survivors can hold).

    Returns ``(step, state, degraded)``.  Recovery wall time lands on
    the attached profiler as ``fault.recovery_us`` plus an ``instant``
    trace event, so ``tracereport`` shows it for chaos runs."""
    from ..ckpt import manager as ckpt_mod

    t0 = time.perf_counter()
    dm = degrade(ctx.topo, dead_pes, world_n=ctx.n_pes)
    ctx.refingerprint(dm.fingerprint)
    step, state = ckpt_mod.restore(ckpt_dir, template, shardings=shardings)
    wall = time.perf_counter() - t0
    prof = ctx._active_profile()
    if prof is not None:
        prof.count("fault.recovery_us", int(wall * 1e6))
    fault_mod.fault_event(prof, "fault.recovered",
                          dead=list(dm.dead), step=step,
                          recovery_us=int(wall * 1e6))
    return step, state, dm


__all__ = ["DegradedMesh", "degrade", "recover"]
