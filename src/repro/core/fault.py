"""Deterministic fault injection + typed failures (DESIGN.md §17).

Production scale means PEs and NoC links disappear mid-run.  OpenSHMEM
1.3 has NO fault-tolerance semantics — a dead core simply hangs its
peers at the next barrier — so this layer is deliberately beyond-spec:
faults surface as *typed Python errors* the runtime can catch and
recover from, never as silent hangs.

Three pieces:

  * :class:`FaultPlan` — a declarative, step-keyed schedule of fault
    events (dead PEs, dropped links, slow stragglers, and their heals).
    Purely host data, so a chaos run is exactly reproducible.
  * :class:`FaultInjector` — the active half, attached to a NetOps
    backend (``net.fault``).  Every ``ppermute`` consults it: patterns
    are static host objects, so the check is pure host code that costs
    one ``is None`` test when no injector is attached and works
    identically under SIM, NoC-SIM and SPMD tracing.
  * :class:`PEFailure` / :class:`LinkFailure` / :class:`DeadlineExceeded`
    — typed errors carrying the offending PE/link, the compiled
    pattern, and the fault-plan step, so recovery code (and test
    assertions) see *what* failed, not just *that* something did.

Routing semantics: a transfer whose dimension-ordered XY route crosses a
dropped link first tries the alternate YX route
(:meth:`~repro.core.topology.MeshTopology.route_alt`); only when both
are severed does :class:`LinkFailure` surface — at which point the
pending-op engine's retry/backoff (``Ctx`` in ``core/shmem.py``) takes
over, and a ``heal_after`` budget on the drop makes transient faults
deterministically recoverable after a known number of attempts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .pattern import CommPattern


# ---------------------------------------------------------------------------
# typed failures
# ---------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base of all injected-fault errors.  Carries the offending
    resource, the compiled pattern that tripped it, and the plan step."""

    def __init__(self, msg: str, *, pe: int | None = None,
                 link: tuple[int, int] | None = None,
                 pattern: CommPattern | None = None,
                 step: int | None = None, op: str | None = None,
                 attempts: int = 0):
        super().__init__(msg)
        self.pe = pe
        self.link = link
        self.pattern = pattern
        self.step = step
        self.op = op
        self.attempts = attempts


class PEFailure(FaultError):
    """A transfer named a dead PE as source or destination."""


class LinkFailure(FaultError):
    """A transfer's route (and its alternate) crosses a dropped link."""


class DeadlineExceeded(FaultError):
    """quiet()/fence() could not complete within its deadline — the
    straggler-detection surface (a slow PE's DMA never landing)."""


def _canon(a: int, b: int) -> tuple[int, int]:
    return (a, b) if a <= b else (b, a)


# ---------------------------------------------------------------------------
# the declarative plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault edge.  kind: "pe" | "link" | "straggler" with
    heal counterparts "heal_pe" | "heal_link" | "heal_straggler"."""

    step: int
    kind: str
    target: tuple
    delay_s: float = 0.0
    heal_after: int | None = None


class FaultPlan:
    """A deterministic schedule of faults, keyed by train/engine step.

    Builder methods return ``self`` so plans chain::

        plan = (FaultPlan().kill_pe(5, pe=9)
                           .drop_link(3, 0, 1, heal_after=2)
                           .slow_pe(2, pe=7, delay_s=0.05))

    The plan is pure data; :class:`FaultInjector` interprets it.  A
    ``heal_after=k`` on a dropped link makes the drop TRANSIENT: the
    k-th failed attempt heals it, so retry-with-backoff succeeds on a
    known attempt — the deterministic analogue of a flaky link."""

    def __init__(self):
        self.events: list[FaultEvent] = []

    def kill_pe(self, step: int, pe: int) -> "FaultPlan":
        self.events.append(FaultEvent(int(step), "pe", (int(pe),)))
        return self

    def heal_pe(self, step: int, pe: int) -> "FaultPlan":
        self.events.append(FaultEvent(int(step), "heal_pe", (int(pe),)))
        return self

    def drop_link(self, step: int, a: int, b: int,
                  heal_after: int | None = None) -> "FaultPlan":
        self.events.append(FaultEvent(
            int(step), "link", _canon(int(a), int(b)),
            heal_after=heal_after))
        return self

    def heal_link(self, step: int, a: int, b: int) -> "FaultPlan":
        self.events.append(FaultEvent(
            int(step), "heal_link", _canon(int(a), int(b))))
        return self

    def slow_pe(self, step: int, pe: int, delay_s: float) -> "FaultPlan":
        self.events.append(FaultEvent(
            int(step), "straggler", (int(pe),), delay_s=float(delay_s)))
        return self

    def heal_straggler(self, step: int, pe: int) -> "FaultPlan":
        self.events.append(FaultEvent(
            int(step), "heal_straggler", (int(pe),)))
        return self

    def state_at(self, step: int) -> tuple[frozenset, dict, dict]:
        """Cumulative fault state once every event with
        ``event.step <= step`` has applied: ``(dead_pes,
        {link: heal_after}, {pe: delay_s})``."""
        dead: set[int] = set()
        dropped: dict[tuple[int, int], int | None] = {}
        slow: dict[int, float] = {}
        for ev in sorted(self.events, key=lambda e: e.step):
            if ev.step > step:
                break
            if ev.kind == "pe":
                dead.add(ev.target[0])
            elif ev.kind == "heal_pe":
                dead.discard(ev.target[0])
            elif ev.kind == "link":
                dropped[ev.target] = ev.heal_after
            elif ev.kind == "heal_link":
                dropped.pop(ev.target, None)
            elif ev.kind == "straggler":
                slow[ev.target[0]] = ev.delay_s
            elif ev.kind == "heal_straggler":
                slow.pop(ev.target[0], None)
            else:
                raise ValueError(f"unknown fault kind {ev.kind!r}")
        return frozenset(dead), dropped, slow

    def __repr__(self) -> str:
        return f"FaultPlan({len(self.events)} events)"


# ---------------------------------------------------------------------------
# the injector (attached as net.fault)
# ---------------------------------------------------------------------------

def fault_event(profile, name: str, n: int = 1, **args) -> None:
    """Record a fault-layer event on an attached Profiler/Tracer: always
    a counter; additionally an ``instant()`` trace event when the
    profile is a Tracer (level 3) — what `tracereport` summarizes for
    chaos runs (DESIGN.md §17)."""
    if profile is None or not profile.enabled:
        return
    profile.count(name, n)
    inst = getattr(profile, "instant", None)
    if inst is not None:
        inst(name, **args)


class FaultInjector:
    """Interprets a :class:`FaultPlan` against live traffic.

    Attach with ``ShmemContext(fault=plan)`` (or ``net.fault =
    FaultInjector(plan, topo)`` directly); drive the clock with
    :meth:`set_step` from the train/engine loop.  ``check()`` is called
    by every backend ``ppermute`` — dead-PE and dropped-link faults
    raise typed errors at ISSUE time (the NoC would never accept the
    packet); straggler delays accumulate in :attr:`pending_delay_s` and
    surface at the COMPLETION point, ``Ctx.quiet`` (a slow PE's DMA
    takes longer to land, not longer to enqueue)."""

    def __init__(self, plan: FaultPlan, topo=None, profile=None):
        self.plan = plan
        self.topo = topo
        self.profile = profile
        self.step = 0
        self.pending_delay_s = 0.0
        self.stats: dict[str, int] = {}
        self._healed: set[tuple[int, int]] = set()
        self._link_attempts: dict[tuple[int, int], int] = {}
        self._refresh()

    # -- clock ---------------------------------------------------------------
    def set_step(self, step: int) -> None:
        self.step = int(step)
        self._refresh()

    def _refresh(self) -> None:
        dead, dropped, slow = self.plan.state_at(self.step)
        self.dead = dead
        self.dropped = {lk: ha for lk, ha in dropped.items()
                        if lk not in self._healed}
        self.slow = slow

    def _bump(self, key: str, n: int = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + n

    # -- introspection --------------------------------------------------------
    @property
    def dead_pes(self) -> tuple[int, ...]:
        return tuple(sorted(self.dead))

    def consume_delay(self) -> float:
        """Drain the straggler delay accumulated since the last call —
        ``Ctx._enqueue`` attaches it to the issuing Future."""
        d, self.pending_delay_s = self.pending_delay_s, 0.0
        return d

    # -- the per-ppermute check ----------------------------------------------
    def _blocked(self, route, dropped) -> tuple[int, int] | None:
        for u, v in route:
            lk = _canon(u, v)
            if lk in dropped:
                return lk
        return None

    def check(self, p: CommPattern, net=None) -> None:
        """Raise PEFailure/LinkFailure when the pattern touches a dead
        PE or an unroutable dropped link; accumulate straggler delay."""
        if self.dead:
            for s, d in p.pairs:
                bad = s if s in self.dead else (d if d in self.dead
                                                else None)
                if bad is not None:
                    self._bump("fault.pe_hits")
                    fault_event(self.profile, "fault.pe_failure",
                                pe=bad, step=self.step)
                    raise PEFailure(
                        f"PE {bad} is dead (fault plan step {self.step}); "
                        f"pattern touches it with pair ({s}, {d})",
                        pe=bad, pattern=p, step=self.step)
        if self.dropped and self.topo is not None:
            for s, d in p.pairs:
                if s == d:
                    continue
                lk = self._blocked(self.topo.route(s, d), self.dropped)
                if lk is None:
                    continue
                alt = self.topo.route_alt(s, d)
                if self._blocked(alt, self.dropped) is None:
                    # the YX route avoids every dropped link: the
                    # adaptive-routing path — traffic flows, one counter
                    self._bump("fault.reroutes")
                    fault_event(self.profile, "fault.reroute",
                                link=list(lk), src=s, dst=d,
                                step=self.step)
                    continue
                tries = self._link_attempts.get(lk, 0) + 1
                self._link_attempts[lk] = tries
                heal = self.dropped[lk]
                if heal is not None and tries >= heal:
                    # transient drop: this failed attempt heals it —
                    # the NEXT attempt (a retry) goes through
                    self._healed.add(lk)
                    self._refresh()
                self._bump("fault.link_hits")
                fault_event(self.profile, "fault.link_failure",
                            link=list(lk), src=s, dst=d, step=self.step,
                            attempt=tries)
                raise LinkFailure(
                    f"link {lk} is down (fault plan step {self.step}, "
                    f"attempt {tries}) and the alternate YX route is "
                    f"also severed for pair ({s}, {d})",
                    link=lk, pattern=p, step=self.step, attempts=tries)
        if self.slow:
            delay = 0.0
            worst = None
            for s, d in p.pairs:
                for pe in (s, d):
                    t = self.slow.get(pe, 0.0)
                    if t > delay:
                        delay, worst = t, pe
            if delay > 0.0:
                self.pending_delay_s = max(self.pending_delay_s, delay)
                self._bump("fault.straggler_hits")
                fault_event(self.profile, "fault.straggler",
                            pe=worst, delay_s=delay, step=self.step)


def as_injector(fault, topo=None, profile=None) -> FaultInjector | None:
    """Normalize the ``fault=`` knob: a FaultPlan wraps into a fresh
    injector, an injector passes through (its topo/profile filled in
    when unset), None stays None."""
    if fault is None:
        return None
    if isinstance(fault, FaultPlan):
        return FaultInjector(fault, topo=topo, profile=profile)
    if isinstance(fault, FaultInjector):
        if fault.topo is None:
            fault.topo = topo
        if fault.profile is None:
            fault.profile = profile
        return fault
    raise TypeError(f"fault= expects FaultPlan | FaultInjector | None, "
                    f"got {type(fault).__name__}")


__all__ = [
    "FaultError", "PEFailure", "LinkFailure", "DeadlineExceeded",
    "FaultEvent", "FaultPlan", "FaultInjector", "as_injector",
    "fault_event",
]
