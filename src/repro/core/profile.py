"""Runtime profiler — the ``shmem_pcontrol`` analogue (DESIGN.md §13).

The paper's contribution is a *measured* performance evaluation; PRs 1-4
built an analytic selection stack (alpha-beta + congestion pricing of the
very :class:`~repro.core.pattern.Schedule` objects that execute) but
nothing in the runtime ever looked at what actually ran.  This module is
the measurement half of closing that loop:

  * :class:`Profiler` records one :class:`OpSample` per collective (kind,
    interned schedule id, team shape, payload bytes, resolved algorithm /
    chunk count / embedding, wall time, bytes moved, hottest-link load,
    model-predicted time) plus lightweight counters for RMA and raw
    ppermute traffic.  Attach it with ``ShmemContext(profile=...)`` (it
    propagates to the context's :class:`~repro.core.netops.NetOps` and
    every :class:`~repro.core.shmem.Ctx`).
  * ``pcontrol(level)`` follows OpenSHMEM ``shmem_pcontrol`` semantics:
    0 disables collection, 1 keeps aggregate counters, >=2 additionally
    keeps the per-op timeline.  When disabled (or when no profiler is
    attached — the default) the hot path pays ONE ``is None``/flag test.
  * Samples recorded while JAX is tracing (inside ``jit``/``shard_map``
    staging) are flagged ``traced=True``: their wall times are trace
    times, not execution times, and the tuner's online refinement skips
    them.  Eager SIM execution produces honest (dispatch-inclusive)
    wall times; :func:`measure` is the jit+warmup steady-state timer the
    calibration sweep uses (same methodology as ``benchmarks/_util``).
  * ``to_json()``/``dump(path)`` export the aggregate counters and the
    timeline in one machine-readable document; ``add_sink(fn)`` streams
    every committed sample to observers (``Tuner.observe`` uses this for
    online refinement — DESIGN.md §13).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
from typing import Any, Callable


def trace_clean() -> bool:
    """True when called OUTSIDE any JAX trace — wall times measured here
    are execution times; under tracing they are staging times."""
    try:
        import jax
        return bool(jax.core.trace_state_clean())
    except Exception:       # very old/new jax: assume eager
        return True


@dataclasses.dataclass
class OpSample:
    """One profiled operation — the per-op record the timeline exports.

    ``kind`` distinguishes timed collectives ("collective"), non-blocking
    RMA issues ("rma"), bare selection decisions recorded outside any
    timed region ("selection"), calibration measurements ("measure"),
    quiet/fence memory-ordering points ("sync" — wall time split into
    ``issue_s`` + ``stall_s``, DESIGN.md §16), and user spans
    ("span")."""

    collective: str
    nbytes: float = 0.0
    n_pes: int = 0
    team: str = ""                 # group shape, e.g. "n16", "team4of16"
    kind: str = "collective"
    t_start: float = 0.0           # seconds since the profiler's epoch
    wall_s: float = 0.0
    algorithm: str = ""
    chunks: int = 1
    embedding: str = ""            # "", "snake", or "perm:..."
    schedule: str = ""             # interned Schedule name (e.g. allreduce.ring)
    n_stages: int = 0
    bytes_moved: float = 0.0       # schedule total wire bytes
    max_link_load: float = 0.0     # hottest stage's hottest-link multiplicity
    predicted_s: float = float("nan")   # alpha-beta modeled time
    traced: bool = False           # recorded under jit/shard_map staging
    fingerprint: str = ""          # tuner topology key (tuner.fingerprint)
    issue_s: float = 0.0           # "sync" kind: time spent issuing
    stall_s: float = 0.0           # "sync" kind: time stalled on pending ops
    meta: dict | None = None       # free-form span annotations (trace args)
    stage_costs: list | None = None  # per-stage cost-model attribution:
    #                                  [{nbytes, hops, load, predicted_s}]
    #                                  (perfdiff/tracereport read these)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["predicted_s"] != d["predicted_s"]:     # NaN (unpredicted):
            d["predicted_s"] = None                  # json.dump would emit
        return d                                     # an invalid literal


def _emb_str(embedding) -> str:
    """Canonical string form of an embedding knob/order for sample and
    tuning-DB keys: "" identity/off, "snake"/"auto" pass through, an
    explicit order becomes "perm:i,j,...";"""
    if embedding is None:
        return ""
    if isinstance(embedding, str):
        return embedding
    return "perm:" + ",".join(str(int(p)) for p in embedding)


class Profiler:
    """pcontrol-style runtime profiler (levels: 0 off, 1 counters,
    >=2 counters + per-op timeline).  Thread-safe; the open-op stack is
    thread-local so concurrent contexts don't interleave notes."""

    #: consecutive failures after which a raising sink is dropped
    SINK_MAX_FAILURES = 3

    def __init__(self, level: int = 2, max_samples: int = 100_000):
        self.level = int(level)
        self.max_samples = max_samples
        self.samples: list[OpSample] = []
        self.dropped = 0
        self.sink_errors = 0
        self.sinks_dropped = 0
        self._counters: dict[str, dict[str, float]] = {}
        self._sinks: list[Callable[[OpSample], None]] = []
        self._sink_fails: dict[int, int] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._epoch = time.perf_counter()

    # -- control (shmem_pcontrol) -------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.level > 0

    def pcontrol(self, level: int) -> None:
        """OpenSHMEM ``shmem_pcontrol``: 0 disables collection, 1 enables
        the default (counters), >= 2 enables detailed collection (the
        per-op timeline).  Takes effect on the next recorded op."""
        self.level = int(level)

    def reset(self) -> None:
        with self._lock:
            self.samples = []
            self._counters = {}
            self.dropped = 0
            self._epoch = time.perf_counter()

    def add_sink(self, fn: Callable[[OpSample], None]) -> None:
        """Stream every committed sample to `fn` (e.g. ``Tuner.observe``
        for online refinement).  Sinks run synchronously at commit, after
        the sample is final; they see disabled-level nothing.

        A sink must never abort the instrumented op: exceptions are
        caught and counted (``sink_errors``), and a sink that fails
        ``SINK_MAX_FAILURES`` consecutive times is dropped
        (``sinks_dropped``) so a broken observer cannot tax every
        subsequent op."""
        if fn not in self._sinks:
            self._sinks.append(fn)
            self._sink_fails[id(fn)] = 0

    # -- recording -----------------------------------------------------------
    def _open_stack(self) -> list[OpSample]:
        st = getattr(self._tls, "open", None)
        if st is None:
            st = []
            self._tls.open = st
        return st

    @contextlib.contextmanager
    def op(self, collective: str, nbytes: float = 0.0, n_pes: int = 0,
           team: str = "", kind: str = "collective", fingerprint: str = ""):
        """Time a region as one op sample.  Selection notes emitted while
        the region is open (``note``) enrich this sample; nested ``op``
        regions record separately (innermost note wins)."""
        if not self.enabled:
            yield None
            return
        s = OpSample(collective=collective, nbytes=float(nbytes),
                     n_pes=int(n_pes), team=team or f"n{n_pes}", kind=kind,
                     traced=not trace_clean(), fingerprint=fingerprint)
        stack = self._open_stack()
        stack.append(s)
        t0 = time.perf_counter()
        s.t_start = t0 - self._epoch
        try:
            yield s
        finally:
            s.wall_s = time.perf_counter() - t0
            stack.pop()
            self._commit(s)

    def note(self, algorithm: str | None = None, chunks: int | None = None,
             schedule=None, topo=None, link=None, embedding=None,
             collective: str | None = None, nbytes: float | None = None,
             n_pes: int | None = None) -> None:
        """Record the RESOLVED selection of the innermost open op (the
        executors call this once algorithm/chunks/embedding are known —
        DESIGN.md §13).  The note only enriches an open op of the SAME
        collective (or one opened without a name): a selection made
        inside some other timed region — e.g. a ``Comm`` allreduce
        traced inside a ``train_step`` op — must not relabel that
        region's sample, so it commits a bare "selection" sample
        instead (visible in the level >= 2 timeline)."""
        if not self.enabled:
            return
        stack = self._open_stack()
        matches = bool(stack) and (
            collective is None or not stack[-1].collective
            or stack[-1].collective == collective)
        if matches:
            s = stack[-1]
        else:
            stack = []                  # commit as a standalone selection
            s = OpSample(collective=collective or "", kind="selection",
                         t_start=time.perf_counter() - self._epoch,
                         traced=not trace_clean())
        if algorithm is not None:
            s.algorithm = algorithm
        if chunks is not None:
            s.chunks = int(chunks)
        if embedding is not None:
            s.embedding = _emb_str(embedding)
        if collective is not None and not s.collective:
            s.collective = collective
        if nbytes is not None and not s.nbytes:
            s.nbytes = float(nbytes)
        if n_pes is not None and not s.n_pes:
            s.n_pes = int(n_pes)
            if not s.team:
                s.team = f"n{s.n_pes}"
        if schedule is not None:
            s.schedule = schedule.name
            s.n_stages = len(schedule.stages)
            s.bytes_moved = float(schedule.total_bytes())
            # the object references the tracer renders per-PE stage spans
            # and link heatmaps from (schedules/topologies are interned;
            # not exported by to_dict)
            s._sched, s._topo = schedule, topo
            try:
                s.max_link_load = max(
                    (st.pattern.max_link_load(topo)
                     for st in schedule.stages), default=0.0)
            except Exception:
                s.max_link_load = 0.0
            try:
                # per-stage attribution: the exact (bytes, hops, load)
                # descriptors eq. 1 prices, plus the per-stage modeled
                # time when a link model is known — what perfdiff
                # decomposes regressions against and the tracer stamps
                # onto stage spans (DESIGN.md §18)
                s.stage_costs = []
                for st in schedule.stages:
                    nb, hops, load = st.cost(topo)
                    c = {"nbytes": float(nb), "hops": float(hops),
                         "load": float(load)}
                    if link is not None:
                        c["predicted_s"] = link.time(nb, hops, load)
                    s.stage_costs.append(c)
            except Exception:
                s.stage_costs = None
            if link is not None:
                s.predicted_s = schedule.pipelined_time(
                    max(s.chunks, 1), topo, link)
        if not stack:
            self._commit(s)

    def count(self, key: str, n: int = 1, nbytes: float = 0.0) -> None:
        """Bare aggregate counter (no timeline entry) — what the NetOps
        ppermute hook uses; near-zero cost, safe under tracing."""
        if not self.enabled:
            return
        with self._lock:
            c = self._counters.setdefault(
                key, {"count": 0.0, "total_s": 0.0, "total_bytes": 0.0})
            c["count"] += n
            c["total_bytes"] += float(nbytes)

    def record_rma(self, op: str, nbytes: float, pattern=None,
                   n_pes: int = 0) -> None:
        """One non-blocking RMA issue (put_nbi/get_nbi) — counters always,
        a timeline entry at level >= 2.  No wall time: completion is
        pinned later by quiet()."""
        if not self.enabled:
            return
        self.count(f"rma.{op}", 1, nbytes)
        if self.level >= 2:
            s = OpSample(collective=op, kind="rma", nbytes=float(nbytes),
                         n_pes=n_pes,
                         t_start=time.perf_counter() - self._epoch,
                         traced=not trace_clean())
            if pattern is not None:
                s.n_stages = 1
                s.bytes_moved = float(nbytes) * max(len(pattern.pairs), 1)
            with self._lock:
                if len(self.samples) < self.max_samples:
                    self.samples.append(s)
                else:
                    self.dropped += 1

    def record_sync(self, op: str, n_ops: int, nbytes: float, *,
                    issue_s: float, stall_s: float = 0.0, n_pes: int = 0,
                    t_start: float | None = None) -> None:
        """One memory-ordering point (``quiet``/``fence``) with its wall
        time split into ISSUE time (building/dispatching the completion
        or ordering program) and STALL time (blocking until the pending
        ops actually land) — the split that was previously folded
        invisibly into op wall time (DESIGN.md §16)."""
        if not self.enabled:
            return
        if t_start is None:
            t_start = (time.perf_counter() - self._epoch
                       - issue_s - stall_s)
        s = OpSample(collective=op, kind="sync", nbytes=float(nbytes),
                     n_pes=int(n_pes), t_start=t_start,
                     wall_s=issue_s + stall_s, issue_s=float(issue_s),
                     stall_s=float(stall_s), traced=not trace_clean(),
                     meta={"n_ops": int(n_ops)})
        self._commit(s)

    def _commit(self, s: OpSample) -> None:
        if not self.enabled:    # pcontrol(0) raced the op: drop cleanly
            return
        key = f"{s.kind}.{s.collective}" + (
            f".{s.algorithm}" if s.algorithm else "")
        with self._lock:
            c = self._counters.setdefault(
                key, {"count": 0.0, "total_s": 0.0, "total_bytes": 0.0})
            c["count"] += 1
            c["total_s"] += s.wall_s
            c["total_bytes"] += s.nbytes
            if s.kind == "sync":
                c["issue_s"] = c.get("issue_s", 0.0) + s.issue_s
                c["stall_s"] = c.get("stall_s", 0.0) + s.stall_s
            if self.level >= 2:
                if len(self.samples) < self.max_samples:
                    self.samples.append(s)
                else:
                    self.dropped += 1
        for sink in list(self._sinks):
            try:
                sink(s)
                self._sink_fails[id(sink)] = 0
            except Exception:
                # a sink must not abort the instrumented op: count the
                # failure and drop the sink once it fails repeatedly
                self.sink_errors += 1
                fails = self._sink_fails.get(id(sink), 0) + 1
                self._sink_fails[id(sink)] = fails
                if fails >= self.SINK_MAX_FAILURES:
                    try:
                        self._sinks.remove(sink)
                    except ValueError:
                        pass
                    self.sinks_dropped += 1

    # -- export --------------------------------------------------------------
    def counters(self) -> dict[str, dict[str, float]]:
        with self._lock:
            return {k: dict(v) for k, v in self._counters.items()}

    def timeline(self) -> list[dict]:
        with self._lock:
            return [s.to_dict() for s in self.samples]

    def to_json(self) -> dict:
        return {
            "schema": 1,
            "level": self.level,
            "dropped": self.dropped,
            "sink_errors": self.sink_errors,
            "sinks_dropped": self.sinks_dropped,
            "counters": self.counters(),
            "timeline": self.timeline(),
        }

    def dump(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)


def measure(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            profile: Profiler | None = None, **sample_kw) -> float:
    """Steady-state wall time per call, seconds: jit, force the first
    compile+run, warm up, then average `iters` dispatches — the single
    copy of the calibration methodology (``Tuner.tune`` and the bench
    harnesses measure identically).  With `profile`, commits one
    "measure"-kind sample carrying `sample_kw`."""
    import jax
    jitted = jax.jit(fn)
    out = jitted(*args)
    jax.block_until_ready(out)
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(jitted(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(*args)
    jax.block_until_ready(out)
    t = (time.perf_counter() - t0) / iters
    if profile is not None and profile.enabled:
        s = OpSample(collective=sample_kw.pop("collective", "measure"),
                     kind="measure", wall_s=t,
                     t_start=t0 - profile._epoch)
        emb = sample_kw.pop("embedding", None)
        if emb is not None:
            s.embedding = _emb_str(emb)
        for k, v in sample_kw.items():
            if hasattr(s, k):
                setattr(s, k, v)
        if not s.team:
            s.team = f"n{s.n_pes}"
        profile._commit(s)
    return t
