"""ARL-OpenSHMEM-for-Epiphany API surface, bound to a NetOps backend.

The OpenSHMEM 1.3 routine families the paper implements, in JAX:

  setup/query     shmem_init / my_pe / n_pes / ptr      -> ShmemContext
  RMA             put / get (+ _nbi, quiet, fence)       §3.3-3.4
  atomics         fetch_add / add / swap / testset       §3.5
  collectives     barrier_all / barrier / broadcast /
                  collect / fcollect / reduce(to_all) /
                  alltoall                                §3.6
  locks           set_lock / test_lock / clear_lock       §3.7

Semantics notes (DESIGN.md §6, §10): gets are owner-pushed (the paper's
IPI-get is the *only* get on this substrate); atomics are deterministic
PE-ordered.  Non-blocking RMA runs on a pending-op engine (the e-DMA
descriptor queue analogue): `put_nbi`/`get_nbi` enqueue `Future`s carrying
their compiled pattern and payload size; `quiet` drains and COMPLETES all
pending ops in issue order (the DMA-status spin-wait); `fence` imposes
per-destination-PE ordering on the pending queue WITHOUT completing it
(OpenSHMEM 1.3 distinguishes the two — §10 documents the mapping).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import collectives as coll
from .netops import NetOps, SimNetOps, SpmdNetOps
from .pattern import CommPattern, PatternLike, as_pattern
from .topology import MeshTopology


@dataclasses.dataclass
class Future:
    """Pending-op record of a non-blocking RMA (put_nbi/get_nbi) — one
    entry of the context's DMA descriptor queue (DESIGN.md §10).

    The value is lazily scheduled by XLA (the 'e-DMA engine'); `quiet()`
    completes it, `fence()` orders it against later same-destination ops
    without completing it.  Reading .value before quiet() is legal in JAX
    but forfeits the ordering guarantee — exactly like reading a DMA
    target buffer before shmem_quiet on the Epiphany.

    pattern : the compiled pattern that executes (for a get, the
              owner->requester push of the IPI-get);
    op      : "put" | "get";
    nbytes  : per-PE payload bytes the op moves (cost accounting);
    seq     : issue order within the owning context (monotonic)."""

    value: Any
    pattern: CommPattern | None = None
    op: str = "put"
    nbytes: float = 0.0
    seq: int = -1
    _done: bool = False

    @property
    def done(self) -> bool:
        """True once quiet() has pinned this op's completion."""
        return self._done

    def target_pes(self) -> tuple[int, ...]:
        """Destination PEs the op writes to — what fence() orders by."""
        if self.pattern is None:
            return ()
        return tuple(int(i) for i in np.nonzero(self.pattern.dst_mask)[0])


class ShmemContext:
    """One PE's view of the library (SPMD) or the whole chip's (SIM)."""

    def __init__(self, net: NetOps, topo: MeshTopology | None = None,
                 use_wand_barrier: bool = False, link=None):
        self.net = net
        self.topo = topo
        self.use_wand_barrier = use_wand_barrier
        # alpha-beta LinkModel that algorithm="auto" prices schedules with
        # (None = abmodel.ICI_V5E); pair with topo so selection and the
        # benchmarks' derived column agree on constants.
        self.link = link
        self._pending: list[Future] = []
        self._op_seq = 0

    # -- setup / query ------------------------------------------------------
    @property
    def n_pes(self) -> int:
        return self.net.n_pes

    def my_pe(self):
        return self.net.my_pe()

    def ptr(self, pe: int, offset: int = 0) -> tuple[int, int]:
        """shmem_ptr: on Epiphany, remote addresses come from shifting the
        core coordinates into the high bits.  The analogue of a 'global
        address' here is the (pe, offset) pair used by static patterns."""
        return (pe % self.n_pes, offset)

    def compile(self, pattern: PatternLike) -> CommPattern:
        """Compile (or pass through) a static (src, dst) pattern for this
        context's PE count — the shmem_init-time schedule precompilation
        (DESIGN.md §9).  Interned: same pattern, same object."""
        return as_pattern(pattern, self.n_pes)

    def _owner_push(self, pattern: PatternLike) -> CommPattern:
        """(requester, owner) pairs -> the compiled owner->requester push
        pattern the IPI-get executes.  Compiled directly from the inverse
        pairs so fan-out reads (many requesters, one owner) validate
        against the pattern that actually runs — whose destinations (the
        requesters) must be unique, not its sources."""
        if isinstance(pattern, CommPattern):
            return pattern.inverse
        return self.compile([(o, r) for r, o in pattern])

    # -- RMA ------------------------------------------------------------------
    def put(self, x, pattern: PatternLike, local=None):
        """Deliver src's shard to dst for each (src, dst); PEs not addressed
        keep `local` (default: their own x)."""
        p = self.compile(pattern)
        local = x if local is None else local
        recv = self.net.ppermute(x, p)
        return self.net.select(p, recv, local)

    def get(self, x, pattern: PatternLike, local=None):
        """(requester, owner) pairs; owner pushes (IPI-get).  Many
        requesters may name the same owner (fan-out read)."""
        return self.put(x, self._owner_push(pattern), local=local)

    def iput(self, x, pattern, *, sst: int = 1, dst: int = 1,
             nelems: int | None = None, local=None):
        """Strided put (shmem_iput / the paper's §4 proposed non-blocking
        strided extension over the 2D DMA descriptors): take every sst-th
        element of the source's leading axis, deliver to every dst-th slot
        of the target's leading axis."""
        p = self.compile(pattern)
        local = x if local is None else local
        n = nelems if nelems is not None else (x.shape[-1] // max(sst, 1))
        sel = x[..., ::sst][..., :n]
        recv = self.net.ppermute(sel, p)
        upd = local.at[..., : n * dst:dst].set(recv)
        return self.net.select(p, upd, local)

    def iget(self, x, pattern, **kw):
        return self.iput(x, self._owner_push(pattern), **kw)

    # -- pending-op engine (the e-DMA descriptor queue; DESIGN.md §10) -------
    def _enqueue(self, value, pattern: CommPattern, op: str, payload) -> Future:
        nbytes = float(sum(l.size * l.dtype.itemsize
                           for l in jax.tree.leaves(payload)))
        if isinstance(self.net, SimNetOps):
            nbytes /= self.n_pes            # leading PE axis is not payload
        f = Future(value, pattern=pattern, op=op, nbytes=nbytes,
                   seq=self._op_seq)
        self._op_seq += 1
        self._pending.append(f)
        return f

    @property
    def pending_count(self) -> int:
        """Outstanding non-blocking ops not yet completed by quiet()."""
        return len(self._pending)

    def pending_ops(self) -> tuple[Future, ...]:
        return tuple(self._pending)

    def put_nbi(self, x, pattern, local=None) -> Future:
        p = self.compile(pattern)
        return self._enqueue(self.put(x, p, local=local), p, "put", x)

    def get_nbi(self, x, pattern, local=None) -> Future:
        p = self._owner_push(pattern)
        return self._enqueue(self.put(x, p, local=local), p, "get", x)

    def quiet(self, *futures: Future):
        """shmem_quiet: drain the pending queue — pin COMPLETION of all
        outstanding non-blocking ops, in issue order, before anything that
        consumes the returned values (the DMA-idle spin-wait analogue).

        Completion here is `lax.optimization_barrier` over the pending
        values: XLA may not sink the transfers past any consumer of the
        fenced results.  With explicit `futures`, only those ops are
        completed (per-handle quiet); otherwise the whole queue drains and
        empties.  Drained futures are marked done and their .value is
        replaced by the fenced value."""
        fs = list(futures) or self._pending
        if not fs:
            return ()
        fs = sorted(fs, key=lambda f: f.seq)     # completion in issue order
        vals = [f.value for f in fs]
        fenced = lax.optimization_barrier(tuple(vals))
        for f, v in zip(fs, fenced):
            f.value, f._done = v, True
        self._pending = [f for f in self._pending if not f._done]
        return fenced

    def fence(self):
        """shmem_fence: per-destination ordering WITHOUT completion
        (OpenSHMEM 1.3 §9.10; the paper's dma-ordering wait).

        Each pending op's value is data-chained after every earlier
        pending op that writes an overlapping destination PE, so XLA
        cannot deliver two same-target puts out of issue order — but the
        ops stay pending (only quiet() completes them and empties the
        queue).  Ops to disjoint PE sets remain unordered, exactly the
        freedom OpenSHMEM grants.  Returns the (order-chained) pending
        values; () when the queue is empty."""
        if not self._pending:
            return ()
        last_for_pe: dict[int, Future] = {}
        for f in sorted(self._pending, key=lambda x: x.seq):
            targets = f.target_pes() or tuple(range(self.n_pes))
            deps: list[Future] = []
            for pe in targets:
                d = last_for_pe.get(pe)
                if d is not None and d is not f and d not in deps:
                    deps.append(d)
            if deps:
                chained = lax.optimization_barrier(
                    tuple([f.value] + [d.value for d in deps]))
                f.value = chained[0]
            for pe in targets:
                last_for_pe[pe] = f
        return tuple(f.value for f in self._pending)

    # -- collectives ----------------------------------------------------------
    def barrier_all(self, token=None):
        """WAND hardware barrier analogue (zero-payload psum, left to XLA)
        when enabled, else the dissemination software barrier."""
        if self.use_wand_barrier and isinstance(self.net, SpmdNetOps):
            tok = jnp.zeros((), jnp.int32) if token is None else token
            return self.net.axis_psum(tok)
        return coll.barrier(self.net, token)

    def barrier(self, token=None):
        return coll.barrier(self.net, token)

    def broadcast(self, x, root: int = 0, pipeline_chunks=None):
        return coll.broadcast(self.net, x, root,
                              pipeline_chunks=pipeline_chunks,
                              topo=self.topo, link=self.link)

    def collect(self, x, axis: int = 0, pipeline_chunks=None):
        return coll.collect(self.net, x, axis,
                            pipeline_chunks=pipeline_chunks,
                            topo=self.topo, link=self.link)

    def fcollect(self, x, axis: int = 0, algorithm=None,
                 pipeline_chunks=None):
        return coll.fcollect(self.net, x, axis, algorithm,
                             pipeline_chunks=pipeline_chunks,
                             topo=self.topo, link=self.link)

    def to_all(self, x, op: str = "sum", algorithm=None,
               pipeline_chunks=None):
        """shmem_TYPE_OP_to_all.  algorithm="auto" prices the candidate
        schedules against this context's topology and link model
        (DESIGN.md §9); pipeline_chunks="auto" additionally prices chunked
        double-buffered execution and picks the chunk count (§10) —
        bit-identical to monolithic, whatever is selected."""
        return coll.allreduce(self.net, x, op, algorithm=algorithm,
                              topo=self.topo, link=self.link,
                              pipeline_chunks=pipeline_chunks)

    def reduce_scatter(self, x, op: str = "sum"):
        return coll.reduce_scatter(self.net, x, op)

    def alltoall(self, x, axis: int = 0, pipeline_chunks=None):
        return coll.alltoall(self.net, x, axis,
                             pipeline_chunks=pipeline_chunks,
                             topo=self.topo, link=self.link)

    # -- atomics (§3.5) ---------------------------------------------------------
    def testset(self, var, value):
        """The TESTSET primitive: atomically 'test-if-not-zero and
        conditional write'.  Local (per-PE) flavor; remote flavors compose
        it with put/get patterns."""
        old = var
        new = jnp.where(var == 0, value, var)
        return old, new

    def atomic_fetch_add(self, var, contrib, pattern: PatternLike):
        """Each (requester, target): requester adds `contrib` to target's
        `var`, fetching the pre-update value.  One requester per target per
        call (a permutation pattern — e.g. the paper's Fig. 5 'tight loop
        on the next neighboring PE').  Returns (fetched, new_var)."""
        p = self.compile(pattern)
        delivered = self.net.ppermute(contrib, p)
        fetched = self.net.ppermute(var, p.inverse)
        new_var = self.net.select(p, var + delivered, var)
        return fetched, new_var

    def atomic_fetch_add_shared(self, var, contrib):
        """All PEs atomically add to the *same* symmetric var (owned
        replicated): returns per-PE fetched old value under the
        deterministic PE ordering (exclusive scan) and the final var."""
        prefix = coll.exclusive_scan(self.net, contrib, "sum")
        fetched = var + prefix
        total = coll.allreduce(self.net, contrib, "sum")
        return fetched, var + total

    def atomic_swap(self, var, value, pattern):
        p = self.compile(pattern)
        delivered = self.net.ppermute(value, p)
        fetched = self.net.ppermute(var, p.inverse)
        new_var = self.net.select(p, delivered, var)
        return fetched, new_var

    def atomic_compare_swap(self, var, cond, value, pattern):
        p = self.compile(pattern)
        delivered = self.net.ppermute(value, p)
        dcond = self.net.ppermute(cond, p)
        fetched = self.net.ppermute(var, p.inverse)
        swapped = jnp.where(var == dcond, delivered, var)
        new_var = self.net.select(p, swapped, var)
        return fetched, new_var

    # -- locks (§3.7) -------------------------------------------------------
    # The lock lives on PE 0 (as in the paper).  Under SPMD determinism the
    # arbitration among simultaneous requesters is PE order — the
    # observable semantics of TESTSET polling with deterministic timing.
    def set_lock(self, lock, want):
        """lock: symmetric int32 (0 = free, else 1+holder).  want: per-PE
        bool.  Returns (granted: per-PE bool, new_lock)."""
        pe = self.my_pe()
        ids = jnp.where(want, pe + 1, jnp.zeros_like(pe) + self.n_pes + 1)
        winner = coll.allreduce(self.net, ids.astype(jnp.int32), "min")
        free = lock == 0
        granted = free & want & (winner == pe + 1)
        new_lock = jnp.where(free & (winner <= self.n_pes),
                             winner.astype(lock.dtype), lock)
        return granted, new_lock

    def test_lock(self, lock, want):
        """Non-blocking acquire: same as set_lock but losers simply fail
        (return False) instead of spinning."""
        return self.set_lock(lock, want)

    def clear_lock(self, lock, holder_releases):
        pe = self.my_pe()
        is_holder = lock == (pe + 1).astype(lock.dtype)
        release = coll.allreduce(
            self.net, (is_holder & holder_releases).astype(jnp.int32), "max")
        return jnp.where(release > 0, jnp.zeros_like(lock), lock)

    # -- critical section combinator -----------------------------------------
    def critical(self, state, fn):
        """Serialize fn over PEs in rank order: PE k applies fn to the
        state produced by PE k-1 (lock-protected update region analogue)."""
        n = self.n_pes
        pe = self.my_pe()
        for turn in range(n):
            updated = fn(state)
            mask = np.arange(n) == turn
            mine = self.net.select(mask, updated, state)
            state = coll.broadcast(self.net, mine, root=turn)
        return state


def spmd_ctx(axis, topo=None, **kw) -> ShmemContext:
    return ShmemContext(SpmdNetOps(axis), topo, **kw)


def sim_ctx(n_pes: int, topo=None, **kw) -> ShmemContext:
    return ShmemContext(SimNetOps(n_pes), topo, **kw)
