"""ARL-OpenSHMEM-for-Epiphany API surface, bound to a NetOps backend.

The OpenSHMEM 1.3 routine families the paper implements, in JAX:

  setup/query     shmem_init / my_pe / n_pes / ptr      -> ShmemContext
  RMA             put / get (+ _nbi, quiet, fence)       §3.3-3.4
  atomics         fetch_add / add / swap / testset       §3.5
  collectives     barrier_all / barrier / broadcast /
                  collect / fcollect / reduce(to_all) /
                  alltoall                                §3.6
  locks           set_lock / test_lock / clear_lock       §3.7
  teams/contexts  team_world / team_split_strided /
                  team_split_2d / ctx_create              1.4+ (DESIGN §11)

Semantics notes (DESIGN.md §6, §10): gets are owner-pushed (the paper's
IPI-get is the *only* get on this substrate); atomics are deterministic
PE-ordered.  Non-blocking RMA runs on a pending-op engine (the e-DMA
descriptor queue analogue): `put_nbi`/`get_nbi` enqueue `Future`s carrying
their compiled pattern and payload size; `quiet` drains and COMPLETES all
pending ops in issue order (the DMA-status spin-wait); `fence` imposes
per-destination-PE ordering on the pending queue WITHOUT completing it
(OpenSHMEM 1.3 distinguishes the two — §10 documents the mapping).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import collectives as coll
from . import fault as fault_mod
from . import team as team_mod
from . import tuner as tuner_mod
from .fault import DeadlineExceeded, LinkFailure
from .netops import NetOps, NocSimNetOps, SimNetOps, SpmdNetOps
from .pattern import CommPattern, PatternLike, as_pattern
from .profile import Profiler, trace_clean
from .topology import MeshTopology

_NULL_CM = contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff policy for failed non-blocking RMA (DESIGN.md §17).

    A :class:`~repro.core.fault.LinkFailure` at issue time is retried up
    to `max_retries` times with exponential backoff (the injector's
    alternate-route and transient-heal logic decides whether a retry can
    succeed); a :class:`~repro.core.fault.PEFailure` is NEVER retried —
    a dead PE needs the elastic path (core/elastic.py), not patience.
    `deadline_s` is the default quiet()/fence() deadline when the caller
    passes none."""

    max_retries: int = 3
    backoff_s: float = 1e-3
    backoff_mult: float = 2.0
    deadline_s: float | None = None


@dataclasses.dataclass(eq=False)    # a handle: identity, not value, equality
class Future:
    """Pending-op record of a non-blocking RMA (put_nbi/get_nbi) — one
    entry of the context's DMA descriptor queue (DESIGN.md §10).

    The value is lazily scheduled by XLA (the 'e-DMA engine'); `quiet()`
    completes it, `fence()` orders it against later same-destination ops
    without completing it.  Reading .value before quiet() is legal in JAX
    but forfeits the ordering guarantee — exactly like reading a DMA
    target buffer before shmem_quiet on the Epiphany.

    pattern : the compiled pattern that executes (for a get, the
              owner->requester push of the IPI-get);
    op      : "put" | "get";
    nbytes  : per-PE payload bytes the op moves (cost accounting);
    seq     : issue order within the owning context (monotonic);
    delay_s : injected straggler delay (fault layer, DESIGN.md §17) —
              the extra completion time a slow PE adds, charged at
              quiet() where a real slow DMA would be felt."""

    value: Any
    pattern: CommPattern | None = None
    op: str = "put"
    nbytes: float = 0.0
    seq: int = -1
    delay_s: float = 0.0
    _done: bool = False

    @property
    def done(self) -> bool:
        """True once quiet() has pinned this op's completion."""
        return self._done

    def target_pes(self) -> tuple[int, ...]:
        """Destination PEs the op writes to — what fence() orders by."""
        if self.pattern is None:
            return ()
        return tuple(int(i) for i in np.nonzero(self.pattern.dst_mask)[0])


class Ctx:
    """An OpenSHMEM 1.4 communication context (``shmem_ctx_create``): a
    PRIVATE pending-op queue over the owning :class:`ShmemContext`'s
    substrate (DESIGN.md §11).

    Non-blocking RMA issued on one context is invisible to every other:
    ``quiet()``/``fence()`` here drain/order ONLY this context's queue, so
    independent streams (say, gradient sync on one context while
    activation collectives fly on another) no longer serialize behind a
    global drain — the OpenSHMEM 1.4 rationale, and the analogue of
    giving each stream its own e-DMA descriptor chain.

    An optional `team` makes the context team-scoped: RMA patterns are
    given in TEAM coordinates and lifted to the world pattern that
    executes (``Team.lift``), like ``shmem_team_create_ctx``."""

    def __init__(self, shmem: "ShmemContext", team=None):
        self.shmem = shmem
        self.team = team
        self._pending: list[Future] = []
        self._op_seq = 0

    @property
    def n_pes(self) -> int:
        return self.shmem.n_pes

    def compile(self, pattern: PatternLike) -> CommPattern:
        """Compile a pattern for this context — TEAM coordinates when the
        context is team-scoped (lifted to world), world otherwise."""
        if self.team is not None:
            return self.team.lift(pattern)
        return self.shmem.compile(pattern)

    def _owner_push(self, pattern: PatternLike) -> CommPattern:
        if self.team is None:
            return self.shmem._owner_push(pattern)
        if isinstance(pattern, CommPattern):
            return self.team.lift(pattern.inverse)
        return self.compile([(o, r) for r, o in pattern])

    # -- the pending-op engine (the e-DMA descriptor queue; DESIGN.md §10) ---
    def _enqueue(self, value, pattern: CommPattern, op: str, payload
                 ) -> Future:
        nbytes = float(sum(l.size * l.dtype.itemsize
                           for l in jax.tree.leaves(payload)))
        if isinstance(self.shmem.net, SimNetOps):
            nbytes /= self.n_pes            # leading PE axis is not payload
        # Straggler delay charged by the fault injector at issue time
        # rides on the Future and is FELT at quiet() — a slow PE's DMA
        # takes longer to land, not longer to enqueue (DESIGN.md §17).
        inj = self.shmem.net.fault
        delay = inj.consume_delay() if inj is not None else 0.0
        f = Future(value, pattern=pattern, op=op, nbytes=nbytes,
                   seq=self._op_seq, delay_s=delay)
        self._op_seq += 1
        self._pending.append(f)
        prof = self.shmem.profile
        if prof is not None and prof.enabled:
            prof.record_rma(op, nbytes, pattern, n_pes=self.n_pes)
        return f

    @property
    def pending_count(self) -> int:
        """Outstanding non-blocking ops not yet completed by quiet()."""
        return len(self._pending)

    def pending_ops(self) -> tuple[Future, ...]:
        return tuple(self._pending)

    def _issue(self, fn, p: CommPattern, op: str):
        """Issue an RMA with retry/backoff (DESIGN.md §17): a
        :class:`LinkFailure` (route + alternate both severed) is retried
        up to ``RetryPolicy.max_retries`` times with exponential backoff
        — the injector's transient-heal budget decides whether a retry
        can succeed.  A ``PEFailure`` propagates immediately: dead PEs
        need the elastic path, not patience.  The failing op name rides
        on the raised error."""
        pol = self.shmem.retry
        backoff = pol.backoff_s
        attempt = 0
        while True:
            try:
                return fn()
            except LinkFailure as e:
                attempt += 1
                e.op = op
                if attempt > pol.max_retries:
                    raise
                fault_mod.fault_event(
                    self.shmem._active_profile(), "fault.retries",
                    op=op, attempt=attempt, backoff_us=int(backoff * 1e6))
                prof = self.shmem._active_profile()
                if prof is not None:
                    prof.count("fault.backoff_us", int(backoff * 1e6))
                time.sleep(backoff)
                backoff *= pol.backoff_mult

    def put_nbi(self, x, pattern, local=None) -> Future:
        p = self.compile(pattern)
        return self._enqueue(
            self._issue(lambda: self.shmem.put(x, p, local=local), p, "put"),
            p, "put", x)

    def get_nbi(self, x, pattern, local=None) -> Future:
        p = self._owner_push(pattern)
        return self._enqueue(
            self._issue(lambda: self.shmem.put(x, p, local=local), p, "get"),
            p, "get", x)

    def _deadline(self, deadline_s):
        return deadline_s if deadline_s is not None \
            else self.shmem.retry.deadline_s

    def quiet(self, *futures: Future, deadline_s: float | None = None):
        """shmem_ctx_quiet: drain THIS context's pending queue — pin
        COMPLETION of its outstanding non-blocking ops, in issue order,
        before anything that consumes the returned values.  Other
        contexts' queues are untouched (per-context isolation).

        Completion here is `lax.optimization_barrier` over the pending
        values: XLA may not sink the transfers past any consumer of the
        fenced results.  With explicit `futures`, only those ops are
        completed (per-handle quiet); otherwise the whole queue drains and
        empties.  Drained futures are marked done and their .value is
        replaced by the fenced value.

        `deadline_s` (default: ``RetryPolicy.deadline_s``) bounds the
        completion wait (DESIGN.md §17): when the injected straggler
        delay riding on a pending Future exceeds it, quiet raises
        :class:`~repro.core.fault.DeadlineExceeded` with the slowest
        op's pattern attached and the queue UNTOUCHED — the op never
        completed, so recovery code sees a consistent pending state.
        Within the deadline the delay is actually slept, so measured
        wall time degrades the way a real slow DMA would."""
        fs = list(futures) or self._pending
        if not fs:
            return ()
        deadline = self._deadline(deadline_s)
        delay = max((f.delay_s for f in fs), default=0.0)
        if delay > 0.0:
            fprof = self.shmem._active_profile()
            if deadline is not None and delay > deadline:
                slow = max(fs, key=lambda f: f.delay_s)
                fault_mod.fault_event(
                    fprof, "fault.deadline_exceeded", op=slow.op,
                    delay_us=int(delay * 1e6),
                    deadline_us=int(deadline * 1e6))
                raise DeadlineExceeded(
                    f"quiet() deadline {deadline:g}s exceeded: slowest "
                    f"pending {slow.op} carries an injected straggler "
                    f"delay of {delay:g}s",
                    pattern=slow.pattern, op=slow.op)
            if fprof is not None:
                fprof.count("fault.straggler_wait_us", int(delay * 1e6))
            time.sleep(delay)
            for f in fs:
                f.delay_s = 0.0
        prof = self.shmem.profile
        # Stall-vs-issue split (DESIGN.md §16): only meaningful outside a
        # trace (eager SIM), where block_until_ready IS the semantic
        # shmem_quiet wait for the pending transfers to land.
        timed = prof is not None and prof.enabled and trace_clean()
        t0 = time.perf_counter() if timed else 0.0
        alien = [f for f in fs if not f._done and f not in self._pending]
        if alien:
            raise ValueError(
                "quiet() got futures issued on a different context — "
                "per-context isolation means each context drains its own "
                "queue; call that context's quiet()")
        fs = sorted(fs, key=lambda f: f.seq)     # completion in issue order
        nb = sum(f.nbytes for f in fs)
        if prof is not None and prof.enabled:
            prof.count("quiet.drained", len(fs), nb)
        vals = [f.value for f in fs]
        fenced = lax.optimization_barrier(tuple(vals))
        for f, v in zip(fs, fenced):
            f.value, f._done = v, True
        self._pending = [f for f in self._pending if not f._done]
        if timed:
            t1 = time.perf_counter()
            jax.block_until_ready(fenced)
            t2 = time.perf_counter()
            prof.record_sync("quiet", len(fs), nb, issue_s=t1 - t0,
                             stall_s=t2 - t1, n_pes=self.n_pes,
                             t_start=t0 - prof._epoch)
        return fenced

    def fence(self, *, deadline_s: float | None = None):
        """shmem_ctx_fence: per-destination ordering WITHOUT completion
        (OpenSHMEM §9.10), scoped to THIS context's queue.

        `deadline_s` (default: ``RetryPolicy.deadline_s``): fence never
        waits, but a pending op already KNOWN to carry a straggler delay
        beyond the deadline can be detected here without sleeping —
        raises :class:`~repro.core.fault.DeadlineExceeded` so the caller
        learns about the doomed op at the ordering point instead of the
        completion point (DESIGN.md §17).

        Each pending op's value is data-chained after every earlier
        pending op that writes an overlapping destination PE, so XLA
        cannot deliver two same-target puts out of issue order — but the
        ops stay pending (only quiet() completes them and empties the
        queue).  Ops to disjoint PE sets remain unordered, exactly the
        freedom OpenSHMEM grants.  Returns the (order-chained) pending
        values; () when the queue is empty."""
        if not self._pending:
            return ()
        deadline = self._deadline(deadline_s)
        if deadline is not None:
            delay = max(f.delay_s for f in self._pending)
            if delay > deadline:
                slow = max(self._pending, key=lambda f: f.delay_s)
                fault_mod.fault_event(
                    self.shmem._active_profile(),
                    "fault.deadline_exceeded", op=slow.op,
                    delay_us=int(delay * 1e6),
                    deadline_us=int(deadline * 1e6))
                raise DeadlineExceeded(
                    f"fence() deadline {deadline:g}s already unmeetable: "
                    f"pending {slow.op} carries an injected straggler "
                    f"delay of {delay:g}s",
                    pattern=slow.pattern, op=slow.op)
        prof = self.shmem.profile
        timed = prof is not None and prof.enabled and trace_clean()
        t0 = time.perf_counter() if timed else 0.0
        last_for_pe: dict[int, Future] = {}
        for f in sorted(self._pending, key=lambda x: x.seq):
            targets = f.target_pes() or tuple(range(self.n_pes))
            deps: list[Future] = []
            for pe in targets:
                d = last_for_pe.get(pe)
                if d is not None and d is not f and d not in deps:
                    deps.append(d)
            if deps:
                chained = lax.optimization_barrier(
                    tuple([f.value] + [d.value for d in deps]))
                f.value = chained[0]
            for pe in targets:
                last_for_pe[pe] = f
        if timed:
            # fence orders but never completes: all issue, zero stall
            prof.record_sync("fence", len(self._pending),
                             sum(f.nbytes for f in self._pending),
                             issue_s=time.perf_counter() - t0,
                             stall_s=0.0, n_pes=self.n_pes,
                             t_start=t0 - prof._epoch)
        return tuple(f.value for f in self._pending)


class ShmemContext:
    """One PE's view of the library (SPMD) or the whole chip's (SIM)."""

    def __init__(self, net: NetOps, topo: MeshTopology | None = None,
                 use_wand_barrier: bool = False, link=None, embedding=None,
                 profile=None, tuner=None, fault=None, retry=None,
                 fingerprint=None):
        self.net = net
        self.topo = topo
        self.use_wand_barrier = use_wand_barrier
        # alpha-beta LinkModel that algorithm="auto" prices schedules with
        # (None = abmodel.ICI_V5E); pair with topo so selection and the
        # benchmarks' derived column agree on constants.
        self.link = link
        # ring embedding policy for this context's collectives (DESIGN.md
        # §12): None = logical rings; "auto"/"snake"/an explicit rank
        # order run ring algorithms in mesh-embedded coordinates (and
        # "auto" selection prices the embedded candidates).
        self.embedding = embedding
        # pcontrol-style profiler (DESIGN.md §13): one op sample per
        # collective, RMA counters, JSON export.  Propagated to the
        # NetOps backend so raw ppermute traffic lands in its counters.
        # When None (the default) the hot path pays one `is None` test.
        self.profile = profile
        # measured-performance autotuner: a Tuner (whose DB then also
        # refines ONLINE from this context's profiler samples) or a bare
        # TunedSelector; choose_algorithm/choose_schedule/choose_chunks/
        # choose_embedding consult it before the analytic model.
        self.tuner = tuner
        self._sel = tuner.selector() if hasattr(tuner, "selector") else tuner
        # `fingerprint` overrides the machine identity collectives tune
        # under — the elastic path passes the degraded-mesh fingerprint
        # so the TunedSelector re-tunes instead of replaying full-mesh
        # winners on a mesh that no longer exists (DESIGN.md §17).
        self._fp = fingerprint if fingerprint is not None \
            else tuner_mod.fingerprint(topo, net.n_pes)
        if fingerprint is not None:
            self.refingerprint(fingerprint)
        # retry/backoff policy for nbi RMA + default quiet/fence deadline
        # (DESIGN.md §17); fault= attaches a FaultPlan/FaultInjector to
        # the backend so every ppermute consults it.
        self.retry = retry if retry is not None else RetryPolicy()
        self.fault_injector = fault_mod.as_injector(
            fault, topo=topo, profile=profile)
        if self.fault_injector is not None:
            net.fault = self.fault_injector
        if profile is not None:
            net.profile = profile
            if hasattr(tuner, "observe"):
                profile.add_sink(tuner.observe)
        # The default communication context: ShmemContext-level nbi RMA,
        # quiet and fence run on it, so shmem_quiet stays oblivious to
        # traffic issued on explicitly-created contexts (DESIGN.md §11).
        self.ctx_default = Ctx(self)

    # -- profiling control (shmem_pcontrol; DESIGN.md §13) -------------------
    def pcontrol(self, level: int) -> None:
        """``shmem_pcontrol``: 0 disables collection, 1 enables counters,
        >= 2 enables the per-op timeline.  Attaches a fresh
        :class:`~repro.core.profile.Profiler` when none was passed at
        construction (so ``ctx.pcontrol(2)`` alone turns profiling on)."""
        if self.profile is None:
            if level <= 0:
                return
            self.profile = Profiler(level=level)
            self.net.profile = self.profile
            if hasattr(self.tuner, "observe"):
                self.profile.add_sink(self.tuner.observe)
        else:
            self.profile.pcontrol(level)

    def _active_profile(self):
        p = self.profile
        return p if (p is not None and p.enabled) else None

    # -- elastic re-tuning (DESIGN.md §17) -----------------------------------
    def refingerprint(self, fp: str) -> None:
        """Re-key this context's tuning identity — called by the elastic
        restart path after the mesh degrades.  Profiler op samples and
        the TunedSelector's DB lookups both switch to `fp`, so tuned
        decisions measured on the full mesh stop applying and fresh
        measurements accumulate under the degraded-mesh key."""
        self._fp = str(fp)
        sel = self._sel
        if sel is not None and hasattr(sel, "with_fingerprint"):
            self._sel = sel.with_fingerprint(self._fp)

    def _group_desc(self, group) -> str:
        if group is None:
            return f"n{self.n_pes}"
        if isinstance(group, team_mod.TeamPartition):
            return f"part{group.n_teams}x{group.size}"
        return f"team{group.size}of{group.world_n}"

    def _prof_op(self, collective: str, x=None, group=None):
        """(context manager, active profiler): the timing wrapper every
        collective method runs under.  One `is None` test when profiling
        is off — the near-zero disabled path."""
        prof = self._active_profile()
        if prof is None:
            return _NULL_CM, None
        nbytes = coll._payload_bytes(self.net, x) if x is not None else 0.0
        return prof.op(collective, nbytes=nbytes, n_pes=self.n_pes,
                       team=self._group_desc(group),
                       fingerprint=self._fp), prof

    # -- setup / query ------------------------------------------------------
    @property
    def n_pes(self) -> int:
        return self.net.n_pes

    def my_pe(self):
        return self.net.my_pe()

    def ptr(self, pe: int, offset: int = 0) -> tuple[int, int]:
        """shmem_ptr: on Epiphany, remote addresses come from shifting the
        core coordinates into the high bits.  The analogue of a 'global
        address' here is the (pe, offset) pair used by static patterns."""
        return (pe % self.n_pes, offset)

    def compile(self, pattern: PatternLike) -> CommPattern:
        """Compile (or pass through) a static (src, dst) pattern for this
        context's PE count — the shmem_init-time schedule precompilation
        (DESIGN.md §9).  Interned: same pattern, same object."""
        return as_pattern(pattern, self.n_pes)

    def _owner_push(self, pattern: PatternLike) -> CommPattern:
        """(requester, owner) pairs -> the compiled owner->requester push
        pattern the IPI-get executes.  Compiled directly from the inverse
        pairs so fan-out reads (many requesters, one owner) validate
        against the pattern that actually runs — whose destinations (the
        requesters) must be unique, not its sources."""
        if isinstance(pattern, CommPattern):
            return pattern.inverse
        return self.compile([(o, r) for r, o in pattern])

    # -- RMA ------------------------------------------------------------------
    def put(self, x, pattern: PatternLike, local=None):
        """Deliver src's shard to dst for each (src, dst); PEs not addressed
        keep `local` (default: their own x)."""
        p = self.compile(pattern)
        local = x if local is None else local
        recv = self.net.ppermute(x, p)
        return self.net.select(p, recv, local)

    def get(self, x, pattern: PatternLike, local=None):
        """(requester, owner) pairs; owner pushes (IPI-get).  Many
        requesters may name the same owner (fan-out read)."""
        return self.put(x, self._owner_push(pattern), local=local)

    def iput(self, x, pattern, *, sst: int = 1, dst: int = 1,
             nelems: int | None = None, local=None):
        """Strided put (shmem_iput / the paper's §4 proposed non-blocking
        strided extension over the 2D DMA descriptors): take every sst-th
        element of the source's leading axis, deliver to every dst-th slot
        of the target's leading axis."""
        p = self.compile(pattern)
        local = x if local is None else local
        n = nelems if nelems is not None else (x.shape[-1] // max(sst, 1))
        sel = x[..., ::sst][..., :n]
        recv = self.net.ppermute(sel, p)
        upd = local.at[..., : n * dst:dst].set(recv)
        return self.net.select(p, upd, local)

    def iget(self, x, pattern, **kw):
        return self.iput(x, self._owner_push(pattern), **kw)

    # -- communication contexts (DESIGN.md §11) ------------------------------
    # ShmemContext-level nbi RMA + quiet/fence delegate to the DEFAULT
    # context; shmem_ctx_create gives a stream its own pending queue so
    # its quiet/fence cannot drain (or be drained by) unrelated traffic.

    def ctx_create(self, team=None) -> Ctx:
        """shmem_ctx_create / shmem_team_create_ctx: a new communication
        context with a private pending-op queue (team-scoped when `team`
        is given — RMA patterns then use team coordinates)."""
        return Ctx(self, team=team)

    @property
    def _pending(self) -> list[Future]:
        return self.ctx_default._pending

    @property
    def pending_count(self) -> int:
        """Outstanding nbi ops on the DEFAULT context (quiet() completes
        these; explicitly-created contexts track their own)."""
        return self.ctx_default.pending_count

    def pending_ops(self) -> tuple[Future, ...]:
        return self.ctx_default.pending_ops()

    def put_nbi(self, x, pattern, local=None) -> Future:
        return self.ctx_default.put_nbi(x, pattern, local=local)

    def get_nbi(self, x, pattern, local=None) -> Future:
        return self.ctx_default.get_nbi(x, pattern, local=local)

    def quiet(self, *futures: Future, deadline_s: float | None = None):
        """shmem_quiet: drain the DEFAULT context's pending queue (see
        Ctx.quiet; ops issued on created contexts need their own
        ctx.quiet — per-context isolation, DESIGN.md §11)."""
        return self.ctx_default.quiet(*futures, deadline_s=deadline_s)

    def fence(self, *, deadline_s: float | None = None):
        """shmem_fence: per-destination ordering of the DEFAULT context's
        queue without completing it (see Ctx.fence)."""
        return self.ctx_default.fence(deadline_s=deadline_s)

    # -- teams (OpenSHMEM 1.4+; DESIGN.md §11) -------------------------------
    def team_world(self) -> team_mod.Team:
        return team_mod.team_world(self.n_pes)

    def team_split_strided(self, parent: team_mod.Team | None, start: int,
                           stride: int, size: int) -> team_mod.Team:
        """shmem_team_split_strided over `parent` (None = world)."""
        parent = parent if parent is not None else self.team_world()
        return team_mod.split_strided(parent, start, stride, size)

    def team_split_2d(self, topo: MeshTopology | None = None,
                      axis: int = -1) -> team_mod.TeamPartition:
        """Row (axis=-1) / column (axis=0) teams of this context's
        topology — the partition the hierarchical collectives run over."""
        topo = topo if topo is not None else self.topo
        if topo is None:
            raise ValueError("team_split_2d needs a topology (pass topo= "
                             "or build the context with one)")
        return team_mod.split_2d(self.team_world(), topo, axis)

    def _resolve_team(self, team, pe_start, log_pe_stride, pe_size):
        """The 1.3 active-set shim: ``(PE_start, logPE_stride, PE_size)``
        resolves to the interned Team the explicit API names — same team
        object, same lifted patterns, same compiled schedules.  A world
        team short-circuits to the flat path (identical schedules, and it
        keeps pipelined execution available)."""
        if pe_size is not None or pe_start is not None or log_pe_stride:
            if team is not None:
                raise ValueError("pass team= OR an active set, not both")
            if pe_size is None:
                raise ValueError("an active set needs PE_size")
            team = team_mod.from_active_set(pe_start or 0, log_pe_stride,
                                            pe_size, self.n_pes)
        if (isinstance(team, team_mod.Team)
                and team.members == tuple(range(self.n_pes))):
            return None     # identity ranks: the flat path IS the world team
        return team

    # -- collectives ----------------------------------------------------------
    def barrier_all(self, token=None):
        """WAND hardware barrier analogue (zero-payload psum, left to XLA)
        when enabled, else the dissemination software barrier."""
        if self.use_wand_barrier and isinstance(self.net, SpmdNetOps):
            tok = jnp.zeros((), jnp.int32) if token is None else token
            return self.net.axis_psum(tok)
        return coll.barrier(self.net, token)

    def barrier(self, token=None, team=None, algorithm=None):
        """algorithm: None/"dissem" (the paper's dissemination barrier),
        "tree" (binomial gather + broadcast), or "auto" (congestion-model
        pick between the two)."""
        cm, prof = self._prof_op("barrier", group=team)
        with cm:
            return coll.barrier(self.net, token, team=team,
                                algorithm=algorithm,
                                topo=self.topo, link=self.link,
                                profile=prof)

    def broadcast(self, x, root: int = 0, pipeline_chunks=None, team=None):
        """With `team`, `root` is a TEAM rank; non-members keep x."""
        cm, prof = self._prof_op("broadcast", x, team)
        with cm:
            return coll.broadcast(self.net, x, root,
                                  pipeline_chunks=pipeline_chunks,
                                  topo=self.topo, link=self.link, team=team,
                                  profile=prof, tuner=self._sel)

    def collect(self, x, axis: int = 0, pipeline_chunks=None, team=None):
        cm, prof = self._prof_op("collect", x, team)
        with cm:
            return coll.collect(self.net, x, axis,
                                pipeline_chunks=pipeline_chunks,
                                topo=self.topo, link=self.link, team=team,
                                embedding=self.embedding,
                                profile=prof, tuner=self._sel)

    def fcollect(self, x, axis: int = 0, algorithm=None,
                 pipeline_chunks=None, team=None):
        cm, prof = self._prof_op("fcollect", x, team)
        with cm:
            return coll.fcollect(self.net, x, axis, algorithm,
                                 pipeline_chunks=pipeline_chunks,
                                 topo=self.topo, link=self.link, team=team,
                                 embedding=self.embedding,
                                 profile=prof, tuner=self._sel)

    def to_all(self, x, op: str = "sum", algorithm=None,
               pipeline_chunks=None, team=None, partition=None,
               PE_start=None, logPE_stride: int = 0, PE_size=None):
        """shmem_TYPE_OP_to_all.  algorithm="auto" prices the candidate
        schedules against this context's topology and link model
        (DESIGN.md §9); pipeline_chunks="auto" additionally prices chunked
        double-buffered execution and picks the chunk count (§10) —
        bit-identical to monolithic, whatever is selected.

        Grouping (DESIGN.md §11): `team` scopes the reduction to a Team's
        members (non-members pass through); the OpenSHMEM 1.3 active-set
        triple ``(PE_start, logPE_stride, PE_size)`` resolves to the same
        interned Team — and therefore the same compiled schedules — as
        the explicit team API.  `partition` adds the hierarchical
        two-level schedule to the "auto" candidates (algorithm="hier"
        forces it)."""
        team = self._resolve_team(team, PE_start, logPE_stride, PE_size)
        cm, prof = self._prof_op("allreduce", x,
                                 team if team is not None else partition)
        with cm:
            return coll.allreduce(self.net, x, op, algorithm=algorithm,
                                  topo=self.topo, link=self.link,
                                  pipeline_chunks=pipeline_chunks,
                                  team=team, partition=partition,
                                  embedding=self.embedding,
                                  profile=prof, tuner=self._sel)

    def reduce_scatter(self, x, op: str = "sum", team=None):
        cm, prof = self._prof_op("reduce_scatter", x, team)
        with cm:
            return coll.reduce_scatter(self.net, x, op, team=team,
                                       profile=prof)

    def alltoall(self, x, axis: int = 0, pipeline_chunks=None, team=None):
        cm, prof = self._prof_op("alltoall", x, team)
        with cm:
            return coll.alltoall(self.net, x, axis,
                                 pipeline_chunks=pipeline_chunks,
                                 topo=self.topo, link=self.link, team=team,
                                 profile=prof, tuner=self._sel)

    # -- atomics (§3.5) ---------------------------------------------------------
    def testset(self, var, value):
        """The TESTSET primitive: atomically 'test-if-not-zero and
        conditional write'.  Local (per-PE) flavor; remote flavors compose
        it with put/get patterns."""
        old = var
        new = jnp.where(var == 0, value, var)
        return old, new

    def atomic_fetch_add(self, var, contrib, pattern: PatternLike):
        """Each (requester, target): requester adds `contrib` to target's
        `var`, fetching the pre-update value.  One requester per target per
        call (a permutation pattern — e.g. the paper's Fig. 5 'tight loop
        on the next neighboring PE').  Returns (fetched, new_var)."""
        p = self.compile(pattern)
        delivered = self.net.ppermute(contrib, p)
        fetched = self.net.ppermute(var, p.inverse)
        new_var = self.net.select(p, var + delivered, var)
        return fetched, new_var

    def atomic_fetch_add_shared(self, var, contrib):
        """All PEs atomically add to the *same* symmetric var (owned
        replicated): returns per-PE fetched old value under the
        deterministic PE ordering (exclusive scan) and the final var."""
        prefix = coll.exclusive_scan(self.net, contrib, "sum")
        fetched = var + prefix
        total = coll.allreduce(self.net, contrib, "sum")
        return fetched, var + total

    def atomic_swap(self, var, value, pattern):
        p = self.compile(pattern)
        delivered = self.net.ppermute(value, p)
        fetched = self.net.ppermute(var, p.inverse)
        new_var = self.net.select(p, delivered, var)
        return fetched, new_var

    def atomic_compare_swap(self, var, cond, value, pattern):
        p = self.compile(pattern)
        delivered = self.net.ppermute(value, p)
        dcond = self.net.ppermute(cond, p)
        fetched = self.net.ppermute(var, p.inverse)
        swapped = jnp.where(var == dcond, delivered, var)
        new_var = self.net.select(p, swapped, var)
        return fetched, new_var

    # -- locks (§3.7) -------------------------------------------------------
    # The lock lives on PE 0 (as in the paper).  Under SPMD determinism the
    # arbitration among simultaneous requesters is PE order — the
    # observable semantics of TESTSET polling with deterministic timing.
    def set_lock(self, lock, want):
        """lock: symmetric int32 (0 = free, else 1+holder).  want: per-PE
        bool.  Returns (granted: per-PE bool, new_lock)."""
        pe = self.my_pe()
        ids = jnp.where(want, pe + 1, jnp.zeros_like(pe) + self.n_pes + 1)
        winner = coll.allreduce(self.net, ids.astype(jnp.int32), "min")
        free = lock == 0
        granted = free & want & (winner == pe + 1)
        new_lock = jnp.where(free & (winner <= self.n_pes),
                             winner.astype(lock.dtype), lock)
        return granted, new_lock

    def test_lock(self, lock, want):
        """Non-blocking acquire: same as set_lock but losers simply fail
        (return False) instead of spinning."""
        return self.set_lock(lock, want)

    def clear_lock(self, lock, holder_releases):
        pe = self.my_pe()
        is_holder = lock == (pe + 1).astype(lock.dtype)
        release = coll.allreduce(
            self.net, (is_holder & holder_releases).astype(jnp.int32), "max")
        return jnp.where(release > 0, jnp.zeros_like(lock), lock)

    # -- critical section combinator -----------------------------------------
    def critical(self, state, fn):
        """Serialize fn over PEs in rank order: PE k applies fn to the
        state produced by PE k-1 (lock-protected update region analogue)."""
        n = self.n_pes
        pe = self.my_pe()
        for turn in range(n):
            updated = fn(state)
            mask = np.arange(n) == turn
            mine = self.net.select(mask, updated, state)
            state = coll.broadcast(self.net, mine, root=turn)
        return state


def spmd_ctx(axis, topo=None, **kw) -> ShmemContext:
    return ShmemContext(SpmdNetOps(axis), topo, **kw)


def sim_ctx(n_pes: int, topo=None, noc: bool = False, **kw) -> ShmemContext:
    """noc=True simulates the NoC's link contention: patterns execute as
    link-disjoint waves (netops.NocSimNetOps) — bit-identical results,
    congestion-scaled wall time."""
    net = NocSimNetOps(n_pes, topo=topo) if noc else SimNetOps(n_pes)
    return ShmemContext(net, topo, **kw)
