"""The paper's alpha-beta communication model (eq. 1): T_c = alpha + beta*L.

Two uses, mirroring the paper:
  * `fit()` recovers (alpha, beta^-1) with standard deviations from
    (message-size, time) samples, exactly as printed in the paper's figure
    subtitles.
  * `IciModel` predicts stage times for the TPU target (the Epiphany NoC
    constants are included for the paper-scale benchmarks), which is what
    the benchmark harness reports in its `derived` column and what the
    roofline collective term cross-checks against.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ABFit:
    alpha: float          # latency, seconds
    beta: float           # seconds / byte
    alpha_std: float
    beta_std: float

    @property
    def inv_beta(self) -> float:
        """Peak effective bandwidth (the paper's beta^-1), bytes/s."""
        return math.inf if self.beta == 0 else 1.0 / self.beta

    def time(self, nbytes: float) -> float:
        return self.alpha + self.beta * nbytes


def fit(sizes_bytes, times_s) -> ABFit:
    """Least-squares fit of T = alpha + beta*L with parameter std devs.

    Needs at least two samples at two DISTINCT sizes — a single point or
    a constant size grid cannot separate alpha from beta (the normal
    matrix is singular) and raises rather than returning garbage."""
    x = np.asarray(sizes_bytes, dtype=np.float64)
    y = np.asarray(times_s, dtype=np.float64)
    if x.ndim != 1 or x.shape != y.shape:
        raise ValueError(
            f"fit needs matching 1-D size/time samples, got shapes "
            f"{x.shape} and {y.shape}")
    if len(x) < 2:
        raise ValueError(
            f"fit needs >= 2 (size, time) samples to recover (alpha, "
            f"beta), got {len(x)}")
    if np.unique(x).size < 2:
        raise ValueError(
            f"fit needs >= 2 distinct message sizes (all samples are at "
            f"{x[0]:g} B — alpha and beta are not separable)")
    A = np.stack([np.ones_like(x), x], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    resid = y - A @ coef
    dof = max(len(x) - 2, 1)
    sigma2 = float(resid @ resid) / dof
    cov = sigma2 * np.linalg.inv(A.T @ A)
    return ABFit(
        alpha=float(coef[0]),
        beta=float(coef[1]),
        alpha_std=float(np.sqrt(cov[0, 0])),
        beta_std=float(np.sqrt(cov[1, 1])),
    )


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-link alpha-beta constants, congestion-aware (eq. 1 extended):

        T = alpha + hop_s * max_hops + beta * nbytes * load_eff
        load_eff = 1 + contention * (max_link_load - 1)

    ``max_link_load`` is the stage's flow multiplicity through its hottest
    physical link under XY routing (``CommPattern.max_link_load``): flows
    sharing a link serialize there, so the stage's bandwidth term scales
    with the hottest link's occupancy, not just the payload.  `contention`
    calibrates how fully they serialize (1.0 = strict serialization, 0.0 =
    the old hop-only model); :func:`fit_contention` recovers it from
    measurements the way :func:`fit` recovers (alpha, beta)."""

    alpha_s: float        # per-message launch latency
    hop_s: float          # added latency per mesh hop
    bw_Bps: float         # per-link bandwidth
    contention: float = 1.0   # fraction of hot-link serialization realized

    def time(self, nbytes: float, hops: float = 1.0,
             link_load: float = 1.0) -> float:
        load_eff = 1.0 + self.contention * (max(link_load, 1.0) - 1.0)
        return (self.alpha_s + self.hop_s * hops
                + nbytes * load_eff / self.bw_Bps)


# TPU v5e ICI: ~50 GB/s/link, ~1 us software launch, ~0.1 us/hop.
ICI_V5E = LinkModel(alpha_s=1e-6, hop_s=1e-7, bw_Bps=50e9)
# Cross-pod DCN: ~25 GB/s/host-link, tens of us latency.
DCN = LinkModel(alpha_s=20e-6, hop_s=0.0, bw_Bps=25e9)
# The paper's NoC @600MHz: put peak 2.4 GB/s, ~0.1 us put latency,
# ~1.5 clk/hop.
EPIPHANY_NOC = LinkModel(alpha_s=1e-7, hop_s=2.5e-9, bw_Bps=2.4e9)
# The paper's measured remote-read path is ~10x slower than the write path
# (Fig. 3); model the direct-get with a 10x beta penalty.
EPIPHANY_NOC_GET = LinkModel(alpha_s=1e-7, hop_s=5e-9, bw_Bps=0.24e9)


def stage_time(nbytes: float, hops: float, link: LinkModel = ICI_V5E,
               link_load: float = 1.0) -> float:
    return link.time(nbytes, hops, link_load)


def _stage3(stage) -> tuple[float, float, float]:
    """Accept both the congestion-aware (bytes, hops, load) descriptor and
    the legacy (bytes, hops) pair (load defaults to 1 — no contention)."""
    b, h, *rest = stage
    return b, h, (rest[0] if rest else 1.0)


def modeled_collective_time(stages: list[tuple],
                            link: LinkModel = ICI_V5E) -> float:
    """Sum of (nbytes, hops[, max_link_load]) stage costs — collectives
    built from ppermute stages are serialized, so stage times add."""
    return sum(link.time(*_stage3(st)) for st in stages)


def modeled_pipelined_time(stages: list[tuple], n_chunks: int,
                           link: LinkModel = ICI_V5E) -> float:
    """Chunked (double-buffered) schedule execution time (DESIGN.md §10).

    The payload of every stage is split into `n_chunks` pieces and stage k
    of chunk i overlaps stage k+1 of chunk i-1 — the e-DMA discipline of
    the paper's put pipeline.  The pipeline fills in one chunk's worth of
    stage times and drains in (C-1) repeats of the bottleneck stage:

        T(C) = sum_k t_k(b_k / C)  +  (C - 1) * max_k t_k(b_k / C)

    Each chunk pays the full per-message alpha and hop latency at every
    stage, so small messages prefer C=1 (monolithic); for large messages
    the bandwidth term dominates and T(C) ~ (S + C - 1)/(S*C) of the
    monolithic time — the classic pipelined-tree gain."""
    if n_chunks <= 1 or not stages:
        return modeled_collective_time(stages, link)
    per = [link.time(b / n_chunks, h, ld)
           for b, h, ld in map(_stage3, stages)]
    return sum(per) + (n_chunks - 1) * max(per)


def modeled_overlapped_time(stages: list[tuple], compute_s: float,
                            link: LinkModel = ICI_V5E) -> float:
    """Comm-compute overlapped schedule time (DESIGN.md §14).

    Each stage's transfer is issued non-blocking (`put_nbi`) while one
    compute block of `compute_s` seconds consumes the previously arrived
    payload — the fusion layer's double-buffer discipline.  With S stages
    there are S+1 compute blocks (the local block needs no transfer); a
    stage only extends the critical path by the part of its wire time the
    concurrent compute block fails to hide:

        T = (S + 1) * compute_s  +  sum_k max(0, t_k - compute_s)
    """
    t_comm = [link.time(*_stage3(st)) for st in stages]
    return ((len(stages) + 1) * compute_s
            + sum(max(0.0, t - compute_s) for t in t_comm))


def fit_contention(link_loads, times_s) -> float:
    """Recover the LinkModel `contention` factor from measurements of the
    SAME transfer at different hot-link multiplicities: least-squares fit
    of  t(load) = t(1) * (1 + gamma * (load - 1))  with t(1) taken from
    the load==1 samples.  Returns gamma clipped to [0, 1].

    Needs at least one load<=1 baseline sample AND at least one load>1
    sample — with no loaded point gamma is unidentifiable (the
    degenerate grid the guards below reject)."""
    loads = np.asarray(link_loads, dtype=np.float64)
    times = np.asarray(times_s, dtype=np.float64)
    if loads.ndim != 1 or loads.shape != times.shape:
        raise ValueError(
            f"fit_contention needs matching 1-D load/time samples, got "
            f"shapes {loads.shape} and {times.shape}")
    if len(loads) < 2:
        raise ValueError(
            f"fit_contention needs >= 2 (load, time) samples, got "
            f"{len(loads)}")
    base = times[loads <= 1.0]
    if len(base) == 0:
        raise ValueError("fit_contention needs at least one load==1 sample")
    if not (loads > 1.0).any():
        raise ValueError(
            "fit_contention needs at least one load>1 sample — an "
            "all-unit load grid cannot identify the contention factor")
    t1 = float(base.mean())
    x = loads - 1.0
    denom = float(x @ x)
    if denom == 0.0 or t1 <= 0.0:
        return 0.0
    gamma = float(x @ (times / t1 - 1.0)) / denom
    return min(max(gamma, 0.0), 1.0)


def choose_chunks(stages: list[tuple],
                  link: LinkModel = ICI_V5E, max_chunks: int = 32,
                  tuner=None, key: tuple | None = None) -> int:
    """Pick the chunk count (power of two, 1 = monolithic) minimizing the
    modeled pipelined time of a schedule's (bytes, hops[, max_link_load])
    stage costs.

    With a `tuner` (a ``repro.core.tuner.TunedSelector``) and a `key`
    ``(collective, algorithm, n, nbytes, topo)``, the MEASURED best chunk
    count for that point is consulted first (DESIGN.md §13 precedence);
    the analytic pipeline model is the fallback for unmeasured points."""
    if tuner is not None and key is not None:
        collective, algorithm, n, nbytes, topo = key
        c = tuner.chunks(collective, algorithm, n, nbytes, topo,
                         max_chunks=max_chunks)
        if c is not None:
            return max(1, min(int(c), max_chunks))
    candidates = [1 << k for k in range(max(1, max_chunks).bit_length())
                  if (1 << k) <= max_chunks]
    return min(candidates,
               key=lambda c: modeled_pipelined_time(stages, c, link))
