"""Network primitives: the JAX analogue of the Epiphany memory-mapped NoC.

Two interchangeable backends sit beneath every collective algorithm:

  * ``SpmdNetOps`` — runs inside ``jax.shard_map``; a ``ppermute`` edge is
    the analogue of an Epiphany memory-mapped remote store (sender-driven,
    one hop per mesh neighbor on the ICI torus).
  * ``SimNetOps``  — single-device oracle; arrays carry a leading PE axis
    and ``ppermute`` is a gather.  Algorithm code is identical, so every
    collective can be property-tested on one CPU device for arbitrary PE
    counts (including non-powers-of-two and subsets — the cases the paper
    notes eLib's 2D indexing cannot express).

Both expose the same minimal surface, so ``collectives.py`` is written once.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .pattern import CommPattern, PatternLike, as_pattern

AxisNames = str | tuple[str, ...]


def _mask_of(pe_mask) -> np.ndarray:
    """A select mask may be given as a host bool array or as a compiled
    CommPattern (meaning: its destination set)."""
    if isinstance(pe_mask, CommPattern):
        return pe_mask.dst_mask
    return pe_mask


class NetOps:
    """Protocol: n_pes, my_pe(), ppermute(), with sender-driven semantics."""

    n_pes: int
    # Optional attached repro.core.profile.Profiler (ShmemContext sets it):
    # ppermute traffic lands in its aggregate counters.  Plain class
    # attribute, NOT a dataclass field — the default costs subclasses
    # nothing and the hot path pays one `is None` test when unattached.
    profile = None
    # Optional attached repro.core.fault.FaultInjector (ShmemContext's
    # fault= knob sets it): every ppermute consults the fault plan and
    # raises typed PEFailure/LinkFailure instead of silently moving data
    # a dead mesh could not (DESIGN.md §17).  Patterns are static host
    # objects, so the check is pure host code and works identically
    # under eager SIM and SPMD tracing.
    fault = None

    def my_pe(self):
        raise NotImplementedError

    def _check_fault(self, p: CommPattern) -> None:
        f = self.fault
        if f is not None:
            f.check(p, self)

    def _count_ppermute(self, p: CommPattern, x) -> None:
        """Aggregate-counter hook (near-zero when no profiler attached)."""
        prof = self.profile
        if prof is not None and prof.enabled:
            nbytes = float(sum(l.size * l.dtype.itemsize
                               for l in jax.tree.leaves(x)))
            prof.count(f"ppermute[n{p.n_pes},e{len(p.pairs)}]", 1, nbytes)

    def ppermute(self, x, perm: PatternLike):
        """Static point-to-point pattern: for each (src, dst) pair, dst
        receives src's shard; PEs not named as a dst receive zeros.
        `perm` is a raw (src, dst) pair list or a compiled
        :class:`~repro.core.pattern.CommPattern` (preferred on hot paths —
        compiled once, reused every call).

        This is the 'remote store' primitive.  Like the Epiphany NoC (and
        unlike a remote load) it never blocks the sender — which is why a
        shmem *get* on this substrate is always the paper's IPI-get: the
        owner pushes (DESIGN.md §2)."""
        raise NotImplementedError

    # -- helpers shared by both backends ------------------------------------
    def select(self, pe_mask, a, b):
        """Per-PE static selection: where PE's entry in `pe_mask` (a host
        bool array indexed by pe id, or a CommPattern standing for its
        destination set) is True take `a` else `b`."""
        m = jnp.asarray(_mask_of(pe_mask))[self.my_pe()]
        return jax.tree.map(lambda x, y: jnp.where(m, x, y), a, b)


@dataclasses.dataclass
class SpmdNetOps(NetOps):
    """Inside shard_map over `axis` (one name or a tuple, flattened
    row-major into the PE space)."""

    axis: AxisNames
    n_pes: int = dataclasses.field(init=False)

    def __post_init__(self):
        self.n_pes = int(lax.axis_size(self.axis))

    def my_pe(self):
        return lax.axis_index(self.axis)

    def ppermute(self, x, perm):
        p = as_pattern(perm, self.n_pes)
        if self.fault is not None:
            self._check_fault(p)
        if self.profile is not None:
            self._count_ppermute(p, x)
        rounds = p.unique_src_rounds()

        def one(v):
            # destinations are disjoint across rounds and non-destinations
            # receive zeros, so rounds combine losslessly
            acc = lax.ppermute(v, self.axis, list(rounds[0])) if rounds \
                else jnp.zeros_like(v)
            for r in rounds[1:]:
                recv = lax.ppermute(v, self.axis, list(r))
                acc = (acc | recv) if v.dtype == jnp.bool_ else acc + recv
            return acc

        return jax.tree.map(one, x)

    def axis_all_gather(self, x, *, tiled=True):
        return jax.tree.map(
            lambda v: lax.all_gather(v, self.axis, tiled=tiled), x)

    def axis_psum(self, x):
        return lax.psum(x, self.axis)


@dataclasses.dataclass
class SimNetOps(NetOps):
    """Single-device simulation: every array carries a leading PE axis."""

    n_pes: int

    def my_pe(self):
        return jnp.arange(self.n_pes)

    def _expand_pe_index(self, idx, v):
        return idx.reshape(idx.shape + (1,) * (v.ndim - 1))

    def ppermute(self, x, perm):
        # device-resident index arrays are cached per interned pattern —
        # the hot path no longer re-uploads host indices every call
        p = as_pattern(perm, self.n_pes)
        if self.fault is not None:
            self._check_fault(p)
        if self.profile is not None:
            self._count_ppermute(p, x)
        has, gather_idx = p.gather_arrays_device()

        def one(v):
            recv = v[gather_idx]
            mask = self._expand_pe_index(has, v)
            return jnp.where(mask, recv, jnp.zeros_like(recv))

        return jax.tree.map(one, x)

    def select(self, pe_mask, a, b):
        m = jnp.asarray(_mask_of(pe_mask))

        def one(x, y):
            mm = self._expand_pe_index(m, x)
            return jnp.where(mm, x, y)

        return jax.tree.map(one, a, b)


@dataclasses.dataclass
class NocSimNetOps(SimNetOps):
    """Congestion-faithful simulation: a ppermute moves one gather-row per
    link-disjoint WAVE of its pattern (``CommPattern.link_waves``) — the
    flows a real NoC could fly concurrently share a wave, contending
    flows land in later waves, the way the eMesh serializes transmissions
    through a shared physical link.  Results are bit-identical to
    :class:`SimNetOps` (destinations are disjoint across waves,
    non-destinations receive zeros), but measured wall time scales with
    the pattern's hot-link multiplicity — what lets
    ``benchmarks/bench_congestion.py`` validate the congestion term of
    the alpha-beta model against an execution, not just against itself.

    All waves run as ONE stacked gather (wave results then reduced over
    the wave axis): a chain of per-wave gathers feeding adds triggers an
    exponential XLA CPU compile blow-up on deep schedules — the stacked
    form keeps compiles linear while still moving waves-x the data.  The
    single-wave case takes the same stacked shape and every stage output
    is an optimization_barrier, so XLA cannot fuse/recompose stages and
    the measured time differences are data-volume-driven, not
    fusion-luck-driven."""

    topo: "object" = None
    _stack_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def _wave_arrays(self, p: CommPattern):
        got = self._stack_cache.get(p)
        if got is None:
            import jax as _jax
            waves = p.link_waves(self.topo)
            has = np.concatenate([w.gather_arrays()[0] for w in waves])
            idx = np.concatenate([w.gather_arrays()[1] for w in waves])
            with _jax.ensure_compile_time_eval():
                got = (len(waves), jnp.asarray(has), jnp.asarray(idx))
            self._stack_cache[p] = got
        return got

    def ppermute(self, x, perm):
        from jax import lax
        p = as_pattern(perm, self.n_pes)
        if not p.pairs:                  # empty pattern: zeros, like base
            return super().ppermute(x, p)
        if self.fault is not None:
            self._check_fault(p)
        if self.profile is not None:
            self._count_ppermute(p, x)
        n_waves, has, idx = self._wave_arrays(p)

        def one(v):
            recv = v[idx]                              # (W*n_pes, ...)
            mask = self._expand_pe_index(has, v)
            recv = jnp.where(mask, recv, jnp.zeros_like(recv))
            stacked = recv.reshape((n_waves, self.n_pes) + v.shape[1:])
            # keep the payload dtype: sum() would promote sub-32-bit ints
            # (lossless cast — wave destinations are disjoint, so at most
            # one wave contributes per slot)
            out = stacked.any(0) if v.dtype == jnp.bool_ \
                else stacked.sum(0).astype(v.dtype)
            return lax.optimization_barrier(out)

        return jax.tree.map(one, x)


# -- per-PE dynamic slicing helpers (work under both backends) --------------

def dyn_slice_block(net: NetOps, x, block_index, block_size: int, axis: int):
    """Slice x[..., block_index*block_size : +block_size, ...] where
    block_index is a per-PE traced scalar.

    Under SPMD `x` is the local shard; under SIM `x` has the leading PE axis
    and block_index is a vector over PEs (we vmap)."""
    if isinstance(net, SimNetOps):
        def one(v, i):
            starts = [0] * v.ndim
            sizes = list(v.shape)
            starts[axis] = i * block_size
            sizes[axis] = block_size
            return lax.dynamic_slice(v, starts, sizes)
        return jax.vmap(one, in_axes=(0, 0))(x, block_index)
    starts = [0] * x.ndim
    sizes = list(x.shape)
    starts[axis] = block_index * block_size
    sizes[axis] = block_size
    return lax.dynamic_slice(x, starts, sizes)


def dyn_update_block(net: NetOps, x, update, block_index, block_size: int,
                     axis: int):
    if isinstance(net, SimNetOps):
        def one(v, u, i):
            starts = [0] * v.ndim
            starts[axis] = i * block_size
            return lax.dynamic_update_slice(v, u, starts)
        return jax.vmap(one, in_axes=(0, 0, 0))(x, update, block_index)
    starts = [0] * x.ndim
    starts[axis] = block_index * block_size
    return lax.dynamic_update_slice(x, update, starts)
