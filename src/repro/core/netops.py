"""Network primitives: the JAX analogue of the Epiphany memory-mapped NoC.

Two interchangeable backends sit beneath every collective algorithm:

  * ``SpmdNetOps`` — runs inside ``jax.shard_map``; a ``ppermute`` edge is
    the analogue of an Epiphany memory-mapped remote store (sender-driven,
    one hop per mesh neighbor on the ICI torus).
  * ``SimNetOps``  — single-device oracle; arrays carry a leading PE axis
    and ``ppermute`` is a gather.  Algorithm code is identical, so every
    collective can be property-tested on one CPU device for arbitrary PE
    counts (including non-powers-of-two and subsets — the cases the paper
    notes eLib's 2D indexing cannot express).

Both expose the same minimal surface, so ``collectives.py`` is written once.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

AxisNames = str | tuple[str, ...]


class NetOps:
    """Protocol: n_pes, my_pe(), ppermute(), with sender-driven semantics."""

    n_pes: int

    def my_pe(self):
        raise NotImplementedError

    def ppermute(self, x, perm: Sequence[tuple[int, int]]):
        """Static point-to-point pattern: for each (src, dst) pair, dst
        receives src's shard; PEs not named as a dst receive zeros.

        This is the 'remote store' primitive.  Like the Epiphany NoC (and
        unlike a remote load) it never blocks the sender — which is why a
        shmem *get* on this substrate is always the paper's IPI-get: the
        owner pushes (DESIGN.md §2)."""
        raise NotImplementedError

    # -- helpers shared by both backends ------------------------------------
    def select(self, pe_mask: np.ndarray, a, b):
        """Per-PE static selection: where PE's entry in `pe_mask` (a host
        bool array indexed by pe id) is True take `a` else `b`."""
        m = jnp.asarray(pe_mask)[self.my_pe()]
        return jax.tree.map(lambda x, y: jnp.where(m, x, y), a, b)


@dataclasses.dataclass
class SpmdNetOps(NetOps):
    """Inside shard_map over `axis` (one name or a tuple, flattened
    row-major into the PE space)."""

    axis: AxisNames
    n_pes: int = dataclasses.field(init=False)

    def __post_init__(self):
        self.n_pes = int(lax.axis_size(self.axis))

    def my_pe(self):
        return lax.axis_index(self.axis)

    def ppermute(self, x, perm):
        perm = [(int(s), int(d)) for s, d in perm]
        return jax.tree.map(lambda v: lax.ppermute(v, self.axis, perm), x)

    def axis_all_gather(self, x, *, tiled=True):
        return jax.tree.map(
            lambda v: lax.all_gather(v, self.axis, tiled=tiled), x)

    def axis_psum(self, x):
        return lax.psum(x, self.axis)


@dataclasses.dataclass
class SimNetOps(NetOps):
    """Single-device simulation: every array carries a leading PE axis."""

    n_pes: int

    def my_pe(self):
        return jnp.arange(self.n_pes)

    def _expand_pe_index(self, idx, v):
        return idx.reshape(idx.shape + (1,) * (v.ndim - 1))

    def ppermute(self, x, perm):
        src_for_dst = np.full((self.n_pes,), -1, dtype=np.int64)
        for s, d in perm:
            src_for_dst[int(d) % self.n_pes] = int(s) % self.n_pes
        has = jnp.asarray(src_for_dst >= 0)
        gather_idx = jnp.asarray(np.where(src_for_dst >= 0, src_for_dst, 0))

        def one(v):
            recv = v[gather_idx]
            mask = self._expand_pe_index(has, v)
            return jnp.where(mask, recv, jnp.zeros_like(recv))

        return jax.tree.map(one, x)

    def select(self, pe_mask, a, b):
        m = jnp.asarray(pe_mask)

        def one(x, y):
            mm = self._expand_pe_index(m, x)
            return jnp.where(mm, x, y)

        return jax.tree.map(one, a, b)


# -- per-PE dynamic slicing helpers (work under both backends) --------------

def dyn_slice_block(net: NetOps, x, block_index, block_size: int, axis: int):
    """Slice x[..., block_index*block_size : +block_size, ...] where
    block_index is a per-PE traced scalar.

    Under SPMD `x` is the local shard; under SIM `x` has the leading PE axis
    and block_index is a vector over PEs (we vmap)."""
    if isinstance(net, SimNetOps):
        def one(v, i):
            starts = [0] * v.ndim
            sizes = list(v.shape)
            starts[axis] = i * block_size
            sizes[axis] = block_size
            return lax.dynamic_slice(v, starts, sizes)
        return jax.vmap(one, in_axes=(0, 0))(x, block_index)
    starts = [0] * x.ndim
    sizes = list(x.shape)
    starts[axis] = block_index * block_size
    sizes[axis] = block_size
    return lax.dynamic_slice(x, starts, sizes)


def dyn_update_block(net: NetOps, x, update, block_index, block_size: int,
                     axis: int):
    if isinstance(net, SimNetOps):
        def one(v, u, i):
            starts = [0] * v.ndim
            starts[axis] = i * block_size
            return lax.dynamic_update_slice(v, u, starts)
        return jax.vmap(one, in_axes=(0, 0, 0))(x, update, block_index)
    starts = [0] * x.ndim
    starts[axis] = block_index * block_size
    return lax.dynamic_update_slice(x, update, starts)
