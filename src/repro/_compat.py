"""Version shims for the pinned environment.

The codebase targets the current jax API; older pinned jax (< 0.5) lacks
two spellings we use pervasively.  Both have exact legacy equivalents, so
we backfill them at import rather than sprinkling call sites with guards:

  * ``jax.set_mesh(mesh)``   -> the mesh itself (``Mesh`` has always been a
    context manager; entering it is what ``set_mesh`` does ambiently).
    ONLY the ``with jax.set_mesh(mesh):`` form is supported — every call
    site in this repo uses it.  A bare ``jax.set_mesh(mesh)`` statement
    would silently not install an ambient mesh on legacy jax.
  * ``jax.shard_map``        -> ``jax.experimental.shard_map.shard_map``,
    with the ``check_vma`` kwarg mapped to its old name ``check_rep``.
  * ``jax.sharding.AxisType`` -> a stand-in enum, with ``jax.make_mesh``
    wrapped to drop the ``axis_types`` kwarg (legacy meshes are implicitly
    all-Auto, so dropping it is exact for the Auto case we use).
"""
from __future__ import annotations

import enum

import jax


def install() -> None:
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = lambda mesh: mesh
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _legacy_sm

        def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
            if check_vma is not None:
                kw["check_rep"] = check_vma
            return _legacy_sm(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map
    if not hasattr(jax.lax, "axis_size"):
        # legacy spelling: a psum of 1 over a named axis constant-folds to
        # the (python int) axis size
        jax.lax.axis_size = lambda axis: jax.lax.psum(1, axis)
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType
        _legacy_make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
            if axis_types is not None and any(
                    t is not AxisType.Auto for t in axis_types):
                raise NotImplementedError(
                    "legacy jax supports only Auto axes")
            return _legacy_make_mesh(axis_shapes, axis_names, *args, **kw)

        jax.make_mesh = make_mesh
