"""Per-KV-block attention partials for the fused ring-attention path.

The monolithic flash kernel (`flash_attention.py`) streams the WHOLE KV
sequence through its in-kernel fori loop.  Ring attention (DESIGN.md §14)
instead sees the KV sequence one remote block at a time — each ring step
delivers the next neighbor's KV shard while the current one is consumed —
so the kernel here computes the *un-normalized* online-softmax partial
state for ONE block:

    acc = sum_j exp(s_j - m) v_j     (B, Hq, Lq, D)   f32
    m   = max_j s_j                  (B, Hq, Lq)      f32 (NEG_INF if none)
    l   = sum_j exp(s_j - m)         (B, Hq, Lq)      f32

Partial states from successive blocks merge with the standard flash
rescaling (`merge_partials`) and `finalize` applies the deferred division,
reproducing the monolithic kernel's arithmetic to f32 allclose regardless
of how the KV sequence was split.

Masking is GLOBAL-position based: the caller passes the query rows'
positions and each KV block's positions (`k_pos`, with -1 marking padded
slots) so causal / sliding-window / ragged-edge semantics survive the
sequence sharding — a block's rows mask exactly as they would have in the
monolithic kernel.  Same pinned-jax constraint as flash_attention: refs
are indexed with slices only.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import DEFAULT_BK, DEFAULT_BQ, NEG_INF


def _partials_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref,
                     acc_ref, m_ref, l_ref, *, lk_pad: int, bk: int,
                     causal: bool, window: int | None,
                     softcap: float | None, sm_scale: float):
    q = q_ref[...][0, 0].astype(jnp.float32) * sm_scale     # (BQ, D)
    bq, d = q.shape
    q_pos = qp_ref[...].reshape(bq, 1)

    n_kb = lk_pad // bk

    def body(i, carry):
        acc, m_i, l_i = carry
        start = i * bk
        kv_idx = (slice(None), slice(None), pl.ds(start, bk), slice(None))
        k = pl.load(k_ref, kv_idx)[0, 0].astype(jnp.float32)     # (BK, D)
        v = pl.load(v_ref, kv_idx)[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (BQ, BK)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = pl.load(kp_ref, (pl.ds(start, bk),)).reshape(1, bk)
        mask = k_pos >= 0                    # -1 marks padded KV slots
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    acc_ref[...] = acc[None, None]
    m_ref[...] = m_i[:, 0][None, None]
    l_ref[...] = l_i[:, 0][None, None]


def _partials_pallas(q, k, v, q_pos, k_pos, *, causal, window, softcap,
                     sm_scale, bq, bk, interpret):
    b_sz, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    kernel = functools.partial(
        _partials_kernel, lk_pad=lk, bk=bk, causal=causal, window=window,
        softcap=softcap, sm_scale=sm_scale)
    grid = (b_sz, hq, lq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((bq,), lambda b, h, i: (i,)),
            pl.BlockSpec((lk,), lambda b, h, i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i: (b, h, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b_sz, hq, lq, d), jnp.float32),
            jax.ShapeDtypeStruct((b_sz, hq, lq), jnp.float32),
            jax.ShapeDtypeStruct((b_sz, hq, lq), jnp.float32),
        ),
        interpret=interpret,
    )(q, k, v, q_pos, k_pos)


def _partials_ref(q, k, v, q_pos, k_pos, *, causal, window, softcap,
                  sm_scale):
    """XLA reference — identical arithmetic to the kernel, one KV block."""
    hq, hkv = q.shape[1], k.shape[1]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    qf = q.astype(jnp.float32) * sm_scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    mask = kp >= 0
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return acc, m, l


def attn_block_partials(q, k, v, q_pos, k_pos, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None,
                        sm_scale: float | None = None,
                        use_pallas: bool = False,
                        bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                        interpret: bool = True):
    """Un-normalized flash partials of q against ONE KV block.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D); q_pos: (Lq,) int32 global
    query positions; k_pos: (Lk,) int32 global key positions (-1 = padded
    slot, always masked).  Returns (acc f32 (B,Hq,Lq,D), m f32 (B,Hq,Lq),
    l f32 (B,Hq,Lq)) — merge with `merge_partials`, then `finalize`."""
    d = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    if not use_pallas:
        return _partials_ref(q, k, v, q_pos, k_pos, causal=causal,
                             window=window, softcap=softcap,
                             sm_scale=sm_scale)
    lq, lk = q.shape[2], k.shape[2]
    pq = (-lq) % bq
    pk = (-lk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=-1)
    acc, m, l = _partials_pallas(q, k, v, q_pos, k_pos, causal=causal,
                                 window=window, softcap=softcap,
                                 sm_scale=sm_scale, bq=bq, bk=bk,
                                 interpret=interpret)
    return acc[:, :, :lq], m[:, :, :lq], l[:, :, :lq]


def merge_partials(a, b):
    """Combine two un-normalized partial states (associative and, up to
    f32 rounding, order-insensitive — the flash rescaling rule)."""
    acc_a, m_a, l_a = a
    acc_b, m_b, l_b = b
    m = jnp.maximum(m_a, m_b)
    wa = jnp.exp(m_a - m)
    wb = jnp.exp(m_b - m)
    acc = acc_a * wa[..., None] + acc_b * wb[..., None]
    l = l_a * wa + l_b * wb
    return acc, m, l


def finalize(state, dtype=None):
    """Apply the deferred softmax division: out = acc / max(l, 1e-30),
    the same epsilon-guarded division the monolithic kernel performs.
    Fully-masked rows come out exactly 0."""
    acc, _, l = state
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out if dtype is None else out.astype(dtype)
