"""Blockwise (flash) attention Pallas kernel for the model zoo's hot spot.

Covers every attention variant the assigned architectures need:
  * GQA (kv-head groups)           — internlm2 / qwen2 / gemma2 / danube
  * causal masking                 — all decoders
  * sliding-window                 — h2o-danube, gemma2 local layers
  * logit soft-capping (tanh)      — gemma2
  * non-causal                     — hubert encoder, phi-3-vision image part

TPU adaptation notes: Q is tiled (BQ, D) into VMEM per grid step, the KV
sequence streams through an in-kernel fori loop at (BK, D) granularity with
f32 online-softmax accumulators — the standard MXU-friendly flash schedule
(block sizes multiples of 128 lanes / 8 sublanes).  The HBM->VMEM streaming
plays the role Epiphany SRAM staging played for the paper's copy loop.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, lk_pad: int, lk_valid: int,
                 bk: int, causal: bool, window: int | None,
                 softcap: float | None, sm_scale: float, q_start_map):
    # NOTE: refs are indexed with slices only (never bare python ints):
    # the pinned jax's interpret-mode discharge rule rejects scalar int
    # indices inside pl.load/pl.store (AttributeError on `.shape`), and
    # slice indexing lowers identically on the compiled path.
    qb = pl.program_id(2)
    q = q_ref[...][0, 0].astype(jnp.float32) * sm_scale  # (BQ, D)
    bq, d = q.shape
    q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_kb = lk_pad // bk

    def body(i, carry):
        acc, m_i, l_i = carry
        start = i * bk
        kv_idx = (slice(None), slice(None), pl.ds(start, bk), slice(None))
        k = pl.load(k_ref, kv_idx)[0, 0].astype(jnp.float32)     # (BK, D)
        v = pl.load(v_ref, kv_idx)[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (BQ, BK)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = k_pos < lk_valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)).astype(
        o_ref.dtype)[None, None]


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, sm_scale: float | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    lk_valid: int | None = None, interpret: bool = False):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D); Lq % bq == Lk % bk == 0
    (ops.py pads and passes lk_valid for the ragged edge)."""
    b_sz, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert lq % bq == 0 and lk % bk == 0, (lq, lk, bq, bk)
    group = hq // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    lk_valid = lk if lk_valid is None else lk_valid

    kernel = functools.partial(
        _attn_kernel, lk_pad=lk, lk_valid=lk_valid, bk=bk, causal=causal,
        window=window, softcap=softcap, sm_scale=sm_scale, q_start_map=None)
    grid = (b_sz, hq, lq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
