"""put_copy — the paper's hand-tuned shmem_put memcpy, as a Pallas kernel.

The Epiphany version used a zero-overhead hardware loop with 4-way unrolled
staggered double-word loads/remote-stores (8 B / 2 clk peak) plus an
unaligned edge path.  The TPU translation (DESIGN.md §2):

  * the double-word register pair  -> an (8, 128) VMEM tile (sublane x lane);
  * the hardware loop              -> the Pallas grid;
  * 4-way unrolling                -> a row-multiple block shape (the Mosaic
    compiler pipelines tile loads the way the staggered unroll did);
  * the unaligned edge path        -> wrapper-side padding to tile multiples
    with a masked final store (ops.py), since TPU stores are tile-granular
    exactly like Epiphany dword stores were 8-byte-granular.

Also provides the 2D-strided descriptor copy that mirrors the e-DMA
engine's 2D stride capability (paper §3.4) — the substrate a strided
put_nbi extension would use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (sublane, lane) tile; rows a 4x multiple of the 8-row sublane tile — the
# analogue of the 4-way unrolled dword loop.
BLOCK_ROWS = 32
BLOCK_COLS = 128


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def put_copy_2d(src: jax.Array, *, block_rows: int = BLOCK_ROWS,
                block_cols: int = BLOCK_COLS, interpret: bool = False):
    """Tiled copy of a 2D array (rows, cols), rows % block_rows == 0 and
    cols % block_cols == 0 (the fast path; ops.py pads the edge case)."""
    rows, cols = src.shape
    assert rows % block_rows == 0 and cols % block_cols == 0, (rows, cols)
    grid = (rows // block_rows, cols // block_cols)
    return pl.pallas_call(
        _copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block_cols),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        interpret=interpret,
    )(src)


def _strided_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def dma_copy_2d(src: jax.Array, dst: jax.Array, *, src_origin: tuple[int, int],
                dst_origin: tuple[int, int], region: tuple[int, int],
                block_rows: int = BLOCK_ROWS, block_cols: int = BLOCK_COLS,
                interpret: bool = False):
    """2D-strided DMA-descriptor copy: move `region` from `src` at
    `src_origin` into `dst` at `dst_origin` (block-aligned origins/region —
    the descriptor granularity).  Returns the updated dst."""
    (sr, sc), (dr, dc), (nr, nc) = src_origin, dst_origin, region
    assert nr % block_rows == 0 and nc % block_cols == 0
    assert sr % block_rows == 0 and sc % block_cols == 0
    assert dr % block_rows == 0 and dc % block_cols == 0
    grid = (nr // block_rows, nc // block_cols)
    sro, sco = sr // block_rows, sc // block_cols
    dro, dco = dr // block_rows, dc // block_cols

    def dst_index(i, j):
        return (dro + i, dco + j)

    def _kernel(src_ref, dst_in_ref, dst_ref):
        del dst_in_ref  # aliased with dst_ref; untouched blocks stay put
        dst_ref[...] = src_ref[...]

    # input_output_aliasing keeps the untouched part of dst in place.
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block_cols),
                               lambda i, j: (sro + i, sco + j)),
                  pl.BlockSpec((block_rows, block_cols), dst_index)],
        out_specs=pl.BlockSpec((block_rows, block_cols), dst_index),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(src, dst)
    return out
