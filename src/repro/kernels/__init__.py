"""Pallas TPU kernels for the perf-critical compute layers.

put_copy / reduce_combine mirror the paper's hand-tuned copy loop and
reduction combine; flash_attention / ssd_scan are the model zoo's hot
spots.  ops.py holds the jit'd public wrappers, ref.py the pure-jnp
oracles used by the allclose tests.
"""
from . import ops, ref
