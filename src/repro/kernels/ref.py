"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core import collectives as coll

NEG_INF = -1e30


def put_copy_ref(src):
    return jnp.asarray(src) + 0  # identity copy


def dma_copy_ref(src, dst, *, src_origin, dst_origin, region):
    (sr, sc), (dr, dc), (nr, nc) = src_origin, dst_origin, region
    block = jax.lax.dynamic_slice(src, (sr, sc), (nr, nc))
    return jax.lax.dynamic_update_slice(dst, block, (dr, dc))


def reduce_combine_ref(bufs, op: str = "sum"):
    fn = coll.OPS[op]
    acc = bufs[0]
    for b in bufs[1:]:
        acc = fn(acc, b)
    return acc


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  sm_scale=None, lk_valid=None):
    """q: (B,Hq,Lq,D); k,v: (B,Hkv,Lk,D). Dense reference attention."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    group = hq // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    lk_valid = lk if lk_valid is None else lk_valid
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    # matmuls run in the input dtype with f32 accumulation (MXU-style);
    # avoids materializing f32 copies of q/k/v (§Perf P4)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * sm_scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(lq)[:, None]
    k_pos = jnp.arange(lk)[None, :]
    mask = k_pos < lk_valid
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vv,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attention_blockwise(q, k, v, *, causal=True, window=None, softcap=None,
                        sm_scale=None, lk_valid=None, block: int = 1024,
                        unroll: bool = False):
    """Flash-style attention in pure XLA: lax.scan over KV blocks with
    online-softmax carries.  O(Lq*block) memory instead of O(Lq*Lk) — the
    long-context (32k prefill) path on any backend, same math as the
    Pallas kernel.  Freely differentiable (scan transposes)."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    dv = v.shape[-1]                      # may differ from d (MLA)
    group = hq // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    lk_valid = lk if lk_valid is None else lk_valid
    pad = (-lk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = k.shape[2] // block
    kb = jnp.moveaxis(k.reshape(b, hkv, nblk, block, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hkv, nblk, block, dv), 2, 0)
    qf = q * jnp.asarray(sm_scale, q.dtype)
    q_pos = jnp.arange(lq)[:, None]

    def body(carry, inp):
        acc, m_i, l_i = carry
        kk, vv, start = inp
        kk = jnp.repeat(kk, group, axis=1)
        vv = jnp.repeat(vv, group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kk,
                            preferred_element_type=jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = start + jnp.arange(block)[None, :]
        mask = k_pos < lk_valid
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(logits, -1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd",
                                       p.astype(vv.dtype), vv,
                                       preferred_element_type=jnp.float32)
        return (acc, m_new, l_new), ()

    acc0 = jnp.zeros((b, hq, lq, dv), jnp.float32)
    m0 = jnp.full((b, hq, lq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, lq, 1), jnp.float32)
    starts = jnp.arange(nblk) * block
    (acc, m_i, l_i), _ = jax.lax.scan(body, (acc0, m0, l0),
                                      (kb, vb, starts),
                                      unroll=nblk if unroll else 1)
    return (acc / jnp.maximum(l_i, 1e-30)).astype(q.dtype)


def ssd_ref(x, dt, a_log, b_mat, c_mat, h0=None):
    """Sequential-scan oracle for the SSD kernel.
    x: (B,L,H,P); dt: (B,L,H); a_log: (H,); b_mat/c_mat: (B,L,G,N)."""
    bsz, length, h, p = x.shape
    _, _, g, n = b_mat.shape
    group = h // g
    bm = jnp.repeat(b_mat, group, axis=2)   # (B,L,H,N)
    cm = jnp.repeat(c_mat, group, axis=2)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp           # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(a_log[None, :] * dt_t)[..., None, None]   # (B,H,1,1)
        upd = (dt_t[..., None, None] * x_t[..., :, None] *
               b_t[..., None, :])                                  # (B,H,P,N)
        state = decay * state.astype(jnp.float32) + upd
        y_t = jnp.einsum("bhn,bhpn->bhp", c_t, state)
        return state, y_t

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                    # (B,L,H,P)
    return y, h_final


def ssd_chunked_ref(x, dt, a_log, b_mat, c_mat, h0=None, chunk: int = 128,
                    unroll: bool = False):
    """Chunked SSD in pure jnp — same math as the kernel, used as the
    models' XLA path (fast on any backend, exercised by the dry-run)."""
    bsz, length, h, p = x.shape
    _, _, g, n = b_mat.shape
    group = h // g
    assert length % chunk == 0
    nc = length // chunk
    bm = jnp.repeat(b_mat, group, axis=2)
    cm = jnp.repeat(c_mat, group, axis=2)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = bm.reshape(bsz, nc, chunk, h, n).astype(jnp.float32)
    cc = cm.reshape(bsz, nc, chunk, h, n).astype(jnp.float32)

    a_dt = a_log[None, None, None, :] * dtc                  # (B,nc,Q,H)
    s = jnp.cumsum(a_dt, axis=2)
    s_last = s[:, :, -1:, :]

    t_idx = jnp.arange(chunk)[:, None]
    u_idx = jnp.arange(chunk)[None, :]
    tri = (t_idx >= u_idx)

    cb = jnp.einsum("bcthn,bcuhn->bchtu", cc, bc)
    # decay[t,u] = exp(s_t - s_u), masked in the EXPONENT: the t<u triangle
    # would overflow exp(+large) to inf, and where(tri, inf*0, 0) still
    # poisons gradients (inf * 0 -> NaN in the VJP)
    delta = (s.transpose(0, 1, 3, 2)[..., :, None]
             - s.transpose(0, 1, 3, 2)[..., None, :])        # (B,nc,H,Q,Q)
    decay = jnp.exp(jnp.where(tri[None, None, None], delta, -1e30))
    m = decay * cb * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchtu,bcuhp->bcthp", m, xc)

    # inter-chunk states, sequential over nc (the only remaining recurrence)
    w = xc * (dtc * jnp.exp(s_last - s))[..., None]           # (B,nc,Q,H,P)
    chunk_upd = jnp.einsum("bcuhp,bcuhn->bchpn", w, bc)       # per-chunk sum
    chunk_decay = jnp.exp(s_last[:, :, 0, :])                 # (B,nc,H)

    def step(state, inp):
        upd, dec, c_blk, s_blk = inp
        y_inter = jnp.exp(s_blk).transpose(0, 2, 1)[..., None] * jnp.einsum(
            "bthn,bhpn->bhtp", c_blk, state)                  # (B,H,Q,P)
        state = dec[..., None, None] * state + upd
        return state, y_inter

    xs = (jnp.moveaxis(chunk_upd, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
          jnp.moveaxis(cc, 1, 0), jnp.moveaxis(s, 1, 0))
    h_final, y_inter = jax.lax.scan(step, h0, xs,
                                    unroll=nc if unroll else 1)
    y_inter = jnp.moveaxis(y_inter, 0, 1).transpose(0, 1, 3, 2, 4)  # (B,nc,Q,H,P)
    y = (y_intra + y_inter).reshape(bsz, length, h, p).astype(x.dtype)
    return y, h_final
