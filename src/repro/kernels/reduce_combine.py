"""reduce_combine — the fused per-stage combine of the paper's reductions.

Every stage of the dissemination/ring reduction (collectives.allreduce)
does `local = op(local, received)` over the symmetric work array.  On
Epiphany this ran as a hardware-loop over SRAM; on TPU it is a VPU
elementwise pass whose only performance question is tiling.  The kernel
fuses the combine for a *list* of k received buffers (k-ary combine),
which on real hardware removes k-1 HBM round-trips when a PE receives
from several peers in one super-step (e.g. fused gradient buckets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 32
BLOCK_COLS = 128

_OPS = {
    "sum": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _combine_kernel(op, k, *refs):
    *in_refs, out_ref = refs
    acc = in_refs[0][...]
    fn = _OPS[op]
    for r in in_refs[1:]:
        acc = fn(acc, r[...])
    out_ref[...] = acc


def reduce_combine_2d(bufs: list[jax.Array], op: str = "sum", *,
                      block_rows: int = BLOCK_ROWS,
                      block_cols: int = BLOCK_COLS,
                      interpret: bool = False):
    """Fused elementwise op over k same-shape 2D buffers (block-multiple
    shapes; ops.py pads the edge case)."""
    assert len(bufs) >= 2
    rows, cols = bufs[0].shape
    assert all(b.shape == (rows, cols) for b in bufs)
    assert rows % block_rows == 0 and cols % block_cols == 0
    grid = (rows // block_rows, cols // block_cols)
    spec = pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_combine_kernel, op, len(bufs)),
        grid=grid,
        in_specs=[spec] * len(bufs),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(bufs[0].shape, bufs[0].dtype),
        interpret=interpret,
    )(*bufs)
