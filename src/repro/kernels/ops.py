"""jit'd public wrappers around the Pallas kernels.

Each wrapper:
  * handles the unaligned edge case by padding to tile multiples (the TPU
    analogue of the paper's unaligned-memory specialization in the put
    copy loop) and un-padding the result;
  * dispatches kernel vs. pure-jnp reference via `use_pallas` — on this
    CPU container kernels run with interpret=True for validation, while
    the models/dry-run default to the XLA reference path (DESIGN.md);
  * makes attention differentiable with a custom VJP whose backward
    recomputes through the reference (flash-style remat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import put_copy as _pc
from . import reduce_combine as _rc
from . import ref
from . import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# Edge-padding plan + jitted-executor cache, per (kernel, shapes, dtype,
# block, interpret) — the gather_arrays_device pattern from PR 4: pad
# shapes were being recomputed and the pallas wrapper re-traced on EVERY
# eager call.  One cached jax.jit closure per key makes the hot path
# re-trace-free (XLA's trace cache keys on the function object, so the
# closure must be the same object across calls).  _PLAN_STATS is test
# observability (tests/test_fused.py asserts the hot path hits).
_EXEC_CACHE: dict = {}
_PLAN_STATS = {"hits": 0, "misses": 0}


def _cached_exec(key, build):
    fn = _EXEC_CACHE.get(key)
    if fn is None:
        _PLAN_STATS["misses"] += 1
        fn = _EXEC_CACHE[key] = build()
    else:
        _PLAN_STATS["hits"] += 1
    return fn


def _clear_exec_cache():
    _EXEC_CACHE.clear()
    _PLAN_STATS["hits"] = _PLAN_STATS["misses"] = 0


def _pad2d(x, br, bc):
    r, c = x.shape
    pr = (-r) % br
    pc_ = (-c) % bc
    if pr or pc_:
        x = jnp.pad(x, ((0, pr), (0, pc_)))
    return x, (r, c)


def put_copy(src, *, use_pallas: bool = True, interpret: bool | None = None):
    """The paper's optimized shmem_put byte-mover (identity copy)."""
    if not use_pallas:
        return ref.put_copy_ref(src)
    interpret = _default_interpret() if interpret is None else interpret
    key = ("put_copy", src.shape, jnp.dtype(src.dtype).name, interpret)

    def build():
        @jax.jit
        def run(x):
            x2 = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
            padded, (r, c) = _pad2d(x2, _pc.BLOCK_ROWS, _pc.BLOCK_COLS)
            out = _pc.put_copy_2d(padded, interpret=interpret)[:r, :c]
            return out.reshape(x.shape)
        return run

    return _cached_exec(key, build)(src)


def dma_copy(src, dst, *, src_origin, dst_origin, region,
             use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return ref.dma_copy_ref(src, dst, src_origin=src_origin,
                                dst_origin=dst_origin, region=region)
    interpret = _default_interpret() if interpret is None else interpret
    return _pc.dma_copy_2d(src, dst, src_origin=src_origin,
                           dst_origin=dst_origin, region=region,
                           interpret=interpret)


def reduce_combine(bufs, op: str = "sum", *, use_pallas: bool = True,
                   interpret: bool | None = None):
    if not use_pallas:
        return ref.reduce_combine_ref(bufs, op)
    interpret = _default_interpret() if interpret is None else interpret
    shape = bufs[0].shape
    key = ("reduce_combine", len(bufs), op, shape,
           jnp.dtype(bufs[0].dtype).name, interpret)

    def build():
        @jax.jit
        def run(*bs):
            flat = [b.reshape(-1, b.shape[-1]) if b.ndim != 2 else b
                    for b in bs]
            padded = []
            for f in flat:
                p, (r, c) = _pad2d(f, _rc.BLOCK_ROWS, _rc.BLOCK_COLS)
                padded.append(p)
            out = _rc.reduce_combine_2d(padded, op,
                                        interpret=interpret)[:r, :c]
            return out.reshape(shape)
        return run

    return _cached_exec(key, build)(*bufs)


# ---------------------------------------------------------------------------
# attention: pallas forward, reference-recompute backward
# ---------------------------------------------------------------------------

def _pad_seq(x, axis, mult):
    n = x.shape[axis]
    p = (-n) % mult
    if p == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, p)
    return jnp.pad(x, pads)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _attention(q, k, v, causal, window, softcap, sm_scale, bq, bk, interpret):
    lq, lk = q.shape[2], k.shape[2]
    qp = _pad_seq(q, 2, bq)
    kp = _pad_seq(k, 2, bk)
    vp = _pad_seq(v, 2, bk)
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              softcap=softcap, sm_scale=sm_scale, bq=bq,
                              bk=bk, lk_valid=lk, interpret=interpret)
    return out[:, :, :lq]


def _attention_fwd(q, k, v, causal, window, softcap, sm_scale, bq, bk,
                   interpret):
    out = _attention(q, k, v, causal, window, softcap, sm_scale, bq, bk,
                     interpret)
    return out, (q, k, v)


def _attention_bwd(causal, window, softcap, sm_scale, bq, bk, interpret,
                   res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(
            q_, k_, v_, causal=causal, window=window, softcap=softcap,
            sm_scale=sm_scale), q, k, v)
    return vjp(g)


_attention.defvjp(_attention_fwd, _attention_bwd)


BLOCKWISE_THRESHOLD = 8192


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              sm_scale=None, use_pallas: bool = False, bq: int = _fa.DEFAULT_BQ,
              bk: int = _fa.DEFAULT_BK, interpret: bool | None = None,
              blockwise_unroll: bool = False):
    """Public attention op.  use_pallas=True runs the flash kernel; the
    XLA path uses the dense reference for short sequences and the
    blockwise-scan flash equivalent beyond BLOCKWISE_THRESHOLD (O(L*blk)
    memory — required for 32k prefill)."""
    if not use_pallas:
        if k.shape[2] >= BLOCKWISE_THRESHOLD:
            return ref.attention_blockwise(
                q, k, v, causal=causal, window=window, softcap=softcap,
                sm_scale=sm_scale,
                block=4096 if blockwise_unroll else 1024,
                unroll=blockwise_unroll)
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap, sm_scale=sm_scale)
    interpret = _default_interpret() if interpret is None else interpret
    return _attention(q, k, v, causal, window, softcap, sm_scale, bq, bk,
                      interpret)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def ssd(x, dt, a_log, b_mat, c_mat, h0=None, *, chunk: int = 128,
        use_pallas: bool = False, interpret: bool | None = None,
        unroll: bool = False):
    """SSD scan: (y, h_final).  Kernel path is forward-only (serving);
    training uses the chunked XLA reference, which is freely differentiable
    and runs the same math (ref.ssd_chunked_ref)."""
    length = x.shape[1]
    pad = (-length) % chunk
    if pad:
        x = _pad_seq(x, 1, chunk)
        dt = _pad_seq(dt, 1, chunk)
        b_mat = _pad_seq(b_mat, 1, chunk)
        c_mat = _pad_seq(c_mat, 1, chunk)
    if not use_pallas:
        y, h = ref.ssd_chunked_ref(x, dt, a_log, b_mat, c_mat, h0,
                                   chunk=chunk, unroll=unroll)
    else:
        interpret = _default_interpret() if interpret is None else interpret
        y, h = _ssd.ssd_scan(x, dt, a_log, b_mat, c_mat, h0, chunk=chunk,
                             interpret=interpret)
    return y[:, :length], h
