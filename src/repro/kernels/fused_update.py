"""k-ary combine + AdamW update — the terminal stage of the fused
reduce-scatter→optimizer path (DESIGN.md §14).

`reduce_combine.py` fuses the per-stage `local = op(local, received)` of a
ring reduction.  This module extends that combine through the *last* ring
stage: the final received chunk is summed with the local partial, divided
by the mean scale, and fed straight into the AdamW moment/param update —
one kernel pass, so the fully-reduced gradient chunk never round-trips
through memory before the optimizer consumes it (and the full gradient is
never materialized anywhere: each PE only ever updates its owned 1/N
chunk).

The arithmetic is kept operation-for-operation identical to
`train/optimizer.py::apply_updates` (f32 moments) so the fused path is
BITWISE equal to grad-allreduce-then-adam_update, not merely close:
elementwise IEEE ops in the same order are deterministic.  Weight decay
applies per element via a mask (1 where the element belongs to a >=2-D
leaf) because chunk boundaries do not respect leaf boundaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .reduce_combine import BLOCK_COLS, BLOCK_ROWS, _OPS


def combine_chunks(bufs, op: str = "sum", *, use_pallas: bool = True,
                   interpret: bool | None = None):
    """k-ary elementwise combine of same-shape chunks (any dtype, incl.
    int) — the fused path's reduction stage, exposed standalone so the
    combine arithmetic is testable bit-for-bit against the unfused ring
    on integer payloads where rounding can't hide reordering."""
    bufs = list(bufs)
    if len(bufs) == 1:
        return bufs[0]
    from . import ops as _ops           # late: ops imports this module
    return _ops.reduce_combine(bufs, op, use_pallas=use_pallas,
                               interpret=interpret)


def _fused_kernel(*refs, ng: int, lr: float, b1: float, b2: float,
                  eps: float, wd_coef: float, scale: float):
    g_refs = refs[:ng]
    p_ref, m_ref, v_ref, wd_ref, h_ref = refs[ng:ng + 5]
    po_ref, mo_ref, vo_ref = refs[ng + 5:]
    g = g_refs[0][...]
    for r in g_refs[1:]:
        g = g + r[...]
    g = g / scale
    c1 = h_ref[...][0, 0]
    c2 = h_ref[...][0, 1]
    p = p_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
    upd = jnp.where(wd_ref[...] != 0, upd + wd_coef * p, upd)
    po_ref[...] = (p - lr * upd).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


def _to_blocked(x, br, bc):
    pad = (-x.size) % (br * bc)
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x.reshape(-1, bc)


def fused_adam_update_2d(g_bufs, p, m, v, wd_mask, c1, c2, *, lr: float,
                         b1: float, b2: float, eps: float, wd_coef: float,
                         scale: float, out_dtype,
                         block_rows: int = BLOCK_ROWS,
                         block_cols: int = BLOCK_COLS,
                         interpret: bool = False):
    """Pallas kernel: combine k gradient chunks, mean-scale, AdamW-update
    the param/moment chunks.  All operands 1-D f32 of equal length except
    wd_mask (int8).  c1/c2 are the traced bias-correction scalars
    1 - beta**t.  Returns (new_p[out_dtype], new_m, new_v) 1-D."""
    n = p.size
    br, bc = block_rows, block_cols
    gs = [_to_blocked(g, br, bc) for g in g_bufs]
    p2 = _to_blocked(p, br, bc)
    m2 = _to_blocked(m, br, bc)
    v2 = _to_blocked(v, br, bc)
    w2 = _to_blocked(wd_mask.astype(jnp.int8), br, bc)
    hyper = jnp.stack([c1, c2]).astype(jnp.float32).reshape(1, 2)
    rows, cols = p2.shape
    grid = (rows // br, cols // bc)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    hspec = pl.BlockSpec((1, 2), lambda i, j: (0, 0))
    kernel = functools.partial(
        _fused_kernel, ng=len(gs), lr=lr, b1=b1, b2=b2, eps=eps,
        wd_coef=wd_coef, scale=scale)
    new_p, new_m, new_v = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * (len(gs) + 4) + [hspec],
        out_specs=(spec, spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, cols), out_dtype),
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        ),
        interpret=interpret,
    )(*gs, p2, m2, v2, w2, hyper)
    return (new_p.reshape(-1)[:n], new_m.reshape(-1)[:n],
            new_v.reshape(-1)[:n])


def _fused_ref(g_bufs, p, m, v, wd_mask, c1, c2, *, lr, b1, b2, eps,
               wd_coef, scale, out_dtype):
    """XLA path — the exact op sequence of the kernel (and of
    optimizer.apply_updates), elementwise on the flat chunks."""
    g = g_bufs[0]
    for r in g_bufs[1:]:
        g = g + r
    g = g / scale
    m_n = b1 * m + (1.0 - b1) * g
    v_n = b2 * v + (1.0 - b2) * g * g
    upd = (m_n / c1) / (jnp.sqrt(v_n / c2) + eps)
    upd = jnp.where(wd_mask != 0, upd + wd_coef * p, upd)
    return (p - lr * upd).astype(out_dtype), m_n, v_n


def fused_adam_update(g_bufs, p, m, v, wd_mask, c1, c2, *, lr: float,
                      b1: float, b2: float, eps: float, wd_coef: float,
                      scale: float = 1.0, out_dtype=None,
                      use_pallas: bool = False,
                      interpret: bool | None = None):
    """Public entry: combine + mean + AdamW on flat f32 chunks.

    g_bufs: list of 1-D f32 gradient partials to sum (the local ring
    partial and the final incoming chunk); p/m/v: f32 param and moment
    chunks; wd_mask: nonzero where weight decay applies; c1/c2: traced
    1 - beta**t scalars.  Static floats lr/b1/b2/eps/wd_coef/scale come
    from AdamWConfig and the mesh.  Returns (new_p, new_m, new_v)."""
    out_dtype = p.dtype if out_dtype is None else out_dtype
    kw = dict(lr=lr, b1=b1, b2=b2, eps=eps, wd_coef=wd_coef, scale=scale,
              out_dtype=out_dtype)
    if not use_pallas:
        return _fused_ref(list(g_bufs), p, m, v, wd_mask, c1, c2, **kw)
    from . import ops as _ops
    interpret = (_ops._default_interpret() if interpret is None
                 else interpret)
    return fused_adam_update_2d(list(g_bufs), p, m, v, wd_mask, c1, c2,
                                interpret=interpret, **kw)
