"""Mamba2 SSD (state-space duality) chunked scan as a Pallas kernel.

The hot spot of the `mamba2-2.7b` / `zamba2-1.2b` architectures.  The SSD
trick: split the sequence into chunks of Q steps; inside a chunk the SSM is
a (masked, decay-weighted) attention-like matmul that feeds the MXU, and
only the chunk boundary states recur — the sequential dependency shrinks
from L steps to L/Q.

Per (batch, head) grid cell the kernel streams chunks through VMEM, carrying
the (P, N) state in an f32 accumulator:

  decay     s_t   = cumsum(A * dt)                within chunk
  intra     y    += ((C B^T) * exp(s_t - s_u) * dt_u, masked u<=t) @ x
  inter     y    += exp(s_t) * (C @ state^T)
  state     h'    = exp(s_Q) h + (x * dt * exp(s_Q - s_u))^T @ B

All matmuls are (Q x N)(N x Q), (Q x Q)(Q x P), (P x Q)(Q x N) with
Q = N = 128 by default — MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                *, length: int, chunk: int):
    # NOTE: refs are indexed with slices only (never bare python ints):
    # the pinned jax's interpret-mode discharge rule rejects scalar int
    # indices inside pl.load/pl.store (AttributeError on `.shape`), and
    # slice indexing lowers identically on the compiled path.
    a_log = a_ref[...][0].astype(jnp.float32)                # scalar A (<0)
    n_chunks = length // chunk

    def body(i, state):
        sl = (slice(None), pl.ds(i * chunk, chunk), slice(None))
        x = pl.load(x_ref, sl + (slice(None),))[0, :, 0]\
            .astype(jnp.float32)                                        # (Q,P)
        dt = pl.load(dt_ref, sl)[0, :, 0].astype(jnp.float32)           # (Q,)
        bm = pl.load(b_ref, sl + (slice(None),))[0, :, 0]\
            .astype(jnp.float32)                                        # (Q,N)
        cm = pl.load(c_ref, sl + (slice(None),))[0, :, 0]\
            .astype(jnp.float32)                                        # (Q,N)

        a_dt = a_log * dt                                    # (Q,)  <= 0
        s = jnp.cumsum(a_dt)                                 # (Q,)
        s_last = s[-1]

        # intra-chunk: M[t,u] = exp(s_t - s_u) * dt_u * (C_t . B_u), u <= t
        cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        u_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        # mask in the exponent: exp(+large) in the t<u triangle is inf
        decay = jnp.exp(jnp.where(t_idx >= u_idx,
                                  s[:, None] - s[None, :], -1e30))
        m = cb * decay * dt[None, :]
        y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (Q,P)

        # inter-chunk: exp(s_t) * C_t . state (state: (P,N))
        y += jnp.exp(s)[:, None] * jax.lax.dot_general(
            cm, state, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        # state update
        w = (x * (dt * jnp.exp(s_last - s))[:, None])        # (Q,P)
        state = jnp.exp(s_last) * state + jax.lax.dot_general(
            w, bm, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (P,N)

        pl.store(y_ref, sl + (slice(None),),
                 y.astype(y_ref.dtype)[None, :, None, :])
        return state

    state0 = h0_ref[...][0, 0].astype(jnp.float32)
    state = jax.lax.fori_loop(0, n_chunks, body, state0)
    hout_ref[...] = state.astype(hout_ref.dtype)[None, None]


def ssd_scan(x, dt, a_log, b_mat, c_mat, h0=None, *,
             chunk: int = DEFAULT_CHUNK, interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a_log: (H,) (negative);
    b_mat, c_mat: (B, L, G, N) with H % G == 0; h0: (B, H, P, N) or None.
    L % chunk == 0 (ops.py pads).  Returns (y, h_final)."""
    bsz, length, h, p = x.shape
    _, _, g, n = b_mat.shape
    group = h // g
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, length=length, chunk=chunk)
    grid = (bsz, h)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, length, 1, p), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, length, 1), lambda b, hh: (b, 0, hh)),
            pl.BlockSpec((1,), lambda b, hh: (hh,)),
            pl.BlockSpec((1, length, 1, n),
                         lambda b, hh: (b, 0, hh // group, 0)),
            pl.BlockSpec((1, length, 1, n),
                         lambda b, hh: (b, 0, hh // group, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh: (b, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, length, 1, p), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a_log, b_mat, c_mat, h0)
    return y, hout
