"""zamba2-1.2b [hybrid]: 38 Mamba2 layers (d2048, state 64) with a shared
attention(32H)+MLP block applied every 6 layers, v32000.
[arXiv:2411.15242; hf]"""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab=32000,
    ssm=SSMConfig(state=64, head_dim=64, n_groups=1, expand=2),
    hybrid_attn_period=6, microbatches=8,
)


def smoke():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
        ssm=SSMConfig(state=8, head_dim=8, n_groups=1, expand=2, chunk=8,
                      conv_width=4),
        hybrid_attn_period=2, remat="none", microbatches=1)
