"""phi-3-vision-4.2b [vlm]: phi3-mini backbone 32L d3072 32H ff8192
v32064 + CLIP frontend (STUB: input_specs provides precomputed patch
embeddings scattered over the first 576 positions).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, head_dim=96, d_ff=8192, vocab=32064,
    frontend="vision", n_frontend_tokens=576, microbatches=8,
)


def smoke():
    return ModelConfig(
        name="phi3v-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
        frontend="vision", n_frontend_tokens=8, remat="none",
        microbatches=1)
