"""mamba2-2.7b [ssm]: 64L d2560, attention-free SSD (state 128,
head_dim 64), v50280. [arXiv:2405.21060]"""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, head_dim=None, d_ff=0, vocab=50280,
    attn="none",
    ssm=SSMConfig(state=128, head_dim=64, n_groups=1, expand=2),
    microbatches=8,
)


def smoke():
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=0, n_kv_heads=0, head_dim=None, d_ff=0, vocab=128,
        attn="none",
        ssm=SSMConfig(state=8, head_dim=8, n_groups=1, expand=2, chunk=8),
        remat="none", microbatches=1)
