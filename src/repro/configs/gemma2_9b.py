"""gemma2-9b [dense]: 42L d3584 16H GQA(kv=8) hd256 ff14336 v256000,
alternating local(4k SWA)/global attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=14336, vocab=256000,
    local_global_period=2, local_window=4096, softcap=50.0,
    final_softcap=30.0, microbatches=16, moment_dtype="bf16",
)


def smoke():
    return ModelConfig(
        name="gemma2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        local_global_period=2, local_window=16, softcap=50.0,
        final_softcap=30.0, remat="none", microbatches=1)
