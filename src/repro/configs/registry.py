"""--arch <id> registry."""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = {
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "gemma2-9b": "gemma2_9b",
    "qwen2-0.5b": "qwen2_0_5b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "zamba2-1.2b": "zamba2_1_2b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "mamba2-2.7b": "mamba2_2_7b",
    "hubert-xlarge": "hubert_xlarge",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str, **overrides):
    cfg = _module(arch).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def smoke_config(arch: str, **overrides):
    cfg = _module(arch).smoke()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
