"""granite-moe-3b-a800m [moe]: 32L d1536 24H GQA(kv=8) 40 experts top-8
(expert ff 512), v49155. [hf:ibm-granite]"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512), microbatches=2,
)


def smoke():
    return ModelConfig(
        name="granite-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64),
        remat="none", microbatches=1)
