"""internlm2-20b [dense]: 48L d6144 48H GQA(kv=8) ff16384 v92544.
[arXiv:2403.17297; hf]"""
import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=92544,
    rope_theta=1e6, microbatches=16, moment_dtype="int8",
    param_dtype=jnp.bfloat16,
)


def smoke():
    return ModelConfig(
        name="internlm2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
        rope_theta=1e6, remat="none", microbatches=1)
