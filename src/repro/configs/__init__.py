"""Assigned-architecture configs (+ the paper's own epiphany16 setup).

Each module exposes CONFIG (full size, dry-run only) and smoke() (reduced
same-family config that runs a real step on CPU).
"""
from .registry import ARCHS, get_config, smoke_config
