"""hubert-xlarge [audio]: encoder-only 48L d1280 16H ff5120, masked-unit
prediction over 504 clusters; conv feature extractor STUBBED (input_specs
provides frame embeddings). [arXiv:2106.07447]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120, vocab=504,
    causal=False, frontend="audio", microbatches=4,
)


def smoke():
    return ModelConfig(
        name="hubert-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=32,
        causal=False, frontend="audio", remat="none", microbatches=1)
