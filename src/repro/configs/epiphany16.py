"""The paper's own platform: 16 PEs on a 4x4 eMesh (Epiphany-III inside
the $99 Parallella).  Used by the paper-scale benchmark suite
(benchmarks/) and the alpha-beta model constants."""
from ..core.topology import epiphany3
from ..core import abmodel

TOPOLOGY = epiphany3()
N_PES = TOPOLOGY.n_pes          # 16
CLOCK_HZ = 600e6
PUT_LINK = abmodel.EPIPHANY_NOC
GET_LINK = abmodel.EPIPHANY_NOC_GET
# IPI-get interrupt service routine entry cost: ~60 clocks to vector
# into the ISR and decode the request.  The seed used 2e-7 s (120
# clocks), which double-counted entry+exit and pushed the modeled
# IPI-get turnover to 128 B where the paper measures 64 B; at 60 clocks
# the model reproduces the paper's crossover exactly (the gated
# ipi_get_turnover_B fidelity row).
ISR_ENTRY_S = 60 / CLOCK_HZ     # 1e-7 s
# message sizes swept in the paper's figures (bytes)
MSG_SIZES = [8 << i for i in range(12)]   # 8 B .. 16 KB
# paper-reported reference numbers, digitized from the figures/text —
# the values benchmarks/paper_fidelity.py gates model derivations
# against (tolerances + source figures live in its TABLE)
PAPER = {
    "put_peak_GBs": 2.4,          # Fig. 3 / text
    "get_peak_GBs": 0.24,         # Fig. 3: get saturates ~10x below put
    "get_put_ratio": 0.1,         # get ~10x slower
    "put_4096B_us": 1.8,          # Fig. 3, digitized 4 KB put latency
    "get_4096B_us": 17.2,         # Fig. 3, digitized 4 KB get latency
    "put_alpha_us": 0.1,          # Fig. 3, small-message latency intercept
    "elib_barrier_us": 2.0,
    "wand_barrier_us": 0.1,
    "dissem_barrier_us_16pe": 0.23,
    "bcast_GBs_over_log2N": 2.4,  # ~2.4/log2(N) GB/s
    "ipi_get_turnover_B": 64,
    "reduce_knee_B": 256,         # Fig. 8: work-array floor, 64 ints
}
