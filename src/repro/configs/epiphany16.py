"""The paper's own platform: 16 PEs on a 4x4 eMesh (Epiphany-III inside
the $99 Parallella).  Used by the paper-scale benchmark suite
(benchmarks/) and the alpha-beta model constants."""
from ..core.topology import epiphany3
from ..core import abmodel

TOPOLOGY = epiphany3()
N_PES = TOPOLOGY.n_pes          # 16
CLOCK_HZ = 600e6
PUT_LINK = abmodel.EPIPHANY_NOC
GET_LINK = abmodel.EPIPHANY_NOC_GET
# message sizes swept in the paper's figures (bytes)
MSG_SIZES = [8 << i for i in range(12)]   # 8 B .. 16 KB
# paper-reported reference numbers (for EXPERIMENTS.md comparisons)
PAPER = {
    "put_peak_GBs": 2.4,          # Fig. 3 / text
    "get_put_ratio": 0.1,         # get ~10x slower
    "elib_barrier_us": 2.0,
    "wand_barrier_us": 0.1,
    "dissem_barrier_us_16pe": 0.23,
    "bcast_GBs_over_log2N": 2.4,  # ~2.4/log2(N) GB/s
    "ipi_get_turnover_B": 64,
}
