"""h2o-danube-3-4b [dense]: 24L d3840 32H GQA(kv=8) ff10240 v32000,
llama+mistral mix with sliding-window attention. [arXiv:2401.16818]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, head_dim=120, d_ff=10240, vocab=32000,
    window=4096, microbatches=8,
)


def smoke():
    return ModelConfig(
        name="danube-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=128,
        window=16, remat="none", microbatches=1)
