"""deepseek-v3-671b [moe]: 61L d7168 128H MLA, 1 shared + 256 routed
top-8 experts (ff 2048), first 3 layers dense (ff 18432), MTP head,
v129280.  EP over the full (data x model) mesh, ZeRO-3 fsdp for the
dense trunk, int8 optimizer moments. [arXiv:2412.19437; hf]"""
import jax.numpy as jnp

from ..models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
    attn="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                  first_dense_layers=3, ep_over_data=True),
    mtp=True, fsdp=True, moment_dtype="int8", microbatches=16,
    param_dtype=jnp.bfloat16,   # 1.3 TB of experts: bf16 storage, f32
                                # optimizer math (deepseek itself used fp8)
)


def smoke():
    return ModelConfig(
        name="deepseek-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=160, vocab=128,
        attn="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                      first_dense_layers=1),
        mtp=True, remat="none", microbatches=1)
