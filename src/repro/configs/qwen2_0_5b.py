"""qwen2-0.5b [dense]: 24L d896 14H GQA(kv=2) ff4864 v151936, QKV bias,
tied embeddings. [arXiv:2407.10671; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, head_dim=64, d_ff=4864, vocab=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6, microbatches=4,
)


def smoke():
    return ModelConfig(
        name="qwen2-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=3, n_kv_heads=1, head_dim=16, d_ff=96, vocab=128,
        qkv_bias=True, tie_embeddings=True, remat="none", microbatches=1)
