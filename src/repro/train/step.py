"""Train-step builder: microbatched grad accumulation, heap-fused gradient
sync over the paper's collectives, AdamW update.

The whole step runs inside one shard_map.  Gradient synchronization packs
every data-replicated grad leaf onto one flat symmetric-heap buffer
(core/heap.py) before a single allreduce — the paper's small-message
alpha-amortization lesson applied to ~hundreds of gradient tensors — then
unpacks.  fsdp / EP-over-data leaves arrive pre-reduced and skip the sync
(parallel/sharding.needs_data_sync).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core import heap
from ..models import transformer
from ..models.config import ModelConfig
from ..parallel import sharding
from ..parallel.comm import AxisSpec, Comm
from . import optimizer as opt


def _split_microbatch(batch: dict, i, mb: int):
    def one(x):
        size = x.shape[0] // mb
        return lax.dynamic_slice_in_dim(x, i * size, size, axis=0)
    return jax.tree.map(one, batch)


BUCKET_BYTES = 64 * 1024 * 1024   # fusion bucket size (f32 elements)

# Above this much data-replicated gradient payload (f32 bytes),
# grad_rs="auto" switches the sync from single-shot allreduce to the
# bucketed ZeRO-style reduce-scatter + allgather (Comm.grad_sync_bucketed):
# the ring moves ~2x the payload instead of recursive doubling's log2(N)x,
# and bucket interleaving overlaps each allgather with the next
# reduce-scatter.  Below it the extra per-bucket alpha is not worth it.
GRAD_RS_AUTO_BYTES = 8 * 1024 * 1024


def fused_grad_sync(comm: Comm, grads, sync_mask, *, fuse: bool = True,
                    bucket_bytes: int = BUCKET_BYTES):
    """Mean-reduce grads over (pod x) data.  sync_mask marks leaves that
    are data-replicated; others pass through untouched.

    Fusion packs leaves onto flat symmetric-heap buffers in buckets of
    `bucket_bytes` — one collective per bucket instead of one per tensor
    (alpha amortization), while keeping each message small enough to
    pipeline.  With comm.grad_rs the buckets go through the bucketed
    reduce-scatter + allgather path (one interleaved issue for ALL
    buckets) instead of one allreduce each."""
    leaves, treedef = jax.tree.flatten(grads)
    mask = treedef.flatten_up_to(sync_mask)
    to_sync = [l for l, m in zip(leaves, mask) if m]
    if not to_sync:
        return grads
    if fuse:
        budget = bucket_bytes // 4
        buckets, cur, cur_n = [], [], 0
        for l in to_sync:
            if cur and cur_n + l.size > budget:
                buckets.append(cur)
                cur, cur_n = [], 0
            cur.append(l)
            cur_n += l.size
        if cur:
            buckets.append(cur)
        specs = [heap.plan_pack(b, dtype=jnp.float32) for b in buckets]
        bufs = [heap.pack(b, s) for b, s in zip(buckets, specs)]
        if comm.grad_rs and comm.backend == "shmem":
            outs = comm.grad_sync_bucketed(bufs, mean=True)
        else:
            outs = [comm.grad_sync(buf, mean=True) for buf in bufs]
        synced = []
        for out, s in zip(outs, specs):
            synced.extend(heap.unpack(out, s))
    else:
        synced = comm.grad_sync(to_sync, mean=True)
    synced = [s.astype(l.dtype) for s, l in zip(synced, to_sync)]
    it = iter(synced)
    out = [next(it) if m else l for l, m in zip(leaves, mask)]
    return treedef.unflatten(out)


def build_train_step(cfg: ModelConfig, axes: AxisSpec, backend: str,
                     adamw: opt.AdamWConfig | None = None,
                     fuse_grads: bool = True, allreduce_algo: str = "paper",
                     grad_rs: bool | str = False, pipeline_chunks=None,
                     topo=None, link=None, embedding=None, autotune=None,
                     profile=None):
    """Returns step(params, opt_state, batch) -> (loss, params, opt_state)
    to be wrapped in shard_map by the launcher.

    grad_rs: True forces the bucketed reduce-scatter + allgather gradient
    sync, False the single-shot allreduce, "auto" switches on it when the
    data-replicated gradient payload exceeds GRAD_RS_AUTO_BYTES (large
    models).  pipeline_chunks threads the chunked-schedule knob (int /
    "auto" / None) to every shmem allreduce in the step.  topo/link give
    the cost model the mesh to price against; with a 2D+ topo and
    allreduce_algo="auto", bucket syncs may take the hierarchical
    two-level allreduce over the mesh's row teams (DESIGN.md §11).
    embedding ("auto"/"snake"/an order, with topo) runs ring syncs in
    mesh-embedded coordinates — every ring hop one physical hop (§12).
    autotune is a measured-performance tuner (core.tuner.Tuner /
    TunedSelector): every "auto" selection in the step consults its
    tuning DB's measured-best variant before the analytic model
    (DESIGN.md §13); profile attaches a core.profile.Profiler so the
    selections the traced step makes are recorded."""
    adamw = adamw or opt.AdamWConfig(moment_dtype=cfg.moment_dtype)

    def step(params, opt_state, batch):
        rs = grad_rs
        if grad_rs == "auto":
            shapes_ = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            mask_ = sharding.needs_data_sync(cfg, shapes_)
            flat, tdef = jax.tree.flatten(shapes_)
            mflat = tdef.flatten_up_to(mask_)
            synced_bytes = sum(4 * int(np.prod(s.shape))
                               for s, m in zip(flat, mflat) if m)
            rs = synced_bytes >= GRAD_RS_AUTO_BYTES
        comm = Comm(axes, backend, allreduce_algo=allreduce_algo,
                    grad_rs=rs, pipeline_chunks=pipeline_chunks,
                    topo=topo, link=link, embedding=embedding,
                    tuner=autotune, profile=profile)
        # clamp grad-accumulation to the local batch (a bigger mesh shrinks
        # B_local; slicing zero-size microbatches would silently no-op)
        b_local = jax.tree.leaves(batch)[0].shape[0]
        mb = max(1, min(cfg.microbatches, b_local))
        while b_local % mb:
            mb -= 1

        def loss_fn(p, microbatch):
            return transformer.train_loss(comm, cfg, p, microbatch)

        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def acc_body(carry, i):
                loss_acc, g_acc = carry
                mbatch = _split_microbatch(batch, i, mb)
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + l, g_acc), ()
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = lax.scan(
                acc_body, (jnp.zeros(()), zeros), jnp.arange(mb))
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)

        # data-axis mean (fused on the symmetric heap); loss for logging
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        mask = sharding.needs_data_sync(cfg, shapes)
        grads = fused_grad_sync(comm, grads, mask, fuse=fuse_grads)
        for a in axes.grad_axes():
            loss = comm.allreduce(loss, a) / comm.axis_size(a)

        new_params, new_state = opt.apply_updates(params, grads, opt_state,
                                                  adamw)
        return loss, new_params, new_state

    return step


def build_eval_loss(cfg: ModelConfig, axes: AxisSpec, backend: str):
    def fn(params, batch):
        comm = Comm(axes, backend)
        return transformer.train_loss(comm, cfg, params, batch)
    return fn
