"""Train-step builder: microbatched grad accumulation, heap-fused gradient
sync over the paper's collectives, AdamW update.

The whole step runs inside one shard_map.  Gradient synchronization packs
every data-replicated grad leaf onto one flat symmetric-heap buffer
(core/heap.py) before a single allreduce — the paper's small-message
alpha-amortization lesson applied to ~hundreds of gradient tensors — then
unpacks.  fsdp / EP-over-data leaves arrive pre-reduced and skip the sync
(parallel/sharding.needs_data_sync).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core import heap
from ..models import transformer
from ..models.config import ModelConfig
from ..parallel import sharding
from ..parallel.comm import AxisSpec, Comm
from . import optimizer as opt


def _split_microbatch(batch: dict, i, mb: int):
    def one(x):
        size = x.shape[0] // mb
        return lax.dynamic_slice_in_dim(x, i * size, size, axis=0)
    return jax.tree.map(one, batch)


BUCKET_BYTES = 64 * 1024 * 1024   # fusion bucket size (f32 elements)

# Above this much data-replicated gradient payload (f32 bytes),
# grad_rs="auto" switches the sync from single-shot allreduce to the
# bucketed ZeRO-style reduce-scatter + allgather (Comm.grad_sync_bucketed):
# the ring moves ~2x the payload instead of recursive doubling's log2(N)x,
# and bucket interleaving overlaps each allgather with the next
# reduce-scatter.  Below it the extra per-bucket alpha is not worth it.
GRAD_RS_AUTO_BYTES = 8 * 1024 * 1024


def plan_fused_buckets(leaves, bucket_bytes: int = BUCKET_BYTES):
    """Greedy bucketing of param/grad leaves for the fused RS+Adam path:
    the same `bucket_bytes` budget as fused_grad_sync, additionally split
    at dtype changes — the fused allgather ships each bucket's UPDATED
    params at their own dtype, so a bucket must be dtype-uniform.
    Returns a list of leaf-index lists (deterministic: the optimizer
    state init and the step must agree on the plan)."""
    budget = bucket_bytes // 4
    buckets, cur, cur_n = [], [], 0
    for i, l in enumerate(leaves):
        if cur and (cur_n + l.size > budget
                    or l.dtype != leaves[cur[0]].dtype):
            buckets.append(cur)
            cur, cur_n = [], 0
        cur.append(i)
        cur_n += l.size
    if cur:
        buckets.append(cur)
    return buckets


def _wd_mask(spec, leaves):
    """int8 weight-decay element mask over a packed bucket: 1 where the
    element belongs to a >=2-D leaf (AdamW decays only those), 0 on 1-D
    leaves and the alignment gaps between leaves.  Static per plan."""
    mask = np.zeros(spec.total, np.int8)
    for leaf, off, shape in zip(leaves, spec.offsets, spec.shapes):
        if leaf.ndim >= 2:
            mask[off:off + int(np.prod(shape))] = 1
    return jnp.asarray(mask)


def init_fused_opt_state(params, n_data: int,
                         bucket_bytes: int = BUCKET_BYTES):
    """Optimizer state for grad_rs="fused": per bucket, this PE's OWNED
    moment chunks — shape (ceil(bucket_total/n_data),) — instead of
    full-tree moments.  Zero-initialized, so the same arrays are valid on
    every PE at step 0; after the first step each PE's chunks track only
    its owned 1/N of each bucket (they never ride the ring)."""
    leaves = jax.tree.leaves(params)
    state = []
    for idxs in plan_fused_buckets(leaves, bucket_bytes):
        spec = heap.plan_pack([leaves[i] for i in idxs], dtype=jnp.float32)
        chunk = -(-spec.total // n_data)
        state.append({"m": jnp.zeros((chunk,), jnp.float32),
                      "v": jnp.zeros((chunk,), jnp.float32)})
    return {"fused": state, "step": jnp.zeros((), jnp.int32)}


def fused_adam_sync(comm: Comm, params, grads, opt_state,
                    adamw: opt.AdamWConfig, sync_mask, *,
                    bucket_bytes: int = BUCKET_BYTES):
    """The fused gradient-sync + optimizer step (DESIGN.md §14): packs
    params and grads onto matching flat f32 buckets and runs
    Comm.grad_sync_fused_update — ring reduce-scatter with the final
    combine landing inside the combine+AdamW kernel, then an allgather of
    the updated params at param dtype.  Replaces BOTH fused_grad_sync and
    opt.apply_updates; bitwise equal to that composition (f32 moments).

    opt_state comes from init_fused_opt_state.  Every leaf must be
    data-replicated (fsdp/EP pre-reduced leaves have no full-bucket
    gradient to scatter) and moments must be f32 (the kernel's identity
    contract)."""
    assert adamw.moment_dtype == "f32", \
        "grad_rs='fused' requires f32 moments (bitwise kernel contract)"
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    mask = treedef.flatten_up_to(sync_mask)
    assert all(mask), \
        "grad_rs='fused' requires every param data-replicated"
    step_c = opt_state["step"] + 1
    t = step_c.astype(jnp.float32)
    c1 = 1.0 - adamw.b1 ** t
    c2 = 1.0 - adamw.b2 ** t
    buckets = plan_fused_buckets(leaves_p, bucket_bytes)
    g_bufs, p_bufs, wd_masks, out_dtypes, out_specs = [], [], [], [], []
    for idxs in buckets:
        pb = [leaves_p[i] for i in idxs]
        gb = [leaves_g[i] for i in idxs]
        spec32 = heap.plan_pack(pb, dtype=jnp.float32)
        g_bufs.append(heap.pack(gb, spec32))
        p_bufs.append(heap.pack(pb, spec32))
        wd_masks.append(_wd_mask(spec32, pb))
        out_dtypes.append(pb[0].dtype)
        # same shapes -> same element offsets: the param-dtype spec the
        # updated bucket unpacks with
        out_specs.append(heap.plan_pack(pb, dtype=pb[0].dtype))
    outs, new_moments = comm.grad_sync_fused_update(
        g_bufs, p_bufs, opt_state["fused"], wd_masks, c1, c2,
        lr=adamw.lr, b1=adamw.b1, b2=adamw.b2, eps=adamw.eps,
        wd_coef=adamw.weight_decay, out_dtypes=out_dtypes, mean=True)
    new_leaves = list(leaves_p)
    for idxs, out, spec in zip(buckets, outs, out_specs):
        for i, val in zip(idxs, heap.unpack(out, spec)):
            new_leaves[i] = val
    new_params = treedef.unflatten(new_leaves)
    return new_params, {"fused": new_moments, "step": step_c}


def fused_grad_sync(comm: Comm, grads, sync_mask, *, fuse: bool = True,
                    bucket_bytes: int = BUCKET_BYTES):
    """Mean-reduce grads over (pod x) data.  sync_mask marks leaves that
    are data-replicated; others pass through untouched.

    Fusion packs leaves onto flat symmetric-heap buffers in buckets of
    `bucket_bytes` — one collective per bucket instead of one per tensor
    (alpha amortization), while keeping each message small enough to
    pipeline.  With comm.grad_rs the buckets go through the bucketed
    reduce-scatter + allgather path (one interleaved issue for ALL
    buckets) instead of one allreduce each."""
    leaves, treedef = jax.tree.flatten(grads)
    mask = treedef.flatten_up_to(sync_mask)
    to_sync = [l for l, m in zip(leaves, mask) if m]
    if not to_sync:
        return grads
    if fuse:
        budget = bucket_bytes // 4
        buckets, cur, cur_n = [], [], 0
        for l in to_sync:
            if cur and cur_n + l.size > budget:
                buckets.append(cur)
                cur, cur_n = [], 0
            cur.append(l)
            cur_n += l.size
        if cur:
            buckets.append(cur)
        specs = [heap.plan_pack(b, dtype=jnp.float32) for b in buckets]
        bufs = [heap.pack(b, s) for b, s in zip(buckets, specs)]
        if comm.grad_rs and comm.backend == "shmem":
            outs = comm.grad_sync_bucketed(bufs, mean=True)
        else:
            outs = [comm.grad_sync(buf, mean=True) for buf in bufs]
        synced = []
        for out, s in zip(outs, specs):
            synced.extend(heap.unpack(out, s))
    else:
        synced = comm.grad_sync(to_sync, mean=True)
    synced = [s.astype(l.dtype) for s, l in zip(synced, to_sync)]
    it = iter(synced)
    out = [next(it) if m else l for l, m in zip(leaves, mask)]
    return treedef.unflatten(out)


def build_train_step(cfg: ModelConfig, axes: AxisSpec, backend: str,
                     adamw: opt.AdamWConfig | None = None,
                     fuse_grads: bool = True, allreduce_algo: str = "paper",
                     grad_rs: bool | str = False, pipeline_chunks=None,
                     topo=None, link=None, embedding=None, autotune=None,
                     profile=None):
    """Returns step(params, opt_state, batch) -> (loss, params, opt_state)
    to be wrapped in shard_map by the launcher.

    grad_rs: True forces the bucketed reduce-scatter + allgather gradient
    sync, False the single-shot allreduce, "auto" switches on it when the
    data-replicated gradient payload exceeds GRAD_RS_AUTO_BYTES (large
    models).  "fused" (shmem only) fuses the sync INTO the optimizer:
    ring reduce-scatter whose final combine lands inside the
    combine+AdamW kernel, then a param-dtype allgather of the updated
    params (DESIGN.md §14) — opt_state must come from
    init_fused_opt_state, every param data-replicated, f32 moments.  pipeline_chunks threads the chunked-schedule knob (int /
    "auto" / None) to every shmem allreduce in the step.  topo/link give
    the cost model the mesh to price against; with a 2D+ topo and
    allreduce_algo="auto", bucket syncs may take the hierarchical
    two-level allreduce over the mesh's row teams (DESIGN.md §11).
    embedding ("auto"/"snake"/an order, with topo) runs ring syncs in
    mesh-embedded coordinates — every ring hop one physical hop (§12).
    autotune is a measured-performance tuner (core.tuner.Tuner /
    TunedSelector): every "auto" selection in the step consults its
    tuning DB's measured-best variant before the analytic model
    (DESIGN.md §13); profile attaches a core.profile.Profiler so the
    selections the traced step makes are recorded."""
    adamw = adamw or opt.AdamWConfig(moment_dtype=cfg.moment_dtype)

    def step(params, opt_state, batch):
        rs = grad_rs
        if grad_rs == "auto":
            shapes_ = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            mask_ = sharding.needs_data_sync(cfg, shapes_)
            flat, tdef = jax.tree.flatten(shapes_)
            mflat = tdef.flatten_up_to(mask_)
            synced_bytes = sum(4 * int(np.prod(s.shape))
                               for s, m in zip(flat, mflat) if m)
            rs = synced_bytes >= GRAD_RS_AUTO_BYTES
        comm = Comm(axes, backend, allreduce_algo=allreduce_algo,
                    grad_rs=rs, pipeline_chunks=pipeline_chunks,
                    topo=topo, link=link, embedding=embedding,
                    tuner=autotune, profile=profile)
        # clamp grad-accumulation to the local batch (a bigger mesh shrinks
        # B_local; slicing zero-size microbatches would silently no-op)
        b_local = jax.tree.leaves(batch)[0].shape[0]
        mb = max(1, min(cfg.microbatches, b_local))
        while b_local % mb:
            mb -= 1

        def loss_fn(p, microbatch):
            return transformer.train_loss(comm, cfg, p, microbatch)

        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def acc_body(carry, i):
                loss_acc, g_acc = carry
                mbatch = _split_microbatch(batch, i, mb)
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + l, g_acc), ()
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = lax.scan(
                acc_body, (jnp.zeros(()), zeros), jnp.arange(mb))
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)

        # data-axis mean (fused on the symmetric heap); loss for logging
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        mask = sharding.needs_data_sync(cfg, shapes)
        for a in axes.grad_axes():
            loss = comm.allreduce(loss, a) / comm.axis_size(a)
        if rs == "fused" and backend == "shmem":
            # the sync IS the optimizer step (DESIGN.md §14): ring RS with
            # the final combine inside the AdamW kernel, params
            # allgathered updated; opt_state from init_fused_opt_state
            new_params, new_state = fused_adam_sync(
                comm, params, grads, opt_state, adamw, mask)
            return loss, new_params, new_state
        grads = fused_grad_sync(comm, grads, mask, fuse=fuse_grads)

        new_params, new_state = opt.apply_updates(params, grads, opt_state,
                                                  adamw)
        return loss, new_params, new_state

    return step


def build_eval_loss(cfg: ModelConfig, axes: AxisSpec, backend: str):
    def fn(params, batch):
        comm = Comm(axes, backend)
        return transformer.train_loss(comm, cfg, params, batch)
    return fn
