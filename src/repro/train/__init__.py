"""train subsystem."""
