"""AdamW in plain JAX, with optimizer-state compression.

Distributed-optimization tricks (DESIGN.md §8):
  * moment dtype f32 / bf16 / int8 — int8 moments use 128-element blockwise
    absmax scales (the symmetric-heap alignment unit), cutting optimizer
    HBM by 8x; required to fit deepseek-v3 on one pod.
  * states inherit the parameter sharding (ZeRO follows fsdp for free).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "f32"      # f32 | bf16 | int8


def _q_encode(x32, dtype: str, nonneg: bool = False):
    if dtype == "f32":
        return x32
    if dtype == "bf16":
        return x32.astype(jnp.bfloat16)
    # int8 blockwise absmax; non-negative tensors (second moments) are
    # stored in the sqrt domain, which linearizes their dynamic range
    # (bitsandbytes-style), else sqrt(v) quantization error wrecks the
    # AdamW denominator.
    flat = x32.reshape(-1)
    pad = (-flat.size) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    if nonneg:
        fp = jnp.sqrt(jnp.maximum(fp, 0.0))
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return {"q": q, "scale": scale}


def _q_decode(s, dtype: str, shape=None, nonneg: bool = False):
    if dtype == "f32":
        return s
    if dtype == "bf16":
        return s.astype(jnp.float32)
    flat = (s["q"].astype(jnp.float32) * s["scale"])
    if nonneg:
        flat = flat * flat
    flat = flat.reshape(-1)
    n = int(np.prod(shape)) if shape else 1
    return flat[:n].reshape(shape)


def init_state(params, cfg: AdamWConfig):
    def one(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return {"m": _q_encode(z, cfg.moment_dtype),
                "v": _q_encode(z, cfg.moment_dtype, nonneg=True)}
    return {"mv": jax.tree.map(one, params),
            "step": jnp.zeros((), jnp.int32)}


def apply_updates(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def one(p, g, mv):
        g32 = g.astype(jnp.float32)
        m = _q_decode(mv["m"], cfg.moment_dtype, p.shape)
        v = _q_decode(mv["v"], cfg.moment_dtype, p.shape, nonneg=True)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * upd).astype(p.dtype)
        return new_p, {"m": _q_encode(m, cfg.moment_dtype),
                       "v": _q_encode(v, cfg.moment_dtype, nonneg=True)}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mv = treedef.flatten_up_to(state["mv"])
    out = [one(p, g, mv) for p, g, mv in zip(flat_p, flat_g, flat_mv)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mv = treedef.unflatten([o[1] for o in out])
    return new_params, {"mv": new_mv, "step": step}
