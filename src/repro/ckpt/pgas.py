"""Async checkpointing ON the PGAS substrate (DESIGN.md §17).

The thread-based async save in :mod:`repro.ckpt.manager` is a host-side
workaround; the substrate the paper defines (arXiv:1608.03545 §3.2's
symmetric heap + arXiv:1604.04205's inter-processor DMA) already has the
right machinery: non-blocking ``put_nbi`` on a DEDICATED communication
context (``shmem_ctx_create``), ordered by the pending-op engine and
completed by ``ctx.quiet()`` only at the epoch boundary.

:class:`PgasCheckpointer` streams every PE's shard of the train state to
a gather PE as a chain of ring rotations (patterns need unique
destinations, so a direct all-to-one fan-in is illegal — the same
fcollect-style rotation the collectives use), overlapping the stream
with subsequent train steps:

    ck.begin(step, state)      # hand the descriptor chain to the engine
    ... more train steps ...   # the 'DMA engine' moves shards
    ck.drain()                 # epoch boundary: ctx.quiet() + write

Two overlap mechanisms compose:

  * per-context isolation (DESIGN.md §11): the rotations ride a PRIVATE
    context, so the train step's own collectives and quiet() calls never
    drain (or stall behind) checkpoint traffic;
  * asynchronous issue (``async_issue=True``, the default): ``begin()``
    only records the descriptor chain and wakes a dedicated worker
    thread — the SIM analogue of the e-DMA engine walking a descriptor
    list after one doorbell write.  The worker's eager XLA dispatches
    release the GIL, so the rotations execute concurrently with the
    train step's own device work; ``begin()`` itself costs microseconds
    (the <10% -of-sync-stall acceptance pin in ``bench_fault.py``).
    ``async_issue=False`` issues on the caller's thread — deterministic
    interleaving for the fault-injection tests.

SIM-oriented, like ``Tuner.tune``: leaves carry the leading PE axis and
the gather PE's rows are reconstructed host-side into global arrays at
drain, then written through the atomic :func:`repro.ckpt.manager.save`.
Leaves without a leading PE axis are treated as replicated, host-copied
at ``begin()`` (so later in-place mutation cannot corrupt the stream)
and written directly.

Fault semantics: the worker issues through the same ``Ctx.put_nbi``
retry/backoff engine as any other RMA, so injected link drops retry with
backoff and a dead PE raises :class:`~repro.core.fault.PEFailure` — the
error is captured by the in-flight task and re-raised at :meth:`drain`,
the stream's completion point.
"""
from __future__ import annotations

import pathlib
import threading

import jax
import numpy as np

from . import manager


class PgasCheckpointer:
    """Overlapped checkpoint stream on a dedicated PGAS context.

    shmem       : the :class:`~repro.core.shmem.ShmemContext` (SIM/NoC-SIM)
    ckpt_dir    : where :func:`repro.ckpt.manager.save` lands the result
    gather_pe   : the PE whose symmetric-heap region accumulates shards
    order       : ring order for the rotations (default: the topology's
                  snake embedding, so every rotation hop is one mesh hop)
    async_issue : True (default) issues the rotations on a dedicated
                  worker thread so ``begin()`` returns immediately;
                  False issues inline on the caller's thread
    """

    def __init__(self, shmem, ckpt_dir, gather_pe: int = 0, order=None,
                 async_issue: bool = True):
        self.shmem = shmem
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.gather_pe = int(gather_pe)
        self.async_issue = bool(async_issue)
        n = shmem.n_pes
        if order is None:
            topo = shmem.topo
            order = (topo.snake_order()
                     if topo is not None
                     and getattr(topo, "n_pes", None) == n
                     else tuple(range(n)))
        self.order = tuple(int(p) for p in order)
        if sorted(self.order) != list(range(n)):
            raise ValueError(f"order must be a permutation of 0..{n - 1}")
        # the dedicated context: checkpoint traffic gets its own pending
        # queue, invisible to the train step's quiet()/fence()
        self.ctx = shmem.ctx_create()
        self.fwd = self.ctx.compile(
            [(self.order[i], self.order[(i + 1) % n]) for i in range(n)])
        self._inflight = None
        self._worker: threading.Thread | None = None
        self._issued: dict[str, tuple] | None = None
        self._error: BaseException | None = None

    @property
    def pending(self) -> int:
        """Outstanding checkpoint rotations not yet completed — the
        dedicated context's pending-op queue depth."""
        return self.ctx.pending_count

    @property
    def in_flight(self) -> bool:
        """A begun checkpoint stream has not been drained yet."""
        return self._inflight is not None

    # -- the descriptor-chain walk (runs on the worker when async) -----------
    def _issue_all(self, work: list[tuple[str, object]]) -> None:
        n = self.shmem.n_pes
        try:
            out: dict[str, tuple] = {}
            for name, arr in work:
                cur, futs = arr, []
                for _ in range(1, n):
                    f = self.ctx.put_nbi(cur, self.fwd)
                    cur = f.value          # chained: rotation k feeds k+1
                    futs.append(f)
                out[name] = (arr, futs)
            self._issued = out
        except BaseException as e:          # surfaces at drain()
            self._error = e

    def _join_issue(self) -> dict[str, tuple]:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            self._inflight = None
            raise err
        issued, self._issued = self._issued, None
        return issued or {}

    def begin(self, step: int, state, meta: dict | None = None) -> int:
        """Queue the checkpoint stream for `state` WITHOUT completing it
        — returns immediately with the number of rotations the stream
        will issue.  A previous in-flight checkpoint is drained first (at
        most one epoch of overlap, like double-buffered DMA
        descriptors)."""
        if self._inflight is not None:
            self.drain()
        n = self.shmem.n_pes
        work: list[tuple[str, object]] = []
        replicated: list[tuple[str, np.ndarray]] = []
        for name, leaf in manager._leaf_paths(state):
            shp = getattr(leaf, "shape", ())
            if len(shp) >= 1 and shp[0] == n:
                work.append((name, leaf))
            else:
                replicated.append(
                    (name, np.array(jax.device_get(leaf))))
        self._inflight = (int(step), replicated, meta)
        if self.async_issue:
            self._worker = threading.Thread(
                target=self._issue_all, args=(work,), daemon=False)
            self._worker.start()
        else:
            self._issue_all(work)
        prof = getattr(self.shmem, "_active_profile", lambda: None)()
        if prof is not None:
            prof.count("ckpt.pgas_begin", 1)
        return len(work) * (n - 1)

    def drain(self) -> pathlib.Path | None:
        """Epoch boundary: join the issue worker, ``ctx.quiet()`` the
        dedicated context (the ONLY completion point of the stream),
        reconstruct the global arrays from the gather PE's accumulated
        rows, and write them through the atomic :func:`manager.save`.
        Returns the checkpoint path, or None when nothing is in flight.
        A fault captured by the stream (dead PE, unhealable link)
        re-raises here — the completion point."""
        if self._inflight is None:
            return None
        rotations = self._join_issue()
        step, replicated, meta = self._inflight
        self._inflight = None
        self.ctx.quiet()
        n = self.shmem.n_pes
        gp = self.gather_pe
        gi = self.order.index(gp)
        flat: dict[str, np.ndarray] = {}
        for name, (own, futs) in rotations.items():
            host_own = np.asarray(jax.device_get(own))
            out = np.empty_like(host_own)
            out[gp] = host_own[gp]                  # k=0: own shard
            for k, f in enumerate(futs, start=1):
                src = self.order[(gi - k) % n]      # k hops behind on ring
                out[src] = np.asarray(jax.device_get(f.value))[gp]
            flat[name] = out
        for name, arr in replicated:
            flat[name] = arr
        prof = getattr(self.shmem, "_active_profile", lambda: None)()
        if prof is not None:
            prof.count("ckpt.pgas_drain", 1,
                       float(sum(a.nbytes for a in flat.values())))
        return manager.save(self.ckpt_dir, step, flat, extra_meta=meta)


__all__ = ["PgasCheckpointer"]
