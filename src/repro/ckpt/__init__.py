"""ckpt subsystem."""
