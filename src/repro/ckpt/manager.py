"""Sharded checkpointing + fault tolerance + elastic re-sharding.

Design (DESIGN.md §8, §17), numpy-based (no orbax dependency):

  * save(): each param/opt leaf is written as a .npy under a temp dir,
    then atomically renamed into place — a crash mid-save never corrupts
    the latest checkpoint; a manifest records step, config hash, and the
    mesh the state was saved under.
  * restore(): loads into the CURRENT mesh; if the mesh changed (elastic
    shrink/grow after node failure) leaves are resharded host-side from
    the saved global arrays (save always materializes global views).
    Corruption surfaces as a typed :class:`CheckpointError` — a LATEST
    pointer at a deleted/partial dir falls back to the newest COMPLETE
    ``step-*`` dir, and a missing leaf name says which leaf, never a
    bare KeyError/FileNotFoundError.
  * FaultToleranceManager: step-deadline straggler detection (deterministic
    simulation hook on CPU), periodic async save, auto-resume.  Async
    saves snapshot device state to HOST before the thread spawns, so
    train steps mutating state mid-save cannot corrupt the checkpoint.
  * For checkpointing that overlaps the train step on the PGAS substrate
    itself (put_nbi streaming on a dedicated context), see
    :mod:`repro.ckpt.pgas`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import shutil
import threading
import time
from typing import Any, Callable

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint could not be resolved or is structurally incomplete
    (no complete step dir, dangling LATEST with no fallback, a manifest
    leaf the template needs that the checkpoint lacks)."""


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str | pathlib.Path, step: int, state: dict,
         extra_meta: dict | None = None) -> pathlib.Path:
    """Atomic checkpoint: write to <dir>/tmp-<step>, fsync, rename to
    <dir>/step-<step>, update LATEST last."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp-{step}"
    final = ckpt_dir / f"step-{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "leaves": [], **(extra_meta or {})}
    for name, leaf in _leaf_paths(state):
        arr = np.asarray(jax.device_get(leaf))
        fn = hashlib.md5(name.encode()).hexdigest()[:16] + ".npy"
        np.save(tmp / fn, arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (ckpt_dir / "LATEST.tmp").write_text(final.name)
    (ckpt_dir / "LATEST.tmp").rename(ckpt_dir / "LATEST")
    return final


def _is_complete(d: pathlib.Path) -> bool:
    """A step dir is COMPLETE when its manifest parses and every leaf
    file it names exists — a crash between leaf writes and the rename
    leaves only a tmp-* dir, but a crash between rename and LATEST (or a
    partial copy) can leave a step dir worth rejecting."""
    mf = d / "manifest.json"
    if not mf.exists():
        return False
    try:
        manifest = json.loads(mf.read_text())
    except (json.JSONDecodeError, OSError):
        return False
    return all((d / l["file"]).exists() for l in manifest.get("leaves", []))


def _complete_steps(ckpt_dir: pathlib.Path) -> list[pathlib.Path]:
    """All complete step-* dirs, newest first."""
    return sorted((d for d in ckpt_dir.glob("step-*")
                   if d.is_dir() and _is_complete(d)),
                  key=lambda d: d.name, reverse=True)


def _resolve_dir(ckpt_dir: str | pathlib.Path) -> pathlib.Path:
    """The step dir to restore from: LATEST when it points at a complete
    dir, else the newest complete ``step-*`` fallback (a crashed save or
    deleted dir leaves LATEST dangling); :class:`CheckpointError` when
    nothing complete exists."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    p = ckpt_dir / "LATEST"
    if p.exists():
        d = ckpt_dir / p.read_text().strip()
        if _is_complete(d):
            return d
    fallback = _complete_steps(ckpt_dir)
    if fallback:
        return fallback[0]
    raise CheckpointError(
        f"no complete checkpoint under {ckpt_dir}: LATEST is "
        f"{'dangling or partial' if p.exists() else 'absent'} and no "
        f"complete step-* dir exists to fall back to")


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    try:
        d = _resolve_dir(ckpt_dir)
    except CheckpointError:
        return None
    return json.loads((d / "manifest.json").read_text())["step"]


def restore(ckpt_dir: str | pathlib.Path, template: dict,
            shardings=None) -> tuple[int, dict]:
    """Restore into the current mesh.  `template` is a pytree of
    ShapeDtypeStructs or arrays (GLOBAL shapes); `shardings` optional
    matching tree of NamedSharding for device placement.  Elastic
    re-sharding falls out for free: saved arrays are global, jax.device_put
    splits them under the current mesh whatever its shape.

    Raises :class:`CheckpointError` (never a bare KeyError or
    FileNotFoundError) when no complete checkpoint exists or the resolved
    checkpoint lacks a leaf the template names."""
    d = _resolve_dir(ckpt_dir)
    manifest = json.loads((d / "manifest.json").read_text())
    by_name = {l["name"]: l for l in manifest["leaves"]}

    names = [n for n, _ in _leaf_paths(template)]
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for n, t, s in zip(names, leaves_t, shard_leaves):
        rec = by_name.get(n)
        if rec is None:
            have = ", ".join(sorted(by_name)[:8])
            raise CheckpointError(
                f"checkpoint {d.name} has no leaf {n!r} (template and "
                f"checkpoint disagree on state structure; checkpoint "
                f"holds: {have}{', ...' if len(by_name) > 8 else ''})")
        arr = np.load(d / rec["file"])
        if tuple(arr.shape) != tuple(t.shape):
            arr = _reshard(arr, tuple(t.shape), n)
        if s is not None:
            out.append(jax.device_put(arr, s))
        else:
            out.append(jax.device_put(arr))
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, out)


def _reshard(arr: np.ndarray, target: tuple[int, ...], name: str):
    """Elastic shape adaptation (same rank): tile or slice along changed
    dims — used when global shapes legitimately change (e.g. optimizer
    flat buffers after an mb change); params keep global shapes across
    mesh changes so this rarely triggers."""
    if arr.ndim != len(target):
        raise ValueError(f"{name}: rank change {arr.shape} -> {target}")
    for ax, (a, b) in enumerate(zip(arr.shape, target)):
        if a == b:
            continue
        if a > b:
            arr = np.take(arr, range(b), axis=ax)
        else:
            reps = [1] * arr.ndim
            reps[ax] = -(-b // a)
            arr = np.tile(arr, reps).take(range(b), axis=ax)
    return arr


@dataclasses.dataclass
class FaultToleranceManager:
    """Periodic checkpoints, straggler detection, restart bookkeeping."""

    ckpt_dir: str
    save_every: int = 100
    step_deadline_s: float = 600.0
    async_save: bool = True
    _last_t: float = dataclasses.field(default_factory=time.time)
    _pending: threading.Thread | None = None
    stragglers: list = dataclasses.field(default_factory=list)

    def on_step(self, step: int, state_fn: Callable[[], dict],
                meta: dict | None = None):
        """Call every train step.  state_fn is lazy so no host transfer
        happens unless a save fires."""
        now = time.time()
        dt = now - self._last_t
        self._last_t = now
        if dt > self.step_deadline_s:
            # straggler / hang: record; a real deployment would trigger
            # the elastic path (drop node, shrink data axis, resume)
            self.stragglers.append({"step": step, "stall_s": dt})
        if step > 0 and step % self.save_every == 0:
            # Snapshot to HOST before any thread exists: the train loop
            # donates/overwrites device buffers on the very next step,
            # and numpy leaves are mutated in place by test harnesses —
            # np.array(device_get(...)) pins the values this save means.
            state = jax.tree.map(
                lambda l: np.array(jax.device_get(l)), state_fn())
            if self.async_save:
                self._join()
                self._pending = threading.Thread(
                    target=save, args=(self.ckpt_dir, step, state),
                    kwargs={"extra_meta": meta}, daemon=False)
                self._pending.start()
            else:
                save(self.ckpt_dir, step, state, extra_meta=meta)

    def _join(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def finalize(self, step: int, state_fn: Callable[[], dict],
                 meta: dict | None = None):
        self._join()
        save(self.ckpt_dir, step, state_fn(), extra_meta=meta)

    def resume_step(self) -> int | None:
        return latest_step(self.ckpt_dir)
