"""parallel subsystem."""
