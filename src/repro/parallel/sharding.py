"""PartitionSpec rules for parameters, caches, and step inputs.

The param tree produced by models.transformer.init_params is mapped to
PartitionSpecs by leaf-path rules:

  * TP dims follow the local sizing in models/layers.py (q heads, FFN
    hidden, vocab, SSM heads over `model`);
  * replicated-over-model leaves (KV proj when n_kv < tp, MLA latents,
    routers, norms) get None there;
  * cfg.fsdp adds `data` on dim 0 of every 2-D block leaf (ZeRO-3),
    matching models.transformer._fsdp_gather;
  * MoE expert leaves are sharded over the EP group (model, or data+model
    when ep_over_data);
  * stacked-layer leading dims are unsharded (scanned).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig, SHAPES
from ..models import layers as L


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: str = "data"
    model: str | None = "model"   # None = dp_only (params replicated)
    pod: str | None = None


# leaf-name -> (model-sharded dims) base rules; dims index into the leaf
# shape *without* the stacked-layer prefix.
def _base_spec(path: tuple[str, ...], leaf, cfg: ModelConfig,
               ax: MeshAxes, tp: int) -> P:
    name = path[-1]
    in_moe = "moe" in path and "shared" not in path
    ep = ((ax.data, ax.model) if (cfg.moe and cfg.moe.ep_over_data)
          else ax.model)
    nd = leaf.ndim
    fsdp0 = cfg.fsdp and nd == 2 and "embed" not in path and name != "proj_mtp"

    def with_fsdp(spec_dims):
        dims = list(spec_dims)
        if fsdp0:
            d0 = dims[0]
            if d0 is None:
                dims[0] = ax.data
            elif isinstance(d0, tuple):
                dims[0] = d0 + (ax.data,)
            else:
                dims[0] = (d0, ax.data)
        return P(*dims)

    if in_moe and name in ("w_gate", "w_up", "w_down"):
        return P(ep, None, None)
    if name == "router":
        return with_fsdp((None, None))
    if name in ("wq", "w_gate", "w_up", "wq_b", "wkv_b", "w_in", "conv_w"):
        return with_fsdp((None, ax.model))
    if name in ("wo", "w_down", "w_out"):
        return with_fsdp((ax.model, None))
    if name in ("wk", "wv"):
        # replicated when kv heads don't divide tp (gathered per q head)
        _, _, repl = L._gqa_dims(cfg, tp)
        return with_fsdp((None, None) if repl else (None, ax.model))
    if name in ("bk", "bv"):
        _, _, repl = L._gqa_dims(cfg, tp)
        return P(None) if repl else P(ax.model)
    if name in ("bq", "a_log", "dt_bias", "d_skip", "norm_w", "conv_b"):
        return P(ax.model)
    if name in ("wq_a", "wkv_a", "proj"):
        return with_fsdp((None, None))
    if name == "table":
        return P(ax.model, None)
    if name == "head":
        return P(None, ax.model)
    if name in ("q_norm", "kv_norm", "ln", "ln1", "ln2", "final_norm"):
        return P(None)
    if nd == 1:
        return P(None)
    raise ValueError(f"no sharding rule for param {'/'.join(path)}")


_STACKED = ("layers", "dense_layers", "pairs", "local", "global")


def _is_stacked(path: tuple[str, ...]) -> bool:
    return any(p in _STACKED for p in path[:-1])


def param_specs(cfg: ModelConfig, params_shape: Any, ax: MeshAxes,
                tp: int):
    """Specs tree matching init_params output (pass a shape tree from
    jax.eval_shape)."""
    def one(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        base = _base_spec(path, leaf, cfg, ax, tp)
        if _is_stacked(path):
            return P(*((None,) + tuple(base)))
        return base
    return jax.tree_util.tree_map_with_path(one, params_shape)


def is_fsdp_leaf(cfg: ModelConfig, path: tuple[str, ...], nd_eff: int) -> bool:
    """The single fsdp predicate shared by specs, init localization, and
    gradient-sync masking (must mirror transformer._fsdp_gather)."""
    return cfg.fsdp and nd_eff == 2 and "embed" not in path


def fsdp_localize(cfg: ModelConfig, params_shape: Any, dp: int):
    """init_params produces model-local/data-full leaves; divide dim0 of
    fsdp leaves by dp to get the true per-chip local shapes."""
    def one(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        stacked = _is_stacked(path)
        nd_eff = leaf.ndim - (1 if stacked else 0)
        if not is_fsdp_leaf(cfg, path, nd_eff):
            return leaf
        dim = 1 if stacked else 0
        shape = list(leaf.shape)
        assert shape[dim] % dp == 0, (path, leaf.shape, dp)
        shape[dim] //= dp
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def fsdp_shard_init(cfg: ModelConfig, params: Any, data_rank, dp: int):
    """Slice freshly-initialized (data-full) fsdp leaves down to this
    chip's shard — used inside shard_map by the init fn."""
    import jax.lax as lax

    def one(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        stacked = _is_stacked(path)
        nd_eff = leaf.ndim - (1 if stacked else 0)
        if not is_fsdp_leaf(cfg, path, nd_eff):
            return leaf
        dim = 1 if stacked else 0
        size = leaf.shape[dim] // dp
        return lax.dynamic_slice_in_dim(leaf, data_rank * size, size,
                                        axis=dim)
    return jax.tree_util.tree_map_with_path(one, params)


def needs_data_sync(cfg: ModelConfig, params_shape: Any):
    """Bool tree: which grad leaves are replicated over `data` and need
    grad_sync.  fsdp 2-D leaves and EP-over-data expert leaves arrive
    already reduced/sharded."""
    def one(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        nd_eff = leaf.ndim - (1 if _is_stacked(path) else 0)
        in_moe = "moe" in path and "shared" not in path
        if in_moe and path[-1] in ("w_gate", "w_up", "w_down") \
                and cfg.moe.ep_over_data:
            return False
        if cfg.fsdp and nd_eff == 2 and "embed" not in path:
            return False
        return True
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# cache + batch specs
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, cache_shape: Any, ax: MeshAxes,
                seq_shards: int = 1):
    """Decode caches: batch over data (or sequence over data when
    seq_shards > 1), heads/latents over model where applicable."""
    def one(kp, leaf):
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        name = path[-1]
        batch_dim = ax.data if seq_shards == 1 else None
        seq_dim = None if seq_shards == 1 else ax.data
        if name in ("k", "v"):          # (layers, B, S, H_local, hd)
            return P(None, batch_dim, seq_dim, ax.model, None)
        if name in ("c_kv", "k_rope"):   # (layers, B, S, r) — model-repl.
            return P(None, batch_dim, seq_dim, None)
        if name == "conv":               # (layers, B, w, conv_local)
            return P(None, batch_dim, None, ax.model)
        if name == "ssm":                # (layers, B, H_local, P, N)
            return P(None, batch_dim, ax.model, None, None)
        raise ValueError(f"no cache rule for {'/'.join(path)}")
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_specs(cfg: ModelConfig, batch: dict, ax: MeshAxes,
                kind: str, seq_shards: int = 1) -> dict:
    """Input sharding: global batch over (pod, data); decode positions
    replicated over model.  When the cache is sequence-sharded
    (seq_shards > 1, long-context decode with tiny batch) the token batch
    is replicated instead."""
    ddims = (ax.data,) if ax.model is not None else (ax.data, "model")
    if ax.pod:
        ddims = (ax.pod,) + ddims
    bdim = None if seq_shards > 1 else \
        (ddims if len(ddims) > 1 else ddims[0])
    out = {}
    for k, v in batch.items():
        if k in ("tokens", "targets"):
            out[k] = P(bdim, None)
        elif k == "positions":
            out[k] = P(bdim)
        elif k in ("frames", "frontend_embeds"):
            out[k] = P(bdim, None, None)
        else:
            raise ValueError(k)
    return out
