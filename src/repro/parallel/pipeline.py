"""Pipeline parallelism over the pod axis (GPipe schedule on shmem puts).

The physically honest mapping for multi-pod training: pipeline stages ==
pods, so the slow DCN links carry only stage-boundary activations (one
microbatch-sized put per tick) instead of gradient allreduces.  Layers
are sharded over `pod` on their stacked dim; every stage runs the same
shard_map code on its layer shard; microbatches flow stage-to-stage via
`ppermute` (the paper's put).  Autodiff reverses the schedule, yielding
the backward pipeline for free.

Scope: homogeneous dense/audio/vlm stacks (uniform scanned layers).
MoE/hybrid keep their EP/DP mappings (DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..models import layers as L
from ..models import transformer
from ..models.config import ModelConfig
from .comm import Comm


def supported(cfg: ModelConfig) -> bool:
    return cfg.family in ("dense", "vlm", "audio") \
        and not cfg.local_global_period


def pipeline_train_loss(comm: Comm, cfg: ModelConfig, params, batch, *,
                        pp_axis: str = "pod", n_micro: int | None = None):
    """GPipe forward+loss: params["layers"] leaves carry L/P layers per
    stage (sharded over pp_axis).  Returns token-mean loss (identical to
    transformer.train_loss up to microbatch boundaries)."""
    P = comm.axis_size(pp_axis)
    stage = comm.axis_index(pp_axis)
    tokens = batch.get("tokens")
    frames = batch.get("frames")
    targets = batch["targets"]
    B = targets.shape[0]
    n_micro = n_micro or max(P, 1)
    assert B % n_micro == 0
    mb = B // n_micro
    seq = targets.shape[1]
    positions = jnp.broadcast_to(jnp.arange(seq)[None], (mb, seq))

    def embed_micro(i):
        sl = lambda a: lax.dynamic_slice_in_dim(a, i * mb, mb, 0)
        if cfg.frontend == "audio":
            return sl(frames).astype(cfg.dtype)
        return transformer._embed_scaled(comm, cfg, params, sl(tokens))

    def my_layers(x):
        def step(x, bp):
            x, _ = transformer._attn_block(comm, cfg, bp, x, positions)
            return x, ()
        step = transformer._maybe_remat(cfg, step)
        x, _ = transformer._scan(cfg, step, x, params["layers"])
        return x

    fwd_perm = [(s, s + 1) for s in range(P - 1)]
    zero = jnp.zeros((mb, seq, cfg.d_model), cfg.dtype)
    n_ticks = n_micro + P - 1

    def tick(carry, t):
        x_in, loss_sum, tok_count = carry
        # stage 0 injects microbatch t (zeros once drained)
        inject = jnp.where(t < n_micro, 1, 0)
        x0 = jax.tree.map(
            lambda a, b: jnp.where((stage == 0) & (inject == 1), a, b),
            embed_micro(jnp.clip(t, 0, n_micro - 1)), x_in)
        y = my_layers(x0)
        # last stage finalizes microbatch m = t - (P - 1)
        m = t - (P - 1)
        valid = (m >= 0) & (m < n_micro)
        h = L.rms_norm(y, params["final_norm"])
        logits = L.lm_logits(comm, cfg, params["embed"], h)
        tgt = lax.dynamic_slice_in_dim(
            targets, jnp.clip(m, 0, n_micro - 1) * mb, mb, 0)
        tok_loss = L.sharded_xent(comm, cfg, logits, tgt)
        is_last = stage == P - 1
        contrib = jnp.where(valid & is_last, jnp.sum(tok_loss), 0.0)
        cnt = jnp.where(valid & is_last, tok_loss.size, 0)
        # ship activations to the next stage (the paper's put on DCN)
        x_next = lax.ppermute(y, pp_axis, fwd_perm) if P > 1 else y
        return (x_next, loss_sum + contrib, tok_count + cnt), ()

    (x_fin, loss_sum, tok_count), _ = lax.scan(
        tick, (zero, jnp.zeros(()), jnp.zeros((), jnp.int32)),
        jnp.arange(n_ticks))
    # loss lives on the last stage: share it (tree broadcast over pp)
    total = comm.allreduce(loss_sum, pp_axis)
    count = comm.allreduce(tok_count, pp_axis)
    return total / jnp.maximum(count, 1)
