"""Comm — the communication substrate switch: `shmem` (the paper) vs `xla`
(the eLib-analogue vendor baseline).

Every model/training communication goes through a Comm handle so the whole
framework can run on either substrate (`--comm shmem|xla`).  Axis roles:

  model  — tensor parallelism (activations allreduce/allgather, vocab-
           sharded loss reductions, MoE expert alltoall)
  data   — data parallelism (fused gradient buckets), sequence sharding of
           KV caches for long-context decode
  pod    — cross-pod DCN; hierarchical gradient reduction hoists the
           smallest number of largest messages onto it (DESIGN.md §8)

Inside shard_map only.  All shmem collectives are differentiable because
they are compositions of lax.ppermute (whose transpose is the reverse
permute) and arithmetic, so the backward pass automatically runs the
reversed communication schedule — the manual-TP backward comes for free.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..core import collectives as coll
from ..core import fusion as fusion_mod
from ..core import team as team_mod
from ..core.netops import SpmdNetOps
from ..core.topology import MeshTopology


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """Mesh axis names by role; tuples are flattened into one PE space.
    model=None disables tensor parallelism (dp_only strategy): the mesh's
    model axis then carries extra data parallelism."""
    data: str | tuple[str, ...] = "data"
    model: str | tuple[str, ...] | None = "model"
    pod: str | None = None

    def data_axes(self) -> tuple[str, ...]:
        d = self.data if isinstance(self.data, tuple) else (self.data,)
        return d

    def grad_axes(self) -> tuple[str, ...]:
        """Axes over which gradients are averaged (pod x data)."""
        return ((self.pod,) if self.pod else ()) + self.data_axes()


_UNSET = object()


class Comm:
    """Substrate-neutral collective surface used by models and training.

    tuning:
      allreduce_algo : "paper" (dissemination for pow2 / ring otherwise,
                       §3.6 verbatim) or "auto" (cost-model selection:
                       candidate Schedules priced with the alpha-beta
                       model on `topo` via `coll.choose_algorithm`;
                       beyond-paper, DESIGN.md §9).  When `topo` is a 2D+
                       mesh, "auto" also prices the hierarchical
                       two-level allreduce over the mesh's row teams
                       (DESIGN.md §11) and "hier" forces it
      topo           : MeshTopology the cost model prices hops against
                       (None = flat unit-hop network)
      link           : alpha-beta LinkModel "auto" prices with
                       (None = abmodel.ICI_V5E)
      grad_rs        : ZeRO-1 style reduce-scatter + allgather gradient
                       sync instead of allreduce (beyond-paper, §Perf P2)
      pipeline_chunks: chunked double-buffered schedule execution for
                       shmem allreduces (int, "auto" = cost-model pick,
                       None = monolithic; bit-identical either way,
                       DESIGN.md §10)
      embedding      : mesh-embedded ring collectives (DESIGN.md §12):
                       None = logical rings; "auto" prices snake/greedy
                       embeddings against the identity and runs the
                       winner; "snake" forces the topology's snake order.
                       Requires `topo`; rank remapping keeps every ring
                       hop a physical mesh hop and the hot link at load 1
                       where the mesh admits a Hamiltonian cycle
      tuner          : measured-performance autotuner (core.tuner.Tuner
                       or TunedSelector, DESIGN.md §13): every "auto"
                       selection consults the tuning DB's measured-best
                       variant first and falls back to the analytic
                       model on unmeasured points
      profile        : core.profile.Profiler; collective selections made
                       while the step traces land in its timeline
    """

    def __init__(self, axes: AxisSpec, backend: str = "shmem",
                 allreduce_algo: str = "paper", grad_rs: bool = False,
                 topo: MeshTopology | None = None, link=None,
                 pipeline_chunks=None, embedding=None, tuner=None,
                 profile=None):
        assert backend in ("shmem", "xla")
        assert allreduce_algo in ("paper", "auto", "rd", "ring", "ring_emb",
                                  "hier")
        self.axes = axes
        self.backend = backend
        self.allreduce_algo = allreduce_algo
        self.grad_rs = grad_rs
        self.topo = topo
        self.link = link
        self.pipeline_chunks = pipeline_chunks
        self.embedding = embedding
        # measured-performance autotuning (DESIGN.md §13): a
        # core.tuner.Tuner or TunedSelector whose DB the "auto" selectors
        # consult before the analytic model; misses fall back to pricing.
        self.tuner = tuner
        self._sel = tuner.selector() if hasattr(tuner, "selector") else tuner
        # attached profiler: selection decisions made while the step is
        # traced land in its timeline as "selection" samples (wall times
        # under tracing are staging times and are flagged as such).
        self.profile = profile
        self._partitions: dict[int, team_mod.TeamPartition | None] = {}

    def _prof(self):
        p = self.profile
        return p if (p is not None and p.enabled) else None

    # -- helpers -------------------------------------------------------------
    def _net(self, axis) -> SpmdNetOps:
        return SpmdNetOps(axis)

    def _topo_for(self, net) -> MeshTopology | None:
        """The configured topology, only when it actually describes this
        axis's PE space — pricing a pod/tp axis against the data-axis
        mesh would feed the selector meaningless hop/load costs."""
        if self.topo is not None and self.topo.n_pes == net.n_pes:
            return self.topo
        return None

    def _embedding_for(self, net):
        """The embedding knob is defined relative to `topo`; on axes the
        topology does not describe it is dropped (an explicit rank order
        would otherwise fail permutation validation against the wrong
        PE count)."""
        return self.embedding if self._topo_for(net) is not None else None

    def _partition_for(self, net) -> team_mod.TeamPartition | None:
        """The row-team partition of `topo` the hierarchical allreduce
        runs over (DESIGN.md §11) — only when the axis PE space IS the
        topology's PE space and the mesh has a second dimension to split;
        None otherwise (flat candidates only).  Cached per PE count so
        the partition's lift/complement caches survive across calls
        (teams/patterns are interned; partitions live here)."""
        got = self._partitions.get(net.n_pes, _UNSET)
        if got is not _UNSET:
            return got
        part = None
        if (self.topo is not None and len(self.topo.shape) >= 2
                and self.topo.n_pes == net.n_pes):
            part = team_mod.split_2d(team_mod.team_world(net.n_pes),
                                     self.topo, axis=-1)
            if part.n_teams <= 1 or part.size <= 1:
                part = None
        self._partitions[net.n_pes] = part
        return part

    def axis_size(self, axis) -> int:
        if axis is None or axis == ():
            return 1
        return int(lax.axis_size(axis))

    def axis_index(self, axis):
        if axis is None or axis == ():
            return jnp.zeros((), jnp.int32)
        return lax.axis_index(axis)

    # -- collectives ----------------------------------------------------------
    def allreduce(self, x, axis, op: str = "sum"):
        if axis is None or axis == ():
            return x
        if self.backend == "xla":
            if op == "sum":
                return jax.tree.map(lambda v: lax.psum(v, axis), x)
            if op == "max":
                return jax.tree.map(lambda v: lax.pmax(v, axis), x)
            if op == "min":
                return jax.tree.map(lambda v: lax.pmin(v, axis), x)
            raise NotImplementedError(op)
        net = self._net(axis)
        algo = None if self.allreduce_algo == "paper" else self.allreduce_algo
        part = self._partition_for(net) if algo in ("auto", "hier") else None
        if algo == "hier" and part is None:
            algo = "auto"       # no usable partition: flat candidates only
        return jax.tree.map(
            lambda v: coll.allreduce(net, v, op, algorithm=algo,
                                     topo=self._topo_for(net), link=self.link,
                                     pipeline_chunks=self.pipeline_chunks,
                                     partition=part,
                                     embedding=self._embedding_for(net),
                                     profile=self._prof(),
                                     tuner=self._sel), x)

    def allgather(self, x, axis, *, concat_axis: int = 0):
        if axis is None or axis == ():
            return x
        if self.backend == "xla":
            return lax.all_gather(x, axis, axis=concat_axis, tiled=True)
        net = self._net(axis)
        return coll.fcollect(net, x, axis=concat_axis,
                             topo=self._topo_for(net), link=self.link,
                             embedding=self._embedding_for(net),
                             profile=self._prof(), tuner=self._sel)

    def reduce_scatter(self, x, axis, *, op: str = "sum", scatter_axis: int = 0):
        if self.backend == "xla":
            return lax.psum_scatter(x, axis, scatter_dimension=scatter_axis,
                                    tiled=True)
        # shmem ring reduce-scatter runs on the flat view; lay the array out
        # so ring chunks coincide with scatter_axis blocks (no padding).
        net = self._net(axis)
        n = net.n_pes
        moved = jnp.moveaxis(x, scatter_axis, 0)
        assert moved.shape[0] % n == 0, (moved.shape, n)
        blk_shape = (moved.shape[0] // n,) + moved.shape[1:]
        own, _ = coll.reduce_scatter(net, moved, op)
        # ring RS leaves PE p holding block (p+1)%n; one rotation ships each
        # block to its home PE so PE i holds block i (psum_scatter layout).
        home = net.ppermute(own, [(p, (p + 1) % n) for p in range(n)])
        blk = home.reshape(blk_shape)
        return jnp.moveaxis(blk, 0, scatter_axis) if scatter_axis != 0 else blk

    def alltoall(self, x, axis, *, split_axis: int = 0, concat_axis: int = 0):
        if axis is None or axis == ():
            return x
        if self.backend == "xla":
            return lax.all_to_all(x, axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)
        assert split_axis == concat_axis, "shmem alltoall is in-place ragged"
        return coll.alltoall(self._net(axis), x, axis=split_axis,
                             profile=self._prof(), tuner=self._sel)

    def broadcast(self, x, axis, root: int = 0):
        if self.backend == "xla":
            # emulate with select + psum (XLA folds to a broadcast)
            idx = lax.axis_index(axis)
            masked = jax.tree.map(
                lambda v: jnp.where(idx == root, v, jnp.zeros_like(v)), x)
            return jax.tree.map(lambda v: lax.psum(v, axis), masked)
        return coll.broadcast(self._net(axis), x, root,
                              profile=self._prof(), tuner=self._sel)

    def ppermute(self, x, axis, perm):
        return lax.ppermute(x, axis, perm)

    # -- gradient synchronization (hierarchical over pod x data) -------------
    def grad_sync(self, grads, *, mean: bool = True):
        """Average gradients over the data(+pod) axes.

        shmem path: dissemination/ring allreduce per DESIGN; when a pod
        axis exists, reduce within pods first (ICI), then across pods
        (DCN) — fewest, largest messages on the slow links."""
        axes = self.axes
        dax = axes.data
        scale_n = 1
        for a in axes.grad_axes():
            scale_n *= self.axis_size(a)
        if self.backend == "xla":
            out = jax.tree.map(lambda g: lax.psum(g, axes.grad_axes()), grads)
        elif self.grad_rs:
            # ZeRO-1 flavored: bandwidth-optimal ring reduce-scatter, then
            # ring allgather — moves ~2x buffer instead of log2(N)x
            def one(g):
                net = self._net(dax)
                emb_team = coll.embedding_team(self._embedding_for(net),
                                               self._topo_for(net),
                                               net.n_pes, self.link)
                own, info = coll.reduce_scatter(net, g, "sum", team=emb_team)
                out = coll.allgather_unpad(net, own, info, team=emb_team)
                if axes.pod is not None:
                    out = self.allreduce(out, axes.pod)
                return out
            out = jax.tree.map(one, grads)
        else:
            out = self.allreduce(grads, dax)
            if axes.pod is not None:
                out = self.allreduce(out, axes.pod)
        if mean:
            out = jax.tree.map(lambda g: g / scale_n, out)
        return out

    def grad_sync_bucketed(self, buckets, *, mean: bool = True):
        """ZeRO-style bucketed gradient sync over the data(+pod) axes:
        every flat symmetric-heap bucket is ring reduce-scattered, then
        ring allgathered, with the two phases issued bucket-interleaved —
        all reduce-scatters first, then the allgathers — so bucket i's
        allgather has no dependency on bucket j>i's reduce-scatter and the
        'DMA engine' can fly them concurrently (the paper's put-overlap
        discipline applied at bucket granularity, DESIGN.md §10).

        This replaces the single-shot allreduce for large models: per
        bucket the wire cost drops from log2(N) full buffers (recursive
        doubling) to ~2x the buffer, and the bucket pipeline hides each
        allgather behind the next reduce-scatter.  Takes and returns a
        LIST of flat buckets (train/step.fused_grad_sync packs them).

        On a 2D+ `topo` with allreduce_algo "auto"/"hier", each bucket is
        priced against the hierarchical two-level schedule (DESIGN.md
        §11): buckets where keeping the bulk bytes on intra-row links
        beats the flat ring take `coll.allreduce_hier` instead of the
        flat reduce-scatter + allgather pair."""
        axes = self.axes
        scale_n = 1
        for a in axes.grad_axes():
            scale_n *= self.axis_size(a)
        if self.backend == "xla":
            out = [lax.psum(b, axes.grad_axes()) for b in buckets]
        else:
            net = self._net(axes.data)
            topo = self._topo_for(net)
            part = self._partition_for(net) \
                if self.allreduce_algo in ("auto", "hier") else None
            # flat buckets ride the ring in embedded coordinates when the
            # embedding knob is on (a covering team: same result, every
            # hop one physical hop — DESIGN.md §12)
            emb = self._embedding_for(net)
            emb_team = coll.embedding_team(emb, topo, net.n_pes, self.link)

            def _hier_wins(b) -> bool:
                if part is None:
                    return False
                if self.allreduce_algo == "hier":
                    return True
                # price hier against the ring schedule the flat path
                # actually executes below — EMBEDDED when the knob is on,
                # logical otherwise (never rd)
                nbytes = float(b.size * b.dtype.itemsize)
                t_hier = coll.allreduce_hier_schedule(
                    part, nbytes, topo=topo, link=self.link,
                    embedding=emb).time(topo, self.link)
                t_flat = coll.allreduce_schedule(
                    net.n_pes, nbytes,
                    "ring_emb" if emb_team is not None else "ring",
                    embedding=None if emb_team is None
                    else emb_team.members).time(topo, self.link)
                return t_hier < t_flat
            hier = [_hier_wins(b) for b in buckets]
            # phase 1: issue every flat bucket's reduce-scatter (pipeline
            # fill); hierarchical buckets run their own RS->cross->AG
            owned = [None if h
                     else coll.reduce_scatter(net, b, "sum", team=emb_team)
                     for b, h in zip(buckets, hier)]
            # phase 2: allgathers drain while later reduce-scatters fly
            out = [coll.allreduce_hier(net, b, "sum", partition=part,
                                       topo=topo, link=self.link,
                                       embedding=emb)
                   if h else coll.allgather_unpad(net, *own, team=emb_team)
                   for b, h, own in zip(buckets, hier, owned)]
            if axes.pod is not None:
                out = [self.allreduce(b, axes.pod) for b in out]
        if mean:
            out = [b / scale_n for b in out]
        return out

    def grad_sync_fused_update(self, g_bufs, p_bufs, moments, wd_masks,
                               c1, c2, *, lr: float, b1: float, b2: float,
                               eps: float, wd_coef: float, out_dtypes,
                               mean: bool = True):
        """grad_rs="fused" (DESIGN.md §14): the bucketed ring
        reduce-scatter of `grad_sync_bucketed` with the final combine of
        every bucket landing inside the k-ary combine+AdamW kernel
        (core/fusion.fused_rs_adam) — the full gradient is never
        materialized, and the allgather ships the UPDATED PARAM chunk at
        param dtype instead of the f32 gradient.

        g_bufs/p_bufs: flat f32 gradient and param buckets (matching
        heap PackSpecs); moments: per-bucket {"m", "v"} OWNED chunks,
        shape (ceil(total/n),); wd_masks: per-bucket int8 weight-decay
        element masks; c1/c2: traced 1-beta**t scalars; out_dtypes: the
        per-bucket param dtype the allgather ships.  Two-phase issue like
        grad_sync_bucketed: every bucket's RS+update first, then the
        allgathers drain.  Returns (updated full param buckets, updated
        moment chunks).  Bitwise equal to
        grad_sync_bucketed-then-apply_updates (f32 moments); no pod axis
        (a pre-reduce over DCN would reorder the summation)."""
        axes = self.axes
        assert self.backend == "shmem", "fused grad sync is shmem-only"
        assert axes.pod is None, \
            "grad_rs='fused' does not support a pod axis"
        scale_n = 1
        for a in axes.grad_axes():
            scale_n *= self.axis_size(a)
        net = self._net(axes.data)
        emb = self._embedding_for(net)
        emb_team = coll.embedding_team(emb, self._topo_for(net),
                                       net.n_pes, self.link)
        prof = self._prof()
        scale = float(scale_n) if mean else 1.0
        # phase 1: every bucket's reduce-scatter + fused optimizer update
        parts = [fusion_mod.fused_rs_adam(
                     net, g, p, mv["m"], mv["v"], w, c1, c2, lr=lr, b1=b1,
                     b2=b2, eps=eps, wd_coef=wd_coef, scale=scale,
                     out_dtype=dt, team=emb_team, profile=prof)
                 for g, p, mv, w, dt in zip(g_bufs, p_bufs, moments,
                                            wd_masks, out_dtypes)]
        # phase 2: allgathers of the updated param chunks drain together
        outs = [coll.allgather_unpad(net, pc, info, team=emb_team)
                for pc, _, _, info in parts]
        new_moments = [{"m": m, "v": v} for _, m, v, _ in parts]
        return outs, new_moments
