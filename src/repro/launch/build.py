"""Step-assembly helpers shared by train.py / serve.py / dryrun.py.

Everything needed to go from (arch config, mesh, comm backend) to jitted,
shard_mapped, correctly-sharded step functions — including abstract
(eval_shape) parameter/optimizer/cache trees for the dry-run path where
nothing is ever allocated.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import transformer
from ..models.config import ModelConfig, SHAPES, input_specs
from ..parallel import sharding
from ..parallel.comm import AxisSpec
from ..serve import step as sstep
from ..train import optimizer as opt
from ..train import step as tstep


def mesh_dims(mesh) -> tuple[int, int, int | None]:
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    return d["data"], d["model"], d.get("pod")


def axis_spec(mesh, cfg=None) -> AxisSpec:
    pod = "pod" if "pod" in mesh.axis_names else None
    if cfg is not None and cfg.shard_strategy == "dp_only":
        return AxisSpec(model=None, pod=pod)
    return AxisSpec(pod=pod)


def mesh_axes(mesh, cfg=None) -> sharding.MeshAxes:
    pod = "pod" if "pod" in mesh.axis_names else None
    if cfg is not None and cfg.shard_strategy == "dp_only":
        return sharding.MeshAxes(model=None, pod=pod)
    return sharding.MeshAxes(pod=pod)


def eff_tp(cfg: ModelConfig, mesh) -> int:
    return 1 if cfg.shard_strategy == "dp_only" else mesh_dims(mesh)[1]


def abstract_params(cfg: ModelConfig, mesh):
    dp, _tp, pod = mesh_dims(mesh)
    tp = eff_tp(cfg, mesh)
    shapes = jax.eval_shape(lambda k: transformer.init_params(
        k, cfg, tp, dp), jax.random.key(0))
    shapes = sharding.fsdp_localize(cfg, shapes, dp)
    specs = sharding.param_specs(cfg, shapes, mesh_axes(mesh, cfg), tp)
    return shapes, specs


def global_shape(local_shape_tree, spec_tree, mesh):
    """Local (per-chip) ShapeDtypeStructs -> global ones, per the specs."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s, spec):
        shape = list(s.shape)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            for a in axs:
                shape[i] *= sizes[a]
        return jax.ShapeDtypeStruct(tuple(shape), s.dtype)

    return jax.tree.map(one, local_shape_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def shard_mapped(fn, mesh, in_specs, out_specs):
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def make_init_fn(cfg: ModelConfig, mesh, backend: str = "shmem"):
    """Jittable global param init: per-chip shards initialized inside
    shard_map.  All chips use the same key, so replicated leaves (KV proj,
    norms, routers) are bitwise identical everywhere and sharded leaves
    are consistent shard-local draws."""
    dp, _tp, _pod = mesh_dims(mesh)
    tp = eff_tp(cfg, mesh)
    shapes, specs = abstract_params(cfg, mesh)

    def init(key):
        import jax.lax as lax
        p = transformer.init_params(key, cfg, tp, dp)
        if cfg.fsdp:
            p = sharding.fsdp_shard_init(cfg, p, lax.axis_index("data"), dp)
        return p

    return shard_mapped(init, mesh, (P(),), specs), shapes, specs


def make_train_step(cfg: ModelConfig, mesh, backend: str = "shmem",
                    fuse_grads: bool = True, allreduce_algo: str = "paper",
                    grad_rs: bool | str = False, pipeline_chunks=None,
                    topo=None, link=None, embedding=None, autotune=None,
                    profile=None):
    dp, tp, pod = mesh_dims(mesh)
    axes = axis_spec(mesh, cfg)
    shapes, pspecs = abstract_params(cfg, mesh)
    ocfg = opt.AdamWConfig(moment_dtype=cfg.moment_dtype)
    ostate_shapes = jax.eval_shape(lambda p: opt.init_state(p, ocfg), shapes)
    ospecs = jax.tree.map(lambda _: P(), ostate_shapes)
    # moment states follow param sharding (q/scale leaves share dim 0)
    ospecs = _opt_specs(ostate_shapes, pspecs, ocfg)
    step = tstep.build_train_step(cfg, axes, backend, adamw=ocfg,
                                  fuse_grads=fuse_grads,
                                  allreduce_algo=allreduce_algo,
                                  grad_rs=grad_rs,
                                  pipeline_chunks=pipeline_chunks,
                                  topo=topo, link=link,
                                  embedding=embedding, autotune=autotune,
                                  profile=profile)
    bspecs_fn = lambda batch: sharding.batch_specs(
        cfg, batch, mesh_axes(mesh, cfg), "train")
    def wrap(batch_tree):
        bs = bspecs_fn(batch_tree)
        return shard_mapped(step, mesh, (pspecs, ospecs, bs),
                            (P(), pspecs, ospecs))
    return wrap, (shapes, pspecs), (ostate_shapes, ospecs), ocfg


def _opt_specs(ostate_shapes, pspecs, ocfg):
    """Moments inherit the param spec (f32/bf16); int8 states are flat
    blockwise (q, scale) pairs and stay chip-local (P())."""
    def per_param(pspec):
        if ocfg.moment_dtype in ("f32", "bf16"):
            return {"m": pspec, "v": pspec}
        rep = {"q": P(), "scale": P()}
        return {"m": rep, "v": rep}

    mv = jax.tree.map(per_param, pspecs,
                      is_leaf=lambda x: isinstance(x, P))
    return {"mv": mv, "step": P()}


def make_serve_steps(cfg: ModelConfig, mesh, shape_name: str,
                     backend: str = "shmem"):
    """(prefill_fn, decode_fn, cache_shapes, cache_specs) for a shape."""
    import dataclasses
    cfg = dataclasses.replace(cfg, fsdp=False)   # serving never fsdp
    dp, tp, pod = mesh_dims(mesh)
    axes = axis_spec(mesh)
    shapes, pspecs = abstract_params(cfg, mesh)
    s = SHAPES[shape_name]
    B, Lc = s["global_batch"], s["seq_len"]
    data_total = dp * (pod or 1)
    seq_shards = 1
    if s["kind"] == "decode" and B < data_total:
        # tiny-batch long-context: shard the cache sequence over data
        seq_shards = dp
    batch_local = B // data_total if seq_shards == 1 else B
    if s["kind"] == "decode":
        cache_shapes = jax.eval_shape(
            lambda: transformer.init_cache(cfg, tp, batch_local,
                                           Lc, seq_shards))
        cspecs = sharding.cache_specs(cfg, cache_shapes, mesh_axes(mesh),
                                      seq_shards)
    else:  # prefill / encoder forward: no decode cache exists
        cache_shapes, cspecs = None, None
    prefill = sstep.build_prefill(cfg, axes, backend)
    decode = sstep.build_decode_step(cfg, axes, backend, seq_shards)

    bdim = ("pod", "data") if pod else "data"

    def wrap_prefill(batch_tree):
        bs = sharding.batch_specs(cfg, batch_tree, mesh_axes(mesh),
                                  "prefill")
        return shard_mapped(prefill, mesh, (pspecs, bs),
                            P(bdim, None, "model"))

    def wrap_decode(batch_tree):
        bs = sharding.batch_specs(cfg, batch_tree, mesh_axes(mesh),
                                  "decode", seq_shards)
        logits_spec = P(None if seq_shards > 1 else
                        (("pod", "data") if pod else "data"), None, "model")
        return shard_mapped(decode, mesh, (pspecs, cspecs, bs),
                            (logits_spec, cspecs))

    return wrap_prefill, wrap_decode, (cache_shapes, cspecs), \
        (shapes, pspecs), seq_shards
