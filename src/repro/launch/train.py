"""Training launcher: real steps on whatever mesh fits this host, with
checkpoint/restart, straggler hooks, and elastic resume.

  python -m repro.launch.train --arch qwen2-0.5b --steps 50 --smoke \
         --data 1 --model 1 --ckpt-dir /tmp/ckpt --resume auto
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--pod", type=int, default=0)
    ap.add_argument("--comm", default="shmem", choices=["shmem", "xla"])
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--step-deadline", type=float, default=600.0,
                    help="per-step straggler deadline (seconds): a step "
                         "exceeding it is recorded as a straggler event "
                         "— the detection edge of the elastic restart "
                         "protocol (DESIGN §17)")
    ap.add_argument("--ckpt-async", default="on", choices=["on", "off"],
                    help="off: periodic saves block the train loop "
                         "(sync); on: saves snapshot to host and "
                         "serialize on a background thread (DESIGN §17)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--allreduce-algo", default="paper",
                    choices=["paper", "auto"],
                    help="paper: the paper's PE-count switch; auto: adds "
                         "the >=1MiB ring switch (EXPERIMENTS §Perf P2)")
    ap.add_argument("--grad-rs", default="off",
                    choices=["off", "on", "auto"],
                    help="bucketed ZeRO-style reduce-scatter+allgather "
                         "gradient sync; auto switches on above "
                         "GRAD_RS_AUTO_BYTES of synced grads (DESIGN §10)")
    ap.add_argument("--pipeline-chunks", default=None,
                    help="chunked double-buffered collective execution: "
                         "an int, or 'auto' for the cost-model pick "
                         "(DESIGN §10)")
    ap.add_argument("--embedding", default="off",
                    choices=["off", "auto", "snake"],
                    help="mesh-embedded ring collectives over the data "
                         "mesh (DESIGN §12): 'snake' runs rings in snake "
                         "coordinates, 'auto' prices embeddings against "
                         "the logical ring and runs the winner")
    ap.add_argument("--topo", default=None,
                    help="physical layout of the DATA axis as RxC "
                         "(non-torus 2D mesh, e.g. 4x4); gives the cost "
                         "model (--allreduce-algo auto, --embedding) real "
                         "hop/contention costs. Without it, --embedding "
                         "falls back to a near-square guess")
    ap.add_argument("--autotune", action="store_true",
                    help="measured-performance selection (DESIGN §13): "
                         "calibrate the data-axis mesh with a small SIM "
                         "sweep when the tuning DB has no entries for it, "
                         "then let every 'auto' selection consult the "
                         "measured-best variant before the analytic model")
    ap.add_argument("--tuning-db", default="",
                    help="path of the persistent tuning database (JSON); "
                         "loaded when it exists, saved after the run — a "
                         "training run warms it, later runs inherit the "
                         "measured-best picks")
    ap.add_argument("--profile-out", default="",
                    help="attach the pcontrol-style runtime profiler and "
                         "dump its JSON (counters + per-op/step timeline) "
                         "to this path at exit (DESIGN §13)")
    ap.add_argument("--trace-out", default="",
                    help="attach the distributed tracer (DESIGN §16) and "
                         "dump a Chrome trace-event JSON here at exit "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="record per-step wall-time histogram + loss "
                         "gauge and dump the registry JSON here at exit")
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "full", "selective"],
                    help="override the config remat policy (§Perf P5)")
    ap.add_argument("--shard-strategy", default=None,
                    choices=[None, "tp", "dp_only"],
                    help="dp_only replicates params and uses the model "
                         "axis as extra DP (§Perf P6)")
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_config
    from ..ckpt import manager as ckpt
    from ..data.pipeline import SyntheticLM
    from ..train import optimizer as opt
    from . import build
    from .mesh import make_mesh

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    over = {}
    if args.remat:
        over["remat"] = args.remat
    if args.shard_strategy:
        over["shard_strategy"] = args.shard_strategy
    if over:
        cfg = dataclasses.replace(cfg, **over)
    mesh = make_mesh(args.data, args.model, args.pod or None)
    pipe = SyntheticLM(
        cfg.vocab, args.seq_len, args.batch,
        frames_dim=cfg.d_model if cfg.frontend == "audio" else None,
        frontend_tokens=(cfg.n_frontend_tokens
                         if cfg.frontend == "vision" else 0))

    with jax.set_mesh(mesh):
        grad_rs = {"off": False, "on": True, "auto": "auto"}[args.grad_rs]
        chunks = args.pipeline_chunks
        if chunks is not None and chunks != "auto":
            chunks = int(chunks)
        embedding = None if args.embedding == "off" else args.embedding
        topo = None
        if args.topo:
            # the operator states the data axis's physical layout — use it
            # for ALL topology-aware selection (hier, embeddings, pricing)
            from ..core.topology import MeshTopology
            shape = tuple(int(p) for p in args.topo.lower().split("x"))
            if int(np.prod(shape)) != args.data:
                raise SystemExit(f"--topo {args.topo} covers "
                                 f"{int(np.prod(shape))} PEs but the data "
                                 f"axis has {args.data}")
            topo = MeshTopology(shape, torus=(False,) * len(shape))
        elif embedding is not None and not args.pod:
            # mesh-embedded rings need a physical layout to embed into:
            # fall back to a near-square non-torus guess for the DATA
            # axis (the Epiphany-style NoC the cost model prices).  With
            # a pod axis the Comm topo would also price pod-axis
            # collectives against this data-axis layout — skip rather
            # than feed the selector a mesh that describes another axis.
            from ..core.topology import MeshTopology
            d, r = args.data, int(args.data ** 0.5)
            while r > 1 and d % r:
                r -= 1
            shape = (r, d // r) if r > 1 else (d,)
            topo = MeshTopology(shape, torus=(False,) * len(shape))
            print(f"[train] --embedding without --topo: assuming data-axis "
                  f"layout {'x'.join(map(str, shape))} (pass --topo to "
                  f"state the real one)")
        elif embedding is not None:
            print("[train] --embedding ignored: with --pod, pass --topo "
                  "to state the data-axis layout explicitly")
            embedding = None
        profiler = None
        if args.trace_out:
            from ..core.trace import LEVEL_FULL, Tracer
            profiler = Tracer(level=LEVEL_FULL)
        elif args.profile_out:
            from ..core.profile import Profiler
            profiler = Profiler(level=2)
        metrics = None
        if args.metrics_out:
            from ..serve.metrics import MetricsRegistry
            metrics = MetricsRegistry()
        tuner = None
        if args.autotune or args.tuning_db:
            from ..core import sim_ctx
            from ..core import tuner as tuner_mod
            tuner = tuner_mod.Tuner(path=args.tuning_db or None)
            if args.autotune and args.data > 1:
                # warm the DB for the data-axis mesh when it holds no
                # measurements for this fingerprint yet: a small SIM
                # sweep on this host — the SPMD step then inherits the
                # measured-best picks by topology fingerprint (§13)
                fp = tuner_mod.fingerprint(topo, args.data)
                if not any(k.startswith(fp + "|")
                           for k in tuner.db.entries):
                    print(f"[train] autotune: calibrating {fp} "
                          "(small SIM sweep)")
                    summary = tuner.tune(
                        sim_ctx(args.data, topo),
                        {"collectives": ("allreduce",),
                         "sizes": (4096, 65536, 1 << 20),
                         "chunks": (1, 4), "iters": 3, "warmup": 1})
                    print(f"[train] autotune: measured "
                          f"{summary['variants']} variants; best "
                          f"{summary['best']}")
        init_fn, pshapes, pspecs = build.make_init_fn(cfg, mesh)
        wrap, _, (oshapes, ospecs), ocfg = build.make_train_step(
            cfg, mesh, args.comm, allreduce_algo=args.allreduce_algo,
            grad_rs=grad_rs, pipeline_chunks=chunks,
            topo=topo, embedding=embedding,
            autotune=tuner if args.autotune else None, profile=profiler)
        ocfg = dataclasses.replace(ocfg, lr=args.lr)

        batch0 = pipe.batch(0)
        step_fn = jax.jit(wrap(batch0), donate_argnums=(0, 1))

        params = jax.jit(init_fn)(jax.random.key(0))
        opt_state = jax.jit(build.shard_mapped(
            lambda p: opt.init_state(p, ocfg), mesh, (pspecs,), ospecs)
        )(params)

        start = 0
        ft = None
        if args.ckpt_dir:
            ft = ckpt.FaultToleranceManager(
                args.ckpt_dir, save_every=args.ckpt_every,
                step_deadline_s=args.step_deadline,
                async_save=args.ckpt_async == "on")
            if args.resume == "auto" and ft.resume_step() is not None:
                start, restored = ckpt.restore(
                    args.ckpt_dir,
                    {"params": params, "opt": opt_state})
                params, opt_state = restored["params"], restored["opt"]
                print(f"[train] resumed from step {start}")

        import contextlib
        losses = []
        for step in range(start, args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, pipe.batch(step))
            with (profiler.op("train_step", n_pes=mesh.devices.size)
                  if profiler is not None else contextlib.nullcontext()):
                loss, params, opt_state = step_fn(params, opt_state, batch)
                loss = float(loss)        # sync: the sample times the step
            losses.append(loss)
            if metrics is not None:
                metrics.histogram("train.step_s",
                                  "full train step wall time").observe(
                    time.time() - t0)
                metrics.gauge("train.loss", "last step loss").set(loss)
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"({time.time() - t0:.2f}s)")
            if ft:
                ft.on_step(step, lambda: {"params": params,
                                          "opt": opt_state})
        if ft:
            ft.finalize(args.steps, lambda: {"params": params,
                                             "opt": opt_state})
            if ft.stragglers:
                print(f"[train] {len(ft.stragglers)} step(s) exceeded "
                      f"--step-deadline {args.step_deadline:g}s "
                      f"(worst {max(s['stall_s'] for s in ft.stragglers):.1f}s)")
            if metrics is not None:
                metrics.counter(
                    "train.stragglers",
                    "steps exceeding the --step-deadline").inc(
                    len(ft.stragglers))
        if tuner is not None and args.tuning_db:
            tuner.save(args.tuning_db)
            print(f"[train] tuning DB ({len(tuner.db)} points) saved to "
                  f"{args.tuning_db}")
        if profiler is not None and args.profile_out:
            profiler.dump(args.profile_out)
            print(f"[train] profile dumped to {args.profile_out}")
        if args.trace_out:
            profiler.dump_chrome(args.trace_out)
            print(f"[train] Chrome trace ({len(profiler._events)} events) "
                  f"written to {args.trace_out} — open in ui.perfetto.dev")
        if metrics is not None:
            metrics.counter("train.steps", "steps executed").inc(
                len(losses))
            metrics.dump(args.metrics_out)
            print(f"[train] metrics written to {args.metrics_out}")
        assert np.isfinite(losses).all(), "NaN/inf loss"
        if len(losses) >= 10:
            a, b = np.mean(losses[:3]), np.mean(losses[-3:])
            print(f"[train] loss {a:.4f} -> {b:.4f} "
                  f"({'improved' if b < a else 'no improvement'})")
        return losses


if __name__ == "__main__":
    main()
