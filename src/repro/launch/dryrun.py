import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * jax.jit(step).lower(**ShapeDtypeStructs).compile() must succeed on the
    16x16 production mesh AND the 2x16x16 multi-pod mesh;
  * memory_analysis() proves the working set fits per chip;
  * cost_analysis() + collective-bytes parsing feed the roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--comm shmem|xla]
Results land in experiments/dryrun/<arch>__<shape>__<mesh>__<comm>.json
"""
import argparse
import json
import pathlib
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in the (scheduled) HLO."""
    dtypes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
              "u8": 1, "f64": 8, "s64": 8, "pred": 1, "s16": 2, "u16": 2}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    counts = {k: 0 for k in kinds}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w\.\-]+ = (.*?)\s*(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        kind = m.group(2)
        if "-start" in ls.split("=")[1].split("(")[0]:
            pass  # async starts counted; done ops carry no new bytes
        if re.search(rf"{kind}-done", ls):
            continue
        shapes = shape_re.findall(m.group(1))
        nbytes = 0
        for dt, dims in shapes:
            if dt not in dtypes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * dtypes[dt]
        out[kind] += nbytes
        counts[kind] += 1
    return {"bytes": out, "counts": counts}


# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (sum both directions ~2x)


def roofline_terms(cost: dict, coll: dict, n_chips: int) -> dict:
    flops = cost.get("flops", 0.0)
    bytes_hbm = cost.get("bytes accessed", 0.0)
    coll_bytes = sum(coll["bytes"].values())
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
        "hlo_flops": flops,
        "hlo_bytes": bytes_hbm,
        "collective_bytes": coll_bytes,
    }


def run_cell(arch: str, shape: str, multipod: bool, comm: str,
             outdir: pathlib.Path, verbose: bool = True) -> dict:
    from ..configs import get_config
    from ..models.config import input_specs, shape_applicable, SHAPES
    from . import build
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    cell = f"{arch}__{shape}__{'2x16x16' if multipod else '16x16'}__{comm}"
    if not ok:
        res = {"cell": cell, "status": "skipped", "reason": why}
        _write(outdir, cell, res)
        if verbose:
            print(f"[dryrun] {cell}: SKIPPED ({why})")
        return res

    mesh = make_production_mesh(multi_pod=multipod)
    n_chips = int(np.prod(mesh.devices.shape))
    kind = SHAPES[shape]["kind"]
    t0 = time.time()
    with jax.set_mesh(mesh):
        specs_in = input_specs(cfg, shape)
        if kind == "train":
            wrap, (pshapes, pspecs), (oshapes, ospecs), _ = \
                build.make_train_step(cfg, mesh, comm)
            step = wrap(specs_in)
            gp = build.global_shape(pshapes, pspecs, mesh)
            go = build.global_shape(oshapes, ospecs, mesh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(gp, go, specs_in)
        elif kind == "prefill":
            wp, wd, _, (pshapes, pspecs), _ = build.make_serve_steps(
                cfg, mesh, shape, comm)
            step = wp(specs_in)
            gp = build.global_shape(pshapes, pspecs, mesh)
            lowered = jax.jit(step).lower(gp, specs_in)
        else:  # decode
            wp, wd, (cshapes, cspecs), (pshapes, pspecs), seq_shards = \
                build.make_serve_steps(cfg, mesh, shape, comm)
            step = wd(specs_in)
            gp = build.global_shape(pshapes, pspecs, mesh)
            gc = build.global_shape(cshapes, cspecs, mesh)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(gp, gc,
                                                               specs_in)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = _collective_bytes(compiled.as_text())
    terms = roofline_terms(cost, coll, n_chips)
    res = {
        "cell": cell, "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": terms,
        "collectives": coll,
    }
    _write(outdir, cell, res)
    if verbose:
        print(f"[dryrun] {cell}: OK  compile={t_compile:.0f}s  "
              f"FLOPs={terms['hlo_flops']:.3e}  "
              f"collB={terms['collective_bytes']:.3e}  "
              f"peak={res['memory']['peak_bytes']}")
        print(f"  memory_analysis: {mem}")
    return res


def _write(outdir: pathlib.Path, cell: str, res: dict):
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / f"{cell}.json").write_text(json.dumps(res, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--comm", default="shmem", choices=["shmem", "xla"])
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()
    outdir = pathlib.Path(args.outdir)

    from ..configs import ARCHS
    from ..models.config import SHAPES
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, args.multipod, args.comm, outdir)
        except Exception as e:  # noqa
            print(f"[dryrun] {a}__{s}: FAILED {type(e).__name__}: {e}")
            failures.append((a, s, str(e)))
    if failures:
        print(f"{len(failures)} cells failed"); sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
