"""Serving launcher: batched prefill + autoregressive decode loop.

  python -m repro.launch.serve --arch qwen2-0.5b --smoke --tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--comm", default="shmem", choices=["shmem", "xla"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_config
    from ..models import transformer
    from ..parallel.comm import AxisSpec, Comm
    from ..serve import step as sstep
    from . import build
    from .mesh import make_mesh

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, fsdp=False)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode loop")
    mesh = make_mesh(args.data, args.model)
    dp, tp, _ = build.mesh_dims(mesh)
    B = args.batch
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab, size=(B, args.prompt_len),
                          dtype=np.int32)

    with jax.set_mesh(mesh):
        init_fn, pshapes, pspecs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))

        cshapes = jax.eval_shape(lambda: transformer.init_cache(
            cfg, tp, B // dp, args.cache_len, 1))
        from ..parallel import sharding
        cspecs = sharding.cache_specs(cfg, cshapes, build.mesh_axes(mesh), 1)
        cache = jax.jit(build.shard_mapped(
            lambda: transformer.init_cache(cfg, tp, B // dp,
                                           args.cache_len, 1),
            mesh, (), cspecs))()

        decode = sstep.build_decode_step(cfg, build.axis_spec(mesh),
                                         args.comm, 1)
        bspec = {"tokens": P("data", None), "positions": P("data")}
        dstep = jax.jit(build.shard_mapped(
            decode, mesh, (pspecs, cspecs, bspec),
            (P("data", None, "model"), cspecs)))

        # prefill by teacher-forcing the prompt through decode steps
        # (cache-exact; batched prefill fast-path is transformer.prefill)
        t0 = time.time()
        tok = prompt[:, :1]
        out_tokens = []
        for t in range(args.prompt_len + args.tokens - 1):
            batch = {"tokens": jnp.asarray(tok),
                     "positions": jnp.full((B,), t, jnp.int32)}
            logits, cache = dstep(params, cache, batch)
            nxt = np.asarray(jnp.argmax(logits[:, 0], -1)).astype(np.int32)
            if t + 1 < args.prompt_len:
                tok = prompt[:, t + 1:t + 2]
            else:
                tok = nxt[:, None]
                out_tokens.append(nxt)
        dt = time.time() - t0
        gen = np.stack(out_tokens, 1)
        print(f"[serve] generated {gen.shape} in {dt:.2f}s "
              f"({B * gen.shape[1] / dt:.1f} tok/s)")
        print(gen[:, :8])
        return gen


if __name__ == "__main__":
    main()
