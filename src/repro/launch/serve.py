"""Serving launcher: the continuous-batching engine on the paged
symmetric-heap KV cache (DESIGN.md §15).

Batch mode (default) submits every request up front and drains; with
``--continuous`` a fixed-rate arrival trace streams requests in while
earlier ones decode, exercising per-step join/evict.  Both use the
paged prefill fast-path (ONE forward pass over the prompt bucket fills
the KV pages) instead of the seed launcher's teacher-forced per-token
decode loop.  Families without attention KV caches (ssm/hybrid/moe)
fall back to the dense-cache decode loop.

  python -m repro.launch.serve --arch qwen2-0.5b --smoke --tokens 16
  python -m repro.launch.serve --arch qwen2-0.5b --smoke --continuous \\
      --requests 16 --rate 2 --tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _legacy_decode_loop(cfg, mesh, args):
    """Dense-cache teacher-forced loop, kept for non-paged families."""
    from ..models import transformer
    from ..serve import step as sstep
    from . import build

    dp, tp, _ = build.mesh_dims(mesh)
    B = args.batch
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab, size=(B, args.prompt_len),
                          dtype=np.int32)
    with jax.set_mesh(mesh):
        init_fn, pshapes, pspecs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))
        cshapes = jax.eval_shape(lambda: transformer.init_cache(
            cfg, tp, B // dp, args.cache_len, 1))
        from ..parallel import sharding
        cspecs = sharding.cache_specs(cfg, cshapes, build.mesh_axes(mesh), 1)
        cache = jax.jit(build.shard_mapped(
            lambda: transformer.init_cache(cfg, tp, B // dp,
                                           args.cache_len, 1),
            mesh, (), cspecs))()
        decode = sstep.build_decode_step(cfg, build.axis_spec(mesh),
                                         args.comm, 1)
        bspec = {"tokens": P("data", None), "positions": P("data")}
        dstep = jax.jit(build.shard_mapped(
            decode, mesh, (pspecs, cspecs, bspec),
            (P("data", None, "model"), cspecs)))
        t0 = time.time()
        tok = prompt[:, :1]
        out_tokens = []
        for t in range(args.prompt_len + args.tokens - 1):
            batch = {"tokens": jnp.asarray(tok),
                     "positions": jnp.full((B,), t, jnp.int32)}
            logits, cache = dstep(params, cache, batch)
            nxt = np.asarray(jnp.argmax(logits[:, 0], -1)).astype(np.int32)
            if t + 1 < args.prompt_len:
                tok = prompt[:, t + 1:t + 2]
            else:
                tok = nxt[:, None]
                out_tokens.append(nxt)
        dt = time.time() - t0
        gen = np.stack(out_tokens, 1)
        print(f"[serve] (dense loop) generated {gen.shape} in {dt:.2f}s "
              f"({B * gen.shape[1] / dt:.1f} tok/s)")
        return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--comm", default="shmem", choices=["shmem", "xla"])
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests (batch mode) / arrival batch")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128,
                    help="max sequence length (paged: page capacity per "
                         "sequence; dense fallback: cache length)")
    ap.add_argument("--continuous", action="store_true",
                    help="stream requests in at --rate per engine step "
                         "instead of submitting all up front")
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests in --continuous mode "
                         "(default: --batch)")
    ap.add_argument("--rate", type=int, default=1,
                    help="engine steps between arrivals (--continuous)")
    ap.add_argument("--slots", type=int, default=0,
                    help="engine batch slots (default: --batch, max 8)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens")
    ap.add_argument("--kv-heap-bytes", type=int, default=0,
                    help="cap the symmetric-heap KV region (0 = size for "
                         "all slots; smaller values exercise admission "
                         "backpressure)")
    ap.add_argument("--autotune", action="store_true",
                    help="consult the measured-performance tuning DB for "
                         "the per-step collectives (DESIGN §13)")
    ap.add_argument("--tuning-db", default="",
                    help="path of the persistent tuning database (JSON)")
    ap.add_argument("--profile-out", default="",
                    help="attach the runtime profiler and dump its "
                         "counters+timeline JSON here at exit")
    ap.add_argument("--trace-out", default="",
                    help="attach the distributed tracer (DESIGN §16) and "
                         "dump a Chrome trace-event JSON here at exit "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="record serving metrics (TTFT/per-token "
                         "histograms, queue/KV gauges, wire bytes) and "
                         "dump the registry JSON here at exit")
    args = ap.parse_args(argv)

    from ..configs import get_config, smoke_config
    from ..models import transformer
    from .mesh import make_mesh

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, fsdp=False)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode loop")
    mesh = make_mesh(args.data, args.model)

    paged_ok = (cfg.family in transformer.paged_families()
                and args.data == 1 and args.comm == "shmem")
    if not paged_ok:
        return _legacy_decode_loop(cfg, mesh, args)

    from ..serve.engine import ServeEngine
    profiler = None
    if args.trace_out:
        # one object serves both sinks: Tracer IS-A Profiler, so
        # --profile-out (counters+timeline) and --trace-out (Chrome
        # trace) can share it
        from ..core.trace import LEVEL_FULL, Tracer
        profiler = Tracer(level=LEVEL_FULL)
    elif args.profile_out:
        from ..core.profile import Profiler
        profiler = Profiler(level=2)
    metrics = None
    if args.metrics_out:
        from ..serve.metrics import ServeMetrics
        metrics = ServeMetrics()
        if profiler is not None:
            metrics.attach(profiler)
    tuner = None
    if args.autotune or args.tuning_db:
        from ..core import tuner as tuner_mod
        tuner = tuner_mod.Tuner(path=args.tuning_db or None)

    n_req = args.requests or args.batch
    slots = args.slots or min(args.batch, 8)
    max_seq = max(args.cache_len, args.prompt_len + args.tokens)
    bucket = -(-args.prompt_len // args.page_size) * args.page_size
    eng = ServeEngine(
        cfg, mesh, max_slots=slots, page_size=args.page_size,
        max_seq=max_seq, prompt_bucket=min(bucket, max_seq),
        kv_heap_bytes=args.kv_heap_bytes or None, backend=args.comm,
        tuner=(tuner if args.autotune else None), profile=profiler,
        metrics=metrics)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(n_req, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    rids = []
    if args.continuous:
        nxt = 0
        while nxt < n_req or not eng.scheduler.idle():
            if nxt < n_req and eng.steps % max(args.rate, 1) == 0:
                rids.append(eng.submit(prompts[nxt], args.tokens))
                nxt += 1
            eng.step()
        eng.run()                      # drain stragglers
    else:
        rids = [eng.submit(p, args.tokens) for p in prompts]
        eng.run()
    dt = time.time() - t0
    gen = np.stack([eng.results[r] for r in rids])
    mode = "continuous" if args.continuous else "batch"
    print(f"[serve] ({mode}, paged) generated {gen.shape} in {dt:.2f}s "
          f"({gen.size / dt:.1f} tok/s, {eng.steps} engine steps, "
          f"page={args.page_size} slots={slots})")
    print(gen[:, :8])

    if tuner is not None and args.tuning_db:
        tuner.save(args.tuning_db)
        print(f"[serve] tuning DB ({len(tuner.db)} points) saved to "
              f"{args.tuning_db}")
    if profiler is not None and args.profile_out:
        profiler.dump(args.profile_out)
        print(f"[serve] profile dumped to {args.profile_out}")
    if args.trace_out:
        profiler.dump_chrome(args.trace_out)
        print(f"[serve] Chrome trace ({len(profiler._events)} events) "
              f"written to {args.trace_out} — open in ui.perfetto.dev")
    if metrics is not None:
        metrics.dump(args.metrics_out)
        h = metrics.ttft_s
        print(f"[serve] metrics written to {args.metrics_out} "
              f"(ttft p50={h.percentile(50) * 1e3:.1f}ms, per-token "
              f"p50={metrics.per_token_s.percentile(50) * 1e3:.2f}ms)")
    return gen


if __name__ == "__main__":
    main()
