"""launch subsystem."""
