"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, DCN pod axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(data: int, model: int, pod: int | None = None):
    """Elastic variant: any (pod,) data x model factorization — the
    fault-tolerance path reshards checkpoints onto these."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))
