"""TP-aware model layers over the Comm substrate.

Megatron-style manual tensor parallelism inside shard_map: attention/SSM
heads and FFN hidden are sharded over the `model` axis; every layer ends
with one allreduce over `model` (shmem dissemination/ring or XLA psum —
the --comm switch).  KV projections are replicated over `model` when
n_kv_heads < tp (GQA groups), costing a few MB but avoiding fractional
shards.  MoE layers switch the model axis from TP to EP: tokens are
sequence-split over `model`, dispatched to expert owners with the paper's
pairwise `alltoall`, and gathered back (DESIGN.md §3).

All functions take local shards; collectives are explicit; autodiff
produces the reversed communication schedule automatically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels import ops as kops
from ..parallel.comm import Comm
from .config import ModelConfig

Params = dict


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (..., L, H, D) with D even; positions: (..., L)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# embedding / LM head (vocab-sharded over `model`)
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig, tp: int) -> Params:
    v_local = -(-cfg.vocab // tp)
    scale = 1.0 / math.sqrt(cfg.d_model)
    p = {"table": jax.random.normal(key, (v_local, cfg.d_model),
                                    jnp.float32) * scale}
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.d_model, v_local),
            jnp.float32) * scale
    return p


def embed(comm: Comm, cfg: ModelConfig, p: Params, tokens):
    """tokens: (B, L) global ids -> (B, L, d) replicated over model."""
    tp = comm.axis_size(comm.axes.model)
    v_local = p["table"].shape[0]
    base = comm.axis_index(comm.axes.model) * v_local
    local_ids = tokens - base
    ok = (local_ids >= 0) & (local_ids < v_local)
    emb = jnp.take(p["table"], jnp.clip(local_ids, 0, v_local - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, 0.0)
    emb = comm.allreduce(emb, comm.axes.model)
    return emb.astype(cfg.dtype)


def lm_logits(comm: Comm, cfg: ModelConfig, p: Params, x):
    w = p["table"].T if cfg.tie_embeddings else p["head"]
    return _dense(x, w.astype(cfg.logit_dtype))   # (B, L, V_local)


def sharded_xent(comm: Comm, cfg: ModelConfig, logits, targets):
    """Cross-entropy with vocab sharded over `model`: the logsumexp and the
    target-logit pick each need one small allreduce (max, then sum)."""
    v_local = logits.shape[-1]
    base = comm.axis_index(comm.axes.model) * v_local
    lg = logits.astype(jnp.float32)
    if cfg.final_softcap is not None:
        lg = cfg.final_softcap * jnp.tanh(lg / cfg.final_softcap)
    # stop-grad on the stabilizer: exact logsumexp gradient is preserved
    # and the max-allreduce needs no VJP (XLA pmax has none)
    m_loc = lax.stop_gradient(jnp.max(lg, -1))
    m = comm.allreduce(m_loc, comm.axes.model, "max")
    se = jnp.sum(jnp.exp(lg - m[..., None]), -1)
    se = comm.allreduce(se, comm.axes.model)
    lse = jnp.log(se) + m
    loc_t = targets - base
    ok = (loc_t >= 0) & (loc_t < v_local)
    tl = jnp.take_along_axis(
        lg, jnp.clip(loc_t, 0, v_local - 1)[..., None], -1)[..., 0]
    tl = jnp.where(ok, tl, 0.0)
    tl = comm.allreduce(tl, comm.axes.model)
    return lse - tl   # (B, L) token losses


# ---------------------------------------------------------------------------
# GQA attention (sharded heads; replicated KV proj when n_kv < tp)
# ---------------------------------------------------------------------------

def _gqa_dims(cfg: ModelConfig, tp: int):
    """Local head bookkeeping.  Head counts that don't divide tp are padded
    with 'ghost' q heads whose outputs are masked to zero (exact semantics,
    a sliver of wasted compute — e.g. qwen2's 14 heads on tp=16).  KV
    projections are stored replicated when n_kv < tp; each chip gathers the
    kv head(s) its q heads map to."""
    nq_local = -(-cfg.n_heads // tp)
    kv_repl = cfg.n_kv_heads < tp or cfg.n_heads % tp != 0
    nkv_store = cfg.n_kv_heads if kv_repl else cfg.n_kv_heads // tp
    return nq_local, nkv_store, kv_repl


def init_attention(key, cfg: ModelConfig, tp: int) -> Params:
    d, hd = cfg.d_model, cfg.hd
    nq_local, nkv_store, _ = _gqa_dims(cfg, tp)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(cfg.n_heads * hd)
    p = {
        "wq": jax.random.normal(k1, (d, nq_local * hd), jnp.float32) * s_in,
        "wk": jax.random.normal(k2, (d, nkv_store * hd), jnp.float32) * s_in,
        "wv": jax.random.normal(k3, (d, nkv_store * hd), jnp.float32) * s_in,
        "wo": jax.random.normal(k4, (nq_local * hd, d), jnp.float32) * s_out,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq_local * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nkv_store * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nkv_store * hd,), jnp.float32)
    return p


def _head_ids(comm: Comm, cfg: ModelConfig, tp: int):
    """(global q-head ids for this chip, validity mask for ghost heads)."""
    nq_local, _, _ = _gqa_dims(cfg, tp)
    first = comm.axis_index(comm.axes.model) * nq_local
    ids = first + jnp.arange(nq_local)
    return ids, ids < cfg.n_heads


def _local_kv(comm: Comm, cfg: ModelConfig, k, v, tp: int):
    """Return per-local-q-head K/V: when the KV proj is replicated, gather
    each q head's kv group head (handles any head/kv/tp combination);
    otherwise K/V are already the local shard (group attention)."""
    nq_local, _, kv_repl = _gqa_dims(cfg, tp)
    if not kv_repl:
        return k, v, cfg.n_kv_heads // tp
    group = cfg.n_heads // cfg.n_kv_heads
    ids, _ = _head_ids(comm, cfg, tp)
    kv_idx = jnp.clip(ids, 0, cfg.n_heads - 1) // group      # (nq_local,)
    k_l = jnp.take(k, kv_idx, axis=2)
    v_l = jnp.take(v, kv_idx, axis=2)
    return k_l, v_l, nq_local                                # group of 1


def kv_cache_plan(cfg: ModelConfig, tp: int):
    """Static per-rank bookkeeping for the replicated-KV decode cache:
    store only the DISTINCT kv heads each chip's q heads touch (ndk of
    them, constant-padded), not one copy per q head — internlm-class GQA
    (group 6, 3 q heads/chip) caches 1 head instead of 3.

    Returns (ndk, store_idx (tp, ndk), q2slot (tp, nq_local))."""
    nq_local, _, kv_repl = _gqa_dims(cfg, tp)
    if not kv_repl:
        return None
    group = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    store, q2slot = [], []
    for r in range(tp):
        ids = [min(r * nq_local + j, cfg.n_heads - 1)
               for j in range(nq_local)]
        kvs = [i // group for i in ids]
        distinct = sorted(set(kvs))
        store.append(distinct)
        q2slot.append([distinct.index(kv) for kv in kvs])
    ndk = max(len(d) for d in store)
    store_idx = np.asarray([d + [d[-1]] * (ndk - len(d)) for d in store],
                           np.int32)
    return ndk, store_idx, np.asarray(q2slot, np.int32)


def attention(comm: Comm, cfg: ModelConfig, p: Params, x, positions, *,
              is_local_layer: bool = False):
    """Full-sequence attention (train/prefill). x replicated over model;
    returns replicated (one allreduce)."""
    tp = comm.axis_size(comm.axes.model)
    B, L, d = x.shape
    hd = cfg.hd
    nq_local, nkv_store, _ = _gqa_dims(cfg, tp)
    q = _dense(x, p["wq"], p.get("bq")).reshape(B, L, nq_local, hd)
    k = _dense(x, p["wk"], p.get("bk")).reshape(B, L, nkv_store, hd)
    v = _dense(x, p["wv"], p.get("bv")).reshape(B, L, nkv_store, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    k, v, nkv_local = _local_kv(comm, cfg, k, v, tp)
    window = cfg.window
    if cfg.local_global_period is not None and is_local_layer:
        window = cfg.local_window
    seq_shards = (comm.axis_size(comm.axes.data)
                  if cfg.attention == "ring" and comm.backend == "shmem"
                  else 1)
    if seq_shards > 1:
        # attention="ring" (DESIGN.md §14): the caller sequence-sharded
        # x over `data` (long-context; `positions` are GLOBAL), so each
        # PE attends its query shard against the KV ring — each rotation
        # a put_nbi hidden behind the previous block's flash partials.
        # Head/TP layout and the wo allreduce are untouched.
        from ..core import fusion, shmem
        sctx = shmem.spmd_ctx(comm.axes.data)
        pos1 = positions[0].astype(jnp.int32)        # shared across batch
        o = fusion.ring_attention(
            sctx, q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), pos1, pos1, causal=cfg.causal,
            window=window, softcap=cfg.softcap, use_pallas=cfg.use_pallas,
            out_dtype=q.dtype)
    else:
        o = kops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=cfg.causal, window=window,
            softcap=cfg.softcap, use_pallas=cfg.use_pallas,
            blockwise_unroll=cfg.probe_unroll)
    o = o.transpose(0, 2, 1, 3)
    if cfg.n_heads % tp:   # zero ghost heads (padded head count)
        _, valid = _head_ids(comm, cfg, tp)
        o = o * valid[None, None, :, None]
    o = o.reshape(B, L, nq_local * hd).astype(cfg.dtype)
    out = _dense(o, p["wo"])
    return comm.allreduce(out, comm.axes.model)


def init_attn_cache(cfg: ModelConfig, tp: int, batch_local: int,
                    cache_len: int, window_bound: int | None = None):
    nq_local, _, kv_repl = _gqa_dims(cfg, tp)
    if kv_repl:
        ndk, _, _ = kv_cache_plan(cfg, tp)   # distinct kv heads only
        nkv_local = ndk
    else:
        nkv_local = cfg.n_kv_heads // tp
    s = cache_len if window_bound is None else min(cache_len, window_bound)
    shape = (batch_local, s, nkv_local, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def attention_decode(comm: Comm, cfg: ModelConfig, p: Params, x, cache,
                     position, *, is_local_layer: bool = False,
                     seq_shards: int = 1):
    """One-token decode against a KV cache.

    Replicated-KV archs cache only each chip's DISTINCT kv heads
    (kv_cache_plan); q heads pick their slot through a one-hot map at
    attend time.  seq_shards > 1: cache sequence dim sharded over `data`
    (long-context); partial softmax stats are combined with two tiny
    allreduces over the data axis (flash-decode on shmem collectives)."""
    tp = comm.axis_size(comm.axes.model)
    B, one, d = x.shape
    hd = cfg.hd
    nq_local, nkv_store, kv_repl = _gqa_dims(cfg, tp)
    q = _dense(x, p["wq"], p.get("bq")).reshape(B, 1, nq_local, hd)
    k = _dense(x, p["wk"], p.get("bk")).reshape(B, 1, nkv_store, hd)
    v = _dense(x, p["wv"], p.get("bv")).reshape(B, 1, nkv_store, hd)
    q = rope(q, position[:, None], cfg.rope_theta)
    k = rope(k, position[:, None], cfg.rope_theta)

    slot_map = None
    if kv_repl:
        ndk, store_idx, q2slot = kv_cache_plan(cfg, tp)
        rank = comm.axis_index(comm.axes.model)
        sidx = jnp.asarray(store_idx)[rank]              # (ndk,)
        k = jnp.take(k, sidx, axis=2)
        v = jnp.take(v, sidx, axis=2)
        q2 = jnp.asarray(q2slot)[rank]                   # (nq_local,)
        slot_map = jax.nn.one_hot(q2, ndk, dtype=jnp.float32)

    S = cache["k"].shape[1]
    window = cfg.window
    if cfg.local_global_period is not None and is_local_layer:
        window = cfg.local_window
    ring = window is not None and S <= (window or 0)

    if seq_shards == 1:
        slot = position % S if ring else position
        ck = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["k"], k, slot)
        cv = jax.vmap(lambda c, u, i: lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["v"], v, slot)
        pos_idx = jnp.arange(S)[None, :]                 # (1,S)
        if ring:
            age = position[:, None] - ((position[:, None] - pos_idx) % S)
            valid = (age >= 0) & (age <= position[:, None])
        else:
            valid = pos_idx <= position[:, None]
            if window is not None:
                valid &= pos_idx > (position[:, None] - window)
        out = _cache_attend(cfg, q, ck, cv, valid, slot_map)
        new_cache = {"k": ck, "v": cv}
    else:
        # sequence-sharded cache: my shard covers rows
        # [shard*S, shard*S + S) of the global sequence
        shard = comm.axis_index(comm.axes.data)
        g_start = shard * S
        slot = position - g_start
        here = (slot >= 0) & (slot < S)
        slot_c = jnp.clip(slot, 0, S - 1)
        upd = lambda c, u, i, h: jnp.where(
            h, lax.dynamic_update_slice_in_dim(c, u, i, axis=0), c)
        ck = jax.vmap(upd)(cache["k"], k, slot_c, here)
        cv = jax.vmap(upd)(cache["v"], v, slot_c, here)
        pos_idx = g_start + jnp.arange(S)[None, :]
        valid = pos_idx <= position[:, None]
        if window is not None:
            valid &= pos_idx > (position[:, None] - window)
        out = _cache_attend(cfg, q, ck, cv, valid, slot_map,
                            comm=comm, combine_axis=comm.axes.data)
        new_cache = {"k": ck, "v": cv}

    if cfg.n_heads % tp:   # zero ghost heads
        _, valid_h = _head_ids(comm, cfg, tp)
        out = out * valid_h[None, None, :, None]
    out = out.reshape(B, 1, nq_local * hd).astype(cfg.dtype)
    y = _dense(out, p["wo"])
    return comm.allreduce(y, comm.axes.model), new_cache


def _cache_attend(cfg, q, ck, cv, valid, slot_map=None, comm=None,
                  combine_axis=None):
    """q: (B,1,Hq,hd); ck/cv: (B,S,K,hd); valid: (B,S) -> (B,1,Hq,hd).

    slot_map (Hq,K) one-hot: replicated-KV path — logits computed against
    all K stored heads (K = distinct kv heads, small) then selected per q
    head.  slot_map None: grouped GQA (Hq = K*group)."""
    B, S = ck.shape[0], ck.shape[1]
    hd = cfg.hd
    qf = q[:, 0].astype(jnp.float32) / math.sqrt(hd)     # (B,Hq,hd)
    kf, vf = ck.astype(jnp.float32), cv.astype(jnp.float32)
    if slot_map is not None:
        logits = jnp.einsum("bqd,bskd->bqks", qf, kf)    # (B,Hq,K,S)
        logits = jnp.einsum("bqks,qk->bqs", logits, slot_map)
    else:
        K = ck.shape[2]
        group = qf.shape[1] // K
        qg = qf.reshape(B, K, group, hd)
        logits = jnp.einsum("bkgd,bskd->bkgs", qg, kf) \
            .reshape(B, K * group, S)
    if cfg.softcap is not None:
        logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    m_loc = jnp.max(logits, -1, keepdims=True)
    if comm is not None:
        m = lax.stop_gradient(comm.allreduce(m_loc, combine_axis, "max"))
    else:
        m = m_loc
    p_ = jnp.exp(logits - m)
    l_loc = jnp.sum(p_, -1, keepdims=True)
    if slot_map is not None:
        ctx = jnp.einsum("bqs,bskd->bqkd", p_, vf)
        acc = jnp.einsum("bqkd,qk->bqd", ctx, slot_map)
    else:
        K = ck.shape[2]
        group = p_.shape[1] // K
        pg = p_.reshape(B, K, group, S)
        acc = jnp.einsum("bkgs,bskd->bkgd", pg, vf) \
            .reshape(B, p_.shape[1], hd)
    if comm is not None:
        l_den = comm.allreduce(l_loc, combine_axis)
        acc = comm.allreduce(acc, combine_axis)
    else:
        l_den = l_loc
    out = acc / jnp.maximum(l_den, 1e-30)
    return out[:, None]                                  # (B,1,Hq,hd)


# ---------------------------------------------------------------------------
# Paged KV attention (serving engine, DESIGN.md §15)
# ---------------------------------------------------------------------------

def paged_kv_update(pool_leaf, page_table, new, positions, page_size: int):
    """Scatter per-position rows into a paged KV pool.

    pool_leaf: (num_pages, page_size, ...) — one layer's page pool;
    page_table: (B, max_pages) int32 physical page ids (0 = null page);
    new: (B, L, ...) rows to write; positions: (B, L) global positions.
    Rows land at pool[page_table[b, pos // page_size], pos % page_size].
    Distinct sequences own distinct pages, so batched writes never
    collide except on the reserved null page (whose contents are never
    read through a valid mask)."""
    B = positions.shape[0]
    page = positions // page_size
    off = positions % page_size
    phys = jnp.take_along_axis(page_table, page, axis=1)     # (B, L)
    return pool_leaf.at[phys, off].set(new.astype(pool_leaf.dtype))


def paged_kv_gather(pool_leaf, page_table):
    """Gather a sequence-contiguous (B, S_max, ...) view of each row's
    pages (S_max = max_pages * page_size).  Invalid/unallocated table
    entries point at the null page; the attention validity mask excludes
    them."""
    got = jnp.take(pool_leaf, page_table, axis=0)   # (B, P, ps, ...)
    B, P, ps = got.shape[0], got.shape[1], got.shape[2]
    return got.reshape((B, P * ps) + got.shape[3:])


def _attend_mq(cfg, q, ck, cv, valid, slot_map=None):
    """Multi-query generalization of `_cache_attend` for the paged path.

    q: (B,L,Hq,hd); ck/cv: (B,S,K,hd); valid: (B,L,S) -> (B,L,Hq,hd).
    Shared by paged prefill (L = prompt bucket) and paged decode (L = 1)
    so both attend through identical einsum contractions — the engine's
    batched-vs-alone bit-identity rests on every op being per-row."""
    B, S = ck.shape[0], ck.shape[1]
    hd = cfg.hd
    qf = q.astype(jnp.float32) / math.sqrt(hd)               # (B,L,Hq,hd)
    kf, vf = ck.astype(jnp.float32), cv.astype(jnp.float32)
    if slot_map is not None:
        logits = jnp.einsum("blqd,bskd->blqks", qf, kf)
        logits = jnp.einsum("blqks,qk->blqs", logits, slot_map)
    else:
        K = ck.shape[2]
        group = qf.shape[2] // K
        qg = qf.reshape(B, qf.shape[1], K, group, hd)
        logits = jnp.einsum("blkgd,bskd->blkgs", qg, kf) \
            .reshape(B, qf.shape[1], K * group, S)
    if cfg.softcap is not None:
        logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
    logits = jnp.where(valid[:, :, None, :], logits, -1e30)
    m = jnp.max(logits, -1, keepdims=True)
    p_ = jnp.exp(logits - m)
    l_den = jnp.sum(p_, -1, keepdims=True)
    if slot_map is not None:
        ctx = jnp.einsum("blqs,bskd->blqkd", p_, vf)
        acc = jnp.einsum("blqkd,qk->blqd", ctx, slot_map)
    else:
        K = ck.shape[2]
        group = p_.shape[2] // K
        pg = p_.reshape(B, p_.shape[1], K, group, S)
        acc = jnp.einsum("blkgs,bskd->blkgd", pg, vf) \
            .reshape(B, p_.shape[1], p_.shape[2], hd)
    return acc / jnp.maximum(l_den, 1e-30)


def attention_paged(comm: Comm, cfg: ModelConfig, p: Params, x, pool,
                    page_table, positions, *, page_size: int,
                    is_local_layer: bool = False):
    """GQA attention against a paged KV pool — one code path for prefill
    (x: (B, L, d), L = prompt bucket) and decode (L = 1).

    pool: {"k","v"} (num_pages, page_size, K_local, hd); page_table:
    (B, max_pages) physical page ids.  K/V rows for every position are
    scattered into the owning page, then each row's pages are gathered
    back sequence-contiguous and attended with a causal(+window) mask.
    Sliding windows are handled purely by masking (pages keep the full
    sequence), so paged results equal the full-length dense cache path."""
    tp = comm.axis_size(comm.axes.model)
    B, L, d = x.shape
    hd = cfg.hd
    nq_local, nkv_store, kv_repl = _gqa_dims(cfg, tp)
    q = _dense(x, p["wq"], p.get("bq")).reshape(B, L, nq_local, hd)
    k = _dense(x, p["wk"], p.get("bk")).reshape(B, L, nkv_store, hd)
    v = _dense(x, p["wv"], p.get("bv")).reshape(B, L, nkv_store, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    slot_map = None
    if kv_repl:
        ndk, store_idx, q2slot = kv_cache_plan(cfg, tp)
        rank = comm.axis_index(comm.axes.model)
        sidx = jnp.asarray(store_idx)[rank]                  # (ndk,)
        k = jnp.take(k, sidx, axis=2)
        v = jnp.take(v, sidx, axis=2)
        q2 = jnp.asarray(q2slot)[rank]                       # (nq_local,)
        slot_map = jax.nn.one_hot(q2, ndk, dtype=jnp.float32)

    pk = paged_kv_update(pool["k"], page_table, k, positions, page_size)
    pv = paged_kv_update(pool["v"], page_table, v, positions, page_size)
    ck = paged_kv_gather(pk, page_table)                     # (B,S_max,K,hd)
    cv = paged_kv_gather(pv, page_table)

    S_max = ck.shape[1]
    window = cfg.window
    if cfg.local_global_period is not None and is_local_layer:
        window = cfg.local_window
    kv_pos = jnp.arange(S_max)[None, None, :]                # (1,1,S)
    valid = kv_pos <= positions[:, :, None]
    if window is not None:
        valid &= kv_pos > (positions[:, :, None] - window)

    out = _attend_mq(cfg, q, ck, cv, valid, slot_map)
    if cfg.n_heads % tp:   # zero ghost heads
        _, valid_h = _head_ids(comm, cfg, tp)
        out = out * valid_h[None, None, :, None]
    out = out.reshape(B, L, nq_local * hd).astype(cfg.dtype)
    y = _dense(out, p["wo"])
    return comm.allreduce(y, comm.axes.model), {"k": pk, "v": pv}


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): latent KV, cache = compressed c_kv (+ rope key)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, tp: int) -> Params:
    m = cfg.mla
    d = cfg.d_model
    nq_local = cfg.n_heads // tp
    ks = jax.random.split(key, 6)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim

    def nrm(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in)

    return {
        "wq_a": nrm(ks[0], (d, m.q_lora_rank), d),
        "wq_b": nrm(ks[1], (m.q_lora_rank, nq_local * qk_dim), m.q_lora_rank),
        "wkv_a": nrm(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), d),
        "wkv_b": nrm(ks[3], (m.kv_lora_rank,
                             nq_local * (m.qk_nope_dim + m.v_dim)),
                     m.kv_lora_rank),
        "wo": nrm(ks[4], (nq_local * m.v_dim, d), cfg.n_heads * m.v_dim),
        "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
    }


def mla_attention(comm: Comm, cfg: ModelConfig, p: Params, x, positions):
    m = cfg.mla
    tp = comm.axis_size(comm.axes.model)
    nq_local = cfg.n_heads // tp
    B, L, d = x.shape
    cq = rms_norm(_dense(x, p["wq_a"]), p["q_norm"])
    q = _dense(cq, p["wq_b"]).reshape(B, L, nq_local,
                                      m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = _dense(x, p["wkv_a"])
    c_kv = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = rope(kv_a[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)

    kv = _dense(c_kv, p["wkv_b"]).reshape(B, L, nq_local,
                                          m.qk_nope_dim + m.v_dim)
    k_nope, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, L, nq_local, m.qk_rope_dim))],
        -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    o = kops.attention(
        qf.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        sm_scale=1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim),
        use_pallas=cfg.use_pallas, blockwise_unroll=cfg.probe_unroll)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, nq_local * m.v_dim)
    return comm.allreduce(_dense(o.astype(cfg.dtype), p["wo"]),
                          comm.axes.model)


def init_mla_cache(cfg: ModelConfig, batch_local: int, cache_len: int):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch_local, cache_len, m.kv_lora_rank),
                              cfg.dtype),
            "k_rope": jnp.zeros((batch_local, cache_len, m.qk_rope_dim),
                                cfg.dtype)}


def mla_decode(comm: Comm, cfg: ModelConfig, p: Params, x, cache, position):
    m = cfg.mla
    tp = comm.axis_size(comm.axes.model)
    nq_local = cfg.n_heads // tp
    B = x.shape[0]
    cq = rms_norm(_dense(x, p["wq_a"]), p["q_norm"])
    q = _dense(cq, p["wq_b"]).reshape(B, 1, nq_local,
                                      m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, position[:, None], cfg.rope_theta)

    kv_a = _dense(x, p["wkv_a"])
    c_kv_new = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope_new = rope(kv_a[..., None, m.kv_lora_rank:],
                      position[:, None], cfg.rope_theta)[:, :, 0]

    upd = lambda c, u, i: lax.dynamic_update_slice_in_dim(c, u, i, axis=0)
    ckv = jax.vmap(upd)(cache["c_kv"], c_kv_new.astype(cfg.dtype), position)
    ckr = jax.vmap(upd)(cache["k_rope"], k_rope_new.astype(cfg.dtype),
                        position)
    S = ckv.shape[1]

    # absorbed attention: score = q_nope . (W_kb^T c) + q_rope . k_rope
    wkv = p["wkv_b"].reshape(m.kv_lora_rank, nq_local, m.qk_nope_dim + m.v_dim)
    w_k = wkv[..., :m.qk_nope_dim]         # (r, h, nope)
    w_v = wkv[..., m.qk_nope_dim:]         # (r, h, v)
    q_abs = jnp.einsum("bohn,rhn->bohr", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))   # (B,1,h,r)
    sc = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    logits = (jnp.einsum("bohr,bsr->bhs", q_abs,
                         ckv.astype(jnp.float32)) +
              jnp.einsum("bohn,bsn->bhs", q_rope.astype(jnp.float32),
                         ckr.astype(jnp.float32))) * sc
    valid = jnp.arange(S)[None, :] <= position[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    pr = jax.nn.softmax(logits, -1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_v.astype(jnp.float32))
    o = o.reshape(B, 1, nq_local * m.v_dim).astype(cfg.dtype)
    y = comm.allreduce(_dense(o, p["wo"]), comm.axes.model)
    return y, {"c_kv": ckv, "k_rope": ckr}


# ---------------------------------------------------------------------------
# MLP (dense swiglu, column+row parallel)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, tp: int, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    ff_local = (d_ff or cfg.d_ff) // tp
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, ff_local), jnp.float32)
        / math.sqrt(d),
        "w_up": jax.random.normal(k2, (d, ff_local), jnp.float32)
        / math.sqrt(d),
        "w_down": jax.random.normal(k3, (ff_local, d), jnp.float32)
        / math.sqrt(d_ff or cfg.d_ff),
    }


def mlp(comm: Comm, cfg: ModelConfig, p: Params, x):
    h = jax.nn.silu(_dense(x, p["w_gate"])) * _dense(x, p["w_up"])
    return comm.allreduce(_dense(h, p["w_down"]), comm.axes.model)


# ---------------------------------------------------------------------------
# MoE (EP over `model` axis, pairwise-alltoall dispatch)
# ---------------------------------------------------------------------------

def moe_ep_size(cfg: ModelConfig, tp: int, dp: int) -> int:
    return tp * dp if cfg.moe.ep_over_data else tp


def init_moe(key, cfg: ModelConfig, tp: int, dp: int = 1) -> Params:
    mo = cfg.moe
    d = cfg.d_model
    e_local = -(-mo.n_experts // moe_ep_size(cfg, tp, dp))
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)

    def nrm(k, shape, fan):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan)

    p = {
        "router": nrm(k1, (d, mo.n_experts), d),
        "w_gate": nrm(k2, (e_local, d, mo.d_ff), d),
        "w_up": nrm(k3, (e_local, d, mo.d_ff), d),
        "w_down": nrm(k4, (e_local, mo.d_ff, d), mo.d_ff),
    }
    if mo.n_shared:
        p["shared"] = init_mlp(k5, cfg, tp, d_ff=mo.n_shared * mo.d_ff)
    return p


def moe(comm: Comm, cfg: ModelConfig, p: Params, x):
    """x: (B, L, d) replicated over model -> same.

    EP dispatch: tokens are sequence-split over the model axis (free — x is
    replicated there), routed top-k with capacity dropping, delivered to
    expert owners with the paper's pairwise `alltoall` (Fig. 9), and
    returned the same way.  With ep_over_data the EP group is the flattened
    (data, model) PE space — 256-way expert sharding for deepseek-v3."""
    mo = cfg.moe
    tp = comm.axis_size(comm.axes.model)
    ep_axes = ((comm.axes.data, comm.axes.model) if mo.ep_over_data
               else comm.axes.model)
    ep = (int(np.prod([comm.axis_size(a) for a in ep_axes]))
          if isinstance(ep_axes, tuple)
          else comm.axis_size(ep_axes))   # None (dp_only) -> 1
    B, L, d = x.shape
    e_local = -(-mo.n_experts // ep)
    e_pad = e_local * ep

    # 1. my token slice among the model group (data split is the batch);
    # decode steps can carry fewer tokens than tp — pad with zero tokens
    # (they route, compute garbage, and are dropped on return)
    flat = x.reshape(B * L, d)
    t_total = B * L
    t_pad = -(-t_total // tp) * tp
    if t_pad != t_total:
        flat = jnp.pad(flat, ((0, t_pad - t_total), (0, 0)))
    t_local = t_pad // tp
    my = comm.axis_index(comm.axes.model)
    xs = lax.dynamic_slice_in_dim(flat, my * t_local, t_local, axis=0)

    # 2. route (over the real expert count)
    gates = jax.nn.softmax(
        _dense(xs, p["router"]).astype(jnp.float32), -1)       # (T, E)
    topv, tope = lax.top_k(gates, mo.top_k)                    # (T, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # 3. capacity + dispatch buffers (E_pad, C, d) via scatter
    cap = max(1, int(mo.capacity_factor * t_local * mo.top_k
                     / mo.n_experts))
    e_flat = tope.reshape(-1)                                  # (T*K,)
    onehot = jax.nn.one_hot(e_flat, mo.n_experts, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(ranks, e_flat[:, None], 1)[:, 0]
    keep = slot < cap
    tok_idx = jnp.repeat(jnp.arange(t_local), mo.top_k)
    disp = jnp.zeros((e_pad, cap, d), x.dtype)
    disp = disp.at[
        jnp.where(keep, e_flat, 0),
        jnp.where(keep, slot, 0)].add(
        jnp.where(keep[:, None], xs[tok_idx], 0.0))

    # 4. alltoall over the EP group: (E_pad, C, d) -> (e_local, ep*C, d)
    a2a = comm.alltoall(disp.reshape(ep, e_local * cap, d),
                        ep_axes, split_axis=0, concat_axis=0)
    exp_in = a2a.reshape(ep, e_local, cap, d).transpose(1, 0, 2, 3) \
        .reshape(e_local, ep * cap, d)

    # 5. expert FFN
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", exp_in,
                                p["w_gate"].astype(x.dtype)))
         * jnp.einsum("ecd,edf->ecf", exp_in, p["w_up"].astype(x.dtype)))
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))

    # 6. alltoall back + combine
    y = y.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3) \
        .reshape(ep, e_local * cap, d)
    back = comm.alltoall(y, ep_axes, split_axis=0, concat_axis=0)
    buf = back.reshape(e_pad, cap, d)
    gathered = buf[jnp.where(keep, e_flat, 0),
                   jnp.where(keep, slot, 0)]                   # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = (topv.reshape(-1) * keep).astype(jnp.float32)[:, None]
    ys = jnp.zeros((t_local, d), jnp.float32).at[tok_idx].add(
        gathered.astype(jnp.float32) * w)

    # 7. allgather token slices back to model-replicated layout
    full = comm.allgather(ys.astype(x.dtype), comm.axes.model, concat_axis=0)
    out = full[:t_total].reshape(B, L, d)
    if mo.n_shared:
        out = out + mlp(comm, cfg, p["shared"], x)
    # aux losses (load balance) for training
    me = jnp.mean(gates, 0)
    ce = jnp.mean(
        jax.nn.one_hot(tope, mo.n_experts, dtype=jnp.float32).sum(1), 0)
    aux = mo.n_experts * jnp.sum(me * ce)
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 block (heads sharded over model)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, tp: int) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    d_in_local = d_in // tp
    nheads_local = d_in_local // s.head_dim
    conv_dim = d_in_local + 2 * s.n_groups * s.state
    ks = jax.random.split(key, 5)

    def nrm(k, shape, fan):
        return jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan)

    return {
        # [z, x, B, C, dt] fused in-proj; B/C replicated groups per shard
        "w_in": nrm(ks[0], (d, 2 * d_in_local + 2 * s.n_groups * s.state
                            + nheads_local), d),
        "conv_w": nrm(ks[1], (s.conv_width, conv_dim), s.conv_width),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads_local)),
        "dt_bias": jnp.zeros((nheads_local,), jnp.float32),
        "d_skip": jnp.ones((nheads_local,), jnp.float32),
        "norm_w": jnp.zeros((d_in_local,), jnp.float32),
        "w_out": nrm(ks[2], (d_in_local, d), d_in),
    }


def _mamba_split(cfg: ModelConfig, tp: int):
    s = cfg.ssm
    d_in_local = s.expand * cfg.d_model // tp
    nheads_local = d_in_local // s.head_dim
    gdim = s.n_groups * s.state
    return d_in_local, nheads_local, gdim


def mamba2(comm: Comm, cfg: ModelConfig, p: Params, x):
    """Full-sequence Mamba2 (train/prefill). One allreduce at out-proj."""
    s = cfg.ssm
    tp = comm.axis_size(comm.axes.model)
    B, L, d = x.shape
    d_in_local, nheads_local, gdim = _mamba_split(cfg, tp)

    zxbcdt = _dense(x, p["w_in"])
    z = zxbcdt[..., :d_in_local]
    xbc = zxbcdt[..., d_in_local:d_in_local * 2 + 2 * gdim]
    dt = zxbcdt[..., -nheads_local:]

    # depthwise causal conv over [x, B, C]
    w = p["conv_w"].astype(xbc.dtype)
    acc = xbc * w[-1]
    for i in range(1, s.conv_width):
        acc = acc + jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :L] * w[-1 - i]
    xbc = jax.nn.silu(acc + p["conv_b"].astype(acc.dtype))

    xs = xbc[..., :d_in_local].reshape(B, L, nheads_local, s.head_dim)
    b_mat = xbc[..., d_in_local:d_in_local + gdim] \
        .reshape(B, L, s.n_groups, s.state)
    c_mat = xbc[..., d_in_local + gdim:] \
        .reshape(B, L, s.n_groups, s.state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a_log = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, _ = kops.ssd(xs, dt, a_log, b_mat, c_mat, chunk=s.chunk,
                    use_pallas=cfg.use_pallas, unroll=cfg.probe_unroll)
    y = y + xs * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, L, d_in_local)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm_w"])
    out = _dense(y.astype(cfg.dtype), p["w_out"])
    return comm.allreduce(out, comm.axes.model)


def init_mamba_cache(cfg: ModelConfig, tp: int, batch_local: int):
    s = cfg.ssm
    d_in_local, nheads_local, gdim = _mamba_split(cfg, tp)
    conv_dim = d_in_local + 2 * gdim
    return {
        "conv": jnp.zeros((batch_local, s.conv_width - 1, conv_dim),
                          cfg.dtype),
        "ssm": jnp.zeros((batch_local, nheads_local, s.head_dim, s.state),
                         jnp.float32),
    }


def mamba2_decode(comm: Comm, cfg: ModelConfig, p: Params, x, cache):
    """Single-step recurrence (decode)."""
    s = cfg.ssm
    tp = comm.axis_size(comm.axes.model)
    B = x.shape[0]
    d_in_local, nheads_local, gdim = _mamba_split(cfg, tp)

    zxbcdt = _dense(x[:, 0], p["w_in"])                     # (B, ...)
    z = zxbcdt[..., :d_in_local]
    xbc = zxbcdt[..., d_in_local:d_in_local * 2 + 2 * gdim]
    dt = zxbcdt[..., -nheads_local:]

    conv_hist = jnp.concatenate([cache["conv"],
                                 xbc[:, None].astype(cfg.dtype)], 1)
    w = p["conv_w"].astype(jnp.float32)
    acc = jnp.einsum("bwc,wc->bc", conv_hist.astype(jnp.float32), w)
    xbc = jax.nn.silu(acc + p["conv_b"].astype(jnp.float32))

    xs = xbc[..., :d_in_local].reshape(B, nheads_local, s.head_dim)
    b_t = xbc[..., d_in_local:d_in_local + gdim].reshape(B, s.n_groups,
                                                         s.state)
    c_t = xbc[..., d_in_local + gdim:].reshape(B, s.n_groups, s.state)
    group = nheads_local // s.n_groups
    b_h = jnp.repeat(b_t, group, 1)
    c_h = jnp.repeat(c_t, group, 1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None] * dt)
    state = cache["ssm"] * a[..., None, None] + (
        dt[..., None, None] * xs[..., None].astype(jnp.float32)
        * b_h[..., None, :].astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", c_h.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(B, d_in_local)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"])
    out = _dense(y[:, None].astype(cfg.dtype), p["w_out"])
    out = comm.allreduce(out, comm.axes.model)
    new_cache = {"conv": conv_hist[:, 1:], "ssm": state}
    return out, new_cache
