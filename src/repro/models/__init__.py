"""models subsystem."""
