"""Model assembly: layer stacks per family, train/prefill/decode entries.

Layer stacks are lax.scan'd over stacked parameters so the traced HLO is
one layer deep regardless of depth (compile-time hygiene for the 512-chip
dry-run).  Heterogeneous patterns scan over their repeating unit:

  dense          scan [attn, mlp] x L
  gemma2         scan [local-SWA pair: attn_l, mlp, attn_g, mlp] x L/2
  moe            unrolled first_dense layers + scan [attn, moe] x rest
  ssm            scan [mamba2] x L
  hybrid/zamba2  scan [mamba2] x period, shared attn block between segments
  audio          scan [attn (non-causal), mlp] x L (encoder)
  vlm            dense stack; image embeds from the stub frontend are
                 scattered over the first n_frontend_tokens positions
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..parallel.comm import Comm
from . import layers as L
from .config import ModelConfig

Params = dict


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "selective":
        # keep matmul outputs, recompute elementwise chains (§Perf P5)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _tp(comm: Comm) -> int:
    return comm.axis_size(comm.axes.model)


def _scan(cfg, body, carry, xs):
    """lax.scan, fully unrolled when probing so cost_analysis sees every
    iteration (XLA counts a while body once)."""
    length = jax.tree.leaves(xs)[0].shape[0]
    return lax.scan(body, carry, xs,
                    unroll=length if cfg.probe_unroll else 1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(key, cfg: ModelConfig, tp: int, dp: int = 1) -> Params:
    """LOCAL parameter shards (call inside shard_map, with the key folded
    by model-rank for sharded leaves — see parallel/sharding.py)."""
    ks = jax.random.split(key, 8)
    p: Params = {"embed": L.init_embedding(ks[0], cfg, tp),
                 "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    fam = cfg.family

    if fam in ("dense", "vlm", "audio"):
        def one(k):
            k1, k2 = jax.random.split(k)
            return {"attn": L.init_attention(k1, cfg, tp),
                    "mlp": L.init_mlp(k2, cfg, tp),
                    "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                    "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
        if cfg.local_global_period:
            assert cfg.n_layers % 2 == 0
            p["pairs"] = {"local": _stack_init(ks[1], cfg.n_layers // 2, one),
                          "global": _stack_init(ks[2], cfg.n_layers // 2, one)}
        else:
            p["layers"] = _stack_init(ks[1], cfg.n_layers, one)

    elif fam == "moe":
        def one_dense(k):
            k1, k2 = jax.random.split(k)
            attn = (L.init_mla(k1, cfg, tp) if cfg.attn == "mla"
                    else L.init_attention(k1, cfg, tp))
            return {"attn": attn, "mlp": L.init_mlp(k2, cfg, tp),
                    "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                    "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}

        def one_moe(k):
            k1, k2 = jax.random.split(k)
            attn = (L.init_mla(k1, cfg, tp) if cfg.attn == "mla"
                    else L.init_attention(k1, cfg, tp))
            return {"attn": attn, "moe": L.init_moe(k2, cfg, tp, dp),
                    "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                    "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
        nd = cfg.moe.first_dense_layers
        if nd:
            p["dense_layers"] = _stack_init(ks[1], nd, one_dense)
        p["layers"] = _stack_init(ks[2], cfg.n_layers - nd, one_moe)
        if cfg.mtp:
            k1, k2 = jax.random.split(ks[3])
            p["mtp"] = {"proj": jax.random.normal(
                k1, (2 * cfg.d_model, cfg.d_model), jnp.float32)
                / math.sqrt(2 * cfg.d_model),
                "block": one_dense(k2),
                "ln": jnp.zeros((cfg.d_model,), jnp.float32)}

    elif fam == "ssm":
        def one(k):
            return {"mamba": L.init_mamba2(k, cfg, tp),
                    "ln": jnp.zeros((cfg.d_model,), jnp.float32)}
        p["layers"] = _stack_init(ks[1], cfg.n_layers, one)

    elif fam == "hybrid":
        def one(k):
            return {"mamba": L.init_mamba2(k, cfg, tp),
                    "ln": jnp.zeros((cfg.d_model,), jnp.float32)}
        p["layers"] = _stack_init(ks[1], cfg.n_layers, one)
        k1, k2 = jax.random.split(ks[2])
        p["shared_attn"] = {"attn": L.init_attention(k1, cfg, tp),
                            "mlp": L.init_mlp(k2, cfg, tp),
                            "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                            "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    else:
        raise ValueError(fam)
    if cfg.param_dtype != jnp.float32:
        p = jax.tree.map(
            lambda w: w.astype(cfg.param_dtype) if w.ndim >= 2 else w, p)
    return p


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def _fsdp_gather(comm: Comm, cfg: ModelConfig, bp):
    """ZeRO-3: block weights live sharded over `data` (dim 0 of every 2-D
    leaf); gather them just-in-time inside the layer (transient in scan).
    The VJP of the gather reduce-scatters the cotangents across data, so
    fsdp leaves arrive in the gradient tree already summed over the data
    axis (train.py skips grad_sync for them)."""
    if not cfg.fsdp:
        return bp
    return jax.tree.map(
        lambda w: comm.allgather(w, comm.axes.data, concat_axis=0)
        if w.ndim == 2 else w, bp)


def _attn_block(comm, cfg, bp, x, positions, is_local=False):
    bp = _fsdp_gather(comm, cfg, bp)
    h = L.rms_norm(x, bp["ln1"])
    if cfg.attn == "mla":
        a = L.mla_attention(comm, cfg, bp["attn"], h, positions)
    else:
        a = L.attention(comm, cfg, bp["attn"], h, positions,
                        is_local_layer=is_local)
    x = x + a
    h = L.rms_norm(x, bp["ln2"])
    if "moe" in bp:
        m, aux = L.moe(comm, cfg, bp["moe"], h)
        return x + m, aux
    return x + L.mlp(comm, cfg, bp["mlp"], h), jnp.zeros((), jnp.float32)


def _mamba_block(comm, cfg, bp, x):
    bp = _fsdp_gather(comm, cfg, bp)
    return x + L.mamba2(comm, cfg, bp["mamba"], L.rms_norm(x, bp["ln"]))


def _embed_scaled(comm, cfg, params, tokens):
    x = L.embed(comm, cfg, params["embed"], tokens)
    if cfg.local_global_period:      # gemma2 scales embeddings by sqrt(d)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    return x


def forward(comm: Comm, cfg: ModelConfig, params: Params, tokens=None, *,
            frames=None, frontend_embeds=None) -> tuple:
    """Full-sequence forward -> (hidden (B,L,d), aux_loss scalar)."""
    if cfg.frontend == "audio":
        x = frames.astype(cfg.dtype)
        B, seq = x.shape[0], x.shape[1]
    else:
        x = _embed_scaled(comm, cfg, params, tokens)
        B, seq = tokens.shape
    if cfg.frontend == "vision" and frontend_embeds is not None:
        nf = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(cfg.dtype), x[:, nf:]], 1)
    positions = jnp.broadcast_to(jnp.arange(seq)[None], (B, seq))
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "vlm", "audio"):
        if cfg.local_global_period:
            def pair(x, bp):
                x, _ = _attn_block(comm, cfg, bp[0], x, positions,
                                   is_local=True)
                x, _ = _attn_block(comm, cfg, bp[1], x, positions)
                return x, ()
            pair = _maybe_remat(cfg, pair)
            x, _ = _scan(cfg, pair, x,
                         (params["pairs"]["local"],
                          params["pairs"]["global"]))
        else:
            def step(x, bp):
                x, _ = _attn_block(comm, cfg, bp, x, positions)
                return x, ()
            step = _maybe_remat(cfg, step)
            x, _ = _scan(cfg, step, x, params["layers"])

    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            for i in range(nd):
                bp = jax.tree.map(lambda a: a[i], params["dense_layers"])
                blk = _maybe_remat(
                    cfg, lambda x, bp=bp: _attn_block(comm, cfg, bp, x,
                                                      positions))
                x, _ = blk(x)

        def step(carry, bp):
            x, aux = carry
            x, a = _attn_block(comm, cfg, bp, x, positions)
            return (x, aux + a), ()
        step = _maybe_remat(cfg, step)
        (x, aux_total), _ = _scan(cfg, step, (x, aux_total),
                                  params["layers"])

    elif fam == "ssm":
        def step(x, bp):
            return _mamba_block(comm, cfg, bp, x), ()
        step = _maybe_remat(cfg, step)
        x, _ = _scan(cfg, step, x, params["layers"])

    elif fam == "hybrid":
        period = cfg.hybrid_attn_period
        n = cfg.n_layers
        starts = list(range(0, n, period))
        def seg_step(x, bp):
            return _mamba_block(comm, cfg, bp, x), ()
        seg_step = _maybe_remat(cfg, seg_step)
        for s0 in starts:
            seg_len = min(period, n - s0)
            seg = jax.tree.map(lambda a: a[s0:s0 + seg_len], params["layers"])
            x, _ = _scan(cfg, seg_step, x, seg)
            shared = _maybe_remat(
                cfg, lambda x: _attn_block(comm, cfg, params["shared_attn"],
                                           x, positions)[0])
            x = shared(x)
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"])
    return x, aux_total


def train_loss(comm: Comm, cfg: ModelConfig, params: Params, batch: dict):
    """Token-mean cross-entropy (+ MoE aux, + MTP head when configured)."""
    h, aux = forward(comm, cfg, params, batch.get("tokens"),
                     frames=batch.get("frames"),
                     frontend_embeds=batch.get("frontend_embeds"))
    logits = L.lm_logits(comm, cfg, params["embed"], h)
    targets = batch["targets"]
    tok_loss = L.sharded_xent(comm, cfg, logits, targets)
    loss = jnp.mean(tok_loss)
    if cfg.mtp and "mtp" in params:
        # depth-1 MTP: combine h_t with emb(target_t) to predict t+2
        emb_next = L.embed(comm, cfg, params["embed"], targets)
        proj = _fsdp_gather(comm, cfg, {"w": params["mtp"]["proj"]})["w"]
        hm = L._dense(jnp.concatenate(
            [L.rms_norm(h, params["mtp"]["ln"]), emb_next], -1), proj)
        B, seq = targets.shape
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (B, seq))
        hm, _ = _attn_block(comm, cfg, params["mtp"]["block"], hm, positions)
        lg2 = L.lm_logits(comm, cfg, params["embed"], hm[:, :-1])
        mtp_loss = jnp.mean(L.sharded_xent(comm, cfg, lg2, targets[:, 1:]))
        loss = loss + 0.1 * mtp_loss
    if cfg.moe is not None:
        loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return loss


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, tp: int, batch_local: int, cache_len: int,
                 kind: str, seq_shards: int = 1, is_local=False):
    S = cache_len // seq_shards
    if kind == "mla":
        return L.init_mla_cache(cfg, batch_local, S)
    if kind == "mamba":
        return L.init_mamba_cache(cfg, tp, batch_local)
    wb = None
    if cfg.window is not None:
        wb = cfg.window
    if is_local and cfg.local_global_period is not None:
        wb = cfg.local_window
    return L.init_attn_cache(cfg, tp, batch_local, S, window_bound=wb)


def init_cache(cfg: ModelConfig, tp: int, batch_local: int, cache_len: int,
               seq_shards: int = 1) -> Params:
    """Stacked (per scanned layer group) decode caches."""
    fam = cfg.family
    def stack(n, fn):
        one = fn()
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a[None], (n,) + a.shape).copy(), one)

    if fam in ("dense", "vlm"):
        if cfg.local_global_period:
            return {"pairs_local": stack(
                        cfg.n_layers // 2,
                        lambda: _layer_cache(cfg, tp, batch_local, cache_len,
                                             "gqa", seq_shards, True)),
                    "pairs_global": stack(
                        cfg.n_layers // 2,
                        lambda: _layer_cache(cfg, tp, batch_local, cache_len,
                                             "gqa", seq_shards))}
        return {"layers": stack(cfg.n_layers, lambda: _layer_cache(
            cfg, tp, batch_local, cache_len, "gqa", seq_shards))}
    if fam == "moe":
        kind = "mla" if cfg.attn == "mla" else "gqa"
        nd = cfg.moe.first_dense_layers
        out = {"layers": stack(cfg.n_layers - nd, lambda: _layer_cache(
            cfg, tp, batch_local, cache_len, kind, seq_shards))}
        if nd:
            out["dense_layers"] = stack(nd, lambda: _layer_cache(
                cfg, tp, batch_local, cache_len, kind, seq_shards))
        return out
    if fam == "ssm":
        return {"layers": stack(cfg.n_layers, lambda: _layer_cache(
            cfg, tp, batch_local, cache_len, "mamba"))}
    if fam == "hybrid":
        n_shared = len(range(0, cfg.n_layers, cfg.hybrid_attn_period))
        return {"layers": stack(cfg.n_layers, lambda: _layer_cache(
                    cfg, tp, batch_local, cache_len, "mamba")),
                "shared": stack(n_shared, lambda: _layer_cache(
                    cfg, tp, batch_local, cache_len, "gqa", seq_shards))}
    raise ValueError(fam)


def _attn_decode_block(comm, cfg, bp, x, cache, position, is_local=False,
                       seq_shards=1):
    h = L.rms_norm(x, bp["ln1"])
    if cfg.attn == "mla":
        a, cache = L.mla_decode(comm, cfg, bp["attn"], h, cache, position)
    else:
        a, cache = L.attention_decode(comm, cfg, bp["attn"], h, cache,
                                      position, is_local_layer=is_local,
                                      seq_shards=seq_shards)
    x = x + a
    h = L.rms_norm(x, bp["ln2"])
    if "moe" in bp:
        m, _ = L.moe(comm, cfg, bp["moe"], h)
        x = x + m
    else:
        x = x + L.mlp(comm, cfg, bp["mlp"], h)
    return x, cache


def decode_step(comm: Comm, cfg: ModelConfig, params: Params, cache: Params,
                tokens, positions, *, seq_shards: int = 1):
    """One decode step: tokens (B,1), positions (B,) -> (logits, new_cache)."""
    x = _embed_scaled(comm, cfg, params, tokens)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        if cfg.local_global_period:
            def pair(x, bps):
                bp_l, bp_g, c_l, c_g = bps
                x, c_l = _attn_decode_block(comm, cfg, bp_l, x, c_l,
                                            positions, is_local=True,
                                            seq_shards=seq_shards)
                x, c_g = _attn_decode_block(comm, cfg, bp_g, x, c_g,
                                            positions, seq_shards=seq_shards)
                return x, (c_l, c_g)
            x, (cl, cg) = _scan(cfg, pair, x,
                                (params["pairs"]["local"],
                                 params["pairs"]["global"],
                                 cache["pairs_local"],
                                 cache["pairs_global"]))
            new_cache = {"pairs_local": cl, "pairs_global": cg}
        else:
            def step(x, bc):
                bp, c = bc
                x, c = _attn_decode_block(comm, cfg, bp, x, c, positions,
                                          seq_shards=seq_shards)
                return x, c
            x, nc = _scan(cfg, step, x, (params["layers"],
                                         cache["layers"]))
            new_cache = {"layers": nc}

    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        new_cache = {}
        if nd:
            dcs = []
            for i in range(nd):
                bp = jax.tree.map(lambda a: a[i], params["dense_layers"])
                c = jax.tree.map(lambda a: a[i], cache["dense_layers"])
                x, c = _attn_decode_block(comm, cfg, bp, x, c, positions,
                                          seq_shards=seq_shards)
                dcs.append(c)
            new_cache["dense_layers"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *dcs)
        def step(x, bc):
            bp, c = bc
            x, c = _attn_decode_block(comm, cfg, bp, x, c, positions,
                                      seq_shards=seq_shards)
            return x, c
        x, nc = _scan(cfg, step, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = nc

    elif fam == "ssm":
        def step(x, bc):
            bp, c = bc
            h = L.rms_norm(x, bp["ln"])
            y, c = L.mamba2_decode(comm, cfg, bp["mamba"], h, c)
            return x + y, c
        x, nc = _scan(cfg, step, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": nc}

    elif fam == "hybrid":
        period = cfg.hybrid_attn_period
        n = cfg.n_layers
        def seg_step(x, bc):
            bp, c = bc
            h = L.rms_norm(x, bp["ln"])
            y, c = L.mamba2_decode(comm, cfg, bp["mamba"], h, c)
            return x + y, c
        nc_layers, nc_shared = [], []
        for si, s0 in enumerate(range(0, n, period)):
            seg_len = min(period, n - s0)
            seg_p = jax.tree.map(lambda a: a[s0:s0 + seg_len],
                                 params["layers"])
            seg_c = jax.tree.map(lambda a: a[s0:s0 + seg_len],
                                 cache["layers"])
            x, c = _scan(cfg, seg_step, x, (seg_p, seg_c))
            nc_layers.append(c)
            sc = jax.tree.map(lambda a: a[si], cache["shared"])
            x, sc = _attn_decode_block(comm, cfg, params["shared_attn"], x,
                                       sc, positions, seq_shards=seq_shards)
            nc_shared.append(sc)
        new_cache = {
            "layers": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                   *nc_layers),
            "shared": jax.tree.map(lambda *xs: jnp.stack(xs), *nc_shared)}
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(comm, cfg, params["embed"], x)
    return logits, new_cache


def prefill(comm: Comm, cfg: ModelConfig, params: Params, tokens=None, *,
            frames=None, frontend_embeds=None):
    """Prefill forward: returns last-position logits (cache fill is modeled
    by the forward pass itself; serving keeps the KV as activations)."""
    h, _ = forward(comm, cfg, params, tokens, frames=frames,
                   frontend_embeds=frontend_embeds)
    logits = L.lm_logits(comm, cfg, params["embed"], h[:, -1:])
    return logits


# ---------------------------------------------------------------------------
# paged KV (serving engine, DESIGN.md §15)
# ---------------------------------------------------------------------------

def paged_families() -> tuple[str, ...]:
    """Families the paged-KV serving path supports (attention KV caches;
    SSM/MLA state is not paged — the engine guards on this)."""
    return ("dense", "vlm")


def init_kv_pool(cfg: ModelConfig, tp: int, num_pages: int,
                 page_size: int) -> Params:
    """Stacked per-layer-group paged KV pools: like `init_cache` but the
    (B, S) cache dims become (num_pages, page_size) — page p of every
    sequence lives at the SAME physical index in every layer's pool, so
    one page table serves the whole stack."""
    if cfg.family not in paged_families():
        raise ValueError(
            f"paged KV supports {paged_families()}, not {cfg.family!r}")

    def stack(n, fn):
        one = fn()
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a[None], (n,) + a.shape).copy(), one)

    def one_pool():
        return L.init_attn_cache(cfg, tp, num_pages, page_size)

    if cfg.local_global_period:
        return {"pairs_local": stack(cfg.n_layers // 2, one_pool),
                "pairs_global": stack(cfg.n_layers // 2, one_pool)}
    return {"layers": stack(cfg.n_layers, one_pool)}


def _attn_block_paged(comm, cfg, bp, x, pool, page_table, positions,
                      page_size, is_local=False):
    h = L.rms_norm(x, bp["ln1"])
    a, pool = L.attention_paged(comm, cfg, bp["attn"], h, pool, page_table,
                                positions, page_size=page_size,
                                is_local_layer=is_local)
    x = x + a
    h = L.rms_norm(x, bp["ln2"])
    return x + L.mlp(comm, cfg, bp["mlp"], h), pool


def _paged_stack(comm, cfg, params, pool, page_table, x, positions,
                 page_size):
    """Run the layer stack against paged KV pools.  One code path for
    prefill (L = prompt bucket) and decode (L = 1): identical traced ops
    per row is what makes the engine's batched-vs-alone decode tokens
    bit-identical (DESIGN.md §15)."""
    if cfg.local_global_period:
        def pair(x, ps):
            bp_l, bp_g, p_l, p_g = ps
            x, p_l = _attn_block_paged(comm, cfg, bp_l, x, p_l, page_table,
                                       positions, page_size, is_local=True)
            x, p_g = _attn_block_paged(comm, cfg, bp_g, x, p_g, page_table,
                                       positions, page_size)
            return x, (p_l, p_g)
        x, (pl, pg) = _scan(cfg, pair, x,
                            (params["pairs"]["local"],
                             params["pairs"]["global"],
                             pool["pairs_local"], pool["pairs_global"]))
        return x, {"pairs_local": pl, "pairs_global": pg}
    def step(x, bc):
        bp, pl = bc
        x, pl = _attn_block_paged(comm, cfg, bp, x, pl, page_table,
                                  positions, page_size)
        return x, pl
    x, np_ = _scan(cfg, step, x, (params["layers"], pool["layers"]))
    return x, {"layers": np_}


def prefill_paged(comm: Comm, cfg: ModelConfig, params: Params, pool: Params,
                  page_table, tokens, positions, *, page_size: int):
    """Paged prefill fast-path: ONE forward pass over the whole prompt
    bucket that also fills the sequence's KV pages (vs the seed launcher's
    per-token teacher forcing).  tokens: (B, L_bucket); positions: (B,
    L_bucket).  Returns (full-bucket logits (B, L, vocab_local), pool).
    Rows past the true prompt length write garbage K/V into the row's own
    reserved (or null) pages; decode overwrites each position before the
    causal mask can ever expose it."""
    x = _embed_scaled(comm, cfg, params, tokens)
    x, pool = _paged_stack(comm, cfg, params, pool, page_table, x,
                           positions, page_size)
    x = L.rms_norm(x, params["final_norm"])
    return L.lm_logits(comm, cfg, params["embed"], x), pool


def decode_step_paged(comm: Comm, cfg: ModelConfig, params: Params,
                      pool: Params, page_table, tokens, positions, *,
                      page_size: int):
    """One paged decode step: tokens (B,1), positions (B,) -> (logits
    (B,1,vocab_local), pool).  Identical to `decode_step` numerics on a
    full-length cache; reads are page-table indexed."""
    x = _embed_scaled(comm, cfg, params, tokens)
    x, pool = _paged_stack(comm, cfg, params, pool, page_table, x,
                           positions[:, None], page_size)
    x = L.rms_norm(x, params["final_norm"])
    return L.lm_logits(comm, cfg, params["embed"], x), pool
