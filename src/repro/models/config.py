"""ModelConfig — one dataclass that spans all 10 assigned architectures.

Families: dense GQA decoders, MoE (top-k + shared experts, MLA), hybrid
(Mamba2 + shared attention), pure SSM, encoder-only audio, VLM (backbone +
stub frontend).  `input_specs()` produces the ShapeDtypeStruct stand-ins
for each assigned input shape (train / prefill / decode / long-decode).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden
    n_shared: int = 0          # shared (always-on) experts
    first_dense_layers: int = 0
    router_noise: float = 0.0
    capacity_factor: float = 1.25
    ep_over_data: bool = False   # EP group = (data x model) instead of model


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 128           # N
    head_dim: int = 64         # P
    n_groups: int = 1          # G (B/C groups)
    chunk: int = 128
    conv_width: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # attention flavor
    attn: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    causal: bool = True
    window: int | None = None            # sliding window (all layers)
    local_global_period: int | None = None  # gemma2: odd layers local SWA
    local_window: int | None = None
    softcap: float | None = None          # attention logit softcap
    final_softcap: float | None = None    # lm-head logit softcap
    rope_theta: float = 10000.0
    mla: MLAConfig | None = None
    # MoE / SSM / hybrid
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid_attn_period: int | None = None   # zamba2: shared attn every k
    # heads
    tie_embeddings: bool = False
    mtp: bool = False            # deepseek multi-token prediction head
    # frontend stub
    frontend: str | None = None  # vision | audio
    n_frontend_tokens: int = 0
    # execution
    dtype: Any = jnp.bfloat16          # activation/compute dtype
    param_dtype: Any = jnp.float32     # stored weights (bf16 for the
                                       # largest archs; optimizer math
                                       # always runs f32)
    use_pallas: bool = False
    attention: str = "mono"      # mono | ring: "ring" runs sequence-sharded
                                 # attention as the fused comm-compute ring
                                 # (core/fusion.ring_attention) when inputs
                                 # are sequence-sharded over `data` on the
                                 # shmem backend (DESIGN.md §14)
    remat: str = "full"          # none | full
    logit_dtype: Any = jnp.float32
    fsdp: bool = False           # ZeRO-3: 2D block weights sharded over data
    probe_unroll: bool = False   # roofline probes: unroll every scan so
                                 # cost_analysis counts all iterations
    microbatches: int = 1        # grad-accumulation steps per train_step
    moment_dtype: str = "f32"    # f32 | bf16 | int8 (optimizer moments)
    shard_strategy: str = "tp"   # tp | dp_only (replicate params, shard the
                                 # batch over data x model — right for small
                                 # models where TP width starves the MXU)

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    # -- parameter counting (for MODEL_FLOPS = 6*N*D) -----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd if self.attn != "none" else 0
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per_layer = 0
        if self.attn == "gqa":
            per_layer += d * hd * (n_q + 2 * n_kv) + n_q * hd * d
            if self.qkv_bias:
                per_layer += hd * (n_q + 2 * n_kv)
        elif self.attn == "mla":
            m = self.mla
            per_layer += d * m.q_lora_rank
            per_layer += m.q_lora_rank * n_q * (m.qk_nope_dim + m.qk_rope_dim)
            per_layer += d * (m.kv_lora_rank + m.qk_rope_dim)
            per_layer += m.kv_lora_rank * n_q * (m.qk_nope_dim + m.v_dim)
            per_layer += n_q * m.v_dim * d
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per_layer_ssm = d * (2 * d_in + 2 * s.n_groups * s.state + nheads)
            per_layer_ssm += d_in * d + nheads  # out proj + A
            per_layer_ssm += s.conv_width * (d_in + 2 * s.n_groups * s.state)
        # mlp
        if self.moe is not None:
            mo = self.moe
            dense_ff = 3 * d * ff
            routed = 3 * d * mo.d_ff
            active_mlp = (mo.top_k + mo.n_shared) * routed + d * mo.n_experts
            total_mlp = (mo.n_experts + mo.n_shared) * routed + d * mo.n_experts
            mlp = active_mlp if active_only else total_mlp
        else:
            mlp = 3 * d * ff
            dense_ff = mlp

        total = 0
        for i in range(self.n_layers):
            is_ssm_layer = (self.family in ("ssm", "hybrid"))
            if is_ssm_layer:
                total += per_layer_ssm + 2 * d
                continue
            total += per_layer + 2 * d
            if self.moe is not None and i < self.moe.first_dense_layers:
                total += dense_ff
            elif self.d_ff > 0:
                total += mlp
        if self.hybrid_attn_period:
            # one shared attention block (+ mlp) reused
            total += per_layer + 3 * d * self.d_ff + 2 * d
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)


# ---------------------------------------------------------------------------
# input shapes (assigned): each cell is (name, seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k eligibility: sub-quadratic state only (DESIGN.md §5)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    s = SHAPES[shape]
    if s["kind"] == "decode" and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k":
        if cfg.family in LONG_OK_FAMILIES:
            return True, ""
        if cfg.window is not None or cfg.local_global_period is not None:
            return True, ""  # SWA-bounded KV
        return False, "pure full-attention arch skipped for 500k decode"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str, *, batch_override=None):
    """ShapeDtypeStruct stand-ins for every model input of a given shape
    cell (no allocation; shardable)."""
    s = SHAPES[shape]
    B = batch_override or s["global_batch"]
    L = s["seq_len"]
    i32 = jnp.int32
    if s["kind"] == "train":
        if cfg.frontend == "audio":
            # encoder masked-prediction: stub frontend provides frame embeds
            return dict(
                frames=jax.ShapeDtypeStruct((B, L, cfg.d_model), cfg.dtype),
                targets=jax.ShapeDtypeStruct((B, L), i32),
            )
        specs = dict(
            tokens=jax.ShapeDtypeStruct((B, L), i32),
            targets=jax.ShapeDtypeStruct((B, L), i32),
        )
        if cfg.frontend == "vision":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
        return specs
    if s["kind"] == "prefill":
        if cfg.frontend == "audio":
            return dict(frames=jax.ShapeDtypeStruct((B, L, cfg.d_model),
                                                    cfg.dtype))
        specs = dict(tokens=jax.ShapeDtypeStruct((B, L), i32))
        if cfg.frontend == "vision":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
        return specs
    # decode: one new token against a cache of length L
    return dict(
        tokens=jax.ShapeDtypeStruct((B, 1), i32),
        positions=jax.ShapeDtypeStruct((B,), i32),
    )
