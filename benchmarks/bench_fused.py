"""Fused comm-compute benchmark (DESIGN.md §14).

Two sections, one per flagship fused path:

  1. Ring vs monolithic attention cross-over by sequence length: measured
     SIM wall time of the ring-attention pipeline (put_nbi KV rotation
     hidden behind each block's flash partials) against
     allgather-KV-then-monolithic-flash, with choose_attention's modeled
     pricing and pick alongside.
  2. Fused reduce-scatter->AdamW vs the unfused composition (ring RS +
     f32 allgather + separate optimizer pass): WIRE BYTES from the
     profiler's ppermute counters — the fused path allgathers updated
     params at param dtype (bf16 here), so it must move strictly fewer
     bytes — plus steady-state wall time and choose_grad_rs's pick.

  PYTHONPATH=src python -m benchmarks.bench_fused
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives as coll, fusion, sim_ctx
from repro.core.netops import SimNetOps
from repro.core.profile import Profiler
from repro.kernels import ring_attention as ra

from ._util import time_fn as _time

N = 4
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


# -- 1. ring vs monolithic attention -----------------------------------------

def _attn_payload(L, B=1, H=4, D=32, seed=0):
    rng = np.random.default_rng(seed)
    Ls = L // N

    def shard(x):
        return jnp.asarray(
            x.reshape(B, H, N, Ls, D).transpose(2, 0, 1, 3, 4))

    q = rng.standard_normal((B, H, L, D)).astype(np.float32)
    k = rng.standard_normal((B, H, L, D)).astype(np.float32)
    v = rng.standard_normal((B, H, L, D)).astype(np.float32)
    pos = jnp.arange(L, dtype=jnp.int32).reshape(N, Ls)
    return shard(q), shard(k), shard(v), pos


def bench_ring_attention():
    print("\n== ring vs monolithic attention (SIM, n=%d) ==" % N)
    ctx = sim_ctx(N)
    net = ctx.net
    for L in (256, 1024, 4096):
        qs, ks, vs, pos = _attn_payload(L)
        kv_block_bytes = 2 * ks[0].size * 4          # one PE's K+V shard

        def ring(q_, k_, v_, p_):
            return fusion.ring_attention(ctx, q_, k_, v_, p_, p_,
                                         causal=True)

        kpos_full = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (N, L))

        def mono(q_, k_, v_, p_, kp_):
            kf = coll.fcollect(net, k_, axis=2)
            vf = coll.fcollect(net, v_, axis=2)
            part = coll._lmap(
                net, lambda a, b, c, d, e: ra.attn_block_partials(
                    a, b, c, d, e, causal=True), q_, kf, vf, p_, kp_)
            return ra.finalize(part, q_.dtype)

        t_ring = _time(ring, qs, ks, vs, pos)
        t_mono = _time(mono, qs, ks, vs, pos, kpos_full)
        # price it the way the selector does: per-block compute measured
        # as the monolithic time split over n blocks
        pick, times = fusion.choose_attention(N, kv_block_bytes,
                                              t_mono / N)
        row(f"attn_mono_{kv_block_bytes}B_us", t_mono * 1e6,
            f"L={L} allgather-KV+flash")
        row(f"attn_ring_{kv_block_bytes}B_us", t_ring * 1e6,
            f"L={L} x{t_mono / max(t_ring, 1e-12):.2f}vs-mono "
            f"pred={times['ring'] * 1e6:.2f}us pick={pick}")


# -- 2. fused RS->AdamW: wire bytes + wall time ------------------------------

_HP = dict(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd_coef=0.1)


def _grad_fns(net, total, wd):
    def fused(g, p, m, v):
        t = jnp.asarray(1.0, jnp.float32)
        c1 = 1.0 - _HP["b1"] ** t
        c2 = 1.0 - _HP["b2"] ** t
        new_p, new_m, new_v, info = fusion.fused_rs_adam(
            net, g, p, m, v, wd, c1, c2, scale=float(N),
            out_dtype=jnp.bfloat16, **_HP)
        return coll.allgather_unpad(net, new_p, info), new_m, new_v

    def unfused(g, p, m, v):
        t = jnp.asarray(1.0, jnp.float32)
        c1 = 1.0 - _HP["b1"] ** t
        c2 = 1.0 - _HP["b2"] ** t
        own, info = coll.reduce_scatter(net, g)
        gm = coll.allgather_unpad(net, own, info) / float(N)
        m = _HP["b1"] * m + (1.0 - _HP["b1"]) * gm
        v = _HP["b2"] * v + (1.0 - _HP["b2"]) * gm * gm
        upd = (m / c1) / (jnp.sqrt(v / c2) + _HP["eps"])
        upd = jnp.where(wd != 0, upd + _HP["wd_coef"] * p, upd)
        return (p - _HP["lr"] * upd).astype(jnp.bfloat16), m, v

    return fused, unfused


def _wire_bytes(net, fn, *args) -> float:
    """Total ppermute payload bytes for ONE eager execution of fn."""
    prof = Profiler(level=1)
    net.profile = prof
    try:
        jax.block_until_ready(fn(*args))
    finally:
        net.profile = None
    return sum(c["total_bytes"] for k, c in prof.counters().items()
               if k.startswith("ppermute"))


def bench_fused_grad_rs():
    print("\n== fused RS->AdamW vs unfused (SIM, n=%d, bf16 params) ==" % N)
    net = SimNetOps(N)
    rng = np.random.default_rng(1)
    for total in (1 << 14, 1 << 22):
        nbytes = total * 4                      # f32 bucket bytes per PE
        chunk = -(-total // N)
        g = jnp.asarray(rng.standard_normal((N, total)).astype(np.float32))
        p = jnp.asarray(np.broadcast_to(
            rng.standard_normal(total).astype(np.float32),
            (N, total)).copy())
        wd = jnp.asarray(np.ones(total, np.int8))
        fused, unfused = _grad_fns(net, total, wd)
        m_c = jnp.zeros((N, chunk), jnp.float32)
        v_c = jnp.zeros((N, chunk), jnp.float32)
        m_f = jnp.zeros((N, total), jnp.float32)
        v_f = jnp.zeros((N, total), jnp.float32)
        b_fused = _wire_bytes(net, fused, g, p, m_c, v_c)
        b_unfused = _wire_bytes(net, unfused, g, p, m_f, v_f)
        # alternate A/B rounds and take each side's median: measurement
        # position shifts CPU allocator warmth by up to ~2x per round
        tf_r, tu_r = [], []
        for _ in range(3):
            tf_r.append(_time(fused, g, p, m_c, v_c))
            tu_r.append(_time(unfused, g, p, m_f, v_f))
        t_fused = float(np.median(tf_r))
        t_unfused = float(np.median(tu_r))
        pick, times = fusion.choose_grad_rs(N, nbytes, param_itemsize=2)
        row(f"grad_rs_unfused_{nbytes}B_us", t_unfused * 1e6,
            f"bytes={b_unfused:.0f} rs+f32-allgather+adam")
        saved = (1.0 - b_fused / max(b_unfused, 1.0)) * 100.0
        ok = "" if b_fused < b_unfused else " WARN_no_bytes_win"
        row(f"grad_rs_fused_{nbytes}B_us", t_fused * 1e6,
            f"bytes={b_fused:.0f} saved={saved:.0f}%{ok} "
            f"pred={times['fused'] * 1e6:.2f}us pick={pick}")


def main():
    bench_ring_attention()
    bench_fused_grad_rs()


if __name__ == "__main__":
    main()
