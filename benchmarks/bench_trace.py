"""Observability-layer cost ladder (DESIGN.md §16): what each tracing
level adds to the eager SIM hot path, plus the trace/heatmap export
costs.

  1. The overhead LADDER on one eager allreduce: no profiler attached
     (base) vs a Tracer at pcontrol levels 0 (off) / 1 (counters) /
     2 (timeline + chrome events) / 3 (full trace: stage spans + flow
     links).  The DISABLED row is the acceptance pin: < 5% over base
     (interleaved rounds, per-variant minima — same methodology as
     bench_tuner's profiler pin).
  2. Heatmap-export cost at 16 PEs (epiphany3) and 64 PEs (8x8) after a
     traced run, and the full `to_chrome` serialization cost.

  PYTHONPATH=src python -m benchmarks.bench_trace
"""
from __future__ import annotations

import json
import os
import pathlib
import time

from repro.core import sim_ctx
from repro.core.topology import MeshTopology, epiphany3
from repro.core.trace import LEVEL_FULL, Tracer

from ._util import sized

TOPO = epiphany3()
N = TOPO.n_pes
NBYTES = 65536
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def out_dir() -> pathlib.Path:
    d = pathlib.Path(os.environ.get("BENCH_OUT_DIR", "bench-reports"))
    d.mkdir(parents=True, exist_ok=True)
    return d


def overhead_ladder() -> None:
    x = sized(NBYTES, N)
    iters = 20

    def time_ctx(ctx) -> float:
        t0 = time.perf_counter()
        for _ in range(iters):
            ctx.to_all(x, "sum", algorithm="ring")
        return (time.perf_counter() - t0) / iters

    variants = [
        ("base", None),
        ("off", Tracer(level=0)),
        ("counters", Tracer(level=1)),
        ("timeline", Tracer(level=2)),
        ("full", Tracer(level=LEVEL_FULL)),
    ]
    ctxs = [(name, sim_ctx(N, TOPO, profile=p)) for name, p in variants]
    for _, ctx in ctxs:
        ctx.to_all(x, "sum", algorithm="ring")          # warm caches
    # interleaved rounds + per-variant minima (see bench_tuner): the
    # flag-test delta is far below block-vs-block scheduler noise
    times: dict[str, list[float]] = {name: [] for name, _ in ctxs}
    for _ in range(5):
        for name, ctx in ctxs:
            times[name].append(time_ctx(ctx))
    best = {name: min(ts) for name, ts in times.items()}
    base = best["base"]
    levels = {name: (p.level if p is not None else None)
              for name, p in variants}
    for name, _ in ctxs:
        t = best[name]
        pct = (t - base) / base * 100.0
        lvl = levels[name]
        row(f"trace_allreduce_{NBYTES}B_{name}", t * 1e6,
            f"vs_base={pct:+.1f}% level={'-' if lvl is None else lvl}")
    off_pct = (best["off"] - base) / base * 100.0
    assert off_pct < 5.0, \
        f"disabled tracer costs {off_pct:.1f}% on the eager path (<5% req)"
    row("trace_disabled_overhead_pct", off_pct, "acceptance: <5%")


def export_costs() -> None:
    for topo, tag in ((epiphany3(), "16pe"),
                      (MeshTopology((8, 8), torus=(False, False)), "64pe")):
        n = topo.n_pes
        tracer = Tracer(level=LEVEL_FULL)
        ctx = sim_ctx(n, topo, profile=tracer)
        x = sized(NBYTES, n)
        for _ in range(4):
            ctx.to_all(x, "sum", algorithm="rd")
        t0 = time.perf_counter()
        hm = tracer.heatmap()
        t_hm = (time.perf_counter() - t0) * 1e6
        row(f"trace_heatmap_{tag}_us", t_hm,
            f"links={hm[0]['n_links']} events={len(tracer._events)}")
        if tag == "16pe":
            t0 = time.perf_counter()
            doc = tracer.to_chrome()
            blob = json.dumps(doc)
            t_ser = (time.perf_counter() - t0) * 1e6
            row("trace_chrome_export_us", t_ser,
                f"events={len(doc['traceEvents'])} bytes={len(blob)}")
            (out_dir() / "bench_trace_sample.json").write_text(blob)


def main():
    overhead_ladder()
    export_costs()


if __name__ == "__main__":
    main()
