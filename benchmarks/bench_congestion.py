"""Congestion model validation: predicted vs measured under link contention.

The congestion-aware cost layer (DESIGN.md §12) prices a stage as

    T = alpha + hop_s * max_hops + beta * nbytes * max_link_load

with ``max_link_load`` the flow multiplicity through the hottest physical
link under XY routing.  The plain SIM backend cannot see contention (a
ppermute is one gather), so this bench runs on the congestion-faithful
``NocSimNetOps``: every pattern executes as link-disjoint waves, the flows
a real NoC could fly concurrently — measured wall time scales with the
hot-link load the model prices.

Sections:
  1. contention calibration — the same payload pushed through patterns of
     known hot-link load; fit the LinkModel `contention` factor
     (abmodel.fit_contention) the way fit() recovers (alpha, beta).
  2. embedded vs logical ring — measured allreduce wall time over message
     sizes, the model's predictions, the crossover, and what
     choose_schedule(embedding="auto") picks at each size (acceptance:
     embedded wins at large sizes and the selector picks it).
  3. rank remapping — max_link_load of the logical ring vs the snake
     embedding vs a greedy optimize_embedding remap, plus the barrier
     algorithm pricing (dissemination vs tree).

  PYTHONPATH=src python -m benchmarks.bench_congestion
"""
from __future__ import annotations

import numpy as np

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core.netops import NocSimNetOps
from repro.core.pattern import ring_pattern
from repro.core.topology import epiphany3

from ._util import sized, time_fn as _time

TOPO = epiphany3()
N = TOPO.n_pes
LINK = abmodel.EPIPHANY_NOC
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


# -- 1. calibrate the contention factor from wave-level execution ------------

def _stage(net, p, v):
    """One executed ring-RS stage shape: receive + local combine.  The
    combine consumer forces the transfer to materialize on BOTH sides —
    a bare permutation gather is elided to a view change by XLA, which
    would time the uncontended case at memcpy-zero cost."""
    return v + net.ppermute(v, p)


def calibrate_contention() -> float:
    """Push the same per-PE payload through patterns of known hot-link
    load and fit gamma: t(load) ~= t(1) * (1 + gamma*(load-1))."""
    net = NocSimNetOps(N, topo=TOPO)
    emb = TOPO.snake_order()
    cases = [
        ("ring_embedded", ring_pattern(N).relabel(emb, N)),   # load 1
        ("ring_logical", ring_pattern(N)),                    # load 2
        ("ring_offset8", ring_pattern(N, 8)),                 # load 4
    ]
    nbytes = 1 << 16
    loads, times = [], []
    for name, p in cases:
        x = sized(nbytes, N)
        t = _time(lambda v, _p=p: _stage(net, _p, v), x, iters=16)
        load = p.max_link_load(TOPO)
        waves = len(p.link_waves(TOPO))
        loads.append(load)
        times.append(t)
        row(f"noc_stage_{name}", t * 1e6,
            f"max_link_load={load:.0f} waves={waves}")
    gamma = abmodel.fit_contention(loads, times)
    row("contention_gamma", 0.0, f"gamma={gamma:.2f} (1.0=full serialization)")
    return gamma


# -- 2. embedded vs logical ring: predicted vs measured crossover ------------

def bench_embedded_ring(gamma: float):
    """Both the ring schedule and the model are STAGE-ADDITIVE (every ring
    stage is the same interned pattern, stages serialize), so the
    schedule's measured time is n_stages x one measured stage.  Timing a
    single jitted stage keeps the measurement volume-honest — XLA elides
    chained permutation gathers wholesale (a 30-deep chain of embedded
    stages compiles to view changes), which would credit the embedding
    with impossible speedups."""
    link = abmodel.LinkModel(LINK.alpha_s, LINK.hop_s, LINK.bw_Bps,
                             contention=gamma)
    net = NocSimNetOps(N, topo=TOPO)
    emb = TOPO.snake_order()
    print("\nname,measured_us,derived (embedded vs logical ring allreduce, "
          "stage-additive NoC-wave measurement)")
    results = []
    for s in (256, 4096, 65536, 1 << 18):
        sched_log = coll.allreduce_schedule(N, s, "ring")
        sched_emb = coll.allreduce_schedule(N, s, "ring_emb", embedding=emb)
        x = sized(max(s // N, 4), N)          # ring stages move 1/N chunks
        k = len(sched_log.stages)
        t_log = k * _time(
            lambda v: _stage(net, sched_log.stages[0].pattern, v), x,
            iters=16)
        t_emb = k * _time(
            lambda v: _stage(net, sched_emb.stages[0].pattern, v), x,
            iters=16)
        p_log = sched_log.time(TOPO, link)
        p_emb = sched_emb.time(TOPO, link)
        algo, chunks = coll.choose_schedule(N, float(s), TOPO, link,
                                            embedding="auto")
        results.append((s, t_log, t_emb))
        row(f"allreduce_ring_{s}B", t_log * 1e6,
            f"emb={t_emb * 1e6:.2f}us speedup=x{t_log / t_emb:.2f} "
            f"pred=x{p_log / p_emb:.2f} auto_pick={algo}/c{chunks}")
    # crossover: the smallest size from which the embedded ring STAYS
    # faster (scanned large-to-small — tiny sizes are alpha/noise bound)
    crossover = None
    for s, t_log, t_emb in reversed(results):
        if t_emb < t_log:
            crossover = s
        else:
            break
    row("embedded_ring_crossover", 0.0,
        f"embedded_faster_from={crossover}B" if crossover is not None
        else "WARN_no_crossover")

    # bit-identity spot check: embedded vs logical full executions on int
    # payloads, on the wave-serial backend
    ctx = sim_ctx(N, TOPO, noc=True)
    xi = np.random.RandomState(0).randint(-99, 99, (N, 257)).astype(np.int32)
    a = np.asarray(ctx.to_all(xi, "sum", algorithm="ring"))
    b = np.asarray(ctx.to_all(xi, "sum", algorithm="ring_emb"))
    row("embedded_bit_identity_int", 0.0,
        "OK" if np.array_equal(a, b) else "FAIL")


# -- 3. the remap pass + barrier pricing -------------------------------------

def bench_remap():
    print("\nname,us,derived (rank remapping / barrier pricing)")
    ring = coll.allreduce_schedule(N, float(1 << 20), "ring")
    emb = TOPO.snake_order()
    ring_emb = coll.allreduce_schedule(N, float(1 << 20), "ring_emb",
                                       embedding=emb)
    l_log = max(st.pattern.max_link_load(TOPO) for st in ring.stages)
    l_emb = max(st.pattern.max_link_load(TOPO) for st in ring_emb.stages)
    remapped, perm = coll.optimize_embedding(ring, TOPO, LINK)
    l_rem = max(st.pattern.max_link_load(TOPO) for st in remapped.stages)
    row("ring_max_link_load", 0.0,
        f"logical={l_log:.0f} snake={l_emb:.0f} greedy_remap={l_rem:.0f}")
    order = coll.choose_embedding(N, TOPO, LINK)
    row("choose_embedding", 0.0,
        "identity" if order is None else f"order[0:4]={list(order[:4])}")
    for algo in ("dissem", "tree"):
        sched = coll.barrier_schedule(N, algo)
        row(f"barrier_{algo}", sched.time(TOPO, LINK) * 1e6,
            f"stages={len(sched)} "
            f"max_load={max(st.pattern.max_link_load(TOPO) for st in sched.stages):.0f}")
    row("choose_barrier", 0.0, coll.choose_barrier(N, TOPO, LINK))


def main():
    print("name,us,derived")
    gamma = calibrate_contention()
    bench_embedded_ring(gamma)
    bench_remap()


if __name__ == "__main__":
    main()
