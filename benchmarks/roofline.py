import os as _os
import sys as _sys

if __name__ == "__main__" and "--table" not in _sys.argv:
    # probe compiles target the production mesh; set before any jax import
    _os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis from the compiled dry-run artifacts.

Two inputs per (arch x shape) cell:
  1. the full-size dry-run JSON (experiments/dryrun/*.json) — proves the
     cell compiles and fits, and gives the HLO structure;
  2. probe extrapolation — XLA's cost_analysis counts a while-loop body
     ONCE regardless of trip count (verified in EXPERIMENTS.md §Dry-run),
     so per-cell totals are recovered by compiling the SAME cell at two
     reduced depths L1 < L2 (scan bodies unchanged), fitting
     cost(L) = a + b*L, and extrapolating to the real depth.  Microbatch
     scans don't change true totals (same tokens), so probes run mb=1.

Terms (per chip, per step), v5e-class constants:
  compute_s    = HLO_FLOPs / 197e12
  memory_s     = HLO_bytes / 819e9
  collective_s = collective_bytes / 50e9
plus MODEL_FLOPS = 6*N*D (active N for MoE) and the useful-compute ratio.

Usage: python -m benchmarks.roofline --arch gemma2-9b --shape train_4k
       python -m benchmarks.roofline --table   (render EXPERIMENTS table)
"""
import argparse
import dataclasses
import json
import pathlib
import sys

sys.path.insert(0, "src")

import numpy as np

DRYRUN_DIR = pathlib.Path("experiments/dryrun")
PROBE_DIR = pathlib.Path("experiments/roofline")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def probe_depths(cfg):
    """Two valid reduced depths for linear fitting, respecting each
    family's repeating unit."""
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period
        return p, 2 * p
    if cfg.local_global_period:
        return 2, 4
    if cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        return nd + 2, nd + 4
    return 2, 4


def compile_probe(arch: str, shape: str, n_layers: int, comm: str,
                  tuning: dict | None = None, overrides: dict | None = None):
    import dataclasses as dc
    import jax
    from repro.configs import get_config
    from repro.launch import build
    from repro.launch.dryrun import _collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES, input_specs

    # depth-reduced probe with every scan unrolled (while bodies are
    # cost-counted once); MTP (depth-constant) lands in the fit intercept
    cfg = dc.replace(get_config(arch), n_layers=n_layers, microbatches=1,
                     probe_unroll=True, **(overrides or {}))
    mesh = make_production_mesh()
    kind = SHAPES[shape]["kind"]
    with jax.set_mesh(mesh):
        specs_in = input_specs(cfg, shape)
        if kind == "train":
            wrap, (ps, psp), (os_, osp), _ = build.make_train_step(
                cfg, mesh, comm, **(tuning or {}))
            lowered = jax.jit(wrap(specs_in), donate_argnums=(0, 1)).lower(
                build.global_shape(ps, psp, mesh),
                build.global_shape(os_, osp, mesh), specs_in)
        elif kind == "prefill":
            wp, _, _, (ps, psp), _ = build.make_serve_steps(
                cfg, mesh, shape, comm)
            lowered = jax.jit(wp(specs_in)).lower(
                build.global_shape(ps, psp, mesh), specs_in)
        else:
            _, wd, (cs, csp), (ps, psp), _ = build.make_serve_steps(
                cfg, mesh, shape, comm)
            lowered = jax.jit(wd(specs_in), donate_argnums=(1,)).lower(
                build.global_shape(ps, psp, mesh),
                build.global_shape(cs, csp, mesh), specs_in)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = _collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll_bytes": float(sum(coll["bytes"].values())),
    }


def extrapolate(arch: str, shape: str, comm: str = "shmem",
                use_cache: bool = True, tuning: dict | None = None,
                overrides: dict | None = None, tag: str = "") -> dict:
    """Fit cost(L)=a+b*L from two probes; extrapolate to the full depth.
    `tuning` feeds the step builder (allreduce_algo/grad_rs/...);
    `overrides` patches the ModelConfig; `tag` namespaces the cache for
    hillclimb variants."""
    from repro.configs import get_config
    cfg = get_config(arch)
    if overrides:
        import dataclasses as dc
        cfg = dc.replace(cfg, **overrides)
    key = f"{arch}__{shape}__{comm}" + (f"__{tag}" if tag else "")
    PROBE_DIR.mkdir(parents=True, exist_ok=True)
    cache = PROBE_DIR / f"{key}.json"
    if use_cache and cache.exists():
        return json.loads(cache.read_text())
    l1, l2 = probe_depths(cfg)
    c1 = compile_probe(arch, shape, l1, comm, tuning, overrides)
    c2 = compile_probe(arch, shape, l2, comm, tuning, overrides)
    full = {}
    for k in c1:
        b = (c2[k] - c1[k]) / (l2 - l1)
        a = c1[k] - b * l1
        full[k] = a + b * cfg.n_layers
    # model flops: 6*N*D for train (fwd+bwd), 2*N*D for inference fwd
    from repro.models.config import SHAPES
    s = SHAPES[shape]
    n_active = cfg.param_count(active_only=cfg.moe is not None)
    if s["kind"] == "train":
        tokens = s["seq_len"] * s["global_batch"]
        model_flops = 6 * n_active * tokens
    elif s["kind"] == "prefill":
        tokens = s["seq_len"] * s["global_batch"]
        model_flops = 2 * n_active * tokens
    else:
        tokens = 1 * s["global_batch"]
        model_flops = 2 * n_active * tokens
    n_chips = 256
    res = {
        "cell": key,
        "probe_depths": [l1, l2],
        "hlo_flops_per_chip": full["flops"],
        "hlo_bytes_per_chip": full["bytes"],
        "coll_bytes_per_chip": full["coll_bytes"],
        "compute_s": full["flops"] / PEAK_FLOPS,
        "memory_s": full["bytes"] / HBM_BW,
        "collective_s": full["coll_bytes"] / ICI_BW,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_ratio": (model_flops / n_chips) / max(full["flops"], 1.0),
    }
    terms = {k: res[k] for k in ("compute_s", "memory_s", "collective_s")}
    res["bottleneck"] = max(terms, key=terms.get)
    res["step_time_s"] = max(terms.values())
    res["roofline_fraction"] = (
        res["model_flops_per_chip"] / PEAK_FLOPS / max(res["step_time_s"],
                                                       1e-12))
    cache.write_text(json.dumps(res, indent=2))
    return res


def render_table(out=sys.stdout):
    rows = []
    for f in sorted(PROBE_DIR.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    hdr = (f"{'cell':52s} {'compute_s':>10} {'memory_s':>10} "
           f"{'coll_s':>10} {'bottleneck':>11} {'useful':>7} {'MFU':>6}")
    print(hdr, file=out)
    for r in rows:
        print(f"{r['cell']:52s} {r['compute_s']:.3e} {r['memory_s']:.3e} "
              f"{r['collective_s']:.3e} {r['bottleneck'][:-2]:>11} "
              f"{min(r['useful_ratio'], 9.99):7.3f} "
              f"{min(r['roofline_fraction'], 9.99):6.3f}", file=out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--comm", default="shmem")
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args()
    if args.table:
        render_table()
        return
    if args.all:
        from repro.configs import ARCHS, get_config
        from repro.models.config import SHAPES, shape_applicable
        for a in ARCHS:
            for s in SHAPES:
                ok, why = shape_applicable(get_config(a), s)
                if not ok:
                    continue
                try:
                    r = extrapolate(a, s, args.comm,
                                    use_cache=not args.no_cache)
                    print(f"[roofline] {a}__{s}: {r['bottleneck']} "
                          f"frac={r['roofline_fraction']:.3f}")
                except Exception as e:  # noqa
                    print(f"[roofline] {a}__{s}: FAILED {e}")
        return
    res = extrapolate(args.arch, args.shape, args.comm,
                      use_cache=not args.no_cache)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
