"""Roofline placement of PROFILED train/serve steps on the current
stack (DESIGN.md §18).

The seed-era version extrapolated from ``experiments/dryrun`` artifacts
that no longer exist and priced everything with hardcoded v5e constants.
This one needs NO pre-existing artifacts: each cell compiles and runs a
real step (smoke scale, CPU-runnable) under the tracer and derives all
three roofline terms from the stack itself —

  compute_s = HLO FLOPs of the step ACTUALLY compiled
              (``jit(...).lower().compile().cost_analysis()``) / peak
  memory_s  = HLO bytes accessed / local-memory bandwidth
  noc_s     = the step's collective payload scheduled by
              ``collectives.choose_schedule`` on the target machine's
              topology and priced by the CALIBRATED LinkModel (the
              tuning DB's refit for that topology when
              ``bench-reports/tuning_db.json`` has one, else the
              machine's default link constants)

and places the step against them: bottleneck = argmax term, MFU =
model FLOPs / (peak * modeled step time).  The measured wall time of
the smoke step rides along as the pinned regression row.  The per-cell
summary is embedded into the trace document's ``repro.roofline``
section (``Tracer.sections``) so ``tracereport`` prints it.

  PYTHONPATH=src python -m benchmarks.roofline
  PYTHONPATH=src python -m benchmarks.roofline --machine v5e-pod
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time

sys.path.insert(0, "src")

from repro.core import abmodel, collectives as coll          # noqa: E402
from repro.core.topology import epiphany3, v5e_pod           # noqa: E402

ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


@dataclasses.dataclass(frozen=True)
class Machine:
    """The roofline ceilings of one target machine."""
    name: str
    peak_flops: float            # FLOP/s, all PEs
    mem_bw_Bps: float            # aggregate local-memory bandwidth
    link: abmodel.LinkModel      # default NoC constants
    topo: object
    n_pes: int


def machines() -> dict[str, Machine]:
    return {
        # Epiphany-III: 16 PEs x 1.2 GFLOPS (FMA @ 600 MHz); 8 B/clk
        # local-memory port per PE
        "epiphany3": Machine("epiphany3", 16 * 1.2e9, 16 * 4.8e9,
                             abmodel.EPIPHANY_NOC, epiphany3(), 16),
        "v5e-pod": Machine("v5e-pod", 197e12, 819e9, abmodel.ICI_V5E,
                           v5e_pod(), 256),
    }


def calibrated_link(machine: Machine) -> tuple[abmodel.LinkModel, str]:
    """The tuning DB's measured refit for the target topology when one
    exists (DESIGN.md §13), else the machine's default constants."""
    db_path = pathlib.Path(os.environ.get("BENCH_OUT_DIR",
                                          "bench-reports"))
    db_path = db_path / "tuning_db.json"
    try:
        if db_path.exists():
            from repro.core import tuner as tun
            db = tun.TuningDB.load(db_path)
            lm = db.link_model(tun.fingerprint(machine.topo,
                                               machine.n_pes))
            if lm is not None:
                return lm, "calibrated"
    except Exception:
        pass
    return machine.link, "default"


def noc_term(nbytes: float, machine: Machine,
             link: abmodel.LinkModel) -> tuple[float, str]:
    """Modeled time of the cell's collective payload on the target
    machine — the same choose_schedule + pipelined pricing the
    executors run."""
    algo, chunks = coll.choose_schedule(machine.n_pes, nbytes,
                                        machine.topo, link)
    stages = coll.allreduce_stages(machine.n_pes, nbytes, machine.topo,
                                   algo if algo != "ring_emb" else None)
    if chunks > 1:
        t = abmodel.modeled_pipelined_time(stages, chunks, link)
    else:
        t = abmodel.modeled_collective_time(stages, link)
    return t, f"{algo}/c{chunks}"


def _cost_analysis(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # older jax returns [dict]
        cost = cost[0] if cost else {}
    return dict(cost or {})


def _timed_us(fn, *args, iters: int = 3) -> float:
    import jax
    jax.block_until_ready(fn(*args))          # warm (compile cached)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
# cells: real profiled steps at smoke scale
# ---------------------------------------------------------------------------

def cell_train(tracer=None, arch: str = "qwen2-0.5b") -> dict:
    """One full train step (fwd+bwd+AdamW through launch.build), its
    HLO counts, and its data-parallel gradient-sync payload (the full
    parameter set — what a data mesh allreduces every step)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.train import optimizer as opt

    cfg = smoke_config(arch)
    mesh = make_mesh(1, 1)
    B, L = 2, 64
    batch = {"tokens": jnp.ones((B, L), jnp.int32),
             "targets": jnp.ones((B, L), jnp.int32)}
    with jax.set_mesh(mesh):
        init_fn, _, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))
        wrap, _, (_, ospecs), ocfg = build.make_train_step(
            cfg, mesh, "shmem", profile=tracer)
        ostate = jax.jit(build.shard_mapped(
            lambda p: opt.init_state(p, ocfg), mesh, (specs,), ospecs)
        )(params)
        step = jax.jit(wrap(batch))
        compiled = step.lower(params, ostate, batch).compile()
        if tracer is not None:
            with tracer.span("roofline.train_step", n_pes=1):
                wall_us = _timed_us(step, params, ostate, batch)
        else:
            wall_us = _timed_us(step, params, ostate, batch)
    cost = _cost_analysis(compiled)
    n_params = cfg.param_count()
    return {
        "cell": f"train_{arch}",
        "wall_us": wall_us,
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": 4.0 * n_params,       # f32 grad allreduce payload
        "model_flops": 6.0 * n_params * B * L,
    }


def cell_decode(tracer=None, arch: str = "qwen2-0.5b") -> dict:
    """One serving decode step (KV-cache token step through serve.step),
    its HLO counts, and the tensor-parallel payload a 16-PE chip would
    allreduce per step (attention + MLP block outputs per layer)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.models import transformer
    from repro.serve import step as sstep

    cfg = smoke_config(arch)
    mesh = make_mesh(1, 1)
    B, S = 2, 64
    with jax.set_mesh(mesh):
        init_fn, _, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))
        cshapes = jax.eval_shape(
            lambda: transformer.init_cache(cfg, 1, B, S, 1))
        cspecs = jax.tree.map(lambda _: P(), cshapes)
        cache = jax.jit(build.shard_mapped(
            lambda: transformer.init_cache(cfg, 1, B, S, 1),
            mesh, (), cspecs))()
        decode = sstep.build_decode_step(cfg, build.axis_spec(mesh),
                                         "shmem", 1, profile=tracer)
        djit = jax.jit(build.shard_mapped(
            decode, mesh,
            (specs, cspecs, {"tokens": P(), "positions": P()}),
            (P(), cspecs)))
        dbatch = {"tokens": jnp.ones((B, 1), jnp.int32),
                  "positions": jnp.zeros((B,), jnp.int32)}
        compiled = djit.lower(params, cache, dbatch).compile()
        if tracer is not None:
            with tracer.span("roofline.decode_step", n_pes=1):
                wall_us = _timed_us(djit, params, cache, dbatch)
        else:
            wall_us = _timed_us(djit, params, cache, dbatch)
    cost = _cost_analysis(compiled)
    n_params = cfg.param_count()
    return {
        "cell": f"decode_{arch}",
        "wall_us": wall_us,
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        # two block-output allreduces per layer, f32 activations
        "coll_bytes": 2.0 * cfg.n_layers * B * cfg.d_model * 4.0,
        "model_flops": 2.0 * n_params * B,
    }


CELLS = [("train", cell_train), ("decode", cell_decode)]


def place(cell: dict, machine: Machine,
          link: abmodel.LinkModel, link_src: str) -> dict:
    """Put one profiled cell on the machine's rooflines."""
    compute_s = cell["hlo_flops"] / machine.peak_flops
    memory_s = cell["hlo_bytes"] / machine.mem_bw_Bps
    noc_s, pick = noc_term(cell["coll_bytes"], machine, link)
    terms = {"compute": compute_s, "memory": memory_s, "noc": noc_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    mfu = cell["model_flops"] / machine.peak_flops / max(step_s, 1e-12)
    return dict(cell, machine=machine.name, link=link_src,
                compute_us=compute_s * 1e6, memory_us=memory_s * 1e6,
                noc_us=noc_s * 1e6, noc_pick=pick,
                bottleneck=bottleneck, step_us=step_s * 1e6, mfu=mfu)


def run(machine_name: str = "epiphany3") -> dict:
    from repro.core.trace import Tracer
    machine = machines()[machine_name]
    link, link_src = calibrated_link(machine)
    tracer = Tracer(level=3)
    cells = []
    for key, fn in CELLS:
        placed = place(fn(tracer), machine, link, link_src)
        cells.append(placed)
        row(f"roofline_{key}_wall_us", placed["wall_us"],
            f"pred={placed['step_us']:.2f}us pick={placed['bottleneck']} "
            f"mfu={min(placed['mfu'], 9.999):.3f} noc={placed['noc_pick']} "
            f"link={link_src}")
        row(f"roofline_{key}_noc_us", placed["noc_us"],
            f"payload={placed['coll_bytes']:.0f}B "
            f"compute={placed['compute_us']:.2f}us "
            f"memory={placed['memory_us']:.2f}us")
    summary = {
        "machine": machine.name,
        "link": link_src,
        "peaks": {"flops": machine.peak_flops,
                  "mem_Bps": machine.mem_bw_Bps,
                  "link_GBs": link.bw_Bps / 1e9},
        "cells": cells,
    }
    tracer.sections["roofline"] = summary
    out_dir = os.environ.get("BENCH_OUT_DIR", "")
    if out_dir:
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        (out / "roofline.json").write_text(json.dumps(summary, indent=1))
        tracer.dump_chrome(out / "roofline_trace.json")
        print(f"[roofline] wrote {out}/roofline.json + roofline_trace.json")
    return summary


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--machine", default="epiphany3",
                    choices=sorted(machines()),
                    help="target machine whose rooflines the profiled "
                         "steps are placed on")
    # benchmarks.run calls main() with no argv: parse an empty list so
    # the harness's own flags are never consumed here
    args = ap.parse_args(argv if argv is not None else [])
    summary = run(args.machine)
    pk = summary["peaks"]
    print(f"# machine={summary['machine']} link={summary['link']} "
          f"peak={pk['flops'] / 1e9:.1f}GFLOP/s mem={pk['mem_Bps'] / 1e9:.1f}GB/s "
          f"noc={pk['link_GBs']:.2f}GB/s")


if __name__ == "__main__":
    main(sys.argv[1:])
