"""Overlap-runtime benchmark: measured overlap fraction for the pending-op
engine (put_nbi -> compute -> quiet) and the pipelined-vs-monolithic
schedule cross-over (DESIGN.md §10).

Three sections, mirroring bench_patterns' predicted-vs-measured discipline
(the modeled columns come from the SAME Schedule objects that execute):

  1. Overlap fraction: wall time of comm alone, compute alone, and the
     put_nbi -> compute -> quiet overlap program.  overlap = (t_comm +
     t_comp - t_both) / min(t_comm, t_comp): 1.0 means the cheaper phase
     fully hides behind the other, 0.0 means serialized.  On a
     single-stream CPU simulator this measures the substrate's true
     concurrency (expect ~0 there; >0 on backends with concurrent thunk
     execution) — the modeled column shows what the e-DMA engine gives.
  2. Pipelined vs monolithic: measured SIM wall time AND modeled time
     (fitted SIM link + paper NoC constants) for chunked vs eager
     execution of the same schedule, plus the modeled cross-over size
     where chunking starts to win.
  3. Selector: choose_schedule must pick n_chunks == 1 below the modeled
     cross-over and > 1 above it, consistent with the schedules' own
     pipelined_time pricing.

  PYTHONPATH=src python -m benchmarks.bench_overlap
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core.netops import SimNetOps
from repro.core.topology import epiphany3

from ._util import sized, time_fn as _time

TOPO = epiphany3()
N = TOPO.n_pes
NOC = abmodel.EPIPHANY_NOC
PIPE_CHUNKS = 8
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _sized(nbytes, seed=0):
    return sized(nbytes, N, seed)


def fit_sim_link() -> abmodel.LinkModel:
    """Fit the SIM substrate's own alpha-beta from bare ring stages (the
    paper's Fig. 3 methodology applied to the simulator)."""
    net = SimNetOps(N)
    pattern = coll.fcollect_schedule(N, 0.0, "ring").stages[0].pattern
    sizes = [64, 256, 1024, 4096, 16384, 65536]
    times = [_time(lambda v: net.ppermute(v, pattern), _sized(s))
             for s in sizes]
    fit = abmodel.fit(sizes, times)
    link = abmodel.LinkModel(alpha_s=max(fit.alpha, 1e-9), hop_s=0.0,
                             bw_Bps=max(fit.inv_beta, 1.0))
    row("sim_link_alpha_us", fit.alpha * 1e6,
        f"beta^-1={fit.inv_beta / 1e9:.2f}GB/s")
    return link


# -- 1. measured overlap fraction --------------------------------------------

def bench_overlap_fraction():
    print("\n== put_nbi -> compute -> quiet overlap fraction ==")
    ctx = sim_ctx(N, TOPO)
    ring = [(i, (i + 1) % N) for i in range(N)]
    x = _sized(1 << 18)
    w = jnp.asarray(np.random.RandomState(7).randn(256, 256)
                    .astype(np.float32))

    def comm_only(v):
        f = ctx.put_nbi(v, ring)
        (out,) = ctx.quiet(f)
        return out

    def compute_only(m):
        acc = m
        for _ in range(8):
            acc = jnp.tanh(acc @ m)
        return acc

    def overlapped(v, m):
        f = ctx.put_nbi(v, ring)          # DMA launch
        acc = compute_only(m)             # independent compute window
        (out,) = ctx.quiet(f)             # completion pin
        return out, acc

    t_comm = _time(comm_only, x, warmup=3, iters=24)
    t_comp = _time(compute_only, w, warmup=3, iters=24)
    t_both = _time(overlapped, x, w, warmup=3, iters=24)
    frac = (t_comm + t_comp - t_both) / max(min(t_comm, t_comp), 1e-12)
    row("overlap_comm_us", t_comm * 1e6, "put_nbi+quiet alone")
    row("overlap_compute_us", t_comp * 1e6, "8x tanh-matmul alone")
    row("overlap_both_us", t_both * 1e6, "put_nbi -> compute -> quiet")
    row("overlap_fraction", frac,
        "measured; 1.0 = cheaper phase fully hidden (the e-DMA target), "
        "~0 = serialized substrate, <0 = combined-program dispatch "
        "overhead on this substrate")


# -- 2. pipelined vs monolithic ----------------------------------------------

def bench_pipelined(sim_link: abmodel.LinkModel):
    print("\n== pipelined vs monolithic (same Schedule objects; "
          f"chunks={PIPE_CHUNKS}) ==")
    ctx = sim_ctx(N, TOPO)
    for nbytes in (4096, 1 << 16, 1 << 20, 1 << 22):
        x = _sized(nbytes)
        sched = coll.allreduce_schedule(N, float(nbytes), "ring")
        t_mono = _time(lambda v: ctx.to_all(v, "sum", algorithm="ring"), x)
        t_pipe = _time(lambda v: ctx.to_all(v, "sum", algorithm="ring",
                                            pipeline_chunks=PIPE_CHUNKS), x)
        # identical bits, by construction — verify on the way through
        same = np.array_equal(
            np.asarray(ctx.to_all(x, "sum", algorithm="ring")),
            np.asarray(ctx.to_all(x, "sum", algorithm="ring",
                                  pipeline_chunks=PIPE_CHUNKS)))
        m_mono = sched.time(TOPO, NOC)
        m_pipe = sched.pipelined_time(PIPE_CHUNKS, TOPO, NOC)
        row(f"allreduce_ring_{nbytes}B_measured", t_mono * 1e6,
            f"pipelined={t_pipe * 1e6:.2f}us bitwise_equal={same}")
        row(f"allreduce_ring_{nbytes}B_noc_model", m_mono * 1e6,
            f"pipelined={m_pipe * 1e6:.2f}us "
            f"speedup=x{m_mono / m_pipe:.2f}")

    # modeled cross-over: smallest size where chunked execution wins
    for name, build in (("broadcast", lambda b: coll.broadcast_schedule(N, b)),
                        ("allreduce_ring",
                         lambda b: coll.allreduce_schedule(N, b, "ring"))):
        for link, lname in ((NOC, "noc"), (sim_link, "simfit")):
            lo, hi = 8.0, float(1 << 24)
            win = (lambda b: build(b).pipelined_time(PIPE_CHUNKS, TOPO, link)
                   < build(b).time(TOPO, link))
            if win(lo) or not win(hi):
                row(f"{name}_pipe_crossover_{lname}_B", float("nan"),
                    f"WARN_no_crossover_in[{lo},{hi}]B")
                continue
            while hi - lo > 1:
                mid = (lo + hi) // 2
                lo, hi = (lo, mid) if win(mid) else (mid, hi)
            row(f"{name}_pipe_crossover_{lname}_B", hi,
                f"pipelined(x{PIPE_CHUNKS}) wins >= {int(hi)}B")


# -- 3. chunk-count selection ------------------------------------------------

def bench_selector():
    print("\n== choose_schedule (algorithm, n_chunks) selection ==")
    for nbytes in (64, 4096, 1 << 20, 1 << 24):
        algo, chunks = coll.choose_schedule(N, float(nbytes), TOPO, NOC)
        t = coll.allreduce_schedule(N, float(nbytes), algo)\
            .pipelined_time(chunks, TOPO, NOC)
        row(f"choose_schedule_{nbytes}B", t * 1e6, f"{algo} chunks={chunks}")
    # the selector must take chunked schedules above its own cross-over
    small = coll.choose_schedule(N, 64.0, TOPO, NOC)
    big = coll.choose_schedule(N, float(1 << 24), TOPO, NOC)
    ok = small[1] == 1 and big[1] > 1
    row("selector_chunks_smallVbig", 0.0,
        f"small={small} big={big} {'OK' if ok else 'WARN_mismatch'}")


def main():
    print("name,us,derived")
    link = fit_sim_link()
    bench_overlap_fraction()
    bench_pipelined(link)
    bench_selector()


if __name__ == "__main__":
    main()
