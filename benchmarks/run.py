"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (paper Figs. 3-9 + kernel layer),
then the roofline table if dry-run/probe artifacts exist.

  PYTHONPATH=src python -m benchmarks.run
"""
import pathlib
import sys

sys.path.insert(0, "src")


def main() -> None:
    from . import paper_benches

    print("name,us_per_call,derived")
    for bench in paper_benches.ALL:
        bench()

    print("\n== compiled CommPattern schedules: predicted vs measured ==")
    try:
        from . import bench_patterns
        bench_patterns.main()
    except Exception as e:  # keep the rest of the harness running
        print(f"pattern bench skipped: {e}")

    print("\n== congestion model: predicted vs measured under contention ==")
    try:
        from . import bench_congestion
        bench_congestion.main()
    except Exception as e:  # keep the rest of the harness running
        print(f"congestion bench skipped: {e}")

    print("\n== substrate A/B (ARL shmem vs XLA 'eLib') ==")
    try:
        from . import bench_substrate
        bench_substrate.main()
    except Exception as e:  # subprocess-heavy; non-fatal
        print(f"substrate bench skipped: {e}")

    probe_dir = pathlib.Path("experiments/roofline")
    if probe_dir.exists() and any(probe_dir.glob("*.json")):
        print("\n== roofline (from dry-run probes) ==")
        from . import roofline
        roofline.render_table()


if __name__ == "__main__":
    main()
