"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (paper Figs. 3-9 + the fidelity
acceptance rows + kernel layer), then the schedule/congestion/substrate/
tuner/fused/serve/trace/fault reports and the revived roofline bench
(profiled steps on compute/memory/NoC rooflines — no artifacts needed).

``--json OUT`` additionally writes every bench's rows as one
machine-readable ``BENCH_*.json`` document (standardized
size/measured/predicted/picked fields parsed from each row — the CI
perf-trajectory artifact) stamped with this machine's fingerprint, so
``check_regression.py`` can warn on cross-machine comparisons;
``--only a,b`` restricts which benches run.

  PYTHONPATH=src python -m benchmarks.run
  PYTHONPATH=src python -m benchmarks.run --only patterns,tuner \\
      --json bench-reports/BENCH_smoke.json
"""
import argparse
import json
import os
import pathlib
import platform
import re
import socket
import sys
import time

sys.path.insert(0, "src")

# Best-effort extractors for the standardized JSON rows.  Every bench
# module prints (name, us, derived) triples; sizes live in ``_<N>B`` name
# suffixes, predictions in ``fit=``/``noc=``/``pred=`` derived fields,
# picks in ``picked=``/``picks=`` fields or auto_pick rows.
_SIZE_RE = re.compile(r"_(\d+)B(?:_|$)")
_PRED_RE = re.compile(r"(?:fit|noc|pred(?:icted)?)=([\d.eE+-]+)us")
_PICK_RE = re.compile(r"pick(?:ed|s)?=([\w/|.-]+)")


def _std_row(bench: str, name: str, us, derived: str) -> dict:
    size = _SIZE_RE.search(name)
    pred = _PRED_RE.search(derived)
    pick = _PICK_RE.search(derived)
    if pick is None and "pick" in name:
        m = re.match(r"([a-z_]\w*)", derived)
        pick = m
    return {
        "bench": bench,
        "name": name,
        "measured_us": float(us),
        "derived": derived,
        "size_bytes": int(size.group(1)) if size else None,
        "predicted_us": float(pred.group(1)) if pred else None,
        "picked": pick.group(1) if pick else None,
    }


def machine_fingerprint() -> dict:
    """Hostname/CPU/jax-stack identity stamped into every BENCH_*.json
    header — wall times are only comparable within one fingerprint
    (check_regression warns loudly when they differ)."""
    fp = {
        "hostname": socket.gethostname(),
        "cpus": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax
        import jaxlib
        fp["jax"] = jax.__version__
        fp["jaxlib"] = jaxlib.__version__
        fp["xla_backend"] = jax.default_backend()
        fp["device_count"] = jax.device_count()
    except Exception:
        fp["jax"] = None
    return fp


def _run_paper():
    from . import paper_benches
    print("name,us_per_call,derived")
    for bench in paper_benches.ALL:
        bench()
    return paper_benches


def _module_runner(modname: str, header: str):
    def run():
        print(f"\n== {header} ==")
        import importlib
        mod = importlib.import_module(f".{modname}", __package__)
        mod.main()
        return mod
    return run


# Ordered registry: (key, fatal?, runner).  Non-fatal benches report and
# continue (subprocess-heavy or optional ones).
BENCHES = [
    ("paper", True, _run_paper),
    ("patterns", False, _module_runner(
        "bench_patterns",
        "compiled CommPattern schedules: predicted vs measured")),
    ("congestion", False, _module_runner(
        "bench_congestion",
        "congestion model: predicted vs measured under contention")),
    ("tuner", False, _module_runner(
        "bench_tuner",
        "measured-performance autotuner: sweep + tuned-selector checks")),
    ("substrate", False, _module_runner(
        "bench_substrate", "substrate A/B (ARL shmem vs XLA 'eLib')")),
    ("fused", False, _module_runner(
        "bench_fused",
        "fused comm-compute: ring attention + RS->AdamW (bytes + time)")),
    ("serve", False, _module_runner(
        "bench_serve",
        "serving engine: per-token p50/p99 + tok/s vs offered load")),
    ("trace", False, _module_runner(
        "bench_trace",
        "observability: tracing-level overhead ladder + export costs")),
    ("fault", False, _module_runner(
        "bench_fault",
        "fault tolerance: async-ckpt overlap overhead + recovery time")),
    ("roofline", False, _module_runner(
        "roofline",
        "roofline: profiled train/decode steps vs compute/memory/NoC "
        "ceilings")),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write all rows as one machine-readable "
                         "BENCH_*.json (per-row size/measured/predicted/"
                         "picked fields)")
    ap.add_argument("--only", default="",
                    help="comma-separated bench keys to run "
                         f"({','.join(k for k, _, _ in BENCHES)}); "
                         "default: all")
    args = ap.parse_args(argv)
    only = {k.strip() for k in args.only.split(",") if k.strip()}
    unknown = only - {k for k, _, _ in BENCHES}
    if unknown:
        raise SystemExit(f"unknown bench keys: {sorted(unknown)}")

    rows: list[dict] = []
    for key, fatal, runner in BENCHES:
        if only and key not in only:
            continue
        try:
            mod = runner()
        except Exception as e:
            if fatal:
                raise
            print(f"{key} bench skipped: {e}")
            continue
        for name, us, derived in getattr(mod, "ROWS", []):
            rows.append(_std_row(key, name, us, str(derived)))

    if args.json:
        out = pathlib.Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        doc = {"schema": 1,
               "generated_unix": time.time(),
               "machine": machine_fingerprint(),
               "benches": sorted({r["bench"] for r in rows}),
               "rows": rows}
        out.write_text(json.dumps(doc, indent=1))
        print(f"\n[run] wrote {len(rows)} rows to {out}")


if __name__ == "__main__":
    main()
