"""Calibration-sweep smoke for the measured-performance autotuner
(DESIGN.md §13): the CI job that closes the selection loop end to end.

  1. Run a tiny `Tuner.tune` sweep on the SIM backend (epiphany3 mesh),
     with the pcontrol profiler attached, and report per grid point the
     measured-best variant next to the analytic selector's pick.
  2. Persist the tuning DB and the profiler JSON as artifacts
     (``$BENCH_OUT_DIR``, default ``bench-reports/``) and ASSERT the
     tuned selector round-trips from disk (same picks after reload).
  3. Check the acceptance properties: the tuned pick is the measured
     argmin on every covered point and never measured-worse than the
     analytic choice; report the fraction.
  4. Measure the profiler's DISABLED overhead on the eager dispatch path
     (the acceptance bound is < 5%): the same collective timed with no
     profiler vs a disabled one attached.

  PYTHONPATH=src python -m benchmarks.bench_tuner
"""
from __future__ import annotations

import os
import pathlib
import time

from repro.core import (Profiler, Tuner, TuningDB, abmodel,
                        collectives as coll, sim_ctx)
from repro.core import tuner as tuner_mod
from repro.core.topology import epiphany3

from ._util import sized

TOPO = epiphany3()
N = TOPO.n_pes
LINK = abmodel.EPIPHANY_NOC
GRID = {"collectives": ("allreduce", "fcollect"),
        "sizes": (256, 4096, 65536), "chunks": (1, 4),
        "iters": 4, "warmup": 1}
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def out_dir() -> pathlib.Path:
    d = pathlib.Path(os.environ.get("BENCH_OUT_DIR", "bench-reports"))
    d.mkdir(parents=True, exist_ok=True)
    return d


def run_sweep() -> tuple[Tuner, Profiler]:
    prof = Profiler(level=2)
    ctx = sim_ctx(N, TOPO, profile=prof)
    tuner = Tuner(link=LINK)
    t0 = time.perf_counter()
    summary = tuner.tune(ctx, GRID)
    row("tuner_sweep_s", (time.perf_counter() - t0) * 1e6,
        f"points={summary['points']} variants={summary['variants']} "
        f"fp={summary['fingerprint']}")
    fp = summary["fingerprint"]
    sel = tuner.selector()
    for collective in GRID["collectives"]:
        for nbytes in GRID["sizes"]:
            variants = tuner.db.variants(fp, collective, f"n{N}", nbytes)
            meas = {tuner_mod.split_variant(k)[:2]: v["mean_s"]
                    for k, v in variants.items()}
            pick = sel.schedule(collective, N, nbytes, TOPO)
            analytic = coll.choose_schedule(N, nbytes, TOPO, LINK,
                                            collective=collective)
            a_us = meas.get(analytic, float("nan")) * 1e6
            row(f"tuned_{collective}_{nbytes}B", meas[pick] * 1e6,
                f"picked={pick[0]}/c{pick[1]} analytic={analytic[0]}/"
                f"c{analytic[1]}({a_us:.2f}us) variants={len(meas)}")
    lk = tuner.db.link_model(fp)
    row("refit_alpha_us", lk.alpha_s * 1e6,
        f"bw={lk.bw_Bps / 1e9:.2f}GB/s contention={lk.contention:.2f}")
    return tuner, prof


def check_acceptance(tuner: Tuner) -> None:
    """Tuned pick == measured argmin on every covered point; never
    measured-worse than the analytic selector's choice."""
    fp = tuner_mod.fingerprint(TOPO, N)
    sel = tuner.selector()
    total = hits = never_worse = 0
    for collective in GRID["collectives"]:
        for nbytes in GRID["sizes"]:
            variants = tuner.db.variants(fp, collective, f"n{N}", nbytes)
            meas = {tuner_mod.split_variant(k)[:2]: v["mean_s"]
                    for k, v in variants.items()}
            pick = sel.schedule(collective, N, nbytes, TOPO)
            analytic = coll.choose_schedule(N, nbytes, TOPO, LINK,
                                            collective=collective)
            total += 1
            hits += pick == min(meas, key=meas.get)
            never_worse += (analytic not in meas
                            or meas[pick] <= meas[analytic])
    row("tuned_best_fraction", 100.0 * hits / total,
        f"{hits}/{total} grid points pick the measured best (>=90% req)")
    row("tuned_never_worse", 100.0 * never_worse / total,
        f"{never_worse}/{total} never measured-worse than analytic")
    assert hits / total >= 0.9, "tuned selector missed the measured best"
    assert never_worse == total, "tuned pick measured-worse than analytic"


def check_roundtrip(tuner: Tuner, prof: Profiler) -> None:
    d = out_dir()
    db_path = d / "tuning_db.json"
    prof_path = d / "profile.json"
    tuner.save(db_path)
    prof.dump(prof_path)
    reloaded = Tuner(path=str(db_path))
    sel_a, sel_b = tuner.selector(), reloaded.selector()
    mismatches = 0
    for collective in GRID["collectives"]:
        for nbytes in GRID["sizes"]:
            mismatches += (sel_a.schedule(collective, N, nbytes, TOPO)
                           != sel_b.schedule(collective, N, nbytes, TOPO))
    row("db_roundtrip_mismatches", float(mismatches),
        f"db={db_path} profile={prof_path} "
        f"timeline={len(prof.samples)}samples")
    assert mismatches == 0, "tuned selector did not round-trip from disk"


def check_disabled_overhead() -> None:
    """Eager-dispatch overhead of an ATTACHED-BUT-DISABLED profiler (the
    pcontrol(0) state every op pays one flag test for).  Jitted paths
    pay only at trace time; the eager SIM path is the worst case."""
    x = sized(4096, N)
    iters = 20

    def time_ctx(ctx) -> float:
        t0 = time.perf_counter()
        for _ in range(iters):
            ctx.to_all(x, "sum", algorithm="ring")
        return (time.perf_counter() - t0) / iters

    ctx_base = sim_ctx(N, TOPO)
    ctx_off = sim_ctx(N, TOPO, profile=Profiler(level=0))
    for c in (ctx_base, ctx_off):
        c.to_all(x, "sum", algorithm="ring")            # warm caches
    # INTERLEAVED rounds, per-variant minima: the flag-test overhead is
    # far below run-to-run scheduler noise, so block-vs-block timing
    # flaps; alternating rounds see the same machine state and the min
    # discards the noisy ones
    base_ts, off_ts = [], []
    for _ in range(5):
        base_ts.append(time_ctx(ctx_base))
        off_ts.append(time_ctx(ctx_off))
    base, disabled = min(base_ts), min(off_ts)
    overhead = (disabled - base) / base * 100.0
    row("profiler_disabled_overhead_pct", overhead,
        f"base={base * 1e6:.1f}us disabled={disabled * 1e6:.1f}us "
        f"(<5% req)")
    assert overhead < 5.0, \
        f"disabled profiler costs {overhead:.1f}% on the eager path"


def main():
    print("name,us,derived")
    tuner, prof = run_sweep()
    check_acceptance(tuner)
    check_roundtrip(tuner, prof)
    check_disabled_overhead()


if __name__ == "__main__":
    main()
