"""Paper-fidelity acceptance gate (DESIGN.md §18).

The paper's contribution is an *evaluation* — latency/bandwidth curves
for put/get, barrier, broadcast, and reductions on the 16-PE Epiphany
mesh (arXiv:1608.03545 §5, earlier numbers in arXiv:1604.04205).  This
module is its declarative digitization: one :class:`FidelityRow` per
gated paper number (value, source figure, tolerance, comparison mode)
checked against what OUR alpha-beta/congestion model derives for the
same experiment.  The derivations run the exact code the selectors run
(``abmodel`` eq. 1 on the paper's NoC constants, the interned
``collectives`` schedules priced per stage), so any drive-by edit to a
``LinkModel`` constant, a schedule builder, or the ISR-entry cost moves
a derived value and trips the gate — speed claims stay *checked* facts,
not free-text ``paper=`` strings.

``paper_benches.py`` sources its paper comparisons from this table
(:func:`ref`) and re-emits every gated row via :func:`bench_rows`; CI
runs the check next to ``check_regression.py``:

  PYTHONPATH=src python -m benchmarks.paper_fidelity --check
  PYTHONPATH=src python -m benchmarks.paper_fidelity --check \\
      --perturb bw_Bps=1.2e9        # demo: exit 1 on a skewed constant
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Callable

sys.path.insert(0, "src")

from repro.core import abmodel, collectives as coll  # noqa: E402
from repro.configs import epiphany16 as paper        # noqa: E402


@dataclasses.dataclass(frozen=True)
class FidelityModel:
    """Everything the derivations depend on — one knob object so tests
    (and ``--perturb``) can skew a constant and watch the gate trip."""

    link: abmodel.LinkModel = paper.PUT_LINK
    get_link: abmodel.LinkModel = paper.GET_LINK
    topo: object = paper.TOPOLOGY
    n: int = paper.N_PES
    isr_entry_s: float = paper.ISR_ENTRY_S
    sizes: tuple = tuple(paper.MSG_SIZES)
    reduce_work_bytes: int = 256    # SHMEM_REDUCE_MIN_WRKDATA_SIZE * 4


# -- derivations (each: FidelityModel -> scalar) ----------------------------

def _fits(m: FidelityModel) -> tuple[abmodel.ABFit, abmodel.ABFit]:
    put = [abmodel.stage_time(s, 1.0, m.link) for s in m.sizes]
    get = [abmodel.stage_time(s, 1.0, m.get_link) for s in m.sizes]
    return abmodel.fit(m.sizes, put), abmodel.fit(m.sizes, get)


def ipi_get_turnover(m: FidelityModel) -> float:
    """Smallest swept size where the IPI-get protocol (8 B interrupt
    signal + ISR entry + owner-executed put) beats the direct
    read-request get — the paper's 64 B crossover.  Shared with
    ``paper_benches.bench_rma`` so the bench and the gate cannot
    diverge."""
    for s in m.sizes:
        direct = abmodel.stage_time(s, 1.0, m.get_link)
        ipi = (abmodel.stage_time(8, 1.0, m.link)
               + abmodel.stage_time(s, 1.0, m.link) + m.isr_entry_s)
        if ipi < direct:
            return float(s)
    return float("inf")


def _d_put_alpha_us(m):
    return _fits(m)[0].alpha * 1e6


def _d_put_peak(m):
    return _fits(m)[0].inv_beta / 1e9


def _d_get_peak(m):
    return _fits(m)[1].inv_beta / 1e9


def _d_ratio(m):
    fp, fg = _fits(m)
    return fg.inv_beta / fp.inv_beta


def _d_put_4096(m):
    return abmodel.stage_time(4096, 1.0, m.link) * 1e6


def _d_get_4096(m):
    return abmodel.stage_time(4096, 1.0, m.get_link) * 1e6


def _d_dissem_us(m):
    return abmodel.modeled_collective_time(
        coll.barrier_stages(m.n, m.topo), m.link) * 1e6


def _d_elib_over_dissem(m):
    return paper.PAPER["elib_barrier_us"] / _d_dissem_us(m)


def _d_dissem_over_wand(m):
    return _d_dissem_us(m) / paper.PAPER["wand_barrier_us"]


def _d_bcast_eff(m):
    t = abmodel.modeled_collective_time(
        coll.broadcast_stages(m.n, 8192, m.topo), m.link)
    return 8192 / t / 1e9


def _d_reduce_knee(m):
    """Largest size whose work-array-padded allreduce time still equals
    the smallest message's — where the latency floor ends and the curve
    starts rising (paper Fig. 8)."""
    floor = float(m.reduce_work_bytes)
    t = {s: abmodel.modeled_collective_time(
        coll.allreduce_stages(m.n, max(s, floor), m.topo), m.link)
        for s in m.sizes}
    base, knee = t[m.sizes[0]], m.sizes[0]
    for s in m.sizes:
        if t[s] <= base * (1 + 1e-9):
            knee = s
    return float(knee)


# -- the gated table ---------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FidelityRow:
    key: str
    paper_value: float
    units: str
    mode: str                    # "rel" | "max" | "min"
    tol: float                   # rel: |err|<=tol; max: d<=p*(1+tol);
    source: str                  # min: d>=p*(1-tol)
    derive: Callable[[FidelityModel], float]
    note: str = ""


_P = paper.PAPER

#: Every gated paper number.  ``mode="rel"`` rows are two-sided fidelity
#: checks; ``max``/``min`` rows are one-sided bounds used where the model
#: has a DOCUMENTED structural deviation (see each row's note).
TABLE: tuple[FidelityRow, ...] = (
    FidelityRow("put_alpha_us", _P["put_alpha_us"], "us", "rel", 0.10,
                "1608.03545_Fig.3+1604.04205_Fig.4", _d_put_alpha_us,
                "small-message latency intercept of the put fit"),
    FidelityRow("put_peak_GBs", _P["put_peak_GBs"], "GB/s", "rel", 0.02,
                "1608.03545_Fig.3", _d_put_peak,
                "8B/clk eMesh write channel at 600 MHz, DMA-throttled"),
    FidelityRow("get_peak_GBs", _P["get_peak_GBs"], "GB/s", "rel", 0.02,
                "1608.03545_Fig.3", _d_get_peak,
                "round-trip read-request channel"),
    FidelityRow("get_put_ratio", _P["get_put_ratio"], "", "rel", 0.02,
                "1608.03545_Fig.3", _d_ratio,
                "get saturates ~10x below put"),
    FidelityRow("put_4096B_us", _P["put_4096B_us"], "us", "rel", 0.05,
                "1608.03545_Fig.3", _d_put_4096,
                "digitized 4 KB put latency point"),
    FidelityRow("get_4096B_us", _P["get_4096B_us"], "us", "rel", 0.05,
                "1608.03545_Fig.3", _d_get_4096,
                "digitized 4 KB get latency point"),
    FidelityRow("ipi_get_turnover_B", _P["ipi_get_turnover_B"], "B",
                "rel", 0.0, "1608.03545_Fig.3", ipi_get_turnover,
                "exact after the ISR-entry fix (60 clk not 120; the seed "
                "derived 128 B)"),
    FidelityRow("dissem_barrier_us_16pe", _P["dissem_barrier_us_16pe"],
                "us", "max", 1.2, "1608.03545_Fig.6+§5",
                _d_dissem_us,
                "documented deviation: the model prices each barrier "
                "flag put at the full DMA-descriptor launch alpha where "
                "the chip's flag is a bare word store — modeled ~2.1x "
                "the measured 0.23 us, bounded at 2.2x"),
    FidelityRow("barrier_beats_elib_x", _P["elib_barrier_us"]
                / _P["dissem_barrier_us_16pe"], "x", "min", 0.55,
                "1608.03545_§5", _d_elib_over_dissem,
                "dissemination barrier must stay >=3.9x faster than the "
                "2.0 us e-lib barrier (paper: 8.7x; the flag-put alpha "
                "deviation halves the modeled margin)"),
    FidelityRow("wand_vs_dissem_x", _P["dissem_barrier_us_16pe"]
                / _P["wand_barrier_us"], "x", "max", 1.2,
                "1608.03545_§5", _d_dissem_over_wand,
                "hardware WAND barrier stays ahead but by a bounded "
                "factor (paper: 2.3x; modeled <=5.06x under the same "
                "flag-put alpha deviation)"),
    FidelityRow("bcast_eff_GBs_8192B", _P["bcast_GBs_over_log2N"] / 4.0,
                "GB/s", "rel", 0.10, "1608.03545_Fig.6", _d_bcast_eff,
                "~2.4/log2(16) GB/s at 8 KB"),
    FidelityRow("reduce_knee_B", _P["reduce_knee_B"], "B", "rel", 0.0,
                "1608.03545_Fig.8", _d_reduce_knee,
                "SHMEM_REDUCE_MIN_WRKDATA_SIZE (64 ints) latency floor"),
)

_ROW_BY_KEY = {r.key: r for r in TABLE}


@dataclasses.dataclass
class FidelityResult:
    row: FidelityRow
    derived: float
    err: float                   # signed relative deviation from paper
    ok: bool


def evaluate(model: FidelityModel | None = None) -> list[FidelityResult]:
    m = model if model is not None else FidelityModel()
    out = []
    for r in TABLE:
        d = float(r.derive(m))
        err = (d - r.paper_value) / abs(r.paper_value)
        if r.mode == "rel":
            ok = abs(err) <= r.tol + 1e-12
        elif r.mode == "max":
            ok = d <= r.paper_value * (1 + r.tol) + 1e-12
        elif r.mode == "min":
            ok = d >= r.paper_value * (1 - r.tol) - 1e-12
        else:
            raise ValueError(f"bad mode {r.mode!r}")
        out.append(FidelityResult(r, d, err, ok))
    return out


def check(model: FidelityModel | None = None, out=None) -> int:
    """Print the acceptance table; 0 when every row holds, 1 otherwise."""
    out = out if out is not None else sys.stdout
    results = evaluate(model)
    print(f"paper-fidelity gate: {len(results)} rows "
          f"(model-derived vs digitized paper values)", file=out)
    print(f"{'key':<26s} {'mode':<4s} {'paper':>10s} {'derived':>10s} "
          f"{'err':>8s} {'tol':>6s} {'verdict':<9s} source", file=out)
    bad = 0
    for res in results:
        r = res.row
        verdict = "OK" if res.ok else "VIOLATION"
        bad += not res.ok
        print(f"{r.key:<26s} {r.mode:<4s} {r.paper_value:>10.4g} "
              f"{res.derived:>10.4g} {res.err:>+8.1%} {r.tol:>6.2f} "
              f"{verdict:<9s} {r.source}", file=out)
    if bad:
        print(f"paper-fidelity gate: {bad}/{len(results)} rows violated",
              file=out)
        return 1
    print(f"paper-fidelity gate: all {len(results)} rows within tolerance",
          file=out)
    return 0


# -- hooks for paper_benches -------------------------------------------------

def ref(key: str) -> str:
    """The derived-column citation string for a gated number — what
    ``paper_benches`` prints instead of a free-text ``paper=``."""
    r = _ROW_BY_KEY[key]
    return f"paper={r.paper_value:g}{r.units}[{r.source}]"


def bench_rows(model: FidelityModel | None = None) -> list[tuple]:
    """Every gated row as a standardized bench (name, value, derived)
    triple — ``paper_benches.bench_fidelity`` re-emits these so the
    fidelity trajectory lands in BENCH_*.json alongside wall times."""
    out = []
    for res in evaluate(model):
        r = res.row
        out.append((f"fidelity_{r.key}", res.derived,
                    f"paper={r.paper_value:g}{r.units} mode={r.mode} "
                    f"tol={r.tol:g} err={res.err:+.1%} "
                    f"src={r.source} "
                    f"{'OK' if res.ok else 'VIOLATION'}"))
    return out


def _perturbed(specs: list[str]) -> FidelityModel:
    """``--perturb [get:]field=value`` -> a FidelityModel with that
    LinkModel constant replaced (put link by default)."""
    m = FidelityModel()
    for spec in specs:
        target, _, rest = spec.partition(":") if ":" in spec \
            else ("put", "", spec)
        field, _, val = rest.partition("=")
        if not val:
            raise SystemExit(f"--perturb wants [get:]field=value, got "
                             f"{spec!r}")
        attr = "get_link" if target == "get" else "link"
        link = dataclasses.replace(getattr(m, attr),
                                   **{field: float(val)})
        m = dataclasses.replace(m, **{attr: link})
    return m


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any gated row is out of tolerance "
                         "(the CI acceptance gate)")
    ap.add_argument("--perturb", action="append", default=[],
                    metavar="[get:]FIELD=VALUE",
                    help="skew a LinkModel constant before deriving "
                         "(e.g. bw_Bps=1.2e9) — demonstrates the gate "
                         "tripping on a drive-by constant change")
    args = ap.parse_args(argv)
    model = _perturbed(args.perturb) if args.perturb else None
    rc = check(model)
    if args.check and rc:
        sys.exit(rc)


if __name__ == "__main__":
    main()
