"""CI perf gate: compare a fresh bench run's ``measured_us`` at PINNED
grid points against a committed ``BENCH_*.json`` baseline and fail on a
>25% regression.

The pins are (bench, row-name) pairs whose name embeds the payload size,
so the same grid point is re-measured run over run (benchmarks/run.py's
standardized rows).  Rows below ``--min-us`` are skipped — alpha-scale
rows are timer noise on shared runners.

When the machine fingerprints stamped into the two documents differ, a
loud warning precedes the table (wall times are only comparable within
one fingerprint — PR 9 hit this variance and had to explain it by
hand).  On failure the gate runs ``repro.tools.perfdiff`` and ships the
attribution report (which cost-model term moved: pick/alpha/beta/
contention) as ``bench-reports/perfdiff_report.{txt,json}`` — the
explanation artifact, not just a ratio (DESIGN.md §18).

  PYTHONPATH=src python -m benchmarks.check_regression \\
      --baseline BENCH_6.json --current bench-reports/BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

sys.path.insert(0, "src")

# Pinned grid points: stable, size-suffixed rows present in the
# bench-smoke subset (patterns + fused + roofline) AND in the full
# committed run.
PINS: list[tuple[str, str]] = [
    ("patterns", "allreduce_rd_65536B"),
    ("patterns", "allreduce_ring_65536B"),
    ("patterns", "fcollect_rd_65536B"),
    ("patterns", "alltoall_65536B"),
    ("fused", "attn_ring_262144B_us"),
    ("fused", "attn_mono_262144B_us"),
    ("fused", "grad_rs_fused_16777216B_us"),
    ("fused", "grad_rs_unfused_16777216B_us"),
    ("serve", "serve_decode_p50_us_occ1"),
    ("serve", "serve_decode_p50_us_occ4"),
    ("serve", "serve_ttft_p50_us_metrics"),
    ("serve", "serve_per_token_p50_us_metrics"),
    ("trace", "trace_allreduce_65536B_off"),
    ("fault", "ckpt_sync_save_16777216B"),
    ("fault", "recovery_restore_16pe_1MB"),
    ("roofline", "roofline_train_wall_us"),
    ("roofline", "roofline_decode_wall_us"),
]


def _rows(path: pathlib.Path) -> dict[tuple[str, str], float]:
    doc = json.loads(path.read_text())
    return {(r["bench"], r["name"]): float(r["measured_us"])
            for r in doc.get("rows", [])}


def _fingerprint_warning(baseline: pathlib.Path,
                         current: pathlib.Path) -> None:
    """Loud cross-machine banner when the stamped fingerprints differ
    (or the baseline predates fingerprinting)."""
    fb = json.loads(baseline.read_text()).get("machine")
    fc = json.loads(current.read_text()).get("machine")
    if fb == fc and fb is not None:
        return
    print("!" * 68)
    if fb is None or fc is None:
        missing = "baseline" if fb is None else "current"
        print(f"!! WARNING: {missing} document carries no machine "
              f"fingerprint")
        print("!! (predates fingerprint stamping) — treat wall-time")
        print("!! comparisons across documents with suspicion")
    else:
        print("!! WARNING: baseline and current runs come from "
              "DIFFERENT machines")
        for key in sorted(set(fb) | set(fc)):
            b, c = fb.get(key), fc.get(key)
            if b != c:
                print(f"!!   {key}: baseline={b!r} current={c!r}")
        print("!! wall-time ratios partly reflect hardware, not code —")
        print("!! regenerate the baseline on THIS machine before "
              "trusting the gate")
    print("!" * 68)


def _emit_attribution(baseline: pathlib.Path, current: pathlib.Path,
                      threshold: float, min_us: float,
                      report_dir: pathlib.Path) -> None:
    """Run perfdiff on the failing pair and ship the explanation
    artifact.  Attribution is best-effort: its own failure must never
    mask the gate verdict."""
    try:
        from repro.tools import perfdiff
        rep = perfdiff.diff_bench(
            json.loads(baseline.read_text()),
            json.loads(current.read_text()),
            threshold=threshold, min_us=min_us,
            baseline=str(baseline), current=str(current))
        text = perfdiff.render(rep)
        print("\n" + text)
        report_dir.mkdir(parents=True, exist_ok=True)
        (report_dir / "perfdiff_report.txt").write_text(text + "\n")
        (report_dir / "perfdiff_report.json").write_text(
            json.dumps(rep, indent=1))
        print(f"\nperf gate: attribution report written to "
              f"{report_dir}/perfdiff_report.{{txt,json}}")
    except Exception as e:      # noqa: BLE001
        print(f"perf gate: attribution failed ({e}); the verdict above "
              f"stands")


def check(baseline: pathlib.Path, current: pathlib.Path,
          threshold: float = 1.25, min_us: float = 20.0,
          report_dir: pathlib.Path | None = None) -> int:
    base = _rows(baseline)
    cur = _rows(current)
    _fingerprint_warning(baseline, current)
    compared = regressed = 0
    print(f"perf gate: {current} vs baseline {baseline} "
          f"(fail > x{threshold:.2f})")
    print("bench,name,baseline_us,current_us,ratio,verdict")
    for pin in PINS:
        b = base.get(pin)
        c = cur.get(pin)
        if b is None or c is None:
            where = "baseline" if b is None else "current"
            print(f"{pin[0]},{pin[1]},-,-,-,SKIP(missing in {where})")
            continue
        if not (math.isfinite(b) and math.isfinite(c)) or b < min_us:
            print(f"{pin[0]},{pin[1]},{b:.2f},{c:.2f},-,"
                  f"SKIP(below {min_us:.0f}us floor)")
            continue
        ratio = c / b
        compared += 1
        verdict = "OK" if ratio <= threshold else "REGRESSED"
        regressed += verdict == "REGRESSED"
        print(f"{pin[0]},{pin[1]},{b:.2f},{c:.2f},x{ratio:.2f},{verdict}")
    if compared == 0:
        print("perf gate: no pinned grid point present in both documents")
        return 2
    if regressed:
        print(f"perf gate: {regressed}/{compared} pinned points regressed "
              f"beyond x{threshold:.2f}")
        _emit_attribution(baseline, current, threshold, min_us,
                          report_dir if report_dir is not None
                          else pathlib.Path("bench-reports"))
        return 1
    print(f"perf gate: {compared} pinned points within x{threshold:.2f}")
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json to gate against")
    ap.add_argument("--current", required=True,
                    help="fresh benchmarks.run --json output")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when current > threshold * baseline")
    ap.add_argument("--min-us", type=float, default=20.0,
                    help="skip rows whose baseline is below this (noise)")
    ap.add_argument("--report-dir", default="bench-reports",
                    help="where the perfdiff attribution artifact lands "
                         "on failure")
    args = ap.parse_args(argv)
    rc = check(pathlib.Path(args.baseline), pathlib.Path(args.current),
               args.threshold, args.min_us,
               pathlib.Path(args.report_dir))
    sys.exit(rc)


if __name__ == "__main__":
    main()
