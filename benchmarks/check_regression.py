"""CI perf gate: compare a fresh bench run's ``measured_us`` at PINNED
grid points against a committed ``BENCH_*.json`` baseline and fail on a
>25% regression.

The pins are (bench, row-name) pairs whose name embeds the payload size,
so the same grid point is re-measured run over run (benchmarks/run.py's
standardized rows).  Rows below ``--min-us`` are skipped — alpha-scale
rows are timer noise on shared runners.

  PYTHONPATH=src python -m benchmarks.check_regression \\
      --baseline BENCH_6.json --current bench-reports/BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

# Pinned grid points: stable, size-suffixed rows present in the
# bench-smoke subset (patterns + fused) AND in the full committed run.
PINS: list[tuple[str, str]] = [
    ("patterns", "allreduce_rd_65536B"),
    ("patterns", "allreduce_ring_65536B"),
    ("patterns", "fcollect_rd_65536B"),
    ("patterns", "alltoall_65536B"),
    ("fused", "attn_ring_262144B_us"),
    ("fused", "attn_mono_262144B_us"),
    ("fused", "grad_rs_fused_16777216B_us"),
    ("fused", "grad_rs_unfused_16777216B_us"),
    ("serve", "serve_decode_p50_us_occ1"),
    ("serve", "serve_decode_p50_us_occ4"),
    ("serve", "serve_ttft_p50_us_metrics"),
    ("serve", "serve_per_token_p50_us_metrics"),
    ("trace", "trace_allreduce_65536B_off"),
    ("fault", "ckpt_sync_save_16777216B"),
    ("fault", "recovery_restore_16pe_1MB"),
]


def _rows(path: pathlib.Path) -> dict[tuple[str, str], float]:
    doc = json.loads(path.read_text())
    return {(r["bench"], r["name"]): float(r["measured_us"])
            for r in doc.get("rows", [])}


def check(baseline: pathlib.Path, current: pathlib.Path,
          threshold: float = 1.25, min_us: float = 20.0) -> int:
    base = _rows(baseline)
    cur = _rows(current)
    compared = regressed = 0
    print(f"perf gate: {current} vs baseline {baseline} "
          f"(fail > x{threshold:.2f})")
    print("bench,name,baseline_us,current_us,ratio,verdict")
    for pin in PINS:
        b = base.get(pin)
        c = cur.get(pin)
        if b is None or c is None:
            where = "baseline" if b is None else "current"
            print(f"{pin[0]},{pin[1]},-,-,-,SKIP(missing in {where})")
            continue
        if not (math.isfinite(b) and math.isfinite(c)) or b < min_us:
            print(f"{pin[0]},{pin[1]},{b:.2f},{c:.2f},-,"
                  f"SKIP(below {min_us:.0f}us floor)")
            continue
        ratio = c / b
        compared += 1
        verdict = "OK" if ratio <= threshold else "REGRESSED"
        regressed += verdict == "REGRESSED"
        print(f"{pin[0]},{pin[1]},{b:.2f},{c:.2f},x{ratio:.2f},{verdict}")
    if compared == 0:
        print("perf gate: no pinned grid point present in both documents")
        return 2
    if regressed:
        print(f"perf gate: {regressed}/{compared} pinned points regressed "
              f"beyond x{threshold:.2f}")
        return 1
    print(f"perf gate: {compared} pinned points within x{threshold:.2f}")
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json to gate against")
    ap.add_argument("--current", required=True,
                    help="fresh benchmarks.run --json output")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when current > threshold * baseline")
    ap.add_argument("--min-us", type=float, default=20.0,
                    help="skip rows whose baseline is below this (noise)")
    args = ap.parse_args(argv)
    rc = check(pathlib.Path(args.baseline), pathlib.Path(args.current),
               args.threshold, args.min_us)
    sys.exit(rc)


if __name__ == "__main__":
    main()
