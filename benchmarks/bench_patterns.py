"""Predicted-vs-measured stage costs for the compiled CommPattern layer.

Every collective now executes a :class:`repro.core.pattern.Schedule` of
compiled patterns, and prices itself from the SAME object
(``schedule.cost(topo)``).  This bench closes the loop:

  1. Per-stage: run each schedule's stages as bare ppermutes on the SIM
     backend, fit an alpha-beta model (eq. 1) to the measured
     (bytes, time) samples, and report the fit the same way the paper's
     figure subtitles do.
  2. Per-collective: compare the fitted-model prediction built from the
     schedule's own (bytes, hops) descriptors against the measured wall
     time of the full collective, and the paper-constant (Epiphany NoC)
     prediction alongside.
  3. Selector check: report where `choose_algorithm` places the rd/ring
     cross-over on each topology and verify the measured times agree on
     which side of it the endpoints fall.

SIM wall-clock is CPU time for the simulated chip, NOT Epiphany/TPU time —
the point is that the *shape* of the cost model (per-stage additivity,
payload scaling, stage counts) matches what actually executes.

  PYTHONPATH=src python -m benchmarks.bench_patterns
"""
from __future__ import annotations

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core.netops import SimNetOps
from repro.core.topology import epiphany3

from ._util import sized, time_fn as _time

TOPO = epiphany3()
N = TOPO.n_pes
LINK = abmodel.EPIPHANY_NOC
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _sized(nbytes, n=N):
    return sized(nbytes, n)


# -- 1. fit the SIM substrate's own alpha-beta from single stages ------------

def fit_sim_link() -> abmodel.ABFit:
    """Measure one ring-pattern ppermute per size; fit T = alpha + beta*L.
    This is the substrate's empirical LinkModel — the paper's Fig. 3
    methodology applied to our simulator."""
    net = SimNetOps(N)
    sched = coll.fcollect_schedule(N, 0.0, "ring")
    pattern = sched.stages[0].pattern
    sizes = [64, 256, 1024, 4096, 16384, 65536]
    times = []
    for s in sizes:
        x = _sized(s)
        times.append(_time(lambda v: net.ppermute(v, pattern), x))
    fit = abmodel.fit(sizes, times)
    row("sim_stage_alpha_us", fit.alpha * 1e6,
        f"beta^-1={fit.inv_beta / 1e9:.2f}GB/s "
        f"(+-{fit.alpha_std * 1e6:.2f}us)")
    return fit


# -- 2. predicted vs measured per collective schedule ------------------------

def bench_schedules(fit: abmodel.ABFit):
    sim_link = abmodel.LinkModel(alpha_s=max(fit.alpha, 1e-9), hop_s=0.0,
                                 bw_Bps=max(fit.inv_beta, 1.0))
    cases = []
    for s in (256, 4096, 65536):
        cases.append((f"broadcast_{s}B", coll.broadcast_schedule(N, s),
                      lambda c, v: c.broadcast(v, 0), _sized(s)))
        cases.append((f"allreduce_rd_{s}B",
                      coll.allreduce_schedule(N, s, "rd"),
                      lambda c, v: c.to_all(v, "sum", algorithm="rd"),
                      _sized(s)))
        cases.append((f"allreduce_ring_{s}B",
                      coll.allreduce_schedule(N, s, "ring"),
                      lambda c, v: c.to_all(v, "sum", algorithm="ring"),
                      _sized(s)))
        cases.append((f"fcollect_rd_{s}B", coll.fcollect_schedule(N, s, "rd"),
                      lambda c, v: c.fcollect(v, algorithm="rd"), _sized(s)))
        cases.append((f"alltoall_{s}B", coll.alltoall_schedule(N, s * N),
                      lambda c, v: c.alltoall(v), _sized(s * N)))

    ctx = sim_ctx(N, TOPO)
    print("\nname,measured_us,predicted(fit)/paper-model/stages")
    for name, sched, run, x in cases:
        measured = _time(lambda v, _run=run: _run(ctx, v), x)
        pred_fit = sched.time(None, sim_link)
        pred_noc = sched.time(TOPO, LINK)
        ratio = measured / pred_fit if pred_fit > 0 else float("inf")
        row(name, measured * 1e6,
            f"fit={pred_fit * 1e6:.2f}us(x{ratio:.2f}) "
            f"noc={pred_noc * 1e6:.3f}us stages={len(sched)}")


# -- 3. the cost-model selector's cross-over ---------------------------------

def bench_selector():
    print("\n== choose_algorithm cross-over (alpha-beta priced, "
          "paper NoC link) ==")
    for topo, tname in ((None, "flat"), (TOPO, "epiphany3")):
        lo, hi = 8, 1 << 22
        ends = (coll.choose_algorithm(N, lo, topo, LINK),
                coll.choose_algorithm(N, hi, topo, LINK))
        if ends != ("rd", "ring"):
            # a constant/topology change moved the cross-over outside the
            # probed range — report it, don't kill the harness
            row(f"allreduce_crossover_{tname}_B", float("nan"),
                f"WARN_no_crossover_in[{lo},{hi}]B picks={ends}")
            continue
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if coll.choose_algorithm(N, mid, topo, LINK) == "rd":
                lo = mid
            else:
                hi = mid
        row(f"allreduce_crossover_{tname}_B", float(hi),
            f"rd<= {lo}B < ring (n={N})")

    # the selection must be consistent with the schedules' own pricing
    for nbytes in (64, 1 << 21):
        algo = coll.choose_algorithm(N, nbytes, TOPO, LINK)
        t_rd = coll.allreduce_schedule(N, nbytes, "rd").time(TOPO, LINK)
        t_ring = coll.allreduce_schedule(N, nbytes, "ring").time(TOPO, LINK)
        best = "rd" if t_rd <= t_ring else "ring"
        status = "" if algo == best else " WARN_mismatch"
        row(f"auto_pick_{nbytes}B", 0.0,
            f"{algo}{status} rd={t_rd * 1e6:.2f}us ring={t_ring * 1e6:.2f}us")


def main():
    print("name,us,derived")
    fit = fit_sim_link()
    bench_schedules(fit)
    bench_selector()


if __name__ == "__main__":
    main()
