"""Fault-tolerance cost ladder (DESIGN.md §17): what the elastic
runtime charges the train loop, measured at pinned grid points.

  1. Checkpoint-overlap overhead vs a synchronous save at 16MB of
     train state on a 16-PE SIM mesh: the stall ``manager.save`` imposes
     inline vs the stall ``PgasCheckpointer.begin`` imposes (the stream
     issues on the dedicated context's worker and completes only at the
     epoch-boundary ``drain()``).  The acceptance pin: begin < 10% of
     the sync stall.  ``drain`` wall time is reported for context — it
     sits at the epoch boundary, off the per-step critical path.
  2. Recovery time: the elastic restart protocol
     (degrade -> refingerprint -> restore) on a 16-PE checkpoint, and
     recovery-plus-replay cost as a function of checkpoint interval
     (a longer interval loses more steps to replay — the classic
     interval/overhead trade).

  PYTHONPATH=src python -m benchmarks.bench_fault
"""
from __future__ import annotations

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.ckpt import manager
from repro.ckpt.pgas import PgasCheckpointer
from repro.core import sim_ctx
from repro.core.elastic import recover
from repro.core.topology import epiphany3

TOPO = epiphany3()
N = TOPO.n_pes
NBYTES = 16 << 20                    # the pinned grid point: 16MB state
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _state(nbytes: int):
    w = np.random.RandomState(0).randn(
        N, max(1, nbytes // (N * 4))).astype(np.float32)
    return {"w": jnp.asarray(w)}


def ckpt_overlap() -> None:
    state = _state(NBYTES)
    iters = 5
    with tempfile.TemporaryDirectory() as d:
        for i in range(2):                       # warm the page cache
            manager.save(d, i, state)
        t0 = time.perf_counter()
        for i in range(iters):
            manager.save(d, i, state)
        sync_us = (time.perf_counter() - t0) / iters * 1e6
        row(f"ckpt_sync_save_{NBYTES}B", sync_us,
            f"{NBYTES / 1e6 / (sync_us / 1e6):.0f}MB/s inline stall")

        ck = PgasCheckpointer(sim_ctx(N, TOPO), d)
        ck.begin(0, state)
        ck.drain()                               # warm
        begins, drains = [], []
        for i in range(iters):
            t0 = time.perf_counter()
            ck.begin(i, state)
            begins.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            ck.drain()
            drains.append(time.perf_counter() - t0)
        begin_us = min(begins) * 1e6
        frac = begin_us / sync_us * 100.0
        row(f"ckpt_pgas_begin_{NBYTES}B", begin_us,
            f"{frac:.1f}% of sync stall (acceptance: <10%)")
        row(f"ckpt_pgas_drain_{NBYTES}B", min(drains) * 1e6,
            "epoch-boundary completion, off the critical path")
        assert frac < 10.0, \
            f"async PGAS begin costs {frac:.1f}% of the sync stall"


def recovery() -> None:
    # the protocol alone: degrade + refingerprint + restore of a 1MB
    # 16-PE checkpoint after PE 5 dies
    state = _state(1 << 20)
    with tempfile.TemporaryDirectory() as d:
        manager.save(d, 7, state)
        ctx = sim_ctx(N, TOPO)
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            step, _, dm = recover(ctx, [5], d, state)
            times.append(time.perf_counter() - t0)
        row("recovery_restore_16pe_1MB", min(times) * 1e6,
            f"degrade+refingerprint+restore, n_live={dm.n_live}")


def _toy_step(ctx, w, lr=0.05):
    g = ctx.to_all(w, "sum") / ctx.n_pes
    return w - lr * g


def recovery_vs_interval() -> None:
    """Kill at a fixed step; recovery cost = protocol + replay of the
    steps since the last checkpoint — the interval trade the operator
    tunes ``--ckpt-every`` against."""
    kill_step = 11
    w0 = jnp.asarray(np.random.RandomState(1)
                     .randn(N, 4096).astype(np.float32))
    for every in (2, 8):
        ctx = sim_ctx(N, TOPO)
        with tempfile.TemporaryDirectory() as d:
            ck = PgasCheckpointer(ctx, d, async_issue=False)
            w = w0
            for step in range(kill_step):
                if step % every == 0:
                    ck.begin(step, {"w": w})
                w = _toy_step(ctx, w)
            ck.drain()
            # PE 5 dies at kill_step: recover, then replay to catch up
            t0 = time.perf_counter()
            step, state, dm = recover(ctx, [5], d, {"w": w0})
            w = state["w"]
            for _ in range(step, kill_step):
                w = _toy_step(ctx, w)
            wall = time.perf_counter() - t0
            row(f"recovery_interval_{every}", wall * 1e6,
                f"replayed {kill_step - step} lost steps "
                f"(last ckpt step {step})")


def main():
    ckpt_overlap()
    recovery()
    recovery_vs_interval()


if __name__ == "__main__":
    main()
