"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/.

  PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""
from __future__ import annotations

import json
import pathlib
import sys

DRY = pathlib.Path("experiments/dryrun")
PROBE = pathlib.Path("experiments/roofline")

ARCH_ORDER = ["internlm2-20b", "h2o-danube-3-4b", "gemma2-9b", "qwen2-0.5b",
              "deepseek-v3-671b", "granite-moe-3b-a800m", "zamba2-1.2b",
              "phi-3-vision-4.2b", "mamba2-2.7b", "hubert-xlarge"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _gb(x):
    return "-" if x is None else f"{x / 1e9:.2f}"


def load(mesh: str, comm: str = "shmem"):
    out = {}
    for f in DRY.glob(f"*__{mesh}__{comm}.json"):
        r = json.loads(f.read_text())
        arch, shape = r["cell"].split("__")[:2]
        out[(arch, shape)] = r
    return out


def dryrun_table(mesh: str, out=sys.stdout):
    cells = load(mesh)
    print(f"\n### Dry-run — mesh {mesh} (shmem substrate)\n", file=out)
    print("| arch | shape | status | compile_s | HLO GFLOPs/chip(body) | "
          "coll GB/chip(body) | args GB/chip | temp GB/chip |", file=out)
    print("|---|---|---|---|---|---|---|---|", file=out)
    n_ok = n_skip = 0
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = cells.get((a, s))
            if r is None:
                print(f"| {a} | {s} | MISSING | | | | | |", file=out)
                continue
            if r["status"] == "skipped":
                n_skip += 1
                print(f"| {a} | {s} | skipped: {r['reason']} | | | | | |",
                      file=out)
                continue
            n_ok += 1
            t = r["roofline"]
            m = r["memory"]
            print(f"| {a} | {s} | ok | {r['compile_s']} | "
                  f"{t['hlo_flops'] / 1e9:.1f} | "
                  f"{t['collective_bytes'] / 1e9:.3f} | "
                  f"{_gb(m['argument_bytes'])} | {_gb(m['temp_bytes'])} |",
                  file=out)
    print(f"\n{n_ok} compiled OK, {n_skip} skipped by assignment rules.",
          file=out)
    print("(FLOPs/bytes columns are raw cost_analysis values: scan bodies "
          "counted once — see §Roofline for trip-count-corrected totals.)",
          file=out)


def roofline_table(out=sys.stdout):
    import re
    rows = []
    for f in sorted(PROBE.glob("*.json")):
        if re.search(r"__p\d", f.stem):
            continue          # hillclimb variants live in §Perf, not here
        rows.append(json.loads(f.read_text()))
    by_cell = {tuple(r["cell"].split("__")[:2]): r for r in rows}
    print("\n### Roofline — single pod 16x16, per chip per step "
          "(probe-extrapolated)\n", file=out)
    print("| arch | shape | compute_s | memory_s | collective_s | "
          "bottleneck | MODEL_FLOPS/HLO_FLOPs | roofline fraction |",
          file=out)
    print("|---|---|---|---|---|---|---|---|", file=out)
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = by_cell.get((a, s))
            if r is None:
                continue
            print(f"| {a} | {s} | {r['compute_s']:.3e} | "
                  f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                  f"{r['bottleneck'].replace('_s', '')} | "
                  f"{r['useful_ratio']:.3f} | "
                  f"{r['roofline_fraction']:.3f} |", file=out)


def main():
    print("# Generated dry-run / roofline report")
    for mesh in ("16x16", "2x16x16"):
        if any(DRY.glob(f"*__{mesh}__shmem.json")):
            dryrun_table(mesh)
    if PROBE.exists():
        roofline_table()


if __name__ == "__main__":
    main()
