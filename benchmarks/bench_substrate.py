"""Substrate A/B: the paper's ARL-vs-eLib comparison at framework level.

Compiles the same smoke train step on an 8-chip submesh under both
substrates and reports collective op counts/bytes from the HLO — the
system-level analogue of the paper's Fig. 3 eLib speedup panel.  Runs in
a subprocess so the main process keeps one device.

  PYTHONPATH=src python -m benchmarks.bench_substrate
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.launch.dryrun import _collective_bytes

    out = {}
    for comm in ("shmem", "xla"):
        cfg = smoke_config("qwen2-0.5b")
        mesh = make_mesh(4, 2)
        with jax.set_mesh(mesh):
            batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                     "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
            wrap, (ps, psp), (os_, osp), _ = build.make_train_step(
                cfg, mesh, comm)
            compiled = jax.jit(wrap(batch), donate_argnums=(0, 1)).lower(
                build.global_shape(ps, psp, mesh),
                build.global_shape(os_, osp, mesh), batch).compile()
        coll = _collective_bytes(compiled.as_text())
        cost = compiled.cost_analysis()
        out[comm] = {"counts": coll["counts"], "bytes": coll["bytes"],
                     "flops": cost.get("flops", 0.0)}
    print("SUBSTRATE_JSON:" + json.dumps(out))
""")


def run() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    for line in r.stdout.splitlines():
        if line.startswith("SUBSTRATE_JSON:"):
            return json.loads(line[len("SUBSTRATE_JSON:"):])
    raise RuntimeError(r.stdout[-2000:] + r.stderr[-2000:])


def main():
    out = run()
    print("substrate,op,count,bytes")
    for comm, d in out.items():
        for k in d["counts"]:
            if d["counts"][k]:
                print(f"{comm},{k},{d['counts'][k]},{d['bytes'][k]}")
    s, x = out["shmem"], out["xla"]
    tot_s = sum(s["bytes"].values())
    tot_x = sum(x["bytes"].values())
    print(f"# shmem moves {tot_s/1e6:.1f} MB in "
          f"{sum(s['counts'].values())} ops (ppermute stages); "
          f"xla moves {tot_x/1e6:.1f} MB in "
          f"{sum(x['counts'].values())} fused collectives — the paper's "
          f"explicit-algorithm vs vendor-primitive trade at pod scale")


if __name__ == "__main__":
    main()
