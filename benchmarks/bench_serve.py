"""Serving-engine latency/throughput bench (DESIGN.md §15): per-token
decode latency (p50/p99) and aggregate tok/s vs offered load on the
continuous-batching engine, single host device (the SIM substrate).

Offered load is batch occupancy: `occ` concurrent sequences sharing the
fixed-shape decode step.  Per-token latency IS the engine step wall time
(a sequence's next token lands every step), so p50/p99 come from the
steady-state decode steps and throughput divides total generated tokens
by wall time.  A final churn point measures continuous mode: staggered
arrivals force admission/prefill work between decode steps.

  PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import time

import numpy as np

ARCH = "qwen2-0.5b"
SLOTS = 4
PAGE = 8
MAX_SEQ = 48
BUCKET = 16
TOKENS = 24                 # per request -> 23 steady decode samples
ROWS: list[tuple] = []


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _engine(cfg, mesh, params=None):
    from repro.serve.engine import ServeEngine
    return ServeEngine(cfg, mesh, params=params, max_slots=SLOTS,
                       page_size=PAGE, max_seq=MAX_SEQ,
                       prompt_bucket=BUCKET)


def _prompts(cfg, n):
    rng = np.random.default_rng(0)
    return [rng.integers(1, cfg.vocab, size=12).astype(np.int32)
            for _ in range(n)]


def _drain_timed(eng):
    """Step the engine dry, classifying step wall times."""
    prefill_ts, decode_ts, n_tok = [], [], 0
    while not eng.scheduler.idle():
        t0 = time.perf_counter()
        info = eng.step()
        dt = time.perf_counter() - t0
        n_tok += len(info["admitted"]) + info["decoded"]
        (prefill_ts if info["admitted"] else decode_ts).append(dt)
    return prefill_ts, decode_ts, n_tok


def bench_occupancy(cfg, mesh, params, occ):
    eng = _engine(cfg, mesh, params)
    warm = eng.submit(_prompts(cfg, 1)[0], 2)     # compile both paths
    eng.run()
    del warm
    for p in _prompts(cfg, occ):
        eng.submit(p, TOKENS)
    prefill_ts, decode_ts, n_tok = _drain_timed(eng)
    wall = sum(prefill_ts) + sum(decode_ts)
    p50, p99 = np.percentile(np.asarray(decode_ts) * 1e6, [50, 99])
    tok_s = n_tok / wall
    kv_b = eng.page_bytes * occ * ((12 + TOKENS + PAGE - 1) // PAGE)
    row(f"serve_decode_p50_us_occ{occ}", p50,
        f"steps={len(decode_ts)} page={PAGE}tok kv={kv_b}B")
    row(f"serve_decode_p99_us_occ{occ}", p99,
        f"steps={len(decode_ts)} page={PAGE}tok")
    row(f"serve_tok_per_s_occ{occ}", tok_s,
        f"tokens={n_tok} wall={wall * 1e3:.0f}ms (value is tok/s)")
    if prefill_ts:
        row(f"serve_prefill_step_us_occ{occ}",
            float(np.mean(prefill_ts) * 1e6),
            f"bucket={BUCKET} (admission step: prefill + first decode)")
    return eng.params


def bench_metrics(cfg, mesh, params):
    """The ServeMetrics histograms against external timing (DESIGN.md
    §16 acceptance): the engine-recorded per-token p50 must agree with
    the externally measured decode-step p50, since both time the same
    forced sync — a loose factor-1.5 tolerance absorbs the scheduler
    bookkeeping outside the engine's own timer."""
    from repro.serve.engine import ServeEngine
    from repro.serve.metrics import ServeMetrics

    m = ServeMetrics()
    eng = ServeEngine(cfg, mesh, params=params, max_slots=SLOTS,
                      page_size=PAGE, max_seq=MAX_SEQ,
                      prompt_bucket=BUCKET, metrics=m)
    eng.submit(_prompts(cfg, 1)[0], 2)            # compile both paths
    eng.run()
    for p in _prompts(cfg, SLOTS):
        eng.submit(p, TOKENS)
    _, decode_ts, _ = _drain_timed(eng)
    ext_p50 = float(np.percentile(np.asarray(decode_ts) * 1e6, 50))
    ttft_p50 = m.ttft_s.percentile(50) * 1e6
    tok_p50 = m.per_token_s.percentile(50) * 1e6
    ratio = tok_p50 / ext_p50 if ext_p50 > 0 else float("nan")
    row("serve_ttft_p50_us_metrics", ttft_p50,
        f"n={m.ttft_s.count} (submit->first token, incl. queue wait)")
    row("serve_per_token_p50_us_metrics", tok_p50,
        f"n={m.per_token_s.count} ext_p50={ext_p50:.1f}us "
        f"ratio={ratio:.2f} (req: 1/1.5 <= ratio <= 1.5)")
    assert 1 / 1.5 <= ratio <= 1.5, \
        f"metrics per-token p50 {tok_p50:.1f}us inconsistent with " \
        f"external decode p50 {ext_p50:.1f}us (x{ratio:.2f})"


def bench_churn(cfg, mesh, params):
    """Continuous mode: one arrival every 2 engine steps against a
    saturated 4-slot batch — admission/prefill interleaves with decode."""
    eng = _engine(cfg, mesh, params)
    eng.submit(_prompts(cfg, 1)[0], 2)
    eng.run()                                     # compile
    prompts = _prompts(cfg, 10)
    nxt = 0
    decode_ts, n_tok = [], 0
    t_start = time.perf_counter()
    while nxt < len(prompts) or not eng.scheduler.idle():
        if nxt < len(prompts) and eng.steps % 2 == 0:
            eng.submit(prompts[nxt], TOKENS)
            nxt += 1
        t0 = time.perf_counter()
        info = eng.step()
        dt = time.perf_counter() - t0
        n_tok += len(info["admitted"]) + info["decoded"]
        if not info["admitted"] and info["decoded"]:
            decode_ts.append(dt)
    wall = time.perf_counter() - t_start
    p50, p99 = np.percentile(np.asarray(decode_ts) * 1e6, [50, 99])
    row("serve_decode_p50_us_churn", p50,
        f"arrivals=1/2steps reqs={len(prompts)} steps={eng.steps}")
    row("serve_decode_p99_us_churn", p99, f"steps={len(decode_ts)}")
    row("serve_tok_per_s_churn", n_tok / wall,
        f"tokens={n_tok} wall={wall * 1e3:.0f}ms (value is tok/s)")


def main():
    from repro.configs import smoke_config
    from repro.launch.mesh import make_mesh

    print("name,us,derived")
    cfg = smoke_config(ARCH)
    mesh = make_mesh(1, 1)
    params = None
    for occ in (1, 2, 4):
        params = bench_occupancy(cfg, mesh, params, occ)
    bench_metrics(cfg, mesh, params)
    bench_churn(cfg, mesh, params)


if __name__ == "__main__":
    main()
