"""Teams & hierarchical-collective benchmark (DESIGN.md §11).

Three sections, in the predicted-vs-measured discipline of
bench_patterns/bench_overlap (every modeled column comes from the SAME
Schedule objects that execute):

  1. Team-relative schedules, predicted vs measured: fit the SIM
     substrate's alpha-beta from bare stages, then compare measured SIM
     wall time of row-team collectives against the lifted schedule's own
     pricing (and the paper-NoC prediction alongside).
  2. Flat vs hierarchical allreduce by message size and mesh shape:
     modeled times for flat rd / flat ring / hier on each topology, the
     modeled cross-over size where hier starts to win, and measured SIM
     wall times at a size on each side.
  3. Selector: `choose_algorithm` (monolithic) must pick hier above its
     own cross-over on 2D meshes, and `choose_schedule` — which also
     prices CHUNKED flat execution — must still pick hier for large
     messages on a mesh with an expensive cross axis (the §8 pod story);
     this is the acceptance configuration.

  PYTHONPATH=src python -m benchmarks.bench_teams
"""
from __future__ import annotations

import numpy as np

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core import team as team_mod
from repro.core.netops import SimNetOps
from repro.core.topology import MeshTopology, epiphany3

from ._util import sized, time_fn as _time

NOC = abmodel.EPIPHANY_NOC
ROWS: list[tuple] = []

# (name, topology) cases: the paper's chip, a non-pow2 mesh, and a
# two-tier mesh whose cross axis costs 10x (the DESIGN §8 pod analogue).
MESHES = [
    ("epiphany3_4x4", epiphany3()),
    ("mesh_2x3", MeshTopology(shape=(2, 3), torus=(False, False))),
    ("podded_8x8", MeshTopology(shape=(8, 8), torus=(False, True),
                                link_cost=(10.0, 1.0))),
]


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def fit_sim_link(n: int) -> abmodel.LinkModel:
    net = SimNetOps(n)
    pattern = coll.fcollect_schedule(n, 0.0, "ring").stages[0].pattern
    sizes = [64, 256, 1024, 4096, 16384]
    times = [_time(lambda v: net.ppermute(v, pattern), sized(s, n))
             for s in sizes]
    fit = abmodel.fit(sizes, times)
    link = abmodel.LinkModel(alpha_s=max(fit.alpha, 1e-9), hop_s=0.0,
                             bw_Bps=max(fit.inv_beta, 1.0))
    row("sim_link_alpha_us", fit.alpha * 1e6,
        f"beta^-1={fit.inv_beta / 1e9:.2f}GB/s")
    return link


# -- 1. team-relative schedules: predicted vs measured ------------------------

def bench_team_schedules(sim_link: abmodel.LinkModel):
    print("\n== team-relative schedules, predicted vs measured "
          "(row teams of epiphany3) ==")
    topo = epiphany3()
    n = topo.n_pes
    ctx = sim_ctx(n, topo)
    rows_part = team_mod.split_2d(team_mod.team_world(n), topo, -1)
    team = rows_part.teams[1]           # PEs 4..7
    K = team.size
    for nbytes in (256, 4096, 65536):
        x = sized(nbytes, n)
        cases = [
            (f"team_to_all_rd_{nbytes}B",
             team.lift_schedule(coll.allreduce_schedule(K, nbytes, "rd")),
             lambda v: ctx.to_all(v, "sum", algorithm="rd", team=team)),
            (f"team_bcast_{nbytes}B",
             team.lift_schedule(coll.broadcast_schedule(K, nbytes)),
             lambda v: ctx.broadcast(v, 0, team=team)),
            (f"part_to_all_ring_{nbytes}B",
             rows_part.lift_schedule(
                 coll.allreduce_schedule(K, nbytes, "ring")),
             lambda v: ctx.to_all(v, "sum", algorithm="ring",
                                  team=rows_part)),
        ]
        for name, sched, run in cases:
            measured = _time(run, x)
            pred_fit = sched.time(None, sim_link)
            pred_noc = sched.time(topo, NOC)
            ratio = measured / pred_fit if pred_fit > 0 else float("inf")
            row(name, measured * 1e6,
                f"fit={pred_fit * 1e6:.2f}us(x{ratio:.2f}) "
                f"noc={pred_noc * 1e6:.3f}us stages={len(sched)}")


# -- 2. flat vs hierarchical allreduce ----------------------------------------

def bench_flat_vs_hier():
    print("\n== flat vs hierarchical allreduce (modeled, per mesh; "
          "measured SIM at the endpoints) ==")
    for mname, topo in MESHES:
        n = topo.n_pes
        link = abmodel.ICI_V5E if "podded" in mname else NOC
        lname = "ici" if "podded" in mname else "noc"
        part = team_mod.split_2d(team_mod.team_world(n), topo, -1)
        for nbytes in (4096, 1 << 16, 1 << 20):
            t_hier = coll.allreduce_hier_schedule(
                part, float(nbytes), topo=topo, link=link).time(topo, link)
            flats = {a: coll.allreduce_schedule(n, float(nbytes), a)
                     .time(topo, link)
                     for a in (("rd", "ring") if n & (n - 1) == 0
                               else ("ring",))}
            best_flat = min(flats.values())
            row(f"{mname}_{nbytes}B_hier_{lname}_model", t_hier * 1e6,
                f"bestflat={best_flat * 1e6:.2f}us "
                f"speedup=x{best_flat / t_hier:.2f} "
                f"{' '.join(f'{a}={t * 1e6:.2f}us' for a, t in flats.items())}")

        # modeled cross-over: smallest size where hier beats every flat
        def hier_wins(b: float) -> bool:
            th = coll.allreduce_hier_schedule(
                part, b, topo=topo, link=link).time(topo, link)
            return all(coll.allreduce_schedule(n, b, a).time(topo, link) > th
                       for a in (("rd", "ring") if n & (n - 1) == 0
                                 else ("ring",)))

        lo, hi = 8.0, float(1 << 24)
        if hier_wins(lo) or not hier_wins(hi):
            always = hier_wins(lo) and hier_wins(hi)
            row(f"{mname}_hier_crossover_B", float("nan"),
                "hier wins everywhere (few stages at this PE count)"
                if always else f"WARN_no_crossover_in[{lo},{hi}]B")
        else:
            while hi - lo > 1:
                mid = (lo + hi) // 2
                lo, hi = (mid, hi) if not hier_wins(mid) else (lo, mid)
            row(f"{mname}_hier_crossover_B", hi,
                f"hier wins >= {int(hi)}B (monolithic flat)")

        # measured SIM wall time on each side of the cross-over
        net = SimNetOps(n)
        for nbytes in (4096, 1 << 18):
            x = sized(nbytes, n)
            t_flat = _time(lambda v: coll.allreduce(net, v, "sum",
                                                    algorithm="ring"), x)
            t_h = _time(lambda v: coll.allreduce_hier(net, v, "sum",
                                                      partition=part), x)
            same = np.allclose(
                np.asarray(coll.allreduce(net, x, "sum", algorithm="ring")),
                np.asarray(coll.allreduce_hier(net, x, "sum",
                                               partition=part)),
                rtol=2e-4, atol=1e-5)
            row(f"{mname}_{nbytes}B_measured_us", t_flat * 1e6,
                f"hier={t_h * 1e6:.2f}us allclose={same}")


# -- 3. selector --------------------------------------------------------------

def bench_selector():
    print("\n== selector: choose_algorithm / choose_schedule with a "
          "partition ==")
    ok_all = True
    for mname, topo in MESHES:
        n = topo.n_pes
        link = abmodel.ICI_V5E if "podded" in mname else NOC
        part = team_mod.split_2d(team_mod.team_world(n), topo, -1)
        for nbytes in (64, 4096, 1 << 18, 1 << 20):
            algo = coll.choose_algorithm(n, float(nbytes), topo, link,
                                         partition=part)
            algo_c, chunks = coll.choose_schedule(n, float(nbytes), topo,
                                                  link, partition=part)
            row(f"{mname}_pick_{nbytes}B", 0.0,
                f"choose_algorithm={algo} "
                f"choose_schedule=({algo_c},chunks={chunks})")
    # acceptance check: a (large message, 2D mesh) configuration where
    # choose_schedule — chunked flat candidates included — picks hier
    topo = dict(MESHES)["podded_8x8"]
    part = team_mod.split_2d(team_mod.team_world(topo.n_pes), topo, -1)
    algo, chunks = coll.choose_schedule(topo.n_pes, float(1 << 18), topo,
                                        abmodel.ICI_V5E, partition=part)
    ok = algo == "hier"
    ok_all &= ok
    row("choose_schedule_hier_acceptance", 0.0,
        f"podded_8x8 256KiB -> ({algo},{chunks}) "
        f"{'OK' if ok else 'WARN_expected_hier'}")
    return ok_all


def main():
    print("name,us,derived")
    link = fit_sim_link(16)
    bench_team_schedules(link)
    bench_flat_vs_hier()
    bench_selector()


if __name__ == "__main__":
    main()
