"""One benchmark per paper table/figure (Figs. 3-9).

Each bench runs the algorithm-exact SIM backend on CPU (wall-clock of the
simulated 16-PE chip is NOT Epiphany time) and reports, as its `derived`
column, the alpha-beta-modeled time on the paper's NoC constants — the
same methodology the paper uses for its figure subtitles.  Where the
paper states a number, we print the comparison.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core.topology import epiphany3
from repro.configs import epiphany16 as paper

from . import paper_fidelity as fid

TOPO = epiphany3()
N = TOPO.n_pes
LINK = abmodel.EPIPHANY_NOC
ROWS: list[tuple] = []


def _time(fn, *args, warmup=2, iters=5):
    jitted = jax.jit(fn)
    out = jitted(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(jitted(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def row(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


def _sized(nbytes, n=N):
    w = max(1, nbytes // 4)
    return jnp.asarray(np.random.RandomState(0).randn(n, w)
                       .astype(np.float32))


# -- Fig. 3: put/get bandwidth + alpha-beta fits ---------------------------

def bench_rma():
    ctx = sim_ctx(N, TOPO)
    sizes = paper.MSG_SIZES
    put_t, get_t = [], []
    ring = [(i, (i + 1) % N) for i in range(N)]
    for s in sizes:
        x = _sized(s)
        us = _time(lambda v: ctx.put(v, ring), x)
        put_t.append(abmodel.stage_time(s, 1.0, LINK))
        get_t.append(abmodel.stage_time(s, 1.0, abmodel.EPIPHANY_NOC_GET))
        if s in (64, 4096):
            row(f"shmem_put_{s}B_sim", us,
                f"model={put_t[-1]*1e6:.3f}us")
    fp = abmodel.fit(sizes, put_t)
    fg = abmodel.fit(sizes, get_t)
    row("put_alpha_us", fp.alpha * 1e6,
        f"beta^-1={fp.inv_beta/1e9:.2f}GB/s {fid.ref('put_peak_GBs')}")
    row("get_over_put_ratio", fg.inv_beta / fp.inv_beta,
        fid.ref("get_put_ratio"))
    # IPI-get: one 8-byte interrupt signal + ISR entry + a put executed
    # by the owner — the crossover derivation is shared with the
    # fidelity gate (paper_fidelity.ipi_get_turnover) so the bench and
    # the acceptance table cannot diverge
    turnover = fid.ipi_get_turnover(fid.FidelityModel())
    row("ipi_get_turnover_B", turnover, fid.ref("ipi_get_turnover_B"))


# -- Fig. 4: non-blocking RMA ----------------------------------------------

def bench_rma_nbi():
    ctx = sim_ctx(N, TOPO)
    ring = [(i, (i + 1) % N) for i in range(N)]
    x = _sized(4096)

    def nbi(v):
        f1 = ctx.put_nbi(v, ring)
        f2 = ctx.put_nbi(v * 2.0, ring)     # dual DMA channels
        ctx.quiet(f1, f2)
        return f1.value + f2.value
    us = _time(nbi, x)
    # DMA errata: throttled to < half of 8B/clk => ~4.8 GB/s full,
    # 2.4 GB/s throttled; two channels overlap => max(), not sum
    t_one = abmodel.stage_time(4096, 1.0, LINK)
    row("put_nbi_2ch_4096B_sim", us,
        f"model_overlap={t_one*1e6:.2f}us_vs_serial={2*t_one*1e6:.2f}us")


# -- Fig. 5: atomics ---------------------------------------------------------

def bench_atomics():
    ctx = sim_ctx(N, TOPO)
    ring = [(i, (i + 1) % N) for i in range(N)]
    var = jnp.zeros((N,), jnp.int32)
    one = jnp.ones((N,), jnp.int32)

    def fadd(v):
        f, nv = ctx.atomic_fetch_add(v, one, ring)
        return f + nv
    us = _time(fadd, var)
    # modeled: request traverses to neighbor, TESTSET lock+op+unlock,
    # result returns => 2 network traversals + ~3 core ops
    t = 2 * abmodel.stage_time(4, 1.0, LINK) + 3 / paper.CLOCK_HZ
    row("atomic_fetch_add_neighbor", us,
        f"model={t*1e6:.3f}us={1/t/1e6:.2f}Mops/s")
    f, nv = jax.jit(fadd)(var), None
    # shared-var flavor: deterministic PE-ordered scan semantics
    f2, v2 = ctx.atomic_fetch_add_shared(jnp.zeros((N,), jnp.int32), one)
    assert int(np.asarray(v2)[0]) == N
    row("atomic_fetch_add_shared_final", float(np.asarray(v2)[0]),
        f"expected={N}")


# -- Fig. 6: barrier + broadcast ---------------------------------------------

def bench_barrier():
    for n in (2, 4, 8, 16):
        ctx = sim_ctx(n, TOPO)
        us = _time(lambda t: ctx.barrier(t), jnp.zeros((n,), jnp.int32))
        t = abmodel.modeled_collective_time(
            coll.barrier_stages(n, TOPO), LINK)
        row(f"barrier_{n}pe", us, f"model={t*1e6:.3f}us")
    row("barrier_16pe_paper_dissem_us",
        abmodel.modeled_collective_time(
            coll.barrier_stages(16, TOPO), LINK) * 1e6,
        f"{fid.ref('dissem_barrier_us_16pe')} "
        f"elib={paper.PAPER['elib_barrier_us']}us "
        f"wand={paper.PAPER['wand_barrier_us']}us")


def bench_broadcast():
    ctx = sim_ctx(N, TOPO)
    for s in (64, 1024, 8192):
        x = _sized(s)
        us = _time(lambda v: ctx.broadcast(v, 0), x)
        t = abmodel.modeled_collective_time(
            coll.broadcast_stages(N, s, TOPO), LINK)
        eff = s / t / 1e9
        cite = f" {fid.ref('bcast_eff_GBs_8192B')}" if s == 8192 else ""
        row(f"broadcast64_{s}B", us,
            f"model={t*1e6:.2f}us_eff={eff:.2f}GB/s{cite}")


# -- Fig. 7: collect / fcollect ----------------------------------------------

def bench_collect():
    ctx = sim_ctx(N, TOPO)
    for s in (64, 1024):
        x = _sized(s)
        us_r = _time(lambda v: ctx.collect(v), x)
        us_f = _time(lambda v: ctx.fcollect(v), x)
        t_r = abmodel.modeled_collective_time(
            coll.fcollect_stages(N, s, TOPO, "ring"), LINK)
        t_f = abmodel.modeled_collective_time(
            coll.fcollect_stages(N, s, TOPO, "rd"), LINK)
        row(f"collect64_ring_{s}B", us_r, f"model={t_r*1e6:.2f}us")
        row(f"fcollect64_rd_{s}B", us_f,
            f"model={t_f*1e6:.2f}us_speedup={t_r/t_f:.2f}x")


# -- Fig. 8: reductions (incl. the work-array latency knee) -------------------

def bench_reduce():
    ctx = sim_ctx(N, TOPO)
    SHMEM_REDUCE_MIN_WRKDATA_SIZE = 64 * 4   # bytes, per spec
    for s in (16, 64, 256, 1024, 8192):
        x = _sized(s)
        us = _time(lambda v: ctx.to_all(v, "sum"), x)
        stages = coll.allreduce_stages(N, s, TOPO)
        t = abmodel.modeled_collective_time(stages, LINK)
        if s <= SHMEM_REDUCE_MIN_WRKDATA_SIZE:
            t_eff = abmodel.modeled_collective_time(
                coll.allreduce_stages(N, SHMEM_REDUCE_MIN_WRKDATA_SIZE,
                                      TOPO), LINK)
            note = f"model={t_eff*1e6:.2f}us(work-array-floor)"
        else:
            note = f"model={t*1e6:.2f}us={1/t:.0f}red/s"
        row(f"int_sum_to_all_{s}B", us, note)
    # non-power-of-two PE counts use the ring algorithm (paper §3.6)
    for n in (6, 12):
        ctxn = sim_ctx(n, TOPO)
        x = _sized(1024, n)
        us = _time(lambda v: ctxn.to_all(v, "sum"), x)
        t = abmodel.modeled_collective_time(
            coll.allreduce_stages(n, 1024, TOPO), LINK)
        row(f"int_sum_to_all_{n}pe_ring", us, f"model={t*1e6:.2f}us")


# -- Fig. 9: alltoall ---------------------------------------------------------

def bench_alltoall():
    ctx = sim_ctx(N, TOPO)
    for s in (64, 1024):
        x = _sized(s * N)
        us = _time(lambda v: ctx.alltoall(v), x)
        t = abmodel.modeled_collective_time(
            coll.alltoall_stages(N, s * N, TOPO), LINK)
        row(f"alltoall_{s}B_per_pe", us, f"model={t*1e6:.2f}us")


# -- kernels (the copy loop under put, and the model hot spots) --------------

def bench_kernels():
    from repro.kernels import ops, ref
    x = jnp.asarray(np.random.RandomState(0).randn(256, 512)
                    .astype(np.float32))
    us = _time(lambda v: ops.put_copy(v, use_pallas=False), x)
    row("put_copy_ref_512KB", us, "xla_identity_copy")
    us = _time(lambda v: ops.put_copy(v, interpret=True), x)
    row("put_copy_pallas_interpret", us, "kernel_body_on_cpu")
    bufs = [x, x * 2, x * 3]
    us = _time(lambda *b: ops.reduce_combine(list(b), "sum",
                                             use_pallas=False), *bufs)
    row("reduce_combine3_ref", us, "fused_elementwise")
    q = jnp.asarray(np.random.RandomState(1).randn(1, 4, 256, 64)
                    .astype(np.float32))
    us = _time(lambda a: ref.attention_ref(a, q, q), q)
    row("attention_ref_256", us, "dense")
    us = _time(lambda a: ref.attention_blockwise(a, q, q, block=128), q)
    row("attention_blockwise_256", us, "flash_schedule_xla")


# -- the paper-fidelity acceptance table as bench rows ------------------------

def bench_fidelity():
    """Re-emit every gated paper-fidelity row (model-derived value vs the
    digitized paper number) so the fidelity trajectory is versioned in
    BENCH_*.json next to the wall-time rows.  The hard gate is
    ``python -m benchmarks.paper_fidelity --check`` in CI."""
    for name, val, derived in fid.bench_rows():
        row(name, val, derived)


ALL = [bench_rma, bench_rma_nbi, bench_atomics, bench_barrier,
       bench_broadcast, bench_collect, bench_reduce, bench_alltoall,
       bench_kernels, bench_fidelity]
