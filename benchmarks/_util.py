"""Shared timing/payload helpers for the benchmark harnesses — one copy
of the methodology so bench_patterns and bench_overlap measure (and can
be compared in the same CI artifact) identically."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 8) -> float:
    """Median-free steady-state wall time per call, seconds: jit, force
    the first compile+run, warm up, then average `iters` dispatches."""
    jitted = jax.jit(fn)
    out = jitted(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(jitted(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def sized(nbytes, n: int, seed: int = 0):
    """A (n_pes, nbytes/4) f32 payload — `nbytes` per PE."""
    w = max(1, int(nbytes) // 4)
    return jnp.asarray(np.random.RandomState(seed).randn(n, w)
                       .astype(np.float32))
