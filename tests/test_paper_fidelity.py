"""Paper-fidelity acceptance gate (DESIGN.md §18): every digitized
paper number holds against the model within its tolerance, each row
cites its source figure, and a deliberately perturbed LinkModel
constant trips the gate."""
import dataclasses
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_fidelity as fid  # noqa: E402
from repro.configs import epiphany16 as paper  # noqa: E402


def test_gate_passes_with_default_constants():
    results = fid.evaluate()
    bad = [r.row.key for r in results if not r.ok]
    assert not bad, f"fidelity violations: {bad}"
    assert fid.check(out=open(os.devnull, "w")) == 0


def test_at_least_eight_gated_rows_each_citing_the_paper():
    assert len(fid.TABLE) >= 8
    for r in fid.TABLE:
        assert "1608.03545" in r.source or "1604.04205" in r.source, \
            f"{r.key} cites no source figure"
        assert r.tol >= 0.0 and r.mode in ("rel", "max", "min")


@pytest.mark.parametrize("field,value", [
    ("bw_Bps", 1.2e9),       # halved put bandwidth
    ("alpha_s", 3e-7),       # tripled put latency
])
def test_perturbed_linkmodel_trips_the_gate(field, value):
    link = dataclasses.replace(paper.PUT_LINK, **{field: value})
    model = dataclasses.replace(fid.FidelityModel(), link=link)
    assert any(not r.ok for r in fid.evaluate(model))
    assert fid.check(model, out=open(os.devnull, "w")) == 1


def test_perturbed_check_cli_exits_nonzero(capsys):
    with pytest.raises(SystemExit) as ei:
        fid.main(["--check", "--perturb", "bw_Bps=1.2e9"])
    assert ei.value.code == 1
    # the clean CLI run does not raise
    fid.main(["--check"])
    out = capsys.readouterr().out
    assert "all" in out and "within tolerance" in out


def test_ipi_turnover_matches_paper_after_isr_fix():
    # the corrected ISR entry (60 clocks) reproduces the paper's 64 B
    # crossover; the seed's 120-clock double-count derived 128 B
    assert fid.ipi_get_turnover(fid.FidelityModel()) == 64.0
    seed = dataclasses.replace(fid.FidelityModel(), isr_entry_s=2e-7)
    assert fid.ipi_get_turnover(seed) == 128.0
    assert paper.ISR_ENTRY_S == pytest.approx(60 / paper.CLOCK_HZ)


def test_bench_rows_feed_the_bench_harness():
    rows = fid.bench_rows()
    assert len(rows) == len(fid.TABLE)
    for name, val, derived in rows:
        assert name.startswith("fidelity_")
        assert isinstance(val, float)
        assert "paper=" in derived and "src=" in derived
        assert derived.endswith("OK")
    # ref() citations replace the free-text paper= strings
    assert fid.ref("put_peak_GBs").startswith("paper=2.4GB/s[")


def test_documented_deviation_rows_are_bounded_not_exact():
    # the dissemination-barrier rows carry the flag-put alpha deviation:
    # one-sided bounds with explanatory notes, not silent rel tolerances
    by_key = {r.key: r for r in fid.TABLE}
    assert by_key["dissem_barrier_us_16pe"].mode == "max"
    assert "deviation" in by_key["dissem_barrier_us_16pe"].note
    assert by_key["barrier_beats_elib_x"].mode == "min"
