"""DMA-overlap runtime tests (DESIGN.md §10): the pending-op engine's
quiet/fence ordering semantics, and chunked/double-buffered (pipelined)
schedule execution being bit-identical to eager execution for every
collective, on both the SIM and SPMD backends."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import abmodel, sim_ctx
from repro.core import collectives as coll
from repro.core.netops import SimNetOps
from repro.core.topology import epiphany3

N = 8


@pytest.fixture
def ctx():
    return sim_ctx(N, epiphany3())


def _x(w=6, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(N, w)
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# pending-op engine: quiet drains, fence orders without completing
# ---------------------------------------------------------------------------

def test_quiet_drains_all_pending(ctx):
    x = _x()
    f1 = ctx.put_nbi(x, [(0, 1)])
    f2 = ctx.put_nbi(x, [(2, 3)])
    f3 = ctx.get_nbi(x, [(4, 5)])
    assert ctx.pending_count == 3
    assert [f.seq for f in (f1, f2, f3)] == [0, 1, 2]
    vals = ctx.quiet()
    assert len(vals) == 3
    assert ctx.pending_count == 0
    assert f1.done and f2.done and f3.done
    ref = np.asarray(x).copy()
    ref[1] = ref[0]
    np.testing.assert_allclose(np.asarray(f1.value), ref)


def test_quiet_explicit_futures_completes_only_those(ctx):
    x = _x()
    f1 = ctx.put_nbi(x, [(0, 1)])
    f2 = ctx.put_nbi(x, [(2, 3)])
    ctx.quiet(f1)
    assert f1.done and not f2.done
    assert ctx.pending_count == 1
    assert ctx.pending_ops() == (f2,)
    ctx.quiet()
    assert f2.done and ctx.pending_count == 0


def test_future_metadata(ctx):
    x = _x(w=6)
    f_put = ctx.put_nbi(x, [(0, 1)])
    f_get = ctx.get_nbi(x, [(2, 7)])    # requester 2, owner 7
    assert f_put.op == "put" and f_get.op == "get"
    assert f_put.target_pes() == (1,)
    # IPI-get executes the owner->requester push: destination is PE 2
    assert f_get.target_pes() == (2,)
    assert f_put.nbytes == pytest.approx(6 * 4)   # per-PE payload bytes
    ctx.quiet()


def test_fence_orders_without_completing(ctx):
    x = _x()
    f1 = ctx.put_nbi(x, [(0, 3)])
    f2 = ctx.put_nbi(2 * x, [(1, 3)])   # same destination PE as f1
    f3 = ctx.put_nbi(x, [(4, 5)])       # disjoint destination
    vals = ctx.fence()
    # fence is not quiet: nothing completes, the queue stays full
    assert len(vals) == 3
    assert ctx.pending_count == 3
    assert not (f1.done or f2.done or f3.done)
    # ordering is value-preserving (a pure dependency chain)
    ref2 = np.asarray(2 * x).copy()
    ref2[3] = ref2[1]
    np.testing.assert_allclose(np.asarray(f2.value), ref2)
    ref3 = np.asarray(x).copy()
    ref3[5] = ref3[4]
    np.testing.assert_allclose(np.asarray(f3.value), ref3)
    # quiet after fence still drains everything
    ctx.quiet()
    assert ctx.pending_count == 0 and f1.done and f2.done and f3.done


def test_fence_empty_queue_is_noop(ctx):
    assert ctx.fence() == ()


def test_put_nbi_quiet_matches_blocking_put(ctx):
    x = _x(seed=3)
    blocking = ctx.put(x, [(0, 1), (2, 3)])
    f = ctx.put_nbi(x, [(0, 1), (2, 3)])
    ctx.quiet()
    np.testing.assert_array_equal(np.asarray(f.value), np.asarray(blocking))


# ---------------------------------------------------------------------------
# pipelined schedule execution == eager, bit-identical (SIM)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 6, 8])
@pytest.mark.parametrize("chunks", [2, 3, 7])
def test_pipelined_allreduce_bit_identical(n, chunks):
    net = SimNetOps(n)
    x = jnp.asarray(np.random.RandomState(1).randn(n, 41).astype(np.float32))
    for algo in (["rd", "ring"] if n & (n - 1) == 0 else ["ring"]):
        eager = coll.allreduce(net, x, "sum", algorithm=algo)
        piped = coll.allreduce(net, x, "sum", algorithm=algo,
                               pipeline_chunks=chunks)
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(piped))


@pytest.mark.parametrize("chunks", [2, 5])
def test_pipelined_broadcast_fcollect_collect_alltoall_bit_identical(chunks):
    n = N
    net = SimNetOps(n)
    x = jnp.asarray(np.random.RandomState(2).randn(n, 23).astype(np.float32))
    pairs = [
        (coll.broadcast(net, x, 3), coll.broadcast(net, x, 3,
                                                   pipeline_chunks=chunks)),
        (coll.fcollect(net, x), coll.fcollect(net, x,
                                              pipeline_chunks=chunks)),
        (coll.fcollect(net, x, algorithm="ring"),
         coll.fcollect(net, x, algorithm="ring", pipeline_chunks=chunks)),
        (coll.collect(net, x), coll.collect(net, x,
                                            pipeline_chunks=chunks)),
    ]
    x2 = jnp.asarray(np.random.RandomState(3).randn(n, n * 5)
                     .astype(np.float32))
    pairs.append((coll.alltoall(net, x2),
                  coll.alltoall(net, x2, pipeline_chunks=chunks)))
    for eager, piped in pairs:
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(piped))


def test_pipelined_zero_size_payload():
    # a zero-width leaf (e.g. an unused-parameter gradient) must not crash
    # the chunked paths; it runs as a single empty piece
    net = SimNetOps(4)
    x = jnp.zeros((4, 0), jnp.float32)
    for fn in (lambda: coll.broadcast(net, x, 0, pipeline_chunks=4),
               lambda: coll.allreduce(net, x, "sum", algorithm="ring",
                                      pipeline_chunks=4),
               lambda: coll.allreduce(net, x, "sum", algorithm="rd",
                                      pipeline_chunks=4),
               lambda: coll.fcollect(net, x, pipeline_chunks=4),
               lambda: coll.collect(net, x, pipeline_chunks=4)):
        out = fn()
        assert np.asarray(out).size == 0


def test_pipelined_more_chunks_than_elements(ctx):
    # chunk count above the payload width degrades gracefully
    x = _x(w=3)
    eager = ctx.to_all(x, "sum", algorithm="ring")
    piped = ctx.to_all(x, "sum", algorithm="ring", pipeline_chunks=64)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(piped))


def test_to_all_auto_auto_is_bit_identical(ctx):
    x = _x(w=64, seed=5)
    eager_rd = ctx.to_all(x, "sum", algorithm="rd")
    eager_ring = ctx.to_all(x, "sum", algorithm="ring")
    auto = ctx.to_all(x, "sum", algorithm="auto", pipeline_chunks="auto")
    # whatever (algorithm, chunks) the model picked, the result is one of
    # the two eager answers, bit-for-bit
    assert (np.array_equal(np.asarray(auto), np.asarray(eager_rd))
            or np.array_equal(np.asarray(auto), np.asarray(eager_ring)))


# ---------------------------------------------------------------------------
# pipelined cost model
# ---------------------------------------------------------------------------

def test_pipelined_time_reduces_to_monolithic_at_one_chunk():
    sched = coll.allreduce_schedule(16, 4096.0, "rd")
    assert sched.pipelined_time(1) == pytest.approx(sched.time())


def test_pipelined_model_crossover():
    link = abmodel.EPIPHANY_NOC
    big = coll.broadcast_schedule(16, float(1 << 22))
    small = coll.broadcast_schedule(16, 64.0)
    # large payloads: chunking wins; small payloads: alpha makes it lose
    assert big.pipelined_time(8, None, link) < big.time(None, link)
    assert small.pipelined_time(8, None, link) > small.time(None, link)
    assert abmodel.choose_chunks(big.cost(None), link) > 1
    assert abmodel.choose_chunks(small.cost(None), link) == 1


def test_choose_schedule_picks_chunked_above_crossover():
    link = abmodel.EPIPHANY_NOC
    algo_s, chunks_s = coll.choose_schedule(16, 64.0, None, link)
    algo_b, chunks_b = coll.choose_schedule(16, float(1 << 24), None, link)
    assert chunks_s == 1              # small: monolithic
    assert chunks_b > 1               # large: chunked
    # and the pair selection is consistent with the model's own pricing
    t_pick = coll.allreduce_schedule(16, float(1 << 24), algo_b)\
        .pipelined_time(chunks_b, None, link)
    for algo in ("rd", "ring"):
        for c in (1, 2, 4, 8, 16):
            t = coll.allreduce_schedule(16, float(1 << 24), algo)\
                .pipelined_time(c, None, link)
            assert t_pick <= t + 1e-12


# ---------------------------------------------------------------------------
# SPMD backend: pipelined == eager under shard_map, and the bucketed
# grad sync matches the single-shot allreduce numerically
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import spmd_ctx, sim_ctx

    n = 8
    mesh = jax.make_mesh((n,), ("pe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray(np.random.RandomState(0).randn(n, 24).astype(np.float32))

    def run(fn_name, *args, **kw):
        def body(xl):
            ctx = spmd_ctx("pe")
            return getattr(ctx, fn_name)(xl[0], *args, **kw)[None]
        return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P("pe"),),
                                     out_specs=P("pe")))(x)

    # pipelined == eager BIT-identical on the SPMD backend, every collective
    for name, kw in [("to_all", dict(op="sum", algorithm="ring")),
                     ("to_all", dict(op="sum", algorithm="rd")),
                     ("broadcast", dict(root=3)),
                     ("fcollect", {}),
                     ("collect", {})]:
        args = (kw.pop("op"),) if "op" in kw else ()
        eager = run(name, *args, **kw)
        piped = run(name, *args, **kw, pipeline_chunks=3)
        assert np.array_equal(np.asarray(eager), np.asarray(piped)), name

    # ... and SPMD pipelined == SIM eager (cross-backend)
    piped = run("to_all", "sum", algorithm="ring", pipeline_chunks=4)
    ref = sim_ctx(n).to_all(x, "sum", algorithm="ring")
    assert np.allclose(np.asarray(piped), np.asarray(ref), rtol=1e-5)

    # put_nbi -> quiet inside shard_map
    def body_nbi(xl):
        ctx = spmd_ctx("pe")
        f = ctx.put_nbi(xl[0], [(0, 1), (2, 3)])
        (val,) = ctx.quiet()
        assert ctx.pending_count == 0
        return val[None]
    out = jax.jit(jax.shard_map(body_nbi, mesh=mesh, in_specs=(P("pe"),),
                                out_specs=P("pe")))(x)
    ref = np.asarray(x).copy(); ref[1] = ref[0]; ref[3] = ref[2]
    assert np.allclose(np.asarray(out), ref)

    # bucketed ZeRO-style grad sync == single-shot allreduce sync
    from repro.parallel.comm import AxisSpec, Comm
    g = jnp.asarray(np.random.RandomState(4).randn(n, 50).astype(np.float32))

    def sync(bucketed):
        def body(gl):
            comm = Comm(AxisSpec(data="pe", model=None), "shmem",
                        grad_rs=bucketed)
            if bucketed:
                a, b = gl[0][:20], gl[0][20:]
                out = comm.grad_sync_bucketed([a, b], mean=True)
                return jnp.concatenate(out)[None]
            return comm.grad_sync(gl[0], mean=True)[None]
        return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P("pe"),),
                                     out_specs=P("pe")))(g)

    a = np.asarray(sync(False))
    b = np.asarray(sync(True))
    assert np.allclose(a, b, rtol=1e-5, atol=1e-6)
    assert np.allclose(a, np.asarray(g).mean(0, keepdims=True), rtol=1e-5)
    print("SPMD overlap OK")
""")


def test_spmd_pipelined_and_bucketed_sync():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SPMD overlap OK" in res.stdout
