"""OpenSHMEM API surface tests (sim backend): RMA incl. strided (§4
extension), non-blocking + quiet/fence, TESTSET-derived atomics, locks,
critical sections, shmem_ptr."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sim_ctx
from repro.core.topology import epiphany3

N = 8


@pytest.fixture
def ctx():
    return sim_ctx(N, epiphany3())


def _x(w=6, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(N, w)
                       .astype(np.float32))


def test_put_merges_with_local(ctx):
    x = _x()
    out = ctx.put(x, [(0, 3)])
    ref = np.asarray(x).copy()
    ref[3] = ref[0]
    np.testing.assert_allclose(np.asarray(out), ref)


def test_get_is_owner_pushed(ctx):
    x = _x()
    out = ctx.get(x, [(2, 7)])     # requester 2 reads from owner 7
    ref = np.asarray(x).copy()
    ref[2] = ref[7]
    np.testing.assert_allclose(np.asarray(out), ref)


def test_iput_strided(ctx):
    x = jnp.asarray(np.arange(N * 8, dtype=np.float32).reshape(N, 8))
    # every 2nd element of src 0 into every 2nd slot of dst 1 (4 elems)
    out = ctx.iput(x, [(0, 1)], sst=2, dst=2, nelems=4)
    ref = np.asarray(x).copy()
    ref[1, 0:8:2] = ref[0, 0:8:2]
    np.testing.assert_allclose(np.asarray(out), ref)


def test_iget_strided(ctx):
    x = jnp.asarray(np.arange(N * 4, dtype=np.float32).reshape(N, 4))
    out = ctx.iget(x, [(5, 2)], sst=1, dst=1, nelems=4)
    ref = np.asarray(x).copy()
    ref[5] = ref[2]
    np.testing.assert_allclose(np.asarray(out), ref)


def test_nbi_and_quiet(ctx):
    x = _x()
    f1 = ctx.put_nbi(x, [(0, 1)])
    f2 = ctx.get_nbi(x, [(2, 3)])
    vals = ctx.quiet()
    assert f1._done and f2._done
    ref1 = np.asarray(x).copy(); ref1[1] = ref1[0]
    np.testing.assert_allclose(np.asarray(f1.value), ref1)
    assert len(vals) == 2
    assert not ctx._pending


def test_fence_noop_when_empty(ctx):
    assert ctx.fence() == ()


def test_testset_semantics(ctx):
    var = jnp.asarray(np.array([0, 5, 0, 1] * 2, np.int32))
    old, new = ctx.testset(var, jnp.full((N,), 9, jnp.int32))
    np.testing.assert_array_equal(np.asarray(old), np.asarray(var))
    np.testing.assert_array_equal(
        np.asarray(new), np.where(np.asarray(var) == 0, 9, np.asarray(var)))


def test_atomic_swap_and_cswap(ctx):
    var = jnp.arange(N, dtype=jnp.int32) * 10
    val = jnp.full((N,), 7, jnp.int32)
    ring = [(i, (i + 1) % N) for i in range(N)]
    fetched, new = ctx.atomic_swap(var, val, ring)
    # every PE's var replaced by its ring predecessor's 7
    np.testing.assert_array_equal(np.asarray(new), 7)
    np.testing.assert_array_equal(
        np.asarray(fetched),
        np.roll(np.asarray(var), -1))   # requester i fetched var[i+1]
    # compare value comes from the REQUESTER (PE 0): var[1]=10 == cond[0]=10
    cond = jnp.asarray(np.where(np.arange(N) % 2 == 0, 10, -1)
                       .astype(np.int32))
    f2, n2 = ctx.atomic_compare_swap(var, cond, val, [(0, 1)])
    ref = np.asarray(var).copy()
    ref[1] = 7                      # swap fires
    np.testing.assert_array_equal(np.asarray(n2), ref)
    # and a non-matching compare leaves the target untouched
    f3, n3 = ctx.atomic_compare_swap(var, cond - 1, val, [(0, 1)])
    np.testing.assert_array_equal(np.asarray(n3), np.asarray(var))


def test_lock_arbitration_deterministic(ctx):
    lock = jnp.zeros((N,), jnp.int32)
    want = jnp.asarray(np.array([0, 1, 1, 0, 1, 0, 0, 0], bool))
    granted, new = ctx.set_lock(lock, want)
    g = np.asarray(granted)
    assert g[1] and not g[2] and not g[4]    # lowest wanting PE wins
    assert np.all(np.asarray(new) == 2)      # holder id = pe+1
    # holder releases; others re-contend
    cleared = ctx.clear_lock(new, jnp.ones((N,), bool))
    assert np.all(np.asarray(cleared) == 0)
    g2, new2 = ctx.set_lock(cleared, want & ~jnp.asarray(g))
    assert np.asarray(g2)[2]


def test_test_lock_fails_when_held(ctx):
    lock = jnp.full((N,), 3, jnp.int32)    # held by PE 2
    granted, new = ctx.test_lock(lock, jnp.ones((N,), bool))
    assert not np.asarray(granted).any()
    np.testing.assert_array_equal(np.asarray(new), 3)


def test_critical_section_serializes(ctx):
    # each PE appends its id: the result must reflect rank order
    state = jnp.zeros((N, N), jnp.float32)

    def fn(s):
        pe = ctx.my_pe()
        cnt = jnp.sum(s > 0, axis=-1)
        return s + 0 * pe[..., None] if s.ndim == 1 else s

    out = ctx.critical(jnp.zeros((N,), jnp.float32), lambda s: s + 1)
    assert np.all(np.asarray(out) == N)


def test_ptr(ctx):
    assert ctx.ptr(19, 128) == (19 % N, 128)


def test_barrier_all_wand_vs_dissemination(ctx):
    t1 = ctx.barrier_all()
    t2 = ctx.barrier()
    assert t1.shape[0] == N and t2.shape[0] == N
