import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# -- optional-hypothesis shim ------------------------------------------------
# Four test modules property-test with hypothesis.  On environments without
# the package, install a minimal fixed-seed stand-in under the same import
# name BEFORE test modules import it, so the suite still collects and runs
# (fewer examples, deterministic draws — not a replacement for the real
# thing, which requirements-dev.txt installs in CI).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    _SHIM_MAX_EXAMPLES = 10  # keep the fallback suite fast

    class _Strategy:
        """A draw(rng) callable plus the boundary examples tried first."""

        def __init__(self, draw, boundaries=()):
            self._draw = draw
            self.boundaries = tuple(boundaries)

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         boundaries=(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         boundaries=(min_value, max_value))

    def _sampled_from(seq):
        elems = list(seq)
        return _Strategy(lambda rng: rng.choice(elems),
                         boundaries=(elems[0], elems[-1]))

    def _lists(elem, min_size=0, max_size=10):
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(size)]
        return _Strategy(
            draw, boundaries=([elem.example(random.Random(0))] * min_size,))

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

    def _given(*strategies):
        def deco(fn):
            def wrapper():
                rng = random.Random(0)
                limit = getattr(wrapper, "_shim_max_examples",
                                _SHIM_MAX_EXAMPLES)
                # boundary examples first (min/max of each strategy in
                # lockstep — covers n=1 and n=max), then random draws
                nb = max((len(s.boundaries) for s in strategies), default=0)
                cases = [
                    tuple(s.boundaries[min(i, len(s.boundaries) - 1)]
                          if s.boundaries else s.example(rng)
                          for s in strategies)
                    for i in range(nb)
                ]
                while len(cases) < limit:
                    cases.append(tuple(s.example(rng) for s in strategies))
                for args in cases[:limit]:
                    fn(*args)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=_SHIM_MAX_EXAMPLES, deadline=None, **_):
        def deco(fn):
            fn._shim_max_examples = min(max_examples, _SHIM_MAX_EXAMPLES)
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.lists = _lists
    _st.tuples = _tuples
    _st.sampled_from = _sampled_from
    _hyp.strategies = _st
    _hyp.__is_repro_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
