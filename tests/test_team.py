"""Teams & contexts subsystem tests (DESIGN.md §11): team interning and
translation round-trips, team-scoped collectives (including singleton and
non-contiguous strided teams), the 1.3 active-set shim, the hierarchical
two-level allreduce's equivalence to flat (allclose for floats, exact for
ints), the hier selector, and per-context pending-queue isolation — on
the SIM backend here and on SPMD via a subprocess (like test_overlap)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import abmodel, sim_ctx
from repro.core import collectives as coll
from repro.core import team as team_mod
from repro.core.netops import SimNetOps
from repro.core.topology import MeshTopology, epiphany3

N = 8


@pytest.fixture
def ctx():
    return sim_ctx(N, epiphany3())


def _x(n=N, w=6, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.RandomState(seed).randn(n, w).astype(dtype))


# ---------------------------------------------------------------------------
# team structure: interning, translation, splits
# ---------------------------------------------------------------------------

def test_team_interning_and_world():
    w = team_mod.team_world(N)
    assert w is team_mod.team_world(N)
    assert w.size == N and w.covers_world
    t1 = team_mod.make_team([1, 4, 7], N)
    t2 = team_mod.make_team([4, 1, 7], N)     # order matters: distinct teams
    assert t1 is team_mod.make_team([1, 4, 7], N)
    assert t1 is not t2
    assert t1.translate(4) == 1 and t2.translate(4) == 0


def test_translate_world_pe_round_trip():
    t = team_mod.split_strided(team_mod.team_world(N), 1, 3, 3)  # 1, 4, 7
    assert t.members == (1, 4, 7)
    for r in range(t.size):
        assert t.translate(t.world_pe(r)) == r
    for pe in range(N):
        r = t.translate(pe)
        if r >= 0:
            assert t.world_pe(r) == pe
        else:
            assert pe not in t.members


def test_singleton_team_collectives(ctx):
    t = team_mod.split_strided(team_mod.team_world(N), 3, 1, 1)
    assert t.size == 1 and t.members == (3,)
    x = _x()
    # every team collective over a singleton is the identity
    np.testing.assert_array_equal(np.asarray(ctx.to_all(x, "sum", team=t)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(ctx.broadcast(x, 0, team=t)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(ctx.fcollect(x, team=t)),
                                  np.asarray(x))


def test_invalid_teams_rejected():
    w = team_mod.team_world(N)
    with pytest.raises(ValueError):
        team_mod.make_team([0, 0, 1], N)                   # duplicate
    with pytest.raises(ValueError):
        team_mod.make_team([0, N], N)                      # out of range
    with pytest.raises(ValueError):
        team_mod.split_strided(w, 4, 2, 4)                 # leaves parent
    with pytest.raises(ValueError):
        team_mod.TeamPartition([team_mod.make_team([0, 1], N),
                                team_mod.make_team([1, 2], N)])  # overlap


def test_split_composes_through_parent_ranks():
    w = team_mod.team_world(16)
    evens = team_mod.split_strided(w, 0, 2, 8)             # 0,2,...,14
    sub = team_mod.split_strided(evens, 1, 2, 4)           # parent ranks 1,3,5,7
    assert sub.members == (2, 6, 10, 14)


def test_split_2d_rows_cols_and_complement():
    topo = epiphany3()
    w = team_mod.team_world(16)
    rows = team_mod.split_2d(w, topo, -1)
    cols = team_mod.split_2d(w, topo, 0)
    assert rows.n_teams == 4 and rows.size == 4
    assert [t.members for t in rows.teams[:2]] == [(0, 1, 2, 3), (4, 5, 6, 7)]
    assert [t.members for t in cols.teams[:2]] == [(0, 4, 8, 12), (1, 5, 9, 13)]
    # complement of rows IS the column grouping (interned member teams)
    comp = rows.complement()
    assert [t.members for t in comp.teams] == [t.members for t in cols.teams]
    assert comp.complement() is rows


# ---------------------------------------------------------------------------
# pattern lifting: compile-once per (team, pairs), world-identical shim
# ---------------------------------------------------------------------------

def test_lift_caches_and_interns():
    from repro.core.pattern import ring_pattern
    t = team_mod.make_team([1, 4, 7, 2], N)
    p = ring_pattern(4)
    lifted = t.lift(p)
    assert t.lift(p) is lifted                      # cached per (team, pairs)
    assert lifted.pairs == ((1, 4), (2, 1), (4, 7), (7, 2))
    # world-team lift is the interned world pattern itself
    w = team_mod.team_world(4)
    assert w.lift(ring_pattern(4)) is ring_pattern(4)


def test_team_topology_view_prices_world_distances():
    topo = epiphany3()
    row1 = team_mod.split_2d(team_mod.team_world(16), topo, -1).teams[1]
    tt = row1.topo_view(topo)
    assert row1.topo_view(topo) is tt
    # ranks 0..3 are world PEs 4..7 on one row: 3 hops end to end
    assert tt.hops(0, 3) == topo.hops(4, 7) == 3.0
    # pricing an un-lifted team schedule == pricing the lifted one
    sched = coll.allreduce_schedule(4, 4096.0, "rd")
    assert sched.time(tt, abmodel.EPIPHANY_NOC) == pytest.approx(
        row1.lift_schedule(sched).time(topo, abmodel.EPIPHANY_NOC))


# ---------------------------------------------------------------------------
# team-scoped collectives (SIM): members reduce, non-members untouched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("members", [(1, 4, 7), (0, 2, 4, 6), (5, 2, 7)])
@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_team_to_all_members_only(ctx, members, op):
    t = team_mod.make_team(members, N)
    x = _x(seed=3)
    out = np.asarray(ctx.to_all(x, op, team=t))
    fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
    ref = np.asarray(x).copy()
    red = fn(np.asarray(x)[list(members)], 0)
    for m in members:
        ref[m] = red
    np.testing.assert_allclose(out, ref, rtol=2e-5)


def test_team_to_all_rd_vs_ring_agree(ctx):
    t = team_mod.make_team((0, 3, 5, 6), N)    # pow2 size, scattered PEs
    x = _x(seed=4)
    ring = np.asarray(ctx.to_all(x, "sum", team=t, algorithm="ring"))
    rd = np.asarray(ctx.to_all(x, "sum", team=t, algorithm="rd"))
    auto = np.asarray(ctx.to_all(x, "sum", team=t, algorithm="auto"))
    np.testing.assert_allclose(ring, rd, rtol=2e-5)
    assert (np.array_equal(auto, rd) or np.array_equal(auto, ring))


def test_team_broadcast_root_is_team_rank(ctx):
    t = team_mod.make_team((1, 4, 7), N)
    x = _x(seed=5)
    out = np.asarray(ctx.broadcast(x, root=2, team=t))   # team rank 2 = PE 7
    ref = np.asarray(x).copy()
    for m in (1, 4, 7):
        ref[m] = np.asarray(x)[7]
    np.testing.assert_array_equal(out, ref)


def test_team_fcollect_collect_team_rank_order(ctx):
    t = team_mod.make_team((6, 1, 3), N)       # non-monotonic member order
    x = _x(seed=6, w=2)
    cat = np.asarray(x)[[6, 1, 3]].reshape(-1)
    for out in (np.asarray(ctx.fcollect(x, team=t)),
                np.asarray(ctx.collect(x, team=t))):
        for m in (6, 1, 3):
            np.testing.assert_array_equal(out[m], cat)
        for pe in set(range(N)) - {6, 1, 3}:
            np.testing.assert_array_equal(out[pe], 0)


def test_team_alltoall_transpose(ctx):
    t = team_mod.make_team((0, 2, 5), N)
    blk = 2
    x = jnp.asarray(np.random.RandomState(7).randn(N, 3 * blk)
                    .astype(np.float32))
    out = np.asarray(ctx.alltoall(x, team=t))
    blocks = np.asarray(x)[[0, 2, 5]].reshape(3, 3, blk)
    ref_members = blocks.transpose(1, 0, 2).reshape(3, 3 * blk)
    for r, m in enumerate((0, 2, 5)):
        np.testing.assert_array_equal(out[m], ref_members[r])


def test_team_reduce_scatter_allgather_non_members_zero(ctx):
    t = team_mod.make_team((0, 2, 5), N)
    x = _x(seed=12)
    own, info = ctx.reduce_scatter(x, "sum", team=t)
    gathered = coll.allgather_unpad(ctx.net, own, info, team=t)
    red = np.asarray(x)[[0, 2, 5]].sum(0)
    for m in (0, 2, 5):
        np.testing.assert_allclose(np.asarray(gathered)[m], red, rtol=2e-5)
    for pe in set(range(N)) - {0, 2, 5}:
        np.testing.assert_array_equal(np.asarray(own)[pe], 0)
        np.testing.assert_array_equal(np.asarray(gathered)[pe], 0)


def test_team_plus_hier_rejected(ctx):
    t = team_mod.make_team((0, 1), N)
    with pytest.raises(ValueError):
        ctx.to_all(_x(), "sum", algorithm="hier", team=t)


def test_non_covering_partition_never_selects_hier():
    part = team_mod.TeamPartition([team_mod.make_team((0, 1), N),
                                   team_mod.make_team((2, 3), N)])
    assert not part.covers_world
    algo = coll.choose_algorithm(N, float(1 << 22), epiphany3(),
                                 abmodel.EPIPHANY_NOC, partition=part)
    assert algo != "hier"
    algo_c, _ = coll.choose_schedule(N, float(1 << 22), epiphany3(),
                                     abmodel.EPIPHANY_NOC, partition=part)
    assert algo_c != "hier"


def test_team_barrier_uniform_token(ctx):
    t = team_mod.make_team((0, 2, 5, 7), N)
    tok = np.asarray(ctx.barrier(team=t))
    assert len({tok[m] for m in (0, 2, 5, 7)}) == 1


# ---------------------------------------------------------------------------
# active-set shim: 1.3 triples resolve to the explicit-team schedules
# ---------------------------------------------------------------------------

def test_active_set_resolves_to_interned_team():
    t = team_mod.from_active_set(1, 1, 3, N)   # start 1, stride 2, size 3
    assert t.members == (1, 3, 5)
    assert t is team_mod.split_strided(team_mod.team_world(N), 1, 2, 3)


def test_to_all_active_set_matches_explicit_team(ctx):
    x = _x(seed=8)
    t = team_mod.from_active_set(0, 1, 4, N)
    shim = ctx.to_all(x, "sum", PE_start=0, logPE_stride=1, PE_size=4)
    explicit = ctx.to_all(x, "sum", team=t)
    np.testing.assert_array_equal(np.asarray(shim), np.asarray(explicit))
    # whole-world active set falls onto the flat path: identical to plain
    world = ctx.to_all(x, "sum", PE_start=0, logPE_stride=0, PE_size=N)
    np.testing.assert_array_equal(np.asarray(world),
                                  np.asarray(ctx.to_all(x, "sum")))


def test_to_all_rejects_team_and_active_set(ctx):
    t = team_mod.make_team((0, 1), N)
    with pytest.raises(ValueError):
        ctx.to_all(_x(), "sum", team=t, PE_size=2)
    with pytest.raises(ValueError):
        ctx.to_all(_x(), "sum", logPE_stride=2)    # partial set: needs size


# ---------------------------------------------------------------------------
# hierarchical two-level allreduce: equivalence and selection
# ---------------------------------------------------------------------------
# Tolerance: hier reorders the float summation (per-team partials, then
# cross-team) — results are allclose within a few ulps of the flat answer
# (rtol 2e-5 for f32, like the flat rd-vs-ring tests) and EXACT for int
# dtypes, where addition is associative.

@pytest.mark.parametrize("shape", [(4, 4), (2, 3), (3, 3), (2, 8)])
def test_allreduce_hier_matches_flat(shape):
    topo = MeshTopology(shape=shape, torus=(False, False))
    n = topo.n_pes
    net = SimNetOps(n)
    rows = team_mod.split_2d(team_mod.team_world(n), topo, -1)
    x = _x(n=n, w=13, seed=9)
    flat = np.tile(np.asarray(x).sum(0), (n, 1))
    hier = coll.allreduce_hier(net, x, "sum", partition=rows)
    np.testing.assert_allclose(np.asarray(hier), flat, rtol=2e-5)
    # column partition works the same way
    cols = team_mod.split_2d(team_mod.team_world(n), topo, 0)
    hier_c = coll.allreduce_hier(net, x, "sum", partition=cols)
    np.testing.assert_allclose(np.asarray(hier_c), flat, rtol=2e-5)


def test_allreduce_hier_int_exact():
    topo = epiphany3()
    net = SimNetOps(16)
    rows = team_mod.split_2d(team_mod.team_world(16), topo, -1)
    x = jnp.asarray((np.arange(16 * 11) % 17).reshape(16, 11)
                    .astype(np.int32))
    hier = coll.allreduce_hier(net, x, "sum", partition=rows)
    np.testing.assert_array_equal(np.asarray(hier),
                                  np.tile(np.asarray(x).sum(0), (16, 1)))


def test_allreduce_hier_max_and_weird_widths():
    topo = epiphany3()
    net = SimNetOps(16)
    rows = team_mod.split_2d(team_mod.team_world(16), topo, -1)
    for w in (1, 3, 16, 37):      # padding edge cases around K=4 chunks
        x = _x(n=16, w=w, seed=w)
        out = coll.allreduce_hier(net, x, "max", partition=rows)
        np.testing.assert_allclose(
            np.asarray(out), np.tile(np.asarray(x).max(0), (16, 1)),
            rtol=1e-6)


def test_hier_schedule_prices_what_executes():
    topo = epiphany3()
    rows = team_mod.split_2d(team_mod.team_world(16), topo, -1)
    sched = coll.allreduce_hier_schedule(rows, 4096.0, topo=topo,
                                         link=abmodel.EPIPHANY_NOC)
    # K-1 RS + cross + K-1 AG stages, all world-compiled union patterns
    assert len(sched.stages) >= 2 * (rows.size - 1) + 1
    assert all(st.pattern.n_pes == 16 for st in sched.stages)
    # intra stages move nbytes/K per member; stage hops priced on topo
    assert sched.stages[0].nbytes == pytest.approx(4096.0 / rows.size)
    assert sched.time(topo, abmodel.EPIPHANY_NOC) > 0


def test_choose_algorithm_picks_hier_large_2d():
    """The acceptance configuration: on a 2D mesh the cost model must
    prefer the hierarchical schedule for large payloads (it keeps the
    bulk bytes on intra-row links) and a flat algorithm for tiny ones."""
    topo = epiphany3()
    rows = team_mod.split_2d(team_mod.team_world(16), topo, -1)
    link = abmodel.EPIPHANY_NOC
    small = coll.choose_algorithm(16, 64.0, topo, link, partition=rows)
    big = coll.choose_algorithm(16, float(1 << 20), topo, link,
                                partition=rows)
    assert small in ("rd", "ring")
    assert big == "hier"
    # and the pick is consistent with the schedules' own pricing
    t_hier = coll.allreduce_hier_schedule(
        rows, float(1 << 20), topo=topo, link=link).time(topo, link)
    for algo in ("rd", "ring"):
        assert t_hier <= coll.allreduce_schedule(
            16, float(1 << 20), algo).time(topo, link) + 1e-12


def test_choose_schedule_picks_hier_on_podded_mesh():
    """choose_schedule weighs hier against CHUNKED flat execution too; on
    a mesh with an expensive cross axis (the §8 pod story) hier must win
    for large messages — the bench_teams acceptance configuration."""
    topo = MeshTopology(shape=(8, 8), torus=(False, True),
                        link_cost=(10.0, 1.0))
    rows = team_mod.split_2d(team_mod.team_world(64), topo, -1)
    algo, chunks = coll.choose_schedule(64, float(1 << 18), topo,
                                        abmodel.ICI_V5E, partition=rows)
    assert algo == "hier" and chunks == 1
    small_algo, _ = coll.choose_schedule(64, 64.0, topo, abmodel.ICI_V5E,
                                         partition=rows)
    assert small_algo in ("rd", "ring")


def test_allreduce_auto_with_partition_executes_hier(ctx):
    topo = epiphany3()
    net = SimNetOps(16)
    rows = team_mod.split_2d(team_mod.team_world(16), topo, -1)
    x = _x(n=16, w=1 << 16, seed=10)     # 256 KiB/PE: deep in hier territory
    out = coll.allreduce(net, x, "sum", algorithm="auto", topo=topo,
                         link=abmodel.EPIPHANY_NOC, partition=rows)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(x).sum(0), (16, 1)),
                               rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# per-context pending-op queues: quiet/fence isolation
# ---------------------------------------------------------------------------

def test_ctx_quiet_isolation(ctx):
    x = _x()
    c1 = ctx.ctx_create()
    c2 = ctx.ctx_create()
    f1 = c1.put_nbi(x, [(0, 1)])
    f2 = c2.put_nbi(x, [(2, 3)])
    fd = ctx.put_nbi(x, [(4, 5)])      # default context
    assert (c1.pending_count, c2.pending_count, ctx.pending_count) \
        == (1, 1, 1)
    # quiet on ctx B leaves ctx A (and the default context) pending
    c2.quiet()
    assert f2.done and not f1.done and not fd.done
    assert (c1.pending_count, c2.pending_count, ctx.pending_count) \
        == (1, 0, 1)
    # default-context quiet stays oblivious to created contexts
    ctx.quiet()
    assert fd.done and not f1.done
    c1.quiet()
    assert f1.done and c1.pending_count == 0
    ref = np.asarray(x).copy()
    ref[1] = ref[0]
    np.testing.assert_allclose(np.asarray(f1.value), ref)


def test_ctx_fence_scoped_to_context(ctx):
    x = _x()
    c1 = ctx.ctx_create()
    f1 = c1.put_nbi(x, [(0, 3)])
    f2 = c1.put_nbi(2 * x, [(1, 3)])   # same destination: fence chains
    fd = ctx.put_nbi(x, [(6, 3)])      # default ctx, same dest PE — NOT
    vals = c1.fence()                  # chained by c1's fence
    assert len(vals) == 2 and c1.pending_count == 2
    assert not (f1.done or f2.done) and not fd.done
    assert ctx.pending_count == 1
    c1.quiet()
    ctx.quiet()
    assert f1.done and f2.done and fd.done


def test_ctx_quiet_rejects_foreign_futures(ctx):
    x = _x()
    c1 = ctx.ctx_create()
    f = c1.put_nbi(x, [(0, 1)])
    with pytest.raises(ValueError):
        ctx.quiet(f)           # default context must not drain c1's op
    assert not f.done and c1.pending_count == 1
    c1.quiet()
    assert f.done
    # already-completed futures pass through (re-fence is harmless)
    c1.quiet(f)


def test_ctx_per_context_seq_numbers(ctx):
    x = _x()
    c1 = ctx.ctx_create()
    c2 = ctx.ctx_create()
    a = c1.put_nbi(x, [(0, 1)])
    b = c2.put_nbi(x, [(0, 1)])
    c = c1.put_nbi(x, [(2, 3)])
    assert (a.seq, b.seq, c.seq) == (0, 0, 1)   # issue order per context
    c1.quiet(), c2.quiet()


def test_team_scoped_ctx_lifts_patterns(ctx):
    t = team_mod.make_team((1, 4, 7), N)
    tc = ctx.ctx_create(team=t)
    x = _x(seed=11)
    f = tc.put_nbi(x, [(0, 2)])        # team coords: world 1 -> 7
    assert f.target_pes() == (7,)
    tc.quiet()
    ref = np.asarray(x).copy()
    ref[7] = ref[1]
    np.testing.assert_allclose(np.asarray(f.value), ref)
    # team-scoped get: requester team rank 0 (PE 1) reads owner rank 1 (PE 4)
    g = tc.get_nbi(x, [(0, 1)])
    assert g.target_pes() == (1,)
    tc.quiet()


# ---------------------------------------------------------------------------
# SPMD backend: teams, hier, and context isolation under shard_map
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import spmd_ctx, sim_ctx, team as team_mod
    from repro.core import collectives as coll
    from repro.core.topology import MeshTopology

    n = 8
    mesh = jax.make_mesh((n,), ("pe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    topo = MeshTopology(shape=(2, 4), torus=(False, False))
    x = jnp.asarray(np.random.RandomState(0).randn(n, 24).astype(np.float32))
    t = team_mod.make_team((1, 3, 4, 6), n)
    rows = team_mod.split_2d(team_mod.team_world(n), topo, -1)

    def run(fn):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P("pe"),),
                                     out_specs=P("pe")))(x)

    # team to_all on SPMD == team to_all on SIM, both algorithms
    for algo in ("rd", "ring"):
        def body(xl, _algo=algo):
            return spmd_ctx("pe").to_all(xl[0], "sum", algorithm=_algo,
                                         team=t)[None]
        got = np.asarray(run(body))
        ref = np.asarray(sim_ctx(n).to_all(x, "sum", algorithm=algo, team=t))
        assert np.allclose(got, ref, rtol=1e-5), algo

    # active-set shim == explicit team under shard_map
    def body_shim(xl):
        c = spmd_ctx("pe")
        a = c.to_all(xl[0], "sum", PE_start=1, logPE_stride=0, PE_size=4)
        b = c.to_all(xl[0], "sum",
                     team=team_mod.from_active_set(1, 0, 4, n))
        return (a - b)[None]
    assert np.allclose(np.asarray(run(body_shim)), 0.0)

    # hierarchical allreduce == flat allreduce (rtol documented in §11)
    def body_hier(xl):
        net = spmd_ctx("pe").net
        return coll.allreduce_hier(net, xl[0], "sum", partition=rows)[None]
    got = np.asarray(run(body_hier))
    ref = np.asarray(x).sum(0, keepdims=True)
    assert np.allclose(got, ref, rtol=2e-5)

    # ... and exact for ints
    xi = jnp.asarray((np.arange(n * 12) % 7).reshape(n, 12).astype(np.int32))
    def body_hier_int(xl):
        net = spmd_ctx("pe").net
        return coll.allreduce_hier(net, xl[0], "sum", partition=rows)[None]
    got_i = np.asarray(jax.jit(jax.shard_map(
        body_hier_int, mesh=mesh, in_specs=(P("pe"),),
        out_specs=P("pe")))(xi))
    assert (got_i == np.asarray(xi).sum(0, keepdims=True)).all()

    # per-context quiet isolation inside shard_map
    def body_ctx(xl):
        c = spmd_ctx("pe")
        c1, c2 = c.ctx_create(), c.ctx_create()
        f1 = c1.put_nbi(xl[0], [(0, 1)])
        f2 = c2.put_nbi(2 * xl[0], [(2, 3)])
        c2.quiet()
        assert f2.done and not f1.done
        assert c1.pending_count == 1 and c2.pending_count == 0
        assert c.pending_count == 0
        c1.quiet()
        assert f1.done
        return (f1.value + f2.value)[None]
    out = np.asarray(run(body_ctx))
    ref = np.asarray(x) + 2 * np.asarray(x)
    ref[1] = np.asarray(x)[0] + 2 * np.asarray(x)[1]
    ref[3] = np.asarray(x)[3] + 2 * np.asarray(x)[2]
    assert np.allclose(out, ref)
    print("SPMD teams OK")
""")


def test_spmd_teams_hier_and_ctx_isolation():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SPMD teams OK" in res.stdout
