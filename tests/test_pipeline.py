"""Pipeline parallelism over the pod axis: GPipe schedule on shmem puts
must reproduce the unpipelined loss exactly (same global params)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as Pspec
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.models import transformer
    from repro.parallel import pipeline, sharding
    from repro.parallel.comm import AxisSpec, Comm

    cfg = smoke_config("qwen2-0.5b")
    assert pipeline.supported(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(1, cfg.vocab, (4, 16)).astype(np.int32),
             "targets": rng.integers(1, cfg.vocab, (4, 16)).astype(np.int32)}

    mesh = make_mesh(2, 2)
    with jax.set_mesh(mesh):
        init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(5))
        def fn(p, b):
            comm = Comm(AxisSpec(), "shmem")
            l = transformer.train_loss(comm, cfg, p, b)
            return comm.allreduce(l, "data") / 2
        bspec = {k: Pspec("data", None) for k in batch}
        ref = float(jax.jit(build.shard_mapped(
            fn, mesh, (specs, bspec), Pspec()))(
            params, jax.tree.map(jnp.asarray, batch)))
        gp = jax.tree.map(np.asarray, params)

    mesh2 = make_mesh(1, 2, pod=2)
    with jax.set_mesh(mesh2):
        shapes2, specs2 = build.abstract_params(cfg, mesh2)
        def one(kp, sp):
            path = tuple(str(getattr(k, "key", k)) for k in kp)
            if sharding._is_stacked(path):
                return Pspec(*(("pod",) + tuple(sp)[1:]))
            return sp
        specs_pp = jax.tree_util.tree_map_with_path(one, specs2)
        gp2 = jax.tree.map(lambda a, sp: jax.device_put(
            jnp.asarray(a), jax.sharding.NamedSharding(mesh2, sp)),
            gp, specs_pp)
        def fn2(p, b):
            comm = Comm(AxisSpec(pod="pod"), "shmem")
            return pipeline.pipeline_train_loss(comm, cfg, p, b, n_micro=2)
        bspec2 = {k: Pspec(None, None) for k in batch}
        out = float(jax.jit(build.shard_mapped(
            fn2, mesh2, (specs_pp, bspec2), Pspec()))(
            gp2, jax.tree.map(jnp.asarray, batch)))
        # gradients flow through the reversed pipeline too
        g = jax.jit(build.shard_mapped(
            jax.grad(fn2), mesh2, (specs_pp, bspec2), specs_pp))(
            gp2, jax.tree.map(jnp.asarray, batch))
        gn = sum(float(jnp.abs(l.astype(jnp.float32)).sum())
                 for l in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
    assert abs(ref - out) < 1e-4 * max(1, abs(ref)), (ref, out)
    print("PIPELINE-OK")
""")


def test_pipeline_matches_unpipelined():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "PIPELINE-OK" in r.stdout
