"""Topology hop metrics + alpha-beta fit recovery."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import abmodel
from repro.core.topology import MeshTopology, epiphany3, v5e_multipod, v5e_pod


def test_epiphany_dimensions():
    t = epiphany3()
    assert t.n_pes == 16
    assert t.hops(0, 15) == 6          # (0,0)->(3,3) no wrap
    assert t.max_hops() == 6


def test_torus_wraparound():
    t = v5e_pod()
    assert t.n_pes == 256
    # (0,0) -> (15,15): one hop each way around the torus
    assert t.hops(0, t.rank((15, 15))) == 2
    assert t.hops(0, t.rank((8, 8))) == 16   # antipode


def test_multipod_dcn_weighting():
    t = v5e_multipod(2)
    same_pod = t.hops(t.rank((0, 0, 0)), t.rank((0, 0, 1)))
    cross_pod = t.hops(t.rank((0, 0, 0)), t.rank((1, 0, 0)))
    assert cross_pod == 10.0 * same_pod   # DCN hop ~10x ICI


def test_coords_rank_roundtrip():
    t = MeshTopology(shape=(3, 5, 7))
    for pe in (0, 1, 52, 104):
        assert t.rank(t.coords(pe)) == pe


def test_farthest_first_order():
    t = epiphany3()
    order = t.farthest_first(0, range(16))
    dists = [t.hops(0, p) for p in order]
    assert dists == sorted(dists, reverse=True)
    assert order[-1] == 0


@settings(max_examples=25, deadline=None)
@given(st.floats(1e-7, 1e-4), st.floats(1e-12, 1e-8))
def test_ab_fit_recovers_parameters(alpha, beta):
    sizes = np.array([8 << i for i in range(10)], float)
    times = alpha + beta * sizes
    fit = abmodel.fit(sizes, times)
    assert abs(fit.alpha - alpha) <= 1e-3 * alpha + 1e-12
    assert abs(fit.beta - beta) <= 1e-3 * beta + 1e-20
    assert fit.alpha_std < 1e-6 and fit.beta_std < 1e-9


def test_link_models_sane():
    # put peak on the paper's NoC == 2.4 GB/s; get path ~10x slower
    big = 1 << 20
    t_put = abmodel.EPIPHANY_NOC.time(big)
    t_get = abmodel.EPIPHANY_NOC_GET.time(big)
    assert 9 < t_get / t_put < 11
    assert abs(big / t_put - 2.4e9) / 2.4e9 < 0.01


def test_modeled_collective_time_additive():
    stages = [(100.0, 1.0), (200.0, 2.0)]
    total = abmodel.modeled_collective_time(stages)
    assert total == pytest.approx(
        abmodel.ICI_V5E.time(100.0, 1.0) + abmodel.ICI_V5E.time(200.0, 2.0))
