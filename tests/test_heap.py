"""Property tests for the symmetric heap (paper §3.2 rules)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.heap import (HeapError, SymmetricHeap, pack, plan_pack,
                             unpack)


def test_rule1_reverse_order_free():
    h = SymmetricHeap(1024)
    a = h.malloc(100)
    b = h.malloc(100)
    # freeing the first frees the series (paper: "call it once for the
    # first allocated buffer in a series")
    h.free(a)
    assert h.brk == a.offset
    with pytest.raises(HeapError):
        h.free(b)          # already gone


def test_rule2_realloc_last_only():
    h = SymmetricHeap(1024)
    a = h.malloc(64)
    b = h.malloc(64)
    with pytest.raises(HeapError):
        h.realloc(a, 128)
    b2 = h.realloc(b, 128)
    assert b2.offset == b.offset       # no copy, grows in place
    assert h.brk == b2.offset + 128


def test_rule3_alignment():
    h = SymmetricHeap(4096)
    with pytest.raises(HeapError):
        h.malloc(8, align=4)           # < 8
    with pytest.raises(HeapError):
        h.malloc(8, align=24)          # not a power of 2
    for al in (8, 16, 64, 256):
        a = h.malloc(13, align=al)
        assert a.offset % al == 0


def test_exhaustion():
    h = SymmetricHeap(128)
    h.malloc(100)
    with pytest.raises(HeapError):
        h.malloc(100)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 200), min_size=1, max_size=20))
def test_brk_monotone_and_free_restores(sizes):
    h = SymmetricHeap(1 << 20)
    allocs = []
    brks = [h.brk]
    for s in sizes:
        allocs.append(h.malloc(s))
        assert h.brk >= brks[-1]
        brks.append(h.brk)
    # free in reverse: brk returns exactly
    for a in reversed(allocs):
        h.free(a)
        assert h.brk == a.offset
    assert h.brk == allocs[0].offset


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 7)),
                min_size=1, max_size=6))
def test_pack_unpack_roundtrip(shapes):
    tree = {f"w{i}": jnp.asarray(
        np.random.RandomState(i).randn(*s).astype(np.float32))
        for i, s in enumerate(shapes)}
    spec = plan_pack(tree)
    out = unpack(pack(tree, spec), spec)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(tree[k]), rtol=1e-6)
    # offsets lane-aligned (the TPU analogue of dword alignment)
    assert all(o % 128 == 0 for o in spec.offsets)


def test_pack_mixed_dtypes():
    tree = [jnp.ones((3,), jnp.bfloat16), jnp.arange(4, dtype=jnp.int32)]
    spec = plan_pack(tree, dtype=jnp.float32)
    out = unpack(pack(tree, spec), spec)
    assert out[0].dtype == jnp.bfloat16 and out[1].dtype == jnp.int32
    np.testing.assert_allclose(np.asarray(out[1]), np.arange(4))
