"""Property tests for the symmetric heap (paper §3.2 rules)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.heap import (HeapError, SymmetricHeap, pack, plan_pack,
                             unpack)


def test_rule1_reverse_order_free():
    h = SymmetricHeap(1024)
    a = h.malloc(100)
    b = h.malloc(100)
    # freeing the first frees the series (paper: "call it once for the
    # first allocated buffer in a series")
    h.free(a)
    assert h.brk == a.offset
    with pytest.raises(HeapError):
        h.free(b)          # already gone


def test_rule2_realloc_last_only():
    h = SymmetricHeap(1024)
    a = h.malloc(64)
    b = h.malloc(64)
    with pytest.raises(HeapError):
        h.realloc(a, 128)
    b2 = h.realloc(b, 128)
    assert b2.offset == b.offset       # no copy, grows in place
    assert h.brk == b2.offset + 128


def test_rule3_alignment():
    h = SymmetricHeap(4096)
    with pytest.raises(HeapError):
        h.malloc(8, align=4)           # < 8
    with pytest.raises(HeapError):
        h.malloc(8, align=24)          # not a power of 2
    for al in (8, 16, 64, 256):
        a = h.malloc(13, align=al)
        assert a.offset % al == 0


def test_exhaustion():
    h = SymmetricHeap(128)
    h.malloc(100)
    with pytest.raises(HeapError):
        h.malloc(100)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 200), min_size=1, max_size=20))
def test_brk_monotone_and_free_restores(sizes):
    h = SymmetricHeap(1 << 20)
    allocs = []
    brks = [h.brk]
    for s in sizes:
        allocs.append(h.malloc(s))
        assert h.brk >= brks[-1]
        brks.append(h.brk)
    # free in reverse: brk returns exactly
    for a in reversed(allocs):
        h.free(a)
        assert h.brk == a.offset
    assert h.brk == allocs[0].offset


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 7)),
                min_size=1, max_size=6))
def test_pack_unpack_roundtrip(shapes):
    tree = {f"w{i}": jnp.asarray(
        np.random.RandomState(i).randn(*s).astype(np.float32))
        for i, s in enumerate(shapes)}
    spec = plan_pack(tree)
    out = unpack(pack(tree, spec), spec)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(tree[k]), rtol=1e-6)
    # offsets lane-aligned (the TPU analogue of dword alignment)
    assert all(o % 128 == 0 for o in spec.offsets)


def test_pack_mixed_dtypes():
    tree = [jnp.ones((3,), jnp.bfloat16), jnp.arange(4, dtype=jnp.int32)]
    spec = plan_pack(tree, dtype=jnp.float32)
    out = unpack(pack(tree, spec), spec)
    assert out[0].dtype == jnp.bfloat16 and out[1].dtype == jnp.int32
    np.testing.assert_allclose(np.asarray(out[1]), np.arange(4))


# ---------------------------------------------------------------------------
# alignment-padding paths of the pytree packing
# ---------------------------------------------------------------------------

def test_plan_pack_alignment_padding_layout():
    # leaf sizes 5 and 3 force padding to the 128-element lane boundary
    tree = [jnp.arange(5, dtype=jnp.float32), jnp.ones((3,), jnp.float32)]
    spec = plan_pack(tree)
    assert spec.offsets == (0, 128)          # 5 elements round up to 128
    assert spec.total == 256                 # trailing pad to a lane too
    spec8 = plan_pack(tree, align_elems=8)
    assert spec8.offsets == (0, 8)
    assert spec8.total == 16


def test_plan_pack_scalar_leaves():
    # shape-() leaves occupy one element but still pad to the alignment
    tree = {"a": jnp.asarray(3.0), "b": jnp.asarray(4.0)}
    spec = plan_pack(tree)
    assert spec.shapes == ((), ())
    assert spec.offsets == (0, 128) and spec.total == 256
    out = unpack(pack(tree, spec), spec)
    assert float(out["a"]) == 3.0 and float(out["b"]) == 4.0
    assert out["a"].shape == ()


def test_pack_padding_gaps_stay_zero():
    tree = [jnp.ones((5,), jnp.float32), 2 * jnp.ones((3,), jnp.float32)]
    buf = np.asarray(pack(tree, plan_pack(tree)))
    assert np.all(buf[5:128] == 0)           # inter-leaf pad
    assert np.all(buf[131:] == 0)            # trailing pad
    assert np.all(buf[:5] == 1) and np.all(buf[128:131] == 2)


def test_pack_unpack_padded_roundtrip_multidim():
    # 2-D leaves whose flat sizes are NOT lane multiples: the padded
    # layout must restore exact shapes and values
    tree = [jnp.asarray(np.random.RandomState(0).randn(3, 7)
                        .astype(np.float32)),
            jnp.asarray(np.random.RandomState(1).randn(2, 2, 5)
                        .astype(np.float32))]
    spec = plan_pack(tree)
    assert all(o % 128 == 0 for o in spec.offsets)
    out = unpack(pack(tree, spec), spec)
    for a, b in zip(out, tree):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# free()/realloc() rule error messages (paper §3.2 rules 1-2)
# ---------------------------------------------------------------------------

def test_free_error_messages_are_explicit():
    h = SymmetricHeap(1024)
    a = h.malloc(64)
    b = h.malloc(64)
    h.free(a)                                # frees the series (rule 1)
    with pytest.raises(HeapError, match="unknown or already-freed"):
        h.free(b)
    with pytest.raises(HeapError, match="unknown or already-freed"):
        h.free(a)                            # double free of the head too


def test_realloc_error_message_is_explicit():
    h = SymmetricHeap(1024)
    a = h.malloc(64)
    h.malloc(64)
    with pytest.raises(HeapError, match="last allocation"):
        h.realloc(a, 128)


def test_free_head_then_malloc_reuses_offset():
    h = SymmetricHeap(1024)
    a = h.malloc(100)
    h.malloc(50)
    h.free(a)                                # brk returns to a.offset
    c = h.malloc(10)
    assert c.offset == a.offset
    assert h.brk == c.offset + 10
