"""Profiler + measured-performance autotuner (DESIGN.md §13).

Covers: pcontrol levels and the disabled fast path, per-op samples and
their schedule-derived fields, JSON export, the tuning DB's record /
best / round-trip, selector precedence (measured-best first, analytic
fallback on misses, candidate-set restriction), the calibration sweep's
acceptance properties (picks the measured best everywhere it measured;
never measured-worse than the analytic choice), link-model refitting,
online refinement through the profiler sink, and the SPMD wiring.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Profiler, Tuner, TunedSelector, TuningDB, abmodel,
                        collectives as coll, epiphany3, sim_ctx)
from repro.core import profile as profile_mod
from repro.core import tuner as tuner_mod


def _payload(n, nbytes, seed=0):
    w = max(1, int(nbytes) // 4)
    return jnp.asarray(np.random.RandomState(seed)
                       .randn(n, w).astype(np.float32))


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def test_pcontrol_levels():
    p = Profiler(level=2)
    with p.op("allreduce", nbytes=64, n_pes=4):
        pass
    assert len(p.samples) == 1
    p.pcontrol(1)                      # counters only
    with p.op("allreduce", nbytes=64, n_pes=4):
        pass
    assert len(p.samples) == 1
    assert p.counters()["collective.allreduce"]["count"] == 2
    p.pcontrol(0)                      # fully off
    with p.op("allreduce", nbytes=64, n_pes=4):
        pass
    assert p.counters()["collective.allreduce"]["count"] == 2
    assert not p.enabled


def test_op_sample_fields_and_note():
    p = Profiler(level=2)
    sched = coll.allreduce_schedule(8, 1024.0, "ring")
    with p.op("allreduce", nbytes=1024, n_pes=8, fingerprint="flat:n8"):
        p.note(algorithm="ring", chunks=2, schedule=sched,
               link=abmodel.EPIPHANY_NOC)
    (s,) = p.samples
    assert s.algorithm == "ring" and s.chunks == 2
    assert s.schedule == "allreduce.ring"
    assert s.n_stages == len(sched.stages)
    assert s.bytes_moved == pytest.approx(sched.total_bytes())
    assert s.predicted_s == pytest.approx(
        sched.pipelined_time(2, None, abmodel.EPIPHANY_NOC))
    assert s.wall_s > 0 and s.fingerprint == "flat:n8"
    assert not s.traced


def test_bare_note_records_selection_sample():
    p = Profiler(level=2)
    p.note(algorithm="rd", collective="allreduce", nbytes=64, n_pes=4)
    (s,) = p.samples
    assert s.kind == "selection" and s.algorithm == "rd"


def test_json_export_roundtrip(tmp_path):
    p = Profiler(level=2)
    with p.op("fcollect", nbytes=256, n_pes=4):
        p.note(algorithm="ring", chunks=1)
    path = tmp_path / "profile.json"
    p.dump(path)
    doc = json.loads(path.read_text())
    assert doc["schema"] == 1
    assert doc["counters"]["collective.fcollect.ring"]["count"] == 1
    (row,) = doc["timeline"]
    assert row["collective"] == "fcollect" and row["algorithm"] == "ring"


def test_sim_ctx_records_collective_samples():
    prof = Profiler(level=2)
    ctx = sim_ctx(16, epiphany3(), profile=prof)
    x = _payload(16, 4096)
    ctx.to_all(x, "sum", algorithm="auto")
    ctx.fcollect(x)
    ctx.broadcast(x, root=3)
    ctx.alltoall(_payload(16, 16 * 64))
    ctx.barrier()
    kinds = [(s.collective, s.kind) for s in prof.samples]
    for name in ("allreduce", "fcollect", "broadcast", "alltoall",
                 "barrier"):
        assert (name, "collective") in kinds
    for s in prof.samples:
        assert s.kind == "collective"
        assert s.algorithm != "" and s.wall_s > 0 and not s.traced
        assert s.fingerprint.startswith("mesh4x4")
        if s.collective != "barrier":
            assert s.nbytes > 0
        if s.schedule:
            assert s.n_stages > 0 and s.bytes_moved >= 0
    # the NetOps hook saw the raw ppermutes
    assert any(k.startswith("ppermute[") for k in prof.counters())


def test_rma_and_quiet_counters():
    prof = Profiler(level=2)
    ctx = sim_ctx(4, profile=prof)
    x = _payload(4, 64)
    ctx.put_nbi(x, [(0, 1)])
    ctx.get_nbi(x, [(2, 3)])
    ctx.quiet()
    c = prof.counters()
    assert c["rma.put"]["count"] == 1
    assert c["rma.get"]["count"] == 1
    assert c["quiet.drained"]["count"] == 2
    assert sum(1 for s in prof.samples if s.kind == "rma") == 2


def test_pcontrol_attaches_profiler_lazily():
    ctx = sim_ctx(4)
    assert ctx.profile is None
    ctx.pcontrol(0)                    # no-op: nothing to disable
    assert ctx.profile is None
    ctx.pcontrol(2)
    assert ctx.profile is not None and ctx.net.profile is ctx.profile
    ctx.to_all(_payload(4, 64), "sum")
    assert len(ctx.profile.samples) == 1
    ctx.pcontrol(0)
    ctx.to_all(_payload(4, 64), "sum")
    assert len(ctx.profile.samples) == 1


def test_disabled_profiler_pays_nothing():
    prof = Profiler(level=0)
    ctx = sim_ctx(4, profile=prof)
    ctx.to_all(_payload(4, 64), "sum")
    assert prof.samples == [] and prof.counters() == {}


def test_measure_records_sample():
    prof = Profiler(level=2)
    t = profile_mod.measure(lambda v: v + 1, jnp.zeros((8,)), iters=2,
                            profile=prof, collective="allreduce",
                            nbytes=32.0, n_pes=8, algorithm="ring",
                            chunks=1, fingerprint="flat:n8")
    assert t > 0
    (s,) = prof.samples
    assert s.kind == "measure" and s.wall_s == pytest.approx(t)
    assert s.algorithm == "ring" and s.fingerprint == "flat:n8"


# ---------------------------------------------------------------------------
# abmodel fit guards (satellite regression tests)
# ---------------------------------------------------------------------------

def test_fit_rejects_too_few_samples():
    with pytest.raises(ValueError, match=">= 2"):
        abmodel.fit([1024.0], [1e-5])
    with pytest.raises(ValueError, match="distinct"):
        abmodel.fit([1024.0, 1024.0, 1024.0], [1e-5, 1.1e-5, 0.9e-5])
    with pytest.raises(ValueError, match="matching"):
        abmodel.fit([64.0, 128.0], [1e-5])


def test_fit_contention_rejects_degenerate_grids():
    with pytest.raises(ValueError, match=">= 2"):
        abmodel.fit_contention([1.0], [1e-5])
    with pytest.raises(ValueError, match="load==1"):
        abmodel.fit_contention([2.0, 4.0], [1e-5, 2e-5])
    with pytest.raises(ValueError, match="load>1"):
        abmodel.fit_contention([1.0, 1.0], [1e-5, 1e-5])
    with pytest.raises(ValueError, match="matching"):
        abmodel.fit_contention([1.0, 2.0], [1e-5])
    # the well-posed case still recovers gamma
    g = abmodel.fit_contention([1.0, 2.0, 4.0], [1e-5, 2e-5, 4e-5])
    assert 0.9 < g <= 1.0


# ---------------------------------------------------------------------------
# tuning DB
# ---------------------------------------------------------------------------

def test_db_record_best_and_roundtrip(tmp_path):
    db = TuningDB()
    db.record("flat:n8", "allreduce", "n8", 4096, "ring", 1, None, 2e-4)
    db.record("flat:n8", "allreduce", "n8", 4096, "rd", 1, None, 1e-4)
    db.record("flat:n8", "allreduce", "n8", 4096, "rd", 4, None, 3e-4)
    got = db.best("flat:n8", "allreduce", "n8", 4096)
    assert got[:3] == ("rd", 1, "")
    # same power-of-two bucket: 4000 B keys like 4096 B
    assert db.best("flat:n8", "allreduce", "n8", 4000)[:3] == ("rd", 1, "")
    # candidate restriction: forced to the measured ring
    assert db.best("flat:n8", "allreduce", "n8", 4096,
                   algos=["ring"])[:3] == ("ring", 1, "")
    assert db.best("flat:n8", "allreduce", "n8", 4096,
                   max_chunks=1)[:3] == ("rd", 1, "")
    # unmeasured point: miss
    assert db.best("flat:n8", "allreduce", "n8", 1 << 20) is None
    # widened bucket search finds the neighbor
    assert db.best("flat:n8", "allreduce", "n8", 1 << 14, widen=2) is not None
    db.set_link("flat:n8", abmodel.LinkModel(1e-6, 0.0, 1e9, 0.5))
    path = tmp_path / "db.json"
    db.save(path)
    db2 = TuningDB.load(path)
    assert db2.best("flat:n8", "allreduce", "n8", 4096) == got
    lk = db2.link_model("flat:n8")
    assert lk.bw_Bps == 1e9 and lk.contention == 0.5
    assert db2.link_model("missing") is None


def test_db_running_mean_refines():
    db = TuningDB()
    for t in (1e-4, 2e-4, 3e-4):
        db.record("f", "allreduce", "n4", 256, "ring", 1, None, t)
    v = db.entries[db.key("f", "allreduce", "n4", 256)]["variants"]["ring|c1|"]
    assert v["n"] == 3 and v["mean_s"] == pytest.approx(2e-4)


def test_live_samples_do_not_corrupt_calibrated_best():
    """Eager (dispatch-inclusive) online times are kept in separate
    per-variant LIVE means: a covered point keeps its calibrated pick,
    an uncovered point still answers from live data."""
    db = TuningDB()
    db.record("f", "allreduce", "n8", 4096, "rd", 1, None, 1e-4)
    # a much-"faster" live sample for another variant must not flip it
    db.record("f", "allreduce", "n8", 4096, "ring", 1, None, 1e-6,
              source="live")
    assert db.best("f", "allreduce", "n8", 4096)[0] == "rd"
    # ... nor may a slow live sample of the SAME variant inflate it
    db.record("f", "allreduce", "n8", 4096, "rd", 1, None, 5e-2,
              source="live")
    assert db.best("f", "allreduce", "n8", 4096)[3] == pytest.approx(1e-4)
    # live-only (sweep-uncovered) points still answer
    db.record("f", "allreduce", "n8", 256, "ring", 1, None, 2e-3,
              source="live")
    assert db.best("f", "allreduce", "n8", 256)[:2] == ("ring", 1)


def test_selector_chunks_requires_algorithm_match():
    db = TuningDB()
    db.record("flat:n8", "allreduce", "n8", 4096, "rd", 4, None, 1e-4)
    sel = TunedSelector(db)
    assert sel.chunks("allreduce", "rd", 8, 4096, None) == 4
    assert sel.chunks("allreduce", "ring", 8, 4096, None) is None


def test_selector_embedding_mapping():
    topo = epiphany3()
    n = topo.n_pes
    fp = tuner_mod.fingerprint(topo, n)
    ref = coll.EMBED_REF_BYTES
    db = TuningDB()
    sel = TunedSelector(db)
    assert sel.embedding(n, ref, topo) is None          # miss
    db.record(fp, "allreduce", f"n{n}", ref, "ring", 1, None, 1e-4)
    assert sel.embedding(n, ref, topo) == "identity"    # un-embedded best
    db.record(fp, "allreduce", f"n{n}", ref, "ring_emb", 1,
              topo.snake_order(), 5e-5)
    pick = sel.embedding(n, ref, topo)
    assert tuple(pick) == topo.snake_order()


# ---------------------------------------------------------------------------
# selector precedence in choose_*
# ---------------------------------------------------------------------------

def test_choose_algorithm_consults_tuner_first():
    n, nbytes = 8, 256
    analytic = coll.choose_algorithm(n, nbytes, None, abmodel.EPIPHANY_NOC)
    other = "ring" if analytic == "rd" else "rd"
    db = TuningDB()
    db.record("flat:n8", "allreduce", "n8", nbytes, other, 1, None, 1e-6)
    sel = TunedSelector(db)
    assert coll.choose_algorithm(n, nbytes, None, abmodel.EPIPHANY_NOC,
                                 tuner=sel) == other
    # unmeasured size: falls back to the analytic pick for THAT size
    assert coll.choose_algorithm(n, 1 << 22, None, abmodel.EPIPHANY_NOC,
                                 tuner=sel) == \
        coll.choose_algorithm(n, 1 << 22, None, abmodel.EPIPHANY_NOC)


def test_choose_schedule_consults_tuner_first():
    n, nbytes = 8, 65536
    db = TuningDB()
    db.record("flat:n8", "allreduce", "n8", nbytes, "ring", 8, None, 1e-6)
    sel = TunedSelector(db)
    assert coll.choose_schedule(n, nbytes, None, abmodel.EPIPHANY_NOC,
                                tuner=sel) == ("ring", 8)
    # the measured chunk count must respect the caller's pipeline cap
    assert coll.choose_schedule(n, nbytes, None, abmodel.EPIPHANY_NOC,
                                max_chunks=4, tuner=sel) != ("ring", 8)


def test_choose_chunks_consults_tuner_first():
    n, nbytes = 8, 65536
    stages = coll.allreduce_schedule(n, nbytes, "ring").cost(None)
    analytic = abmodel.choose_chunks(stages, abmodel.EPIPHANY_NOC)
    db = TuningDB()
    db.record("flat:n8", "allreduce", "n8", nbytes, "ring", 16, None, 1e-6)
    sel = TunedSelector(db)
    key = ("allreduce", "ring", n, nbytes, None)
    assert abmodel.choose_chunks(stages, abmodel.EPIPHANY_NOC,
                                 tuner=sel, key=key) == 16
    miss = ("allreduce", "ring", n, 128, None)
    assert abmodel.choose_chunks(stages, abmodel.EPIPHANY_NOC,
                                 tuner=sel, key=miss) == analytic


def test_choose_embedding_consults_tuner_first():
    topo = epiphany3()
    n = topo.n_pes
    fp = tuner_mod.fingerprint(topo, n)
    db = TuningDB()
    # measured best at the reference payload: the UN-embedded ring
    db.record(fp, "allreduce", f"n{n}", coll.EMBED_REF_BYTES, "ring", 1,
              None, 1e-6)
    sel = TunedSelector(db)
    assert coll.choose_embedding(n, topo, abmodel.EPIPHANY_NOC,
                                 tuner=sel) is None
    # analytic pick on this mesh is the snake — the override is visible
    assert coll.choose_embedding(n, topo, abmodel.EPIPHANY_NOC) is not None


def test_tuned_pick_runs_and_matches_untuned_result():
    """A DB-forced algorithm changes the schedule, not the numbers."""
    n = 16
    topo = epiphany3()
    x = _payload(n, 4096)
    db = TuningDB()
    db.record(tuner_mod.fingerprint(topo, n), "allreduce", f"n{n}", 4096,
              "ring", 2, None, 1e-6)
    tuned = sim_ctx(n, topo, tuner=TunedSelector(db))
    plain = sim_ctx(n, topo)
    a = tuned.to_all(x, "sum", algorithm="auto", pipeline_chunks="auto")
    b = plain.to_all(x, "sum", algorithm="auto")
    # different algorithms reorder the float summation: allclose, not ==
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# calibration sweep — the acceptance properties
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def swept():
    ctx = sim_ctx(8, profile=Profiler(level=2))
    tuner = Tuner(link=abmodel.EPIPHANY_NOC)
    grid = {"collectives": ("allreduce", "fcollect"),
            "sizes": (256, 4096), "chunks": (1, 2),
            "iters": 3, "warmup": 1}
    summary = tuner.tune(ctx, grid)
    return ctx, tuner, grid, summary


def test_sweep_fills_db_and_reports(swept):
    ctx, tuner, grid, summary = swept
    assert summary["points"] == 4
    assert len(tuner.db) == 4
    assert summary["fingerprint"] == "flat:n8"
    # the sweep's measurements landed in the attached profiler too
    kinds = {s.kind for s in ctx.profile.samples}
    assert "measure" in kinds


def test_sweep_selector_picks_measured_best(swept):
    """Acceptance: the tuned selector returns the measured argmin on
    EVERY covered grid point (>= 90% required; argmin-by-construction
    gives 100%), and never a variant measured worse than the analytic
    selector's own choice."""
    ctx, tuner, grid, _ = swept
    sel = tuner.selector()
    fp = "flat:n8"
    n = ctx.n_pes
    for collective in grid["collectives"]:
        for nbytes in grid["sizes"]:
            variants = tuner.db.variants(fp, collective, f"n{n}", nbytes)
            assert variants, (collective, nbytes)
            meas = {tuner_mod.split_variant(k)[:2]: v["mean_s"]
                    for k, v in variants.items()}
            best = min(meas, key=meas.get)
            pick = sel.schedule(collective, n, nbytes, None)
            assert pick == best, (collective, nbytes)
            # never measured-worse than the analytic (algorithm, chunks)
            a = coll.choose_schedule(n, nbytes, None, tuner.link,
                                     collective=collective)
            if a in meas:                    # sweep always covers it
                assert meas[pick] <= meas[a]


def test_sweep_covers_analytic_choice(swept):
    """The sweep always measures what the analytic selector would run —
    the 'never worse than analytic' guarantee rests on it."""
    ctx, tuner, grid, _ = swept
    n = ctx.n_pes
    for collective in grid["collectives"]:
        for nbytes in grid["sizes"]:
            a = coll.choose_schedule(n, nbytes, None, tuner.link,
                                     collective=collective)
            variants = tuner.db.variants("flat:n8", collective, f"n{n}",
                                         nbytes)
            have = {tuner_mod.split_variant(k)[:2] for k in variants}
            assert a in have


def test_sweep_refits_link_model(swept):
    _, tuner, _, _ = swept
    lk = tuner.db.link_model("flat:n8")
    assert lk is not None
    assert lk.alpha_s > 0 and lk.bw_Bps > 0
    assert tuner.link_model(None, 8) is lk or (
        tuner.link_model(None, 8).alpha_s == lk.alpha_s)
    # unknown fingerprints keep the prior
    assert tuner.link_model(epiphany3(), 16) is tuner.link


def test_tuner_roundtrips_from_disk(swept, tmp_path):
    _, tuner, grid, _ = swept
    path = tmp_path / "tuning_db.json"
    tuner.save(path)
    reloaded = Tuner(path=str(path))
    sel_a, sel_b = tuner.selector(), reloaded.selector()
    for collective in grid["collectives"]:
        for nbytes in grid["sizes"]:
            assert sel_a.schedule(collective, 8, nbytes, None) == \
                sel_b.schedule(collective, 8, nbytes, None)
    lk = reloaded.db.link_model("flat:n8")
    assert lk.bw_Bps == tuner.db.link_model("flat:n8").bw_Bps


def test_tune_rejects_spmd_context():
    tuner = Tuner()

    class FakeCtx:
        class net:
            pass
    with pytest.raises(ValueError, match="SIM"):
        tuner.tune(FakeCtx())


# ---------------------------------------------------------------------------
# online refinement: profiler sink -> DB
# ---------------------------------------------------------------------------

def test_online_refinement_from_profiler_samples():
    prof = Profiler(level=2)
    tuner = Tuner()
    ctx = sim_ctx(8, profile=prof, tuner=tuner)
    x = _payload(8, 4096)
    ctx.to_all(x, "sum", algorithm="ring")
    ctx.to_all(x, "sum", algorithm="rd")
    variants = tuner.db.variants("flat:n8", "allreduce", "n8", 4096)
    assert variants is not None
    algos = {tuner_mod.split_variant(k)[0] for k in variants}
    assert algos == {"ring", "rd"}
    # the eager wall times refined the DB; the selector now answers
    assert tuner.selector().algorithm("allreduce", 8, 4096, None) in algos


def test_observe_skips_traced_samples():
    tuner = Tuner()
    s = profile_mod.OpSample(collective="allreduce", nbytes=4096, n_pes=8,
                             team="n8", algorithm="ring", wall_s=1e-4,
                             traced=True, fingerprint="flat:n8")
    tuner.observe(s)
    assert len(tuner.db) == 0
    s.traced = False
    tuner.observe(s)
    assert len(tuner.db) == 1


def test_observe_skips_measure_kind_samples():
    """tune() records its calibration measurements itself — the sink
    observing them too would double-count every sweep variant."""
    tuner = Tuner()
    s = profile_mod.OpSample(collective="allreduce", nbytes=4096, n_pes=8,
                             team="n8", algorithm="ring", wall_s=1e-4,
                             kind="measure", fingerprint="flat:n8")
    tuner.observe(s)
    assert len(tuner.db) == 0


def test_tune_with_attached_sink_counts_each_variant_once():
    prof = Profiler(level=2)
    tuner = Tuner()
    ctx = sim_ctx(4, profile=prof, tuner=tuner)   # sink IS wired
    tuner.tune(ctx, {"collectives": ("allreduce",), "sizes": (256,),
                     "chunks": (1,), "iters": 2, "warmup": 1})
    variants = tuner.db.variants("flat:n4", "allreduce", "n4", 256)
    assert variants and all(v["n"] == 1 and v.get("live_n", 0) == 0
                            for v in variants.values())


def test_profile_json_has_no_nan_tokens(tmp_path):
    prof = Profiler(level=2)
    with prof.op("train_step", n_pes=4):    # predicted_s stays NaN
        pass
    path = tmp_path / "p.json"
    prof.dump(path)
    text = path.read_text()
    assert "NaN" not in text
    doc = json.loads(text)                   # strict parse succeeds
    assert doc["timeline"][0]["predicted_s"] is None


# ---------------------------------------------------------------------------
# SPMD wiring: tuned Comm under shard_map
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import Profiler, Tuner, TuningDB, TunedSelector
    from repro.parallel.comm import AxisSpec, Comm

    n = 8
    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jnp.asarray(np.random.RandomState(0).randn(n, 64).astype(np.float32))

    # force the measured-best to the ring at this size; the tuned Comm
    # must still produce the exact mean
    db = TuningDB()
    db.record("flat:n8", "allreduce", "n8", 256, "ring", 1, None, 1e-6)
    prof = Profiler(level=2)

    def sync(tuner):
        def body(gl):
            comm = Comm(AxisSpec(data="data", model=None), "shmem",
                        allreduce_algo="auto", tuner=tuner, profile=prof)
            return comm.grad_sync(gl[0], mean=True)[None]
        return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P("data"),),
                                     out_specs=P("data")))(g)

    out = np.asarray(sync(TunedSelector(db)))
    ref = np.asarray(g).mean(0, keepdims=True)
    assert np.allclose(out, ref, rtol=1e-5)
    # the traced selection was recorded, flagged as traced, and the DB's
    # forced pick was honored
    sels = [s for s in prof.samples if s.collective == "allreduce"]
    assert sels and all(s.traced for s in sels)
    assert any(s.algorithm == "ring" for s in sels)
    # a full Tuner wired through build_train_step-style kwargs also runs
    out2 = np.asarray(sync(Tuner(db=db)))
    assert np.allclose(out2, ref, rtol=1e-5)
    print("SPMD tuned OK")
""")


def test_spmd_tuned_comm():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SPMD tuned OK" in res.stdout
