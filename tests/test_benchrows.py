"""run.py's standardized-row extractors (_std_row and its regexes)
against REAL derived strings from every registered bench — the fields
check_regression pins and perfdiff fits come from these parses, so a
regex drift here silently un-gates CI."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import run  # noqa: E402

# (bench, name, derived, size_bytes, predicted_us, picked) — sampled
# verbatim from a committed BENCH_*.json, one or more per bench.
REPRESENTATIVE = [
    ("paper", "shmem_put_4096B_sim", "model=1.809us",
     4096, None, None),
    ("paper", "put_alpha_us", "beta^-1=2.40GB/s paper=2.4GB/s",
     None, None, None),
    ("paper", "fidelity_put_peak_GBs",
     "paper=2.4GB/s[1608.03545 Fig.4] mode=rel tol=0.02 err=+0.0% "
     "src=1608.03545 Fig.4 OK", None, None, None),
    ("patterns", "allreduce_rd_256B",
     "fit=8.39us(x1.84) noc=1.695us stages=4", 256, 8.39, None),
    ("patterns", "sim_stage_alpha_us", "beta^-1=0.29GB/s (+-10.67us)",
     None, None, None),
    ("congestion", "allreduce_ring_65536B",
     "emb=502.56us speedup=x3.53 pred=x1.95 auto_pick=ring_emb/c16",
     65536, None, "ring_emb/c16"),
    ("congestion", "contention_gamma",
     "gamma=1.00 (1.0=full serialization)", None, None, None),
    ("tuner", "tuned_allreduce_4096B",
     "picked=rd/c1 analytic=ring/c8(773.98us) variants=7",
     4096, None, "rd/c1"),
    ("fused", "attn_ring_65536B_us",
     "L=256 x1.14vs-mono pred=1139.56us pick=ring",
     65536, 1139.56, "ring"),
    ("serve", "serve_decode_p50_us_occ1", "steps=23 page=8tok kv=5120B",
     None, None, None),
    ("trace", "trace_allreduce_65536B_off", "vs_base=-7.8% level=0",
     65536, None, None),
    ("fault", "ckpt_sync_save_16777216B", "324MB/s inline stall",
     16777216, None, None),
    ("roofline", "roofline_train_wall_us",
     "pred=1599.81us pick=compute mfu=1.161 noc=ring/c16 link=default",
     None, 1599.81, "compute"),
    ("roofline", "roofline_decode_noc_us",
     "payload=18432B compute=0.86us memory=0.45us", None, None, None),
]


@pytest.mark.parametrize(
    "bench,name,derived,size,pred,pick", REPRESENTATIVE,
    ids=[f"{b}:{n}" for b, n, *_ in REPRESENTATIVE])
def test_std_row_extracts_fields(bench, name, derived, size, pred, pick):
    r = run._std_row(bench, name, 12.5, derived)
    assert r["bench"] == bench and r["name"] == name
    assert r["measured_us"] == 12.5
    assert r["size_bytes"] == size
    assert r["predicted_us"] == pred
    assert r["picked"] == pick


def test_every_registered_bench_has_a_representative_row():
    keys = {k for k, _, _ in run.BENCHES}
    covered = {b for b, *_ in REPRESENTATIVE}
    # substrate is the one bench that exports no ROWS (subprocess A/B,
    # prints only); everything else must be exercised above
    assert keys - covered == {"substrate"}
    assert covered - keys == set()


def test_size_regex_wants_trailing_boundary():
    # `_65536B_off` and `_64B` match; an interior `B` in a word must not
    assert run._SIZE_RE.search("trace_allreduce_65536B_off").group(1) \
        == "65536"
    assert run._SIZE_RE.search("shmem_put_64B").group(1) == "64"
    assert run._SIZE_RE.search("serve_tok_per_s_occ1") is None


def test_pred_regex_ignores_ratio_predictions():
    # congestion's `pred=x1.95` is a speedup ratio, not microseconds
    assert run._PRED_RE.search("speedup=x3.53 pred=x1.95") is None
    assert run._PRED_RE.search("fit=8.39us(x1.48)").group(1) == "8.39"
    assert run._PRED_RE.search("noc=0.842us").group(1) == "0.842"


def test_machine_fingerprint_identity_fields():
    fp = run.machine_fingerprint()
    for key in ("hostname", "cpus", "platform", "python", "jax"):
        assert key in fp
    assert isinstance(fp["cpus"], int) and fp["cpus"] > 0
