"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.RandomState(0)


@pytest.mark.parametrize("shape", [(32, 128), (37, 300), (8, 128),
                                   (100, 1), (1, 513)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_put_copy(shape, dtype):
    x = jnp.asarray((RNG.randn(*shape) * 10).astype(dtype))
    out = ops.put_copy(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dma_copy_2d_strided(dtype):
    src = jnp.asarray(RNG.randn(64, 256).astype(dtype))
    dst = jnp.asarray(RNG.randn(96, 384).astype(dtype))
    kw = dict(src_origin=(32, 128), dst_origin=(0, 256), region=(32, 128))
    np.testing.assert_allclose(
        np.asarray(ops.dma_copy(src, dst, interpret=True, **kw)),
        np.asarray(ref.dma_copy_ref(src, dst, **kw)))


@pytest.mark.parametrize("op", ["sum", "max", "min", "prod"])
@pytest.mark.parametrize("k", [2, 3, 5])
def test_reduce_combine(op, k):
    bufs = [jnp.asarray(RNG.rand(40, 200).astype(np.float32) + 0.1)
            for _ in range(k)]
    np.testing.assert_allclose(
        np.asarray(ops.reduce_combine(bufs, op, interpret=True)),
        np.asarray(ref.reduce_combine_ref(bufs, op)), rtol=1e-5)


ATTN_CASES = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=17),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=33, softcap=50.0),
]


@pytest.mark.parametrize("kw", ATTN_CASES)
@pytest.mark.parametrize("lq,lk,group", [(64, 64, 2), (100, 100, 1),
                                         (32, 96, 4)])
def test_flash_attention_vs_ref(kw, lq, lk, group):
    if kw.get("causal") and lq != lk:
        pytest.skip("causal assumes aligned positions")
    B, Hkv, D = 2, 2, 32
    q = jnp.asarray(RNG.randn(B, Hkv * group, lq, D).astype(np.float32)) * .5
    k = jnp.asarray(RNG.randn(B, Hkv, lk, D).astype(np.float32)) * .5
    v = jnp.asarray(RNG.randn(B, Hkv, lk, D).astype(np.float32)) * .5
    out = ops.attention(q, k, v, use_pallas=True, interpret=True,
                        bq=32, bk=32, **kw)
    want = ref.attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5)


def test_flash_attention_bf16():
    B, H, L, D = 1, 2, 64, 32
    q = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)).astype(
        jnp.bfloat16) * 0.5
    k = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)).astype(
        jnp.bfloat16) * 0.5
    v = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)).astype(
        jnp.bfloat16) * 0.5
    out = ops.attention(q, k, v, use_pallas=True, interpret=True,
                        bq=32, bk=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_attention_grad_matches_ref():
    B, H, L, D = 1, 2, 48, 16
    q = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)) * .5
    k = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)) * .5
    v = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)) * .5
    g1 = jax.grad(lambda a: ops.attention(
        a, k, v, use_pallas=True, interpret=True, bq=16, bk=16).sum())(q)
    g2 = jax.grad(lambda a: ref.attention_ref(a, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=3e-4)


def test_blockwise_equals_dense():
    B, H, L, D = 1, 2, 200, 16
    q = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)) * .5
    k = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)) * .5
    v = jnp.asarray(RNG.randn(B, H, L, D).astype(np.float32)) * .5
    for kw in ATTN_CASES:
        a = ref.attention_blockwise(q, k, v, block=64, **kw)
        b = ref.attention_ref(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@pytest.mark.parametrize("L,chunk", [(64, 16), (64, 64), (48, 16)])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_kernel_and_chunked_vs_scan(L, chunk, groups):
    B, H, P, N = 2, 4, 16, 8
    x = jnp.asarray(RNG.randn(B, L, H, P).astype(np.float32)) * .3
    dt = jnp.asarray(RNG.rand(B, L, H).astype(np.float32)) * .5
    a_log = -jnp.asarray(RNG.rand(H).astype(np.float32)) - .1
    bm = jnp.asarray(RNG.randn(B, L, groups, N).astype(np.float32)) * .3
    cm = jnp.asarray(RNG.randn(B, L, groups, N).astype(np.float32)) * .3
    y0, h0 = ref.ssd_ref(x, dt, a_log, bm, cm)
    y1, h1 = ops.ssd(x, dt, a_log, bm, cm, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), atol=2e-4)
    if groups == 1 or H % groups == 0:
        y2, h2 = ops.ssd(x, dt, a_log, bm, cm, chunk=chunk,
                         use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y2),
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h2),
                                   atol=2e-4)


def test_ssd_with_initial_state():
    B, L, H, P, N = 1, 32, 2, 8, 4
    x = jnp.asarray(RNG.randn(B, L, H, P).astype(np.float32)) * .3
    dt = jnp.asarray(RNG.rand(B, L, H).astype(np.float32)) * .5
    a_log = -jnp.asarray(RNG.rand(H).astype(np.float32)) - .1
    bm = jnp.asarray(RNG.randn(B, L, 1, N).astype(np.float32)) * .3
    cm = jnp.asarray(RNG.randn(B, L, 1, N).astype(np.float32)) * .3
    h0 = jnp.asarray(RNG.randn(B, H, P, N).astype(np.float32)) * .2
    y0, hf0 = ref.ssd_ref(x, dt, a_log, bm, cm, h0)
    y1, hf1 = ops.ssd(x, dt, a_log, bm, cm, h0, chunk=8, use_pallas=True,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf0), np.asarray(hf1), atol=2e-4)
