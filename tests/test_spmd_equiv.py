"""SPMD (shard_map/ppermute) == SIM backend, and shmem == XLA substrate.

Runs in a subprocess with XLA_FLAGS=8 host devices so the main test
process keeps its single-device view (per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import spmd_ctx, sim_ctx

    n = 8
    mesh = jax.make_mesh((n,), ("pe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray(np.random.RandomState(0).randn(n, 6).astype(np.float32))

    def check(fn_name, *args, **kw):
        def body(xl):
            ctx = spmd_ctx("pe")
            return getattr(ctx, fn_name)(xl[0], *args, **kw)[None]
        out = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P("pe"),),
                                    out_specs=P("pe")))(x)
        ref = getattr(sim_ctx(n), fn_name)(x, *args, **kw)
        assert np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-5), \\
            fn_name

    check("broadcast", 3)
    check("broadcast", 5)
    check("fcollect")
    check("collect")
    check("to_all", "sum")
    check("to_all", "max")
    check("to_all", "sum", algorithm="ring")

    x2 = jnp.asarray(np.random.RandomState(2).randn(n, n * 2)
                     .astype(np.float32))
    def body_a2a(xl):
        return spmd_ctx("pe").alltoall(xl[0])[None]
    out = jax.jit(jax.shard_map(body_a2a, mesh=mesh, in_specs=(P("pe"),),
                                out_specs=P("pe")))(x2)
    ref = sim_ctx(n).alltoall(x2)
    assert np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)

    # shmem vs xla substrate equivalence through the Comm layer
    from repro.parallel.comm import AxisSpec, Comm
    mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    y = jnp.asarray(np.random.RandomState(1).randn(8, 4).astype(np.float32))

    def run(backend):
        def body(v):
            comm = Comm(AxisSpec(), backend)
            a = comm.allreduce(v, "model")
            b = comm.allgather(v, "model", concat_axis=0)
            c = comm.reduce_scatter(b, "model", scatter_axis=0)
            d = comm.alltoall(b, "model", split_axis=0, concat_axis=0)
            e = comm.broadcast(v, "model", root=2)
            f = comm.grad_sync(v)
            return a, b, c, d, e, f
        return jax.jit(jax.shard_map(
            body, mesh=mesh2,
            in_specs=(P(("data", "model")),),
            out_specs=(P("data"), P("data"), P(("data", "model")),
                       P(("data", "model")), P("data"),
                       P(("data", "model"))),
            check_vma=False))(y)

    outs_s = run("shmem")
    outs_x = run("xla")
    for i, (a, b) in enumerate(zip(outs_s, outs_x)):
        assert np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5), i
    print("SPMD-EQUIV-OK")
""")


def test_spmd_equivalence_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SPMD-EQUIV-OK" in r.stdout
