"""Per-arch smoke tests (reduced configs, 1x1 mesh): one train step with
finite loss + shape checks, decode steps, decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, smoke_config
from repro.launch import build
from repro.launch.mesh import make_mesh
from repro.models import transformer
from repro.parallel.comm import AxisSpec, Comm
from repro.serve import step as sstep
from repro.train import optimizer as opt


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


def _batch(cfg, B=4, L=32):
    if cfg.frontend == "audio":
        return {"frames": jnp.ones((B, L, cfg.d_model), cfg.dtype),
                "targets": jnp.ones((B, L), jnp.int32)}
    b = {"tokens": jnp.ones((B, L), jnp.int32),
         "targets": jnp.ones((B, L), jnp.int32)}
    if cfg.frontend == "vision":
        b["frontend_embeds"] = jnp.ones(
            (B, cfg.n_frontend_tokens, cfg.d_model), cfg.dtype)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch, mesh):
    cfg = smoke_config(arch)
    batch = _batch(cfg)
    with jax.set_mesh(mesh):
        init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))
        wrap, _, (osh, ospecs), ocfg = build.make_train_step(cfg, mesh,
                                                             "shmem")
        ostate = jax.jit(build.shard_mapped(
            lambda p: opt.init_state(p, ocfg), mesh, (specs,), ospecs)
        )(params)
        step = jax.jit(wrap(batch))
        loss0, params, ostate = step(params, ostate, batch)
        loss1, params, ostate = step(params, ostate, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1)), arch
    # same repeated batch: one AdamW step should not explode the loss
    assert float(loss1) < float(loss0) * 1.5, (arch, loss0, loss1)
    # output shapes: no NaNs anywhere in updated params
    flat = jax.tree.leaves(params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in flat if l.dtype != jnp.int8), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_smoke(arch, mesh):
    cfg = smoke_config(arch)
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step (DESIGN.md §5)")
    B, S = 2, 64
    with jax.set_mesh(mesh):
        init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))
        cshapes = jax.eval_shape(
            lambda: transformer.init_cache(cfg, 1, B, S, 1))
        cspecs = jax.tree.map(lambda _: P(), cshapes)
        cache = jax.jit(build.shard_mapped(
            lambda: transformer.init_cache(cfg, 1, B, S, 1),
            mesh, (), cspecs))()
        decode = sstep.build_decode_step(cfg, AxisSpec(), "shmem", 1)
        djit = jax.jit(build.shard_mapped(
            decode, mesh,
            (specs, cspecs, {"tokens": P(), "positions": P()}),
            (P(), cspecs)))
        for t in range(3):
            logits, cache = djit(params, cache,
                                 {"tokens": jnp.ones((B, 1), jnp.int32),
                                  "positions": jnp.full((B,), t, jnp.int32)})
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                  "zamba2-1.2b", "gemma2-9b"])
def test_decode_matches_forward(arch, mesh):
    """Teacher-forced decode logits == full forward logits at each step —
    validates KV/SSM cache handling exactly."""
    cfg = smoke_config(arch)
    B, T = 2, 12
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab, size=(B, T)).astype(np.int32)
    with jax.set_mesh(mesh):
        init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(1))

        comm_args = (AxisSpec(), "shmem")

        def fwd(p, tokens):
            comm = Comm(*comm_args)
            h, _ = transformer.forward(comm, cfg, p, tokens)
            from repro.models import layers as L
            return L.lm_logits(comm, cfg, p["embed"], h)
        full = jax.jit(build.shard_mapped(
            fwd, mesh, (specs, P()), P()))(params, jnp.asarray(toks))

        S = 16
        cshapes = jax.eval_shape(
            lambda: transformer.init_cache(cfg, 1, B, S, 1))
        cspecs = jax.tree.map(lambda _: P(), cshapes)
        cache = jax.jit(build.shard_mapped(
            lambda: transformer.init_cache(cfg, 1, B, S, 1),
            mesh, (), cspecs))()
        decode = sstep.build_decode_step(cfg, AxisSpec(), "shmem", 1)
        djit = jax.jit(build.shard_mapped(
            decode, mesh,
            (specs, cspecs, {"tokens": P(), "positions": P()}),
            (P(), cspecs)))
        errs = []
        for t in range(T):
            logits, cache = djit(
                params, cache,
                {"tokens": jnp.asarray(toks[:, t:t + 1]),
                 "positions": jnp.full((B,), t, jnp.int32)})
            errs.append(np.abs(np.asarray(logits[:, 0], np.float32)
                               - np.asarray(full[:, t], np.float32)).max())
    assert max(errs) < 0.12, (arch, errs)  # bf16 activations: chunked-SSD vs single-step recurrence rounding


def test_moe_router_load_balance_aux():
    cfg = smoke_config("granite-moe-3b-a800m")
    mesh = make_mesh(1, 1)
    with jax.set_mesh(mesh):
        init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
        params = jax.jit(init_fn)(jax.random.key(0))

        def fwd(p, tokens):
            comm = Comm(AxisSpec(), "shmem")
            _, aux = transformer.forward(comm, cfg, p, tokens)
            return aux
        aux = jax.jit(build.shard_mapped(fwd, mesh, (specs, P()), P()))(
            params, jnp.ones((2, 16), jnp.int32))
    # balanced-uniform router gives aux ~= n_experts * E[me*ce] ~= 1
    assert 0.2 < float(aux) / cfg.n_layers < 5.0


def test_param_count_sanity():
    """param_count() should be within 20% of actual init sizes."""
    for arch in ["qwen2-0.5b", "gemma2-9b", "granite-moe-3b-a800m"]:
        cfg = smoke_config(arch)
        shapes = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg, 1, 1),
            jax.random.key(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        est = cfg.param_count()
        assert 0.6 < est / actual < 1.6, (arch, est, actual)
