"""End-to-end system tests: the train launcher improves loss and resumes
from checkpoints; the serve launcher generates; TP=2 sharded execution
matches single-device execution numerically."""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest


def test_train_launcher_loss_improves():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "12",
        "--data", "1", "--model", "1", "--seq-len", "64", "--batch", "8",
        "--lr", "1e-3"])
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_train_checkpoint_resume():
    from repro.launch import train as train_mod
    with tempfile.TemporaryDirectory() as d:
        train_mod.main([
            "--arch", "qwen2-0.5b", "--smoke", "--steps", "4",
            "--data", "1", "--model", "1", "--seq-len", "32",
            "--batch", "4", "--ckpt-dir", d, "--ckpt-every", "2"])
        # resume continues from the saved step
        losses = train_mod.main([
            "--arch", "qwen2-0.5b", "--smoke", "--steps", "6",
            "--data", "1", "--model", "1", "--seq-len", "32",
            "--batch", "4", "--ckpt-dir", d, "--resume", "auto",
            "--ckpt-every", "2"])
        assert len(losses) == 2   # steps 4..5 only


def test_serve_launcher_generates():
    from repro.launch import serve as serve_mod
    gen = serve_mod.main([
        "--arch", "qwen2-0.5b", "--smoke", "--batch", "2",
        "--prompt-len", "8", "--tokens", "6", "--cache-len", "32"])
    assert gen.shape == (2, 6)
    assert (gen >= 0).all() and (gen < 151936).all()


TP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.models import transformer
    from repro.parallel.comm import AxisSpec, Comm

    def loss_1x1(arch, batch_np):
        cfg = smoke_config(arch)
        mesh = make_mesh(1, 1)
        with jax.set_mesh(mesh):
            init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
            params = jax.jit(init_fn)(jax.random.key(7))
            def fn(p, b):
                comm = Comm(AxisSpec(), "shmem")
                l = transformer.train_loss(comm, cfg, p, b)
                return comm.allreduce(l, "data") / comm.axis_size("data")
            bspec = {k: P("data", *([None] * (v.ndim - 1)))
                     for k, v in batch_np.items()}
            loss = jax.jit(build.shard_mapped(
                fn, mesh, (specs, bspec), P()))(
                params, jax.tree.map(jnp.asarray, batch_np))
            return float(loss), jax.tree.map(np.asarray, params)

    rng = np.random.default_rng(0)
    for arch in ["qwen2-0.5b", "granite-moe-3b-a800m", "mamba2-2.7b",
                 "zamba2-1.2b", "gemma2-9b"]:
        cfg = smoke_config(arch)
        batch = {"tokens": rng.integers(
                     1, cfg.vocab, size=(4, 16)).astype(np.int32),
                 "targets": rng.integers(
                     1, cfg.vocab, size=(4, 16)).astype(np.int32)}
        l1, gp1 = loss_1x1(arch, batch)
        # sharded run with the SAME global params, re-laid-out
        mesh = make_mesh(2, 2)
        with jax.set_mesh(mesh):
            shapes, specs = build.abstract_params(cfg, mesh)
            def fn(p, b):
                comm = Comm(AxisSpec(), "shmem")
                l = transformer.train_loss(comm, cfg, p, b)
                return comm.allreduce(l, "data") / comm.axis_size("data")
            bspec = {k: P("data", *([None] * (v.ndim - 1)))
                     for k, v in batch.items()}
            gshapes = build.global_shape(shapes, specs, mesh)
            def fit(a, t):
                a = np.asarray(a)
                for ax in range(a.ndim):
                    s_have, s_want = a.shape[ax], t.shape[ax]
                    if s_have == s_want: continue
                    if s_have < s_want:
                        reps = [1]*a.ndim; reps[ax] = -(-s_want//s_have)
                        a = np.tile(a, reps)
                    a = np.take(a, range(s_want), axis=ax)
                return a
            def remap_mamba(kp, a, t):
                # mamba fused in-proj / conv columns are per-shard
                # [z_s, x_s, B, C, dt_s]; rebuild the tp=2 global layout
                # from the tp=1 (G=1 global) params so semantics match.
                name = str(getattr(kp[-1], "key", kp[-1]))
                if not any(str(getattr(k, "key", k)) == "mamba"
                           for k in kp):
                    return fit(a, t)
                ss = cfg.ssm
                d_in = ss.expand * cfg.d_model
                gdim = ss.n_groups * ss.state
                nh = d_in // ss.head_dim
                tp = 2
                a = np.asarray(a)
                def split_cols(mat, axis):
                    z = np.split(mat.take(range(0, d_in), axis), tp, axis)
                    x = np.split(mat.take(range(d_in, 2*d_in), axis),
                                 tp, axis)
                    bc = mat.take(range(2*d_in, 2*d_in+2*gdim), axis)
                    dt = np.split(mat.take(
                        range(2*d_in+2*gdim, 2*d_in+2*gdim+nh), axis),
                        tp, axis)
                    return np.concatenate(
                        [np.concatenate([z[i], x[i], bc, dt[i]], axis)
                         for i in range(tp)], axis)
                def split_conv(mat, axis):
                    x = np.split(mat.take(range(0, d_in), axis), tp, axis)
                    bc = mat.take(range(d_in, d_in+2*gdim), axis)
                    return np.concatenate(
                        [np.concatenate([x[i], bc], axis)
                         for i in range(tp)], axis)
                # leading stacked-layer dim present on all these leaves
                if name == "w_in":
                    return split_cols(a, 2)
                if name in ("conv_w", "conv_b"):
                    return split_conv(a, a.ndim - 1)
                return fit(a, t)   # head-blocked leaves split evenly
            gp = jax.tree_util.tree_map_with_path(remap_mamba, gp1,
                                                  gshapes)
            gp = jax.tree.map(lambda a, sp: jax.device_put(
                jnp.asarray(a), NamedSharding(mesh, sp)), gp, specs)
            l2 = float(jax.jit(build.shard_mapped(
                fn, mesh, (specs, bspec), P()))(
                gp, jax.tree.map(jnp.asarray, batch)))
        ok = abs(l1 - l2) < 0.05 * max(1.0, abs(l1))
        print(f"{arch}: 1x1={l1:.4f} 2x2={l2:.4f}"
              f" {'OK' if ok else 'MISMATCH'}")
        assert ok, (arch, l1, l2)
    print("TP-EQUIV-OK")
""")


def test_tp2_matches_single_device():
    """Same global params, same batch: loss on a 2x2 (data x model) mesh
    must match the 1x1 result — validates manual TP + ghost heads + MoE
    padding + vocab-sharded loss numerics under real sharding.

    Archs whose 1x1 vs 2x2 global param shapes differ only by TP padding
    (ghost heads / padded experts) are tile-extended; the extended slots
    are masked to zero effect by construction, so losses must agree."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", TP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "TP-EQUIV-OK" in r.stdout


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro.launch import train as train_mod

    d = tempfile.mkdtemp()
    # phase 1: train on a (data=2, model=2) mesh, checkpoint
    train_mod.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "4",
        "--data", "2", "--model", "2", "--seq-len", "32", "--batch", "4",
        "--ckpt-dir", d, "--ckpt-every", "2"])
    # phase 2 (elastic shrink after 'node loss'): resume on (1, 2)
    losses = train_mod.main([
        "--arch", "qwen2-0.5b", "--smoke", "--steps", "6",
        "--data", "1", "--model", "2", "--seq-len", "32", "--batch", "4",
        "--ckpt-dir", d, "--resume", "auto", "--ckpt-every", "100"])
    assert len(losses) == 2 and np.isfinite(losses).all(), losses
    print("ELASTIC-OK")
""")


def test_elastic_shrink_resume():
    """Node loss: checkpoint from a 2x2 mesh restores onto 1x2 — global
    arrays re-shard under the new mesh (ckpt/manager.restore)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "ELASTIC-OK" in r.stdout
