"""Fault-injection, retry/backoff, async PGAS checkpointing and elastic
recovery (DESIGN.md §17).

Layers, cheapest first: the declarative FaultPlan as pure data; the
injector against live SIM / NoC-SIM traffic (dead PE, dropped link with
YX reroute, transient drops healing under retry/backoff, stragglers
surfacing at quiet/fence deadlines); the checkpoint layer's crash
atomicity and typed errors; the PGAS checkpoint stream + kill-and-resume
on SIM (loss trajectory allclose to an uninterrupted run resumed from
the same step); the serving engine's graceful drain; and the tp=2 SPMD
kill-and-resume in a subprocess."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import RetryPolicy, sim_ctx
from repro.core.fault import (DeadlineExceeded, FaultInjector, FaultPlan,
                              LinkFailure, PEFailure)
from repro.core.topology import epiphany3


TOPO = epiphany3()          # 4x4, 16 PEs
N = TOPO.n_pes
FAST_RETRY = RetryPolicy(max_retries=3, backoff_s=1e-5, backoff_mult=2.0)


def payload(n=N, w=4, seed=0):
    return jnp.asarray(np.random.RandomState(seed)
                       .randn(n, w).astype(np.float32))


# ---------------------------------------------------------------------------
# FaultPlan: pure data
# ---------------------------------------------------------------------------

def test_fault_plan_state_is_cumulative_and_heals():
    plan = (FaultPlan()
            .slow_pe(1, pe=7, delay_s=0.05)
            .drop_link(2, 4, 5, heal_after=2)
            .kill_pe(3, pe=9)
            .heal_straggler(4, pe=7)
            .heal_link(5, 4, 5)
            .heal_pe(6, pe=9))
    dead, dropped, slow = plan.state_at(0)
    assert (dead, dropped, slow) == (frozenset(), {}, {})
    dead, dropped, slow = plan.state_at(3)
    assert dead == frozenset({9})
    assert dropped == {(4, 5): 2}
    assert slow == {7: 0.05}
    dead, dropped, slow = plan.state_at(99)   # everything healed
    assert (dead, dropped, slow) == (frozenset(), {}, {})


def test_fault_plan_link_key_is_canonical():
    plan = FaultPlan().drop_link(0, 5, 4)
    assert plan.state_at(0)[1] == {(4, 5): None}


# ---------------------------------------------------------------------------
# injector against live traffic (SIM and NoC-SIM)
# ---------------------------------------------------------------------------

@pytest.fixture(params=[False, True], ids=["sim", "noc-sim"])
def noc(request):
    return request.param


def test_dead_pe_raises_typed_pe_failure(noc):
    plan = FaultPlan().kill_pe(3, pe=5)
    ctx = sim_ctx(N, TOPO, noc=noc, fault=plan, retry=FAST_RETRY)
    inj = ctx.fault_injector
    x = payload()
    # before the kill step the mesh is healthy
    ctx.quiet(ctx.put_nbi(x, [(5, 6)]))
    inj.set_step(3)
    assert inj.dead_pes == (5,)
    with pytest.raises(PEFailure) as ei:
        ctx.put_nbi(x, [(5, 6)])
    assert ei.value.pe == 5 and ei.value.step == 3
    assert ei.value.pattern is not None
    # a collective schedule touching the dead PE dies the same way
    with pytest.raises(PEFailure):
        ctx.to_all(x, "sum")
    # traffic among live PEs still flows
    ctx.quiet(ctx.put_nbi(x, [(0, 1)]))


def test_dropped_link_takes_alternate_yx_route(noc):
    # XY route 0->6 is 0-1-2-6; dropping link (1,2) leaves the YX
    # alternate 0-4-5-6 intact -> traffic reroutes, no error
    plan = FaultPlan().drop_link(0, 1, 2)
    ctx = sim_ctx(N, TOPO, noc=noc, fault=plan, retry=FAST_RETRY)
    out = ctx.quiet(ctx.put_nbi(payload(), [(0, 6)]))
    assert len(out) == 1
    assert ctx.fault_injector.stats.get("fault.reroutes") == 1
    assert "fault.link_hits" not in ctx.fault_injector.stats


def test_both_routes_severed_raises_link_failure(noc):
    # sever the XY route (link 1-2) AND the YX alternate (link 4-5)
    plan = FaultPlan().drop_link(0, 1, 2).drop_link(0, 4, 5)
    ctx = sim_ctx(N, TOPO, noc=noc,
                  retry=RetryPolicy(max_retries=2, backoff_s=1e-5),
                  fault=plan)
    with pytest.raises(LinkFailure) as ei:
        ctx.put_nbi(payload(), [(0, 6)])
    e = ei.value
    assert e.link in {(1, 2), (4, 5)}
    assert e.op == "put"
    # every attempt (1 issue + 2 retries) hit the severed pair
    assert e.attempts == 3
    assert ctx.fault_injector.stats["fault.link_hits"] == 3


def test_transient_link_heals_under_retry_backoff(noc):
    # adjacent pair (0, 1): XY and YX routes are the same single link,
    # so the drop is unroutable — but heal_after=2 makes it transient:
    # attempt 1 fails, attempt 2 fails AND heals, attempt 3 succeeds.
    plan = FaultPlan().drop_link(0, 0, 1, heal_after=2)
    ctx = sim_ctx(N, TOPO, noc=noc, fault=plan, retry=FAST_RETRY)
    out = ctx.quiet(ctx.put_nbi(payload(), [(0, 1)]))
    assert len(out) == 1
    stats = ctx.fault_injector.stats
    assert stats["fault.link_hits"] == 2
    # healed: later traffic over the link is clean
    ctx.quiet(ctx.put_nbi(payload(), [(0, 1)]))
    assert stats["fault.link_hits"] == 2


def test_straggler_rides_future_and_deadline_fires(noc):
    plan = FaultPlan().slow_pe(0, pe=3, delay_s=0.02)
    ctx = sim_ctx(N, TOPO, noc=noc, fault=plan, retry=FAST_RETRY)
    f = ctx.put_nbi(payload(), [(3, 2)])
    assert f.delay_s == pytest.approx(0.02)
    # fence sees the doomed op without sleeping
    with pytest.raises(DeadlineExceeded):
        ctx.fence(deadline_s=0.01)
    # quiet under the deadline raises and leaves the queue UNTOUCHED
    with pytest.raises(DeadlineExceeded) as ei:
        ctx.quiet(deadline_s=0.01)
    assert ei.value.op == "put"
    assert ctx.pending_count == 1
    # a generous deadline completes (and actually waits the delay)
    out = ctx.quiet(deadline_s=1.0)
    assert len(out) == 1 and ctx.pending_count == 0


def test_retry_policy_default_deadline_applies():
    plan = FaultPlan().slow_pe(0, pe=3, delay_s=0.05)
    ctx = sim_ctx(N, TOPO, fault=plan,
                  retry=RetryPolicy(backoff_s=1e-5, deadline_s=0.01))
    ctx.put_nbi(payload(), [(3, 2)])
    with pytest.raises(DeadlineExceeded):
        ctx.quiet()                      # no explicit deadline: policy's


def test_fault_events_land_on_tracer_and_tracereport():
    from repro.core.trace import LEVEL_FULL, Tracer
    from repro.tools import tracereport
    tracer = Tracer(level=LEVEL_FULL)
    plan = (FaultPlan().slow_pe(0, pe=3, delay_s=1e-4)
                       .drop_link(0, 1, 2))
    ctx = sim_ctx(N, TOPO, fault=plan, retry=FAST_RETRY, profile=tracer)
    ctx.quiet(ctx.put_nbi(payload(), [(0, 6)]))     # reroute
    ctx.quiet(ctx.put_nbi(payload(), [(3, 2)]))     # straggler
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.json")
        tracer.dump_chrome(path)
        doc = json.loads(open(path).read())
    assert tracereport.validate_trace(doc) == []
    counters = doc["repro"]["counters"]
    assert counters["fault.reroute"]["count"] == 1
    assert counters["fault.straggler"]["count"] == 1
    assert counters["fault.straggler_wait_us"]["count"] >= 1
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs if e.get("ph") in ("i", "I")}
    assert {"fault.reroute", "fault.straggler"} <= names
    lines = tracereport._chaos_report(evs, doc["repro"])
    assert any("fault.reroute" in l for l in lines)
    assert any("instant events" in l for l in lines)


# ---------------------------------------------------------------------------
# checkpoint layer: atomicity, typed errors, async-save race
# ---------------------------------------------------------------------------

def _state(seed=0):
    r = np.random.RandomState(seed)
    return {"w": r.randn(4, 3).astype(np.float32),
            "opt": {"m": r.randn(4, 3).astype(np.float32)}}


def test_async_save_snapshots_before_thread():
    """Regression: a train step mutating state while the async save is
    in flight must not corrupt the checkpoint — on_step snapshots to
    host BEFORE the thread spawns."""
    from repro.ckpt import manager as ckpt
    state = _state()
    want = {k: np.array(v) for k, v in
            [("w", state["w"]), ("m", state["opt"]["m"])]}
    with tempfile.TemporaryDirectory() as d:
        ft = ckpt.FaultToleranceManager(d, save_every=1, async_save=True)
        ft.on_step(1, lambda: state)
        state["w"] *= -1.0               # mutate mid-save, in place
        state["opt"]["m"][:] = 999.0
        ft._join()
        step, restored = ckpt.restore(d, _state())
        assert step == 1
        assert np.array_equal(np.asarray(restored["w"]), want["w"])
        assert np.array_equal(np.asarray(restored["opt"]["m"]), want["m"])


def test_restore_missing_leaf_raises_checkpoint_error():
    from repro.ckpt import manager as ckpt
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, {"w": np.zeros(4, np.float32)})
        bad = {"w": np.zeros(4, np.float32),
               "extra": np.zeros(2, np.float32)}
        with pytest.raises(ckpt.CheckpointError, match="extra"):
            ckpt.restore(d, bad)


def test_dangling_latest_falls_back_to_newest_complete():
    import shutil
    from repro.ckpt import manager as ckpt
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"w": np.full(4, 1.0, np.float32)})
        ckpt.save(d, 2, {"w": np.full(4, 2.0, np.float32)})
        shutil.rmtree(os.path.join(d, "step-00000002"))
        # LATEST still names step 2 — resolution must fall back
        assert ckpt.latest_step(d) == 1
        step, restored = ckpt.restore(d, {"w": np.zeros(4, np.float32)})
        assert step == 1
        assert np.asarray(restored["w"])[0] == 1.0


def test_no_complete_checkpoint_is_typed_not_keyerror():
    from repro.ckpt import manager as ckpt
    with tempfile.TemporaryDirectory() as d:
        assert ckpt.latest_step(d) is None
        with pytest.raises(ckpt.CheckpointError):
            ckpt.restore(d, {"w": np.zeros(2, np.float32)})


def test_crash_mid_save_keeps_previous_and_next_save_recovers():
    from repro.ckpt import manager as ckpt
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, _state())
        # crash mid-save: a tmp dir with partial leaves, never renamed
        tmp = os.path.join(d, "tmp-2")
        os.mkdir(tmp)
        np.save(os.path.join(tmp, "partial.npy"), np.zeros(2))
        assert ckpt.latest_step(d) == 1
        # a step dir whose manifest names a missing leaf file is
        # incomplete — rejected by resolution, not restored from
        import json as _json
        broken = os.path.join(d, "step-00000005")
        os.mkdir(broken)
        with open(os.path.join(broken, "manifest.json"), "w") as fh:
            _json.dump({"step": 5,
                        "leaves": [{"name": "w", "file": "gone.npy",
                                    "shape": [2], "dtype": "float32"}]},
                       fh)
        assert ckpt.latest_step(d) == 1
        # the next save overwrites the stale tmp dir and becomes latest
        ckpt.save(d, 2, _state(1))
        assert ckpt.latest_step(d) == 2


def test_reshard_shrink_grow_round_trips():
    from repro.ckpt.manager import _reshard
    a = np.arange(12, dtype=np.float32).reshape(2, 6)
    grown = _reshard(a, (6, 6), "w")         # tile up
    assert grown.shape == (6, 6)
    back = _reshard(grown, (2, 6), "w")      # slice back down
    assert np.array_equal(back, a)
    # shrink keeps the leading slice
    assert np.array_equal(_reshard(a, (2, 4), "w"), a[:, :4])
    with pytest.raises(ValueError):
        _reshard(a, (2, 6, 1), "w")          # rank change is an error


# ---------------------------------------------------------------------------
# PGAS checkpoint stream: overlap + isolation + round trip
# ---------------------------------------------------------------------------

def _pgas_state(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(N, 8).astype(np.float32)),
            "opt": {"m": jnp.asarray(r.randn(N, 3).astype(np.float32))},
            "scale": jnp.float32(2.5)}


@pytest.mark.parametrize("async_issue", [False, True],
                         ids=["sync-issue", "async-issue"])
def test_pgas_checkpoint_round_trips(async_issue):
    from repro.ckpt import manager as ckpt
    from repro.ckpt.pgas import PgasCheckpointer
    ctx = sim_ctx(N, TOPO)
    state = _pgas_state()
    with tempfile.TemporaryDirectory() as d:
        ck = PgasCheckpointer(ctx, d, async_issue=async_issue)
        n_rot = ck.begin(4, state)
        assert n_rot == 2 * (N - 1)          # two PE-sharded leaves
        assert ck.in_flight
        path = ck.drain()
        assert path is not None and ck.pending == 0
        step, restored = ckpt.restore(d, state)
        assert step == 4
        for got, want in zip(jax.tree.leaves(restored),
                             jax.tree.leaves(state)):
            assert np.allclose(np.asarray(got), np.asarray(want))


def test_pgas_stream_is_isolated_from_default_context():
    """Per-context isolation (DESIGN.md §11): the train step's own
    quiet() must not complete — or stall behind — checkpoint traffic."""
    from repro.ckpt.pgas import PgasCheckpointer
    ctx = sim_ctx(N, TOPO)
    with tempfile.TemporaryDirectory() as d:
        ck = PgasCheckpointer(ctx, d, async_issue=False)
        ck.begin(0, _pgas_state())
        assert ck.pending == 2 * (N - 1)
        # overlapped "train step" traffic on the DEFAULT context
        ctx.quiet(ctx.put_nbi(payload(), [(0, 1)]))
        assert ctx.pending_count == 0        # default ctx drained ...
        assert ck.pending == 2 * (N - 1)     # ... ckpt stream untouched
        ck.drain()
        assert ck.pending == 0


def test_pgas_begin_auto_drains_previous_epoch():
    from repro.ckpt import manager as ckpt
    from repro.ckpt.pgas import PgasCheckpointer
    ctx = sim_ctx(N, TOPO)
    with tempfile.TemporaryDirectory() as d:
        ck = PgasCheckpointer(ctx, d)
        ck.begin(1, _pgas_state(1))
        ck.begin(2, _pgas_state(2))          # drains epoch 1 first
        assert ckpt.latest_step(d) == 1
        ck.drain()
        assert ckpt.latest_step(d) == 2


def test_pgas_stream_surfaces_pe_failure_at_drain():
    from repro.ckpt.pgas import PgasCheckpointer
    plan = FaultPlan().kill_pe(2, pe=5)
    ctx = sim_ctx(N, TOPO, fault=plan, retry=FAST_RETRY)
    ctx.fault_injector.set_step(2)
    with tempfile.TemporaryDirectory() as d:
        ck = PgasCheckpointer(ctx, d)
        ck.begin(2, _pgas_state())
        with pytest.raises(PEFailure):
            ck.drain()
        assert not ck.in_flight              # stream cleaned up


# ---------------------------------------------------------------------------
# elastic: degraded mesh + kill-and-resume on SIM
# ---------------------------------------------------------------------------

def test_degrade_builds_live_ring_team_and_fingerprint():
    from repro.core.elastic import _ring_cost, degrade
    dm = degrade(TOPO, [5])
    assert dm.dead == (5,) and dm.n_live == N - 1
    assert 5 not in dm.live and sorted(dm.live) == [
        p for p in range(N) if p != 5]
    assert dm.fingerprint.endswith(":dead5")
    assert dm.team.size == N - 1
    # the live ring stays congestion-free: no physical link is shared
    max_load, _ = _ring_cost(TOPO, dm.live)
    assert max_load == 1.0


def test_degrade_flat_pe_space_needs_world_n():
    from repro.core.elastic import degrade
    dm = degrade(None, [1], world_n=4)
    assert dm.live == (0, 2, 3)
    assert dm.fingerprint == "flat:n4:dead1"
    with pytest.raises(ValueError):
        degrade(None, [1])


def _toy_run(ctx, w, steps, start=0, lr=0.05, ck=None, ckpt_every=2,
             drive_injector=False):
    """Deterministic toy training loop on the PGAS substrate: allreduce
    the 'gradient', SGD step, loss = mean square.  Checkpoints the
    PRE-step state labeled with its step, so a resume from step k
    replays exactly what the uninterrupted run did from step k."""
    losses = []
    inj = ctx.fault_injector
    for step in range(start, steps):
        if drive_injector and inj is not None:
            inj.set_step(step)
        if ck is not None and step % ckpt_every == 0:
            ck.begin(step, {"w": w})
        g = ctx.to_all(w, "sum") / ctx.n_pes
        losses.append(float(jnp.mean(g * g)))
        w = w - lr * g
    return losses, w


def test_kill_and_resume_sim_matches_uninterrupted_trajectory():
    """The tentpole end-to-end on SIM: async PGAS checkpoints overlap
    the loop; a PE failure at step 5 triggers detect -> drain the
    in-flight stream -> degrade/refingerprint -> restore -> resume; the
    resumed trajectory must equal the uninterrupted run's from the same
    step."""
    from repro.ckpt.pgas import PgasCheckpointer
    from repro.core.elastic import recover
    steps = 9
    w0 = payload(w=8, seed=3)

    # reference: uninterrupted
    ref_losses, _ = _toy_run(sim_ctx(N, TOPO), w0, steps)

    # victim: checkpoint every 2 steps, PE 5 dies at step 5
    plan = FaultPlan().kill_pe(5, pe=5)
    ctx = sim_ctx(N, TOPO, fault=plan, retry=FAST_RETRY)
    with tempfile.TemporaryDirectory() as d:
        # inline issue: deterministic interleaving with the fault clock
        # (the worker-thread overlap path is covered above)
        ck = PgasCheckpointer(ctx, d, async_issue=False)
        with pytest.raises(PEFailure) as ei:
            _toy_run(ctx, w0, steps, ck=ck, drive_injector=True)
        assert ei.value.pe == 5

        # recovery: complete the in-flight stream (issued while the PE
        # was alive — step 4's checkpoint), then the elastic protocol
        ck.drain()
        dead = ctx.fault_injector.dead_pes
        template = {"w": w0}
        step, state, dm = recover(ctx, dead, d, template)
        assert step == 4 and dm.dead == (5,)
        assert ctx._fp == dm.fingerprint        # selector re-keyed

        # resume on a healthy context (replacement hardware) from the
        # restored step: trajectories must match the uninterrupted run
        res_losses, _ = _toy_run(sim_ctx(N, TOPO), state["w"], steps,
                                 start=step)
        np.testing.assert_allclose(res_losses, ref_losses[step:],
                                   rtol=1e-6, atol=1e-7)


def test_recover_reports_to_profiler():
    from repro.ckpt import manager as ckpt
    from repro.core.elastic import recover
    from repro.core.profile import Profiler
    prof = Profiler(level=1)
    ctx = sim_ctx(N, TOPO, profile=prof)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, {"w": np.ones((N, 2), np.float32)})
        step, state, dm = recover(
            ctx, [5, 9], d, {"w": np.zeros((N, 2), np.float32)})
        assert step == 7 and dm.dead == (5, 9)
        assert dm.fingerprint.endswith(":dead5,9")
        assert "fault.recovery_us" in prof.counters()
        assert "fault.recovered" in prof.counters()


# ---------------------------------------------------------------------------
# serving: graceful drain + re-queue on PE loss
# ---------------------------------------------------------------------------

def _make_engine(params=None, **kw):
    from repro.configs import smoke_config
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import ServeEngine
    kw.setdefault("max_slots", 3)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_seq", 32)
    kw.setdefault("prompt_bucket", 16)
    return ServeEngine(smoke_config("qwen2-0.5b"), make_mesh(1, 1),
                       params=params, capture_logits=True, **kw)


def test_serve_pe_failure_drains_requeues_and_regenerates_bitwise():
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 1000, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    eng = _make_engine()
    rids = [eng.submit(p, 5) for p in prompts]
    eng.step()                               # admit all three, 1 token in
    assert sorted(eng.scheduler.active_slots()) == [0, 1, 2]

    real = eng._djit
    shots = {"n": 0}

    def dying_djit(*a, **kw):
        if shots["n"] == 0:
            shots["n"] += 1
            raise PEFailure("PE 1 dropped off the NoC", pe=1, step=1)
        return real(*a, **kw)

    eng._djit = dying_djit
    res = eng.step()
    assert res["faulted"] and res["pe"] == 1
    # FIFO preserved: queue head is back in slot (admission) order
    assert res["requeued"] == rids
    assert [r.rid for r in eng.scheduler.queue] == rids
    assert eng.scheduler.active_slots() == []
    assert eng.kv.pool.live_pages() == 0     # pages freed, nothing leaks
    if eng.metrics is not None:
        assert eng.metrics.pe_failures.value == 1
        assert eng.metrics.requests_requeued.value == len(rids)

    # the engine re-runs everything; greedy decode is bit-identical
    # batched or alone, so results match a fault-free engine exactly
    eng.run()
    ref = _make_engine(params=eng.params)
    for rid, p in zip(rids, prompts):
        q = ref.submit(p, 5)
        ref.run()
        assert np.array_equal(eng.results[rid], ref.results[q]), rid


# ---------------------------------------------------------------------------
# tp=2 SPMD kill-and-resume (subprocess)
# ---------------------------------------------------------------------------

FAULT_RESUME_SCRIPT = textwrap.dedent("""
    import os, shutil, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.ckpt import manager as ckpt
    from repro.data import pipeline as data_mod
    from repro.launch import train as train_mod

    d = tempfile.mkdtemp()
    args = ["--arch", "qwen2-0.5b", "--smoke", "--data", "1",
            "--model", "2", "--seq-len", "32", "--batch", "4",
            "--ckpt-dir", d]

    # phase 1: tp=2 run killed at step 4 — a 'PE failure' injected at
    # the batch fetch — after the periodic async save at step 2 landed
    real_batch = data_mod.SyntheticLM.batch
    def dying_batch(self, step):
        if step == 4:
            raise RuntimeError("injected PE failure: node lost")
        return real_batch(self, step)
    data_mod.SyntheticLM.batch = dying_batch
    try:
        train_mod.main(args + ["--steps", "6", "--ckpt-every", "2"])
        raise SystemExit("kill did not fire")
    except RuntimeError as e:
        assert "node lost" in str(e), e
    data_mod.SyntheticLM.batch = real_batch
    # the async save thread from step 2 may still be renaming — wait
    import time
    for _ in range(100):
        if ckpt.latest_step(d) == 2:
            break
        time.sleep(0.1)
    assert ckpt.latest_step(d) == 2, ckpt.latest_step(d)

    # phase 2: kill-and-resume from the last complete checkpoint
    d2 = d + "-resume"; shutil.copytree(d, d2)
    l_resumed = train_mod.main(
        args[:-1] + [d2, "--steps", "6", "--resume", "auto",
                     "--ckpt-every", "100"])
    assert len(l_resumed) == 4, l_resumed       # steps 2..5 replayed

    # phase 3: the uninterrupted reference resumed from the same step
    d3 = d + "-ref"; shutil.copytree(d, d3)
    l_ref = train_mod.main(
        args[:-1] + [d3, "--steps", "6", "--resume", "auto",
                     "--ckpt-every", "100"])
    assert np.isfinite(l_resumed).all()
    assert np.allclose(l_resumed, l_ref, rtol=1e-5, atol=1e-6), \\
        (l_resumed, l_ref)
    print("FAULT-RESUME-OK")
""")


def test_spmd_tp2_kill_and_resume():
    """A tp=2 SPMD training run killed mid-flight resumes from the last
    complete checkpoint and reproduces the loss trajectory of an
    uninterrupted run resumed from the same step (allclose)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", FAULT_RESUME_SCRIPT],
                       env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "FAULT-RESUME-OK" in r.stdout
