"""Property tests for the paper's collective algorithms (sim backend ==
numpy semantics), including the non-power-of-two and subset cases the
paper notes eLib's 2D indexing cannot express."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import collectives as coll, sim_ctx
from repro.core.netops import SimNetOps

NS = st.integers(min_value=1, max_value=17)
WIDTHS = st.integers(min_value=1, max_value=9)


def _x(n, w, seed=0, dtype=np.float32):
    return jnp.asarray(
        np.random.RandomState(seed).randn(n, w).astype(dtype))


@settings(max_examples=40, deadline=None)
@given(NS, WIDTHS, st.integers(0, 16))
def test_broadcast_any_n_any_root(n, w, root_raw):
    root = root_raw % n
    x = _x(n, w)
    out = sim_ctx(n).broadcast(x, root)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(x)[root], (n, 1)))


@settings(max_examples=40, deadline=None)
@given(NS, WIDTHS)
def test_fcollect_matches_concat(n, w):
    x = _x(n, w)
    out = sim_ctx(n).fcollect(x)
    ref = np.tile(np.asarray(x).reshape(-1), (n, 1))
    np.testing.assert_allclose(np.asarray(out), ref)


@settings(max_examples=40, deadline=None)
@given(NS, WIDTHS)
def test_collect_ring_matches_concat(n, w):
    x = _x(n, w)
    out = sim_ctx(n).collect(x)
    ref = np.tile(np.asarray(x).reshape(-1), (n, 1))
    np.testing.assert_allclose(np.asarray(out), ref)


@settings(max_examples=60, deadline=None)
@given(NS, WIDTHS, st.sampled_from(["sum", "max", "min", "prod"]))
def test_allreduce_ops(n, w, op):
    x = _x(n, w)
    if op == "prod":
        x = jnp.abs(x) * 0.5 + 0.5
    out = sim_ctx(n).to_all(x, op)
    fn = {"sum": np.sum, "max": np.max, "min": np.min,
          "prod": np.prod}[op]
    ref = np.tile(fn(np.asarray(x), 0), (n, 1))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5)


@settings(max_examples=30, deadline=None)
@given(NS, WIDTHS)
def test_allreduce_ring_vs_rd_agree(n, w):
    """The paper's algorithm switch (dissemination pow2 / ring otherwise)
    must be invisible to the caller."""
    x = _x(n, w)
    ring = sim_ctx(n).to_all(x, "sum", algorithm="ring")
    ref = np.tile(np.asarray(x).sum(0), (n, 1))
    np.testing.assert_allclose(np.asarray(ring), ref, rtol=2e-5)
    if n & (n - 1) == 0:
        rd = sim_ctx(n).to_all(x, "sum", algorithm="rd")
        np.testing.assert_allclose(np.asarray(rd), ref, rtol=2e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 9), st.integers(1, 5))
def test_alltoall_transpose(n, blk):
    x = jnp.asarray(np.random.RandomState(1).randn(n, n * blk)
                    .astype(np.float32))
    out = sim_ctx(n).alltoall(x)
    ref = np.asarray(x).reshape(n, n, blk).transpose(1, 0, 2) \
        .reshape(n, n * blk)
    np.testing.assert_allclose(np.asarray(out), ref)


@settings(max_examples=30, deadline=None)
@given(NS)
def test_exclusive_scan_sum(n):
    x = jnp.ones((n,), jnp.float32)
    out = coll.exclusive_scan(SimNetOps(n), x, "sum")
    np.testing.assert_allclose(np.asarray(out).reshape(-1), np.arange(n))


@settings(max_examples=20, deadline=None)
@given(NS)
def test_barrier_token_counts_rounds(n):
    tok = coll.barrier(SimNetOps(n))
    # dissemination: token accumulates 2^rounds - 1 contributions... the
    # important invariant is it ran ceil(log2 n) rounds and is uniform
    assert tok.shape[0] == n
    assert len(set(np.asarray(tok).tolist())) == 1


def test_reduce_scatter_roundtrip():
    for n in (2, 3, 4, 6, 8):
        x = _x(n, 12, seed=3)
        own, info = coll.reduce_scatter(SimNetOps(n), x, "sum")
        back = coll._allgather_unpad(SimNetOps(n), own, info)
        ref = np.tile(np.asarray(x).sum(0), (n, 1))
        np.testing.assert_allclose(np.asarray(back), ref, rtol=2e-5)


def test_dtype_coverage():
    for dtype in (np.float32, np.float64, np.int32):
        x = jnp.asarray((np.arange(6 * 4) % 7).reshape(6, 4).astype(dtype))
        out = sim_ctx(6).to_all(x, "sum")
        np.testing.assert_allclose(np.asarray(out),
                                   np.tile(np.asarray(x).sum(0), (6, 1)))


def test_put_get_patterns():
    n = 8
    ctx = sim_ctx(n)
    x = _x(n, 4, seed=5)
    ring = [(i, (i + 1) % n) for i in range(n)]
    out = ctx.put(x, ring)
    np.testing.assert_allclose(np.asarray(out),
                               np.roll(np.asarray(x), 1, axis=0))
    # get: every PE requests from its right neighbor == roll the other way
    out = ctx.get(x, [(i, (i + 1) % n) for i in range(n)])
    np.testing.assert_allclose(np.asarray(out),
                               np.roll(np.asarray(x), -1, axis=0))


def test_collective_bytes_parser():
    """The dry-run HLO collective parser sums operand bytes correctly."""
    from repro.launch.dryrun import _collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %cp = f32[16]{0} collective-permute(f32[16]{0} %z), source_target_pairs={{0,1}}
  %a2a = s8[64]{0} all-to-all(s8[64]{0} %w), dimensions={0}
  %done = f32[4]{0} all-reduce-done(f32[4]{0} %h)
"""
    out = _collective_bytes(hlo)
    # payload proxy: the op's OUTPUT shape bytes (done-ops excluded)
    assert out["bytes"]["all-gather"] == 8 * 128 * 2
    assert out["bytes"]["all-reduce"] == 256 * 4
    assert out["bytes"]["collective-permute"] == 16 * 4
    assert out["bytes"]["all-to-all"] == 64
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 1   # -done line skipped


def test_allreduce_auto_size_switch():
    """'auto' must pick ring beyond the byte threshold and stay RD below
    (pow-2 PE count), both numerically identical."""
    from repro.core import collectives as coll
    from repro.core.netops import SimNetOps
    n = 8
    small = jnp.ones((n, 16), jnp.float32)
    big = jnp.ones((n, coll.RING_BYTES_THRESHOLD // 4 + 8), jnp.float32)
    net = SimNetOps(n)
    for x in (small, big):
        auto = coll.allreduce(net, x, "sum", algorithm="auto")
        ref = np.tile(np.asarray(x).sum(0), (n, 1))
        np.testing.assert_allclose(np.asarray(auto), ref, rtol=1e-6)
