"""The compiled CommPattern/Schedule layer: compile-once interning,
inverse round-trips, mask correctness vs the old inline loops, hop costs
against MeshTopology, schedule/cost consistency, and the cost-model
algorithm selector."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core.pattern import (CommPattern, Schedule, Stage, as_pattern,
                                binomial_stage_pattern, compile_pattern,
                                ring_pattern, xor_pattern)
from repro.core.topology import epiphany3, v5e_multipod

N = 16
RING = [(i, (i + 1) % N) for i in range(N)]


# -- compile-once caching ----------------------------------------------------

def test_compile_is_interned():
    p1 = compile_pattern(RING, N)
    p2 = compile_pattern(list(reversed(RING)), N)       # order-insensitive
    p3 = compile_pattern([(s + N, d + N) for s, d in RING], N)  # mod n_pes
    assert p1 is p2 is p3
    assert p1 is ring_pattern(N)
    assert as_pattern(p1, N) is p1                      # pass-through


def test_interning_distinguishes_n_pes_and_pairs():
    assert compile_pattern([(0, 1)], 4) is not compile_pattern([(0, 1)], 8)
    assert compile_pattern([(0, 1)], 4) is not compile_pattern([(1, 0)], 4)


def test_direct_construction_rejected():
    with pytest.raises(TypeError):
        CommPattern(((0, 1),), 4)


def test_duplicate_destination_rejected():
    with pytest.raises(ValueError):
        compile_pattern([(0, 2), (1, 2)], 4)


def test_wrong_pe_count_rejected():
    p = compile_pattern(RING, N)
    with pytest.raises(ValueError):
        as_pattern(p, N + 1)


# -- inverse -----------------------------------------------------------------

def test_inverse_roundtrip_identity():
    p = compile_pattern(RING, N)
    assert p.inverse.inverse is p
    assert sorted(p.inverse.pairs) == sorted((d, s) for s, d in p.pairs)
    # inverse is itself interned: compiling the reversed pairs hits it
    assert compile_pattern([(d, s) for s, d in RING], N) is p.inverse


def test_inverse_of_partial_pattern():
    p = compile_pattern([(2, 7), (0, 3)], 8)
    assert p.inverse.pairs == ((3, 0), (7, 2))
    np.testing.assert_array_equal(p.inverse.dst_mask, p.src_mask)
    np.testing.assert_array_equal(p.inverse.src_mask, p.dst_mask)


# -- masks vs the old inline loops ------------------------------------------

@pytest.mark.parametrize("pattern", [
    RING,
    [(0, 3)],
    [(2, 7), (5, 1), (0, 4)],
    [(i, i ^ 4) for i in range(N)],
])
def test_masks_match_inline_loops(pattern):
    p = compile_pattern(pattern, N)
    # the loop every call site used to rebuild per call:
    dst_mask = np.zeros((N,), bool)
    for _, d in pattern:
        dst_mask[d % N] = True
    src_mask = np.zeros((N,), bool)
    for s, _ in pattern:
        src_mask[s % N] = True
    src_for_dst = np.full((N,), -1, dtype=np.int64)
    for s, d in pattern:
        src_for_dst[d % N] = s % N
    np.testing.assert_array_equal(p.dst_mask, dst_mask)
    np.testing.assert_array_equal(p.src_mask, src_mask)
    np.testing.assert_array_equal(p.src_for_dst, src_for_dst)
    has, idx = p.gather_arrays()
    np.testing.assert_array_equal(has, src_for_dst >= 0)
    np.testing.assert_array_equal(idx, np.where(src_for_dst >= 0,
                                                src_for_dst, 0))


# -- hop costs against MeshTopology -----------------------------------------

@pytest.mark.parametrize("topo", [epiphany3(), v5e_multipod(2)],
                         ids=["epiphany3", "v5e_multipod"])
def test_pair_hops_match_topology(topo):
    n = topo.n_pes
    for p in (ring_pattern(n), xor_pattern(n, 4),
              binomial_stage_pattern(n, n // 2)):
        expect = np.array([topo.hops(s, d) for s, d in p.pairs])
        np.testing.assert_allclose(p.pair_hops(topo), expect)
        assert p.max_hops(topo) == expect.max()
        assert p.total_hops(topo) == pytest.approx(expect.sum())
    # cached: second call returns the same array object
    p = ring_pattern(n)
    assert p.pair_hops(topo) is p.pair_hops(topo)


def test_hops_default_flat_network():
    p = ring_pattern(8)
    np.testing.assert_allclose(p.pair_hops(None), np.ones(8))
    assert p.max_hops(None) == 1.0


# -- schedules ---------------------------------------------------------------

def test_schedule_cost_is_derived_from_executing_stages():
    """Every *_stages cost descriptor must be the .cost() of the same
    Schedule whose stages the executor iterates."""
    topo = epiphany3()
    for nbytes in (64.0, 4096.0):
        assert coll.barrier_stages(N, topo) == \
            coll.barrier_schedule(N).cost(topo)
        assert coll.broadcast_stages(N, nbytes, topo) == \
            coll.broadcast_schedule(N, nbytes).cost(topo)
        for algo in ("rd", "ring"):
            assert coll.allreduce_stages(N, nbytes, topo, algo) == \
                coll.allreduce_schedule(N, nbytes, algo).cost(topo)
            assert coll.fcollect_stages(N, nbytes, topo, algo) == \
                coll.fcollect_schedule(N, nbytes, algo).cost(topo)
        assert coll.alltoall_stages(N, nbytes * N, topo) == \
            coll.alltoall_schedule(N, nbytes * N).cost(topo)


def test_schedule_stage_structure():
    sched = coll.allreduce_schedule(8, 800.0, "ring")
    assert len(sched) == 2 * 7                      # rs + ag
    assert all(st.pattern is ring_pattern(8) for st in sched.stages)
    assert all(st.nbytes == pytest.approx(100.0) for st in sched.stages)
    rd = coll.allreduce_schedule(8, 800.0, "rd")
    assert [st.pattern for st in rd.stages] == \
        [xor_pattern(8, 1), xor_pattern(8, 2), xor_pattern(8, 4)]
    fc = coll.fcollect_schedule(8, 100.0, "rd")
    assert [st.nbytes for st in fc.stages] == [100.0, 200.0, 400.0]


def test_schedule_time_matches_abmodel():
    topo = epiphany3()
    sched = coll.broadcast_schedule(16, 1024.0)
    link = abmodel.EPIPHANY_NOC
    assert sched.time(topo, link) == pytest.approx(
        abmodel.modeled_collective_time(sched.cost(topo), link))


# -- cost-model algorithm selection ------------------------------------------

def test_choose_algorithm_small_vs_large():
    assert coll.choose_algorithm(8, 64.0) == "rd"
    assert coll.choose_algorithm(8, float(1 << 21)) == "ring"
    assert coll.choose_algorithm(6, 64.0) == "ring"     # non-pow2: no rd
    assert coll.choose_algorithm(1, 64.0) == "ring"


def test_choose_algorithm_agrees_with_schedule_pricing():
    topo = epiphany3()
    link = abmodel.EPIPHANY_NOC
    for nbytes in (8.0, 512.0, 65536.0, float(1 << 22)):
        algo = coll.choose_algorithm(16, nbytes, topo, link)
        t = {a: coll.allreduce_schedule(16, nbytes, a).time(topo, link)
             for a in ("rd", "ring")}
        assert t[algo] == min(t.values())


def test_allreduce_auto_matches_fixed_algorithms():
    n = 8
    ctx = sim_ctx(n, epiphany3())
    x = jnp.asarray(np.random.RandomState(0).randn(n, 32).astype(np.float32))
    ref = np.tile(np.asarray(x).sum(0), (n, 1))
    out = ctx.to_all(x, "sum", algorithm="auto")
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5)


def test_get_fanout_many_requesters_one_owner():
    """Multiple requesters reading the same owner is a legal get: the
    executed (owner -> requester) push has unique destinations even though
    the forward pattern names the owner twice."""
    n = 8
    ctx = sim_ctx(n, epiphany3())
    x = jnp.asarray(np.random.RandomState(4).randn(n, 4).astype(np.float32))
    out = ctx.get(x, [(0, 2), (1, 2), (5, 2)])
    ref = np.asarray(x).copy()
    ref[0] = ref[1] = ref[5] = ref[2]
    np.testing.assert_allclose(np.asarray(out), ref)
    # module-level collectives.get agrees (zeros where not addressed)
    from repro.core.netops import SimNetOps
    raw = coll.get(SimNetOps(n), x, [(0, 2), (1, 2)])
    np.testing.assert_allclose(np.asarray(raw)[0], np.asarray(x)[2])
    np.testing.assert_allclose(np.asarray(raw)[1], np.asarray(x)[2])
    np.testing.assert_allclose(np.asarray(raw)[3], 0.0)


def test_intern_cache_bounded():
    from repro.core import pattern as pat
    before = pat.cache_size()
    assert before <= pat._INTERN_MAX
    # ad-hoc patterns beyond the cap must not grow the cache unboundedly
    for i in range(64):
        compile_pattern([(0, 1), (1, (i % 30) + 2)], 64)
    assert pat.cache_size() <= pat._INTERN_MAX


# -- compiled patterns through the public API --------------------------------

def test_shmem_api_accepts_compiled_patterns():
    n = 8
    ctx = sim_ctx(n, epiphany3())
    x = jnp.asarray(np.random.RandomState(3).randn(n, 4).astype(np.float32))
    p = ctx.compile([(0, 3)])
    assert p is ctx.compile([(0, 3)])                    # interned via ctx
    out_p = ctx.put(x, p)
    out_l = ctx.put(x, [(0, 3)])
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_l))
    g_p = ctx.get(x, p)
    ref = np.asarray(x).copy()
    ref[0] = ref[3]
    np.testing.assert_allclose(np.asarray(g_p), ref)
    ring = ctx.compile([(i, (i + 1) % n) for i in range(n)])
    f, nv = ctx.atomic_fetch_add(
        jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.int32), ring)
    np.testing.assert_array_equal(np.asarray(nv), 1)
