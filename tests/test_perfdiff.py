"""Regression attribution (DESIGN.md §18): perfdiff decomposes an
injected regression into the cost-model term that caused it — an
algorithm-pick change vs an alpha/beta shift vs a contention-gamma
shift — and check_regression ships the report on a gate failure."""
import copy
import json
import os
import pathlib
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import check_regression  # noqa: E402
from repro.tools import perfdiff  # noqa: E402

BENCH9 = pathlib.Path(__file__).resolve().parents[1] / "BENCH_9.json"


@pytest.fixture(scope="module")
def base_doc():
    return json.loads(BENCH9.read_text())


def test_pick_change_attribution(base_doc):
    cur = copy.deepcopy(base_doc)
    for r in cur["rows"]:
        if (r["bench"], r["name"]) == ("congestion",
                                       "allreduce_ring_65536B"):
            r["measured_us"] *= 2.0
            r["picked"] = "ring/c1"
    rep = perfdiff.diff_bench(base_doc, cur)
    regs = {(e["bench"], e["name"]): e for e in rep["regressions"]}
    e = regs[("congestion", "allreduce_ring_65536B")]
    assert e["attribution"] == "pick"
    assert e["terms"]["pick"] == {"base": "ring_emb/c16",
                                  "cur": "ring/c1"}


def test_beta_shift_attribution(base_doc):
    # scale one size-swept family proportional to payload: the refit
    # beta moves, alpha stays — per-byte cost, not per-op overhead
    cur = copy.deepcopy(base_doc)
    for r in cur["rows"]:
        if r["bench"] == "patterns" \
                and r["name"].startswith("allreduce_rd_") \
                and r.get("size_bytes"):
            r["measured_us"] *= 1.0 + r["size_bytes"] / 65536
    rep = perfdiff.diff_bench(base_doc, cur)
    regs = {(e["bench"], e["name"]): e for e in rep["regressions"]}
    e = regs[("patterns", "allreduce_rd_65536B")]
    assert e["attribution"] == "beta"
    assert e["terms"]["beta_us"] > abs(e["terms"]["alpha_us"])


def test_contention_shift_attribution(base_doc):
    # baseline ran at gamma=0.40 with proportionally cheaper contended
    # stages; the current run serializes fully (gamma=1.00, BENCH_9)
    base = copy.deepcopy(base_doc)
    for r in base["rows"]:
        if (r["bench"], r["name"]) == ("congestion", "contention_gamma"):
            r["derived"] = "gamma=0.40 (1.0=full serialization)"
        if r["bench"] == "congestion" \
                and r["name"].startswith("noc_stage_ring_offset"):
            r["measured_us"] *= 0.4
    rep = perfdiff.diff_bench(base, base_doc)
    assert rep["gamma_base"] == pytest.approx(0.40)
    assert rep["gamma_cur"] == pytest.approx(1.00)
    regs = {(e["bench"], e["name"]): e for e in rep["regressions"]}
    e = regs[("congestion", "noc_stage_ring_offset8")]
    assert e["attribution"] == "contention"


def test_no_regressions_on_identical_docs(base_doc):
    rep = perfdiff.diff_bench(base_doc, base_doc)
    assert rep["regressions"] == []
    assert rep["n_rows_compared"] > 0
    assert "perfdiff" in perfdiff.render(rep)


def test_trace_diff_reports_span_and_link_shifts():
    def trace(dur, link_bytes):
        return {"traceEvents": [
            {"name": "allreduce[ring]", "ph": "X", "ts": 0.0,
             "dur": dur, "pid": 1, "tid": 0, "cat": "collective"},
            {"name": "allreduce.ring.s0", "ph": "X", "ts": 0.0,
             "dur": dur / 2, "pid": 0, "tid": 0, "cat": "stage"},
        ], "repro": {"schema": 1, "heatmap": [
            {"shape": [4, 4], "n_links": 1, "links": [
                {"a": 0, "b": 1, "bytes": link_bytes,
                 "coord_a": [0, 0], "coord_b": [0, 1]}]}]}}

    rep = perfdiff.diff_traces(trace(100.0, 1e6), trace(250.0, 4e6))
    assert rep["kind"] == "trace"
    spans = {d["name"]: d for d in rep["spans"]}
    assert spans["allreduce[ring]"]["delta_us"] == pytest.approx(150.0)
    stages = {d["name"]: d for d in rep["stages"]}
    assert stages["allreduce.ring.s0"]["delta_us"] == pytest.approx(75.0)
    assert rep["hot_links"][0]["cur_bytes"] == pytest.approx(4e6)
    assert "hottest-link" in perfdiff.render(rep)


def test_check_regression_emits_attribution_report(base_doc, tmp_path,
                                                   capsys):
    cur = copy.deepcopy(base_doc)
    for r in cur["rows"]:
        if r["bench"] == "patterns" \
                and r["name"].startswith("allreduce_rd_") \
                and r.get("size_bytes"):
            r["measured_us"] *= 1.0 + r["size_bytes"] / 65536
    cur_path = tmp_path / "BENCH_cur.json"
    cur_path.write_text(json.dumps(cur))
    rc = check_regression.check(BENCH9, cur_path,
                                report_dir=tmp_path / "reports")
    out = capsys.readouterr().out
    assert rc == 1
    assert "REGRESSED" in out
    assert "attribution: BETA" in out
    rep = json.loads((tmp_path / "reports" /
                      "perfdiff_report.json").read_text())
    assert rep["regressions"][0]["attribution"] == "beta"
    assert (tmp_path / "reports" / "perfdiff_report.txt").exists()


def test_fingerprint_mismatch_warns(base_doc, tmp_path, capsys):
    a = copy.deepcopy(base_doc)
    b = copy.deepcopy(base_doc)
    a["machine"] = {"hostname": "runner-a", "cpus": 4}
    b["machine"] = {"hostname": "runner-b", "cpus": 64}
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(b))
    rc = check_regression.check(pa, pb, report_dir=tmp_path)
    out = capsys.readouterr().out
    assert rc == 0
    assert "DIFFERENT machines" in out
    assert "hostname" in out
    # identical fingerprints: no banner
    pb.write_text(json.dumps(a))
    check_regression.check(pa, pb, report_dir=tmp_path)
    assert "DIFFERENT machines" not in capsys.readouterr().out
